"""Out-of-core streaming IHTC: parity with the in-memory driver on
single-buffer streams, bounded-reservoir cascades on multi-chunk streams,
the chunk input formats, and the new runtime-config knobs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import gmm_sample
from repro import runtime
from repro.cluster.metrics import clustering_accuracy
from repro.core import ClusterIndex, ihtc, ihtc_streaming


def _chunked(x: np.ndarray, size: int):
    for lo in range(0, len(x), size):
        yield x[lo:lo + size]


# ----------------------------------------------------------------- parity


@pytest.mark.parametrize("m", [1, 2, 3])
def test_streaming_parity_single_buffer(rng, m):
    """Acceptance contract: a chunk-aligned stream (one chunk == the whole
    level-0 buffer) with a non-overflowing reservoir is bit-identical to
    the in-memory driver — labels, prototypes, masses and backend labels.
    """
    x, _ = gmm_sample(512, rng)
    xj = jnp.asarray(x)
    key = jax.random.PRNGKey(7)
    want = ihtc(xj, 2, m, "kmeans", k=3, key=key)
    got = ihtc_streaming(iter([x]), 2, m, "kmeans", k=3, key=key,
                         chunk_n=512, reservoir_n=1024)
    assert got.n_cascades == 0
    np.testing.assert_array_equal(got.labels_for(0), np.asarray(want.labels))
    np.testing.assert_array_equal(np.asarray(got.proto_labels),
                                  np.asarray(want.proto_labels))
    np.testing.assert_array_equal(
        np.asarray(got.protos).view(np.uint32),
        np.asarray(want.protos).view(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(got.proto_mass).view(np.uint32),
        np.asarray(want.proto_mass).view(np.uint32))
    assert int(got.n_prototypes) == int(want.n_prototypes)


def test_streaming_parity_through_early_stop(rng):
    """The finalize loop must replicate itis's early-stop rule (m larger
    than the data supports), keeping parity intact."""
    x, _ = gmm_sample(64, rng)
    key = jax.random.PRNGKey(3)
    want = ihtc(jnp.asarray(x), 2, 6, "kmeans", k=2, key=key)
    got = ihtc_streaming(iter([x]), 2, 6, "kmeans", k=2, key=key,
                         chunk_n=64, reservoir_n=64)
    np.testing.assert_array_equal(got.labels_for(0), np.asarray(want.labels))


def test_streaming_parity_tiny_raw_fold(rng):
    """A chunk below the reduction threshold folds raw; with one tiny chunk
    that is exactly the in-memory zero-level path (backend on x itself)."""
    x = rng.normal(size=(3, 2)).astype(np.float32)
    key = jax.random.PRNGKey(5)
    want = ihtc(jnp.asarray(x), 2, 2, "kmeans", k=2, key=key)
    got = ihtc_streaming(iter([x]), 2, 2, "kmeans", k=2, key=key,
                         chunk_n=3, reservoir_n=16)
    np.testing.assert_array_equal(got.labels_for(0), np.asarray(want.labels))
    np.testing.assert_array_equal(np.asarray(got.proto_labels),
                                  np.asarray(want.proto_labels))


def test_fit_streaming_index_matches_in_memory_fit(rng):
    """ClusterIndex.build on a single-buffer chunk stream freezes the
    same artifact as building from the resident array."""
    x, _ = gmm_sample(256, rng)
    key = jax.random.PRNGKey(0)
    want = ClusterIndex.build(jnp.asarray(x), 2, 2, "kmeans", k=3, key=key)
    got = ClusterIndex.build(iter([x]), 2, 2, "kmeans", k=3, key=key,
                             chunk_n=256, reservoir_n=512)
    np.testing.assert_array_equal(
        np.asarray(got.protos).view(np.uint32),
        np.asarray(want.protos).view(np.uint32))
    np.testing.assert_array_equal(np.asarray(got.proto_labels),
                                  np.asarray(want.proto_labels))
    q = jnp.asarray(gmm_sample(64, rng)[0])
    np.testing.assert_array_equal(np.asarray(got.assign(q)),
                                  np.asarray(want.assign(q)))


# ------------------------------------------------------- multi-chunk runs


def test_streaming_multichunk_cascades_and_invariants(rng):
    """A reservoir much smaller than n forces mid-stream cascades; the
    pipeline invariants (coverage, mass conservation, the (t*)^m size
    guarantee, GMM accuracy) must survive them."""
    n, chunk, t, m = 4096, 512, 2, 2
    x, true = gmm_sample(n, rng)
    res = ihtc_streaming(_chunked(x, chunk), t, m, "kmeans", k=3,
                         chunk_n=chunk, reservoir_n=640,
                         key=jax.random.PRNGKey(0))
    assert res.n_chunks == n // chunk
    assert res.n_cascades >= 1  # the bounded reservoir actually cascaded
    lab = res.labels()
    assert lab.shape == (n,)
    assert lab.min() >= 0  # every point backed out to a real cluster
    # per-chunk access agrees with the concatenated view
    np.testing.assert_array_equal(res.labels_for(3),
                                  lab[3 * chunk:4 * chunk])
    # mass conservation through chunk reduces + cascades + finalize
    mass = np.asarray(res.proto_mass)[np.asarray(res.proto_valid)]
    assert abs(mass.sum() - n) < 1e-2
    # the paper's guarantee: every final cluster holds >= t^m units
    sizes = np.bincount(lab)
    assert sizes[sizes > 0].min() >= t ** m
    assert clustering_accuracy(true, lab, 3) > 0.85


def test_streaming_quality_tracks_in_memory(rng):
    """Multi-chunk streaming is a different estimator (level-0 TC cannot
    cross chunks) but must cluster the §4 mixture about as well."""
    n = 3000
    x, true = gmm_sample(n, rng)
    mem = ihtc(jnp.asarray(x), 2, 2, "kmeans", k=3, key=jax.random.PRNGKey(1))
    acc_mem = clustering_accuracy(true, np.asarray(mem.labels), 3)
    res = ihtc_streaming(_chunked(x, 500), 2, 2, "kmeans", k=3,
                         chunk_n=500, key=jax.random.PRNGKey(1))
    acc_stream = clustering_accuracy(true, res.labels(), 3)
    assert acc_stream > acc_mem - 0.05, (acc_mem, acc_stream)


def test_streaming_accepts_tuples_ragged_tail_and_empty_chunks(rng):
    """(chunk, n_valid) pairs, bare arrays, a ragged tail shorter than
    chunk_n, and an empty chunk all compose in one stream."""
    x, _ = gmm_sample(700, rng)
    padded = np.zeros((256, 2), np.float32)
    padded[:200] = x[:200]
    chunks = [
        (padded, 200),               # pre-padded pair
        x[200:456],                  # full bare chunk
        np.zeros((0, 2), np.float32),  # empty chunk
        x[456:700],                  # ragged tail (244 rows)
    ]
    res = ihtc_streaming(iter(chunks), 2, 2, "kmeans", k=3, chunk_n=256,
                         key=jax.random.PRNGKey(2))
    assert res.n_chunks == 4
    assert [len(lab) for lab in res.iter_labels()] == [200, 256, 0, 244]
    assert res.n_total == 700
    lab = res.labels()
    assert lab.shape == (700,)
    assert lab.min() >= 0
    mass = np.asarray(res.proto_mass)[np.asarray(res.proto_valid)]
    assert abs(mass.sum() - 700) < 1e-2


def test_streaming_point_chunks_pipeline(rng):
    """End-to-end with the data pipeline's chunk generator."""
    from repro.data import PointStreamConfig, point_chunks

    cfg = PointStreamConfig(n=2000, d=2, chunk=512, seed=0, kind="gmm")
    res = ihtc_streaming(point_chunks(cfg), 2, 2, "kmeans", k=3)
    assert res.chunk_n == 512  # auto from the first chunk
    lab = res.labels()
    assert lab.shape == (2000,)
    assert lab.min() >= 0


# ------------------------------------------------- config + validation


def test_streaming_runtime_config_fields(rng):
    x, _ = gmm_sample(600, rng)
    explicit = ihtc_streaming(_chunked(x, 200), 2, 2, "kmeans", k=3,
                              chunk_n=200, reservoir_n=400,
                              key=jax.random.PRNGKey(4))
    with runtime.configure(chunk_n=200, reservoir_n=400):
        configured = ihtc_streaming(_chunked(x, 200), 2, 2, "kmeans", k=3,
                                    key=jax.random.PRNGKey(4))
    np.testing.assert_array_equal(explicit.labels(), configured.labels())
    cfg = runtime.config_from_env(
        {"REPRO_CHUNK_N": "8192", "REPRO_RESERVOIR_N": "32768"})
    assert (cfg.chunk_n, cfg.reservoir_n) == (8192, 32768)
    with pytest.raises(ValueError):
        runtime.RuntimeConfig(chunk_n=-1)
    with pytest.raises(ValueError):
        runtime.RuntimeConfig(reservoir_n=-2)


def test_streaming_validation_errors(rng):
    x, _ = gmm_sample(64, rng)
    with pytest.raises(ValueError, match="m must be"):
        ihtc_streaming(iter([x]), 2, 0, "kmeans", k=3)
    with pytest.raises(ValueError, match="t must be"):
        ihtc_streaming(iter([x]), 1, 2, "kmeans", k=3)
    with pytest.raises(ValueError, match="empty"):
        ihtc_streaming(iter([]), 2, 2, "kmeans", k=3)
    with pytest.raises(ValueError, match="chunk_n"):  # chunk > chunk_n
        ihtc_streaming(_chunked(x, 64), 2, 2, "kmeans", k=3, chunk_n=32)
    with pytest.raises(ValueError, match="reservoir_n"):
        ihtc_streaming(_chunked(x, 32), 2, 2, "kmeans", k=3, chunk_n=32,
                       reservoir_n=20)
    with pytest.raises(ValueError, match="n_valid"):
        ihtc_streaming(iter([(x, 999)]), 2, 2, "kmeans", k=3)
    # insufficient reservoir for a raw tail slab is caught up front too
    with pytest.raises(ValueError, match="reservoir_n"):
        ihtc_streaming(_chunked(x, 10), 3, 2, "kmeans", k=2, chunk_n=10,
                       reservoir_n=7)
    # ... including when only the compaction degradation path would
    # overflow (post-compaction frontier can exceed reservoir_n//t)
    with pytest.raises(ValueError, match="reservoir_n"):
        ihtc_streaming(iter([(x[:6], 5), (x[6:12], 5), (x[12:18], 5)]),
                       3, 2, "kmeans", k=2, chunk_n=6, reservoir_n=9)


def test_streaming_auto_reservoir_small_chunks_large_t(rng):
    """The auto reservoir default must satisfy the feasibility bound by
    construction, even for small chunks with a large threshold (where the
    compaction term dominates 4x the per-chunk prototype budget)."""
    x = rng.normal(size=(20, 2)).astype(np.float32)
    res = ihtc_streaming(_chunked(x, 5), 4, 1, "kmeans", k=2, chunk_n=5,
                         key=jax.random.PRNGKey(0))
    lab = res.labels()
    assert lab.shape == (20,)
    assert lab.min() >= 0


def test_streaming_all_masked_stream_raises_clearly():
    """A stream whose every chunk is empty/fully masked must fail with a
    clear error, not an opaque backend crash on an empty buffer."""
    z = np.zeros((8, 2), np.float32)
    with pytest.raises(ValueError, match="no valid rows"):
        ihtc_streaming(iter([(z, 0), (z, 0)]), 2, 2, "kmeans", k=3,
                       chunk_n=8)


# ------------------------------------------- pipelined ingest (§18)


def _spill_tuple(res):
    """Everything the back-out chain depends on, as host arrays."""
    s = res.spill
    return (np.asarray(res.protos).view(np.uint32),
            np.asarray(res.proto_mass).view(np.uint32),
            np.asarray(res.proto_valid),
            list(s.chunk_assign), list(s.maps),
            list(s.chunk_offset), list(s.chunk_epoch))


@pytest.mark.parametrize("depth,donate",
                         [(1, False), (1, True), (3, False), (3, True)])
def test_pipelined_ingest_bitwise_parity(rng, depth, donate):
    """Acceptance contract: every prefetch depth x donation setting is
    bitwise identical to the serial loop — through mid-stream cascades,
    a raw-fold tail, and an empty chunk."""
    x, _ = gmm_sample(2048, rng)
    chunks = lambda: iter(
        list(_chunked(x[:1792], 256))
        + [np.zeros((0, 2), np.float32), x[1792:1797]])
    kw = dict(chunk_n=256, reservoir_n=320, key=jax.random.PRNGKey(7))
    ref = ihtc_streaming(chunks(), 2, 2, "kmeans", k=3,
                         prefetch_depth=0, **kw)
    assert ref.n_cascades >= 1  # the parity claim must cover cascades
    got = ihtc_streaming(chunks(), 2, 2, "kmeans", k=3,
                         prefetch_depth=depth, donate_stream=donate, **kw)
    for a, b in zip(_spill_tuple(ref), _spill_tuple(got)):
        if isinstance(a, list):
            assert len(a) == len(b)
            for ai, bi in zip(a, b):
                np.testing.assert_array_equal(np.asarray(ai),
                                              np.asarray(bi))
        else:
            np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(ref.labels(), got.labels())


@pytest.mark.parametrize("depth", [0, 2])
def test_staging_pool_tail_masking_unchanged(rng, depth):
    """Satellite regression: the staging pool reuses buffers with a
    zero-fill watermark instead of a fresh np.zeros per chunk. A full
    chunk followed by shorter ragged chunks leaves stale rows in the
    reused buffer — the masked tail must still read as zeros, so the
    result matches the same stream pre-padded by hand."""
    x, _ = gmm_sample(700, rng)
    ragged = [x[:256], x[256:456], x[456:500], x[500:700]]

    def padded():
        for c in ragged:
            buf = np.zeros((256, 2), np.float32)
            buf[:len(c)] = c
            yield buf, len(c)

    kw = dict(chunk_n=256, reservoir_n=512, key=jax.random.PRNGKey(2),
              prefetch_depth=depth)
    a = ihtc_streaming(iter(ragged), 2, 2, "kmeans", k=3, **kw)
    b = ihtc_streaming(padded(), 2, 2, "kmeans", k=3, **kw)
    np.testing.assert_array_equal(
        np.asarray(a.protos).view(np.uint32),
        np.asarray(b.protos).view(np.uint32))
    np.testing.assert_array_equal(a.labels(), b.labels())


def _prefetch_threads():
    import threading

    from repro.core.streaming import _PREFETCH_THREAD_NAME

    return [t for t in threading.enumerate()
            if t.name == _PREFETCH_THREAD_NAME and t.is_alive()]


def test_prefetch_fault_mid_stream_shuts_down_cleanly(rng):
    """A bad chunk mid-stream must raise the same error the serial loop
    raises, at any depth, and the prefetch thread must not outlive the
    failed fit (no hung queue, no leaked staging buffers)."""
    x, _ = gmm_sample(512, rng)
    bad = np.zeros((300, 2), np.float32)  # 300 rows > chunk_n=256

    def stream():
        yield x[:256]
        yield x[256:512]
        yield bad

    with pytest.raises(ValueError, match="rows > chunk_n") as serial:
        ihtc_streaming(stream(), 2, 2, "kmeans", k=3, chunk_n=256,
                       prefetch_depth=0, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="rows > chunk_n") as piped:
        ihtc_streaming(stream(), 2, 2, "kmeans", k=3, chunk_n=256,
                       prefetch_depth=2, key=jax.random.PRNGKey(0))
    assert str(piped.value) == str(serial.value)
    assert _prefetch_threads() == []
    # a generator that itself explodes propagates the original exception
    def exploding():
        yield x[:256]
        raise RuntimeError("source died")

    with pytest.raises(RuntimeError, match="source died"):
        ihtc_streaming(exploding(), 2, 2, "kmeans", k=3, chunk_n=256,
                       prefetch_depth=2, key=jax.random.PRNGKey(0))
    assert _prefetch_threads() == []


def test_ingest_stats_and_forced_copy_contract(rng):
    """LabelSpill carries ingest telemetry, every spilled map is a true
    host copy (the §12 contract is now enforced at construction), and a
    device array smuggled into LabelSpill raises."""
    from repro.core.plan import LabelSpill

    x, _ = gmm_sample(1024, rng)
    res = ihtc_streaming(_chunked(x, 256), 2, 2, "kmeans", k=3,
                         chunk_n=256, reservoir_n=320,
                         prefetch_depth=2, key=jax.random.PRNGKey(1))
    st = res.spill.ingest_stats
    assert st["prefetch_depth"] == 2 and st["n_chunks"] == 4
    assert st["wall_s"] > 0 and st["ingest_wait_s"] >= 0
    for a in list(res.spill.chunk_assign) + list(res.spill.maps):
        assert isinstance(a, np.ndarray)
    with pytest.raises(TypeError, match="forced"):
        LabelSpill(chunk_assign=[jnp.zeros((4,), jnp.int32)], maps=[],
                   chunk_offset=[0], chunk_epoch=[0], chunk_counts=[4],
                   chunk_n=4, n_cascades=0)


def test_streaming_hole_heavy_reservoir_compacts(rng):
    """Slabs that are mostly masked holes (chunks collapsing to very few
    clusters) can fill the reservoir with fewer valid prototypes than a
    reduction level needs; the fold must compact the holes out and carry
    on, with the back-out chain still exact."""
    # near-duplicate chunks: TC at t=3 collapses 30 rows to a handful of
    # clusters, so each 10-slot slab is mostly holes
    base = rng.normal(size=(1, 2)).astype(np.float32)
    chunks = [base + 1e-4 * rng.normal(size=(30, 2)).astype(np.float32)
              for _ in range(6)]
    res = ihtc_streaming(iter(chunks), 3, 2, "kmeans", k=1, chunk_n=30,
                         reservoir_n=15, key=jax.random.PRNGKey(0))
    lab = res.labels()
    assert lab.shape == (180,)
    assert lab.min() >= 0
    mass = np.asarray(res.proto_mass)[np.asarray(res.proto_valid)]
    assert abs(mass.sum() - 180) < 1e-2
