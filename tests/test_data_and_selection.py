"""Data pipeline determinism + ITIS instance selection as a data stage."""
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, smoke_config
from repro.data import make_batch
from repro.data.instance_selection import (
    SelectionConfig,
    featurize,
    reduced_batch,
    select_instances,
)


def test_batches_are_pure_functions_of_step():
    cfg = smoke_config(ARCHS["qwen2.5-32b"])
    b1 = make_batch(cfg, SHAPES["train_4k"], 7, batch_override=4, seq_override=16)
    b2 = make_batch(cfg, SHAPES["train_4k"], 7, batch_override=4, seq_override=16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = make_batch(cfg, SHAPES["train_4k"], 8, batch_override=4, seq_override=16)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_batches_have_learnable_structure():
    cfg = smoke_config(ARCHS["qwen2.5-32b"])
    b = make_batch(cfg, SHAPES["train_4k"], 0, batch_override=16, seq_override=64)
    toks = np.asarray(b["tokens"])
    assert toks.min() >= 0 and toks.max() < cfg.vocab_size
    # Zipf-ish: top-10 tokens should cover a large fraction
    counts = np.bincount(toks.ravel())
    top = np.sort(counts)[::-1][:10].sum() / toks.size
    assert top > 0.3, top


def test_modality_batches():
    for name in ("phi-3-vision-4.2b", "seamless-m4t-large-v2"):
        cfg = smoke_config(ARCHS[name])
        b = make_batch(cfg, SHAPES["train_4k"], 0, batch_override=2,
                       seq_override=16)
        if cfg.frontend == "vision":
            assert b["patch_embeds"].shape[0] == 2
        else:
            assert b["frames"].shape == (2, 16, cfg.d_model)


def test_instance_selection_reduces_and_weights(rng):
    n, s, vocab = 256, 24, 97
    # corpus with 4 latent topics -> clusterable features
    topics = rng.integers(0, 4, size=n)
    toks = (topics[:, None] * (vocab // 4)
            + rng.integers(0, vocab // 4, size=(n, s))).astype(np.int32)
    toks = jnp.asarray(toks)
    scfg = SelectionConfig(threshold=2, iterations=2, feature_dim=16)
    sel = select_instances(toks, vocab, scfg)
    n_sel = int(jnp.sum(sel.valid))
    assert n_sel <= n // 4
    # masses add up to the corpus size
    total = float(jnp.sum(jnp.where(sel.valid, sel.weights, 0.0)))
    assert abs(total - n) < 1e-2
    # every original example maps to a selected prototype
    assign = np.asarray(sel.assignment)
    assert assign.min() >= 0
    # selected indices are valid distinct examples
    idx = np.asarray(sel.indices)[np.asarray(sel.valid)]
    assert len(set(idx.tolist())) == n_sel

    rb = reduced_batch(toks, sel)
    assert rb["tokens"].shape == (sel.indices.shape[0], s - 1)
    w = np.asarray(rb["weights"])
    assert (w[np.asarray(sel.valid)] > 0).all()
    lab = np.asarray(rb["labels"])
    assert (lab[~np.asarray(sel.valid)] == -1).all()


def test_instance_selection_groups_topics(rng):
    """Same-topic examples should collapse together far more often than not."""
    n, s, vocab = 128, 16, 80
    topics = rng.integers(0, 2, size=n)
    toks = jnp.asarray(
        (topics[:, None] * 40 + rng.integers(0, 8, size=(n, s))).astype(np.int32))
    sel = select_instances(toks, vocab, SelectionConfig(2, 2, feature_dim=8))
    assign = np.asarray(sel.assignment)
    same = cross = 0
    for i in range(0, n, 3):
        for j in range(1, n, 7):
            if assign[i] == assign[j]:
                if topics[i] == topics[j]:
                    same += 1
                else:
                    cross += 1
    assert same > 5 * max(cross, 1)


def test_weighted_loss_unbiased(rng):
    """CE on the weighted reduced corpus ≈ CE on the full corpus when
    cluster members are identical (exactness case)."""
    from repro.train.train_step import cross_entropy

    n, s, v = 32, 8, 11
    base = rng.integers(0, v, size=(n // 4, s + 1)).astype(np.int32)
    full = jnp.asarray(np.repeat(base, 4, axis=0))  # 4 identical copies each
    logits = jnp.asarray(rng.normal(size=(n, s, v)), jnp.float32)
    logits = jnp.repeat(logits[: n // 4], 4, axis=0)
    l_full, _ = cross_entropy(logits, full[:, 1:])
    l_red, _ = cross_entropy(
        logits[::4], full[::4, 1:],  # one representative per duplicate group
        weights=jnp.full((n // 4,), 4.0))
    assert abs(float(l_full) - float(l_red)) < 1e-5
