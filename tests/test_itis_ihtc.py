"""ITIS / IHTC behaviour: reduction factors, back-out consistency, the
(t*)^m final-cluster-size guarantee, and reproduction of the paper's §4
accuracy claims on the GMM simulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import gmm_sample
from repro.cluster.metrics import bss_tss, clustering_accuracy
from repro.core import compose_assignments, ihtc, itis


def test_itis_reduction_factor(rng):
    x, _ = gmm_sample(1024, rng)
    for t in (2, 3):
        for m in (1, 2, 3):
            r = itis(jnp.asarray(x), t, m)
            n_protos = int(r.n_prototypes)
            assert n_protos <= 1024 // (t**m), (t, m, n_protos)
            assert n_protos >= 1


def test_itis_mass_conservation(rng):
    x, _ = gmm_sample(500, rng)
    r = itis(jnp.asarray(x), 2, 3)
    total_mass = float(jnp.sum(jnp.where(r.valid, r.mass, 0.0)))
    assert abs(total_mass - 500) < 1e-3


def test_itis_backout_covers_all(rng):
    x, _ = gmm_sample(300, rng)
    r = itis(jnp.asarray(x), 2, 2)
    ident = jnp.arange(r.protos.shape[0], dtype=jnp.int32)
    assign = np.asarray(compose_assignments(r.assignments, ident))
    assert assign.shape == (300,)
    assert assign.min() >= 0
    valid_ids = np.flatnonzero(np.asarray(r.valid))
    assert set(np.unique(assign)) <= set(valid_ids.tolist())


@pytest.mark.parametrize("backend,kw", [
    ("kmeans", {"k": 3}),
    ("hac", {"k": 3, "linkage": "ward"}),
])
def test_ihtc_min_cluster_size_guarantee(rng, backend, kw):
    """Paper claim: m ITIS iterations at t* ⇒ every final cluster ≥ (t*)^m."""
    x, _ = gmm_sample(800, rng)
    t, m = 2, 3
    res = ihtc(jnp.asarray(x), t, m, backend, **kw)
    lab = np.asarray(res.labels)
    assert lab.min() >= 0
    sizes = np.bincount(lab)
    assert sizes[sizes > 0].min() >= t**m


def test_ihtc_kmeans_accuracy_matches_paper(rng):
    """Paper Table 1: accuracy ≈ 0.92 for m = 0..3 on the GMM mixture."""
    x, true = gmm_sample(3000, rng)
    for m in (0, 1, 2, 3):
        res = ihtc(jnp.asarray(x), 2, m, "kmeans", k=3,
                   key=jax.random.PRNGKey(11))
        acc = clustering_accuracy(true, np.asarray(res.labels), 3)
        assert acc > 0.88, (m, acc)


def test_ihtc_hac_accuracy(rng):
    x, true = gmm_sample(1200, rng)
    res = ihtc(jnp.asarray(x), 2, 2, "hac", k=3, linkage="ward",
               key=jax.random.PRNGKey(3))
    acc = clustering_accuracy(true, np.asarray(res.labels), 3)
    assert acc > 0.80, acc


def test_ihtc_dbscan_runs(rng):
    x, _ = gmm_sample(600, rng)
    res = ihtc(jnp.asarray(x), 2, 2, "dbscan", eps=0.9, min_pts=25.0)
    lab = np.asarray(res.labels)
    assert lab.shape == (600,)
    assert lab.max() >= 0  # found at least one cluster


def test_ihtc_bss_tss_preserved(rng):
    """Paper Tables 4–6: BSS/TSS barely moves under IHTC pre-processing."""
    x, _ = gmm_sample(2000, rng)
    xj = jnp.asarray(x)
    base = ihtc(xj, 2, 0, "kmeans", k=3, key=jax.random.PRNGKey(0))
    red = ihtc(xj, 2, 2, "kmeans", k=3, key=jax.random.PRNGKey(0))
    r0 = float(bss_tss(xj, base.labels, 3))
    r2 = float(bss_tss(xj, red.labels, 3))
    assert r2 > r0 - 0.03, (r0, r2)


def test_ihtc_m0_equals_backend(rng):
    """m=0 must reduce to plain k-means on the raw data."""
    x, _ = gmm_sample(200, rng)
    res = ihtc(jnp.asarray(x), 2, 0, "kmeans", k=3, key=jax.random.PRNGKey(4))
    assert int(res.n_prototypes) == 200
    assert np.asarray(res.labels).shape == (200,)


def test_threshold_validation_rejects_degenerate_t_and_m(rng):
    """Regression: t=1 never shrinks, so the drivers used to run m
    full-size levels silently; now every public entry point rejects it."""
    from repro.core import level_sizes

    x = jnp.asarray(gmm_sample(50, rng)[0])
    for bad_t in (1, 0, -3):
        with pytest.raises(ValueError, match="t must be"):
            level_sizes(50, bad_t, 2)
        with pytest.raises(ValueError, match="t must be"):
            itis(x, bad_t, 2)
        with pytest.raises(ValueError, match="t must be"):
            ihtc(x, bad_t, 2, "kmeans", k=3)
    with pytest.raises(ValueError, match="m must be"):
        itis(x, 2, -1)
    with pytest.raises(ValueError, match="m must be"):
        ihtc(x, 2, -2, "kmeans", k=3)
    with pytest.raises(ValueError, match="m must be"):
        level_sizes(50, 2, -1)


def test_threshold_validation_requires_k_below_n(rng):
    """With any level to run, TC needs a k = t-1 < n neighbour graph."""
    tiny = jnp.asarray(rng.normal(size=(4, 2)), jnp.float32)
    with pytest.raises(ValueError, match="t - 1 < n"):
        ihtc(tiny, 5, 1, "kmeans", k=2)
    with pytest.raises(ValueError, match="t - 1 < n"):
        itis(tiny, 5, 1)
    # m=0 never builds the graph, so a large t is harmless there
    res = ihtc(tiny, 4, 0, "kmeans", k=2)
    assert np.asarray(res.labels).shape == (4,)
