"""Interpret-mode Pallas-vs-reference parity sweep (hypothesis).

The hand-picked parametrizations in test_kernels.py cover a few known-bad
shapes; this sweep drives the three clustering kernels across randomly
drawn *awkward* cases — n not divisible by the block, k near the valid
count, d=1, out-of-range segment ids — with deliberately tiny tile sizes
so multi-block grids (and their padding paths) execute even at test n.
Runs on CPU (interpret=True), so CI exercises the kernel code paths that
only a TPU would otherwise reach.

Shapes are drawn from fixed buckets (the test_tc_properties idiom) to
bound the number of distinct jit compilations.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.knn_topk import knn_topk
from repro.kernels.pairwise_l2 import pairwise_sq_l2
from repro.kernels.segment_sum import segment_sum

# the random sweep needs hypothesis (requirements-dev.txt; CI installs
# it); the pinned edge cases at the bottom run either way
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised in bare containers
    given = None

# awkward-by-construction buckets: primes and off-by-one around the tiny
# tile sizes below, so blocks never divide the row count evenly
NS = (7, 9, 16, 17, 31, 33)
DS = (1, 2, 5, 8)
TILES = (8, 16, 32)

if given is None:  # no hypothesis: stub the sweep out as skips
    SWEEP = pytest.mark.skip(
        reason="parity sweep needs hypothesis "
               "(pip install -r requirements-dev.txt)")

    def given(**kw):  # noqa: F811
        return lambda fn: fn

    class _St:
        def composite(self, fn):
            return lambda: None

        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _St()
else:
    SWEEP = settings(max_examples=25, deadline=None)


@st.composite
def knn_cases(draw):
    n = draw(st.sampled_from(NS))
    d = draw(st.sampled_from(DS))
    seed = draw(st.integers(0, 2**16))
    masked = draw(st.booleans())
    # k spans the full legal range [1, n] — including k >= n_valid, where
    # unfillable slots must come back (inf, -1)
    k = draw(st.integers(1, n))
    bq = draw(st.sampled_from(TILES))
    bk = draw(st.sampled_from(TILES))
    return n, d, k, bq, bk, seed, masked


@SWEEP
@given(case=knn_cases())
def test_knn_topk_parity(case):
    n, d, k, bq, bk, seed, masked = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    valid = (jnp.asarray(rng.random(n) > 0.3) if masked else None)
    gd, gi = knn_topk(x, k, valid, block_q=bq, block_k=bk, interpret=True)
    wd, wi = ref.knn(x, k, valid=valid)
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(gi, wi)


@st.composite
def pairwise_cases(draw):
    n = draw(st.sampled_from(NS))
    m = draw(st.sampled_from(NS))
    d = draw(st.sampled_from(DS))
    seed = draw(st.integers(0, 2**16))
    masked = draw(st.booleans())
    bq = draw(st.sampled_from(TILES))
    bk = draw(st.sampled_from(TILES))
    return n, m, d, bq, bk, seed, masked


@SWEEP
@given(case=pairwise_cases())
def test_pairwise_sq_l2_parity(case):
    n, m, d, bq, bk, seed, masked = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    yv = (jnp.asarray(rng.random(m) > 0.3) if masked else None)
    got = pairwise_sq_l2(x, y, yv, block_q=bq, block_k=bk, interpret=True)
    want = ref.pairwise_sq_l2(x, y, y_valid=yv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@st.composite
def segsum_cases(draw):
    n = draw(st.sampled_from(NS))
    d = draw(st.sampled_from(DS))
    s = draw(st.sampled_from((1, 2, 5, 9, 17)))
    seed = draw(st.integers(0, 2**16))
    weighted = draw(st.booleans())
    bs = draw(st.sampled_from(TILES))
    bn = draw(st.sampled_from(TILES))
    return n, d, s, bs, bn, seed, weighted


@SWEEP
@given(case=segsum_cases())
def test_segment_sum_parity(case):
    n, d, s, bs, bn, seed, weighted = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    # ids straddle the legal range: -1 and s are out of range -> dropped
    ids = jnp.asarray(rng.integers(-1, s + 1, size=n), jnp.int32)
    w = jnp.asarray(rng.random(n), jnp.float32) if weighted else None
    gs, gm = segment_sum(x, ids, s, w, block_s=bs, block_n=bn,
                         interpret=True)
    ws, wm = ref.segment_sum(x, ids, s, weights=w)
    np.testing.assert_allclose(gs, ws, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gm, wm, rtol=1e-4, atol=1e-4)


# pinned worst cases the random sweep might skip in a given run: d=1
# columns, k exactly at the valid count, and a mask denser than k
@pytest.mark.parametrize("n,d,k,bq,bk", [
    (33, 1, 32, 8, 16),   # k = n-1 at d=1, blocks don't divide n
    (17, 1, 17, 16, 8),   # k = n: every slot needs the full candidate set
    (9, 5, 8, 8, 8),      # n just over one tile
])
def test_knn_topk_pinned_edges(rng, n, d, k, bq, bk):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    gd, gi = knn_topk(x, k, block_q=bq, block_k=bk, interpret=True)
    wd, wi = ref.knn(x, k)
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(gi, wi)


def test_knn_topk_k_exceeds_valid_count(rng):
    """k near/above n_valid: the 4 invalid rows force (inf, -1) slots."""
    x = jnp.asarray(rng.normal(size=(12, 3)), jnp.float32)
    valid = jnp.asarray([True] * 8 + [False] * 4)
    gd, gi = knn_topk(x, 9, valid, block_q=8, block_k=8, interpret=True)
    wd, wi = ref.knn(x, 9, valid=valid)
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(gi, wi)
    assert np.isinf(np.asarray(gd)[:, -1]).all()  # only 7 valid others
    assert (np.asarray(gi)[:, -1] == -1).all()
