"""Interpret-mode Pallas-vs-reference parity sweep (hypothesis).

The hand-picked parametrizations in test_kernels.py cover a few known-bad
shapes; this sweep drives the three clustering kernels across randomly
drawn *awkward* cases — n not divisible by the block, k near the valid
count, d=1, out-of-range segment ids — with deliberately tiny tile sizes
so multi-block grids (and their padding paths) execute even at test n.
Runs on CPU (interpret=True), so CI exercises the kernel code paths that
only a TPU would otherwise reach.

Shapes are drawn from fixed buckets (the test_tc_properties idiom) to
bound the number of distinct jit compilations.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fused_assign import (
    fused_topk,
    fused_topk_xla,
    quantize_keys,
)
from repro.kernels.knn_topk import knn_topk
from repro.kernels.pairwise_l2 import pairwise_sq_l2
from repro.kernels.segment_sum import segment_sum

# the random sweep needs hypothesis (requirements-dev.txt; CI installs
# it); the pinned edge cases at the bottom run either way
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised in bare containers
    given = None

# awkward-by-construction buckets: primes and off-by-one around the tiny
# tile sizes below, so blocks never divide the row count evenly
NS = (7, 9, 16, 17, 31, 33)
DS = (1, 2, 5, 8)
TILES = (8, 16, 32)

if given is None:  # no hypothesis: stub the sweep out as skips
    SWEEP = pytest.mark.skip(
        reason="parity sweep needs hypothesis "
               "(pip install -r requirements-dev.txt)")

    def given(**kw):  # noqa: F811
        return lambda fn: fn

    class _St:
        def composite(self, fn):
            return lambda: None

        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _St()
else:
    SWEEP = settings(max_examples=25, deadline=None)


@st.composite
def knn_cases(draw):
    n = draw(st.sampled_from(NS))
    d = draw(st.sampled_from(DS))
    seed = draw(st.integers(0, 2**16))
    masked = draw(st.booleans())
    # k spans the full legal range [1, n] — including k >= n_valid, where
    # unfillable slots must come back (inf, -1)
    k = draw(st.integers(1, n))
    bq = draw(st.sampled_from(TILES))
    bk = draw(st.sampled_from(TILES))
    return n, d, k, bq, bk, seed, masked


@SWEEP
@given(case=knn_cases())
def test_knn_topk_parity(case):
    n, d, k, bq, bk, seed, masked = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    valid = (jnp.asarray(rng.random(n) > 0.3) if masked else None)
    gd, gi = knn_topk(x, k, valid, block_q=bq, block_k=bk, interpret=True)
    wd, wi = ref.knn(x, k, valid=valid)
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(gi, wi)


@st.composite
def pairwise_cases(draw):
    n = draw(st.sampled_from(NS))
    m = draw(st.sampled_from(NS))
    d = draw(st.sampled_from(DS))
    seed = draw(st.integers(0, 2**16))
    masked = draw(st.booleans())
    bq = draw(st.sampled_from(TILES))
    bk = draw(st.sampled_from(TILES))
    return n, m, d, bq, bk, seed, masked


@SWEEP
@given(case=pairwise_cases())
def test_pairwise_sq_l2_parity(case):
    n, m, d, bq, bk, seed, masked = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    yv = (jnp.asarray(rng.random(m) > 0.3) if masked else None)
    got = pairwise_sq_l2(x, y, yv, block_q=bq, block_k=bk, interpret=True)
    want = ref.pairwise_sq_l2(x, y, y_valid=yv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@st.composite
def segsum_cases(draw):
    n = draw(st.sampled_from(NS))
    d = draw(st.sampled_from(DS))
    s = draw(st.sampled_from((1, 2, 5, 9, 17)))
    seed = draw(st.integers(0, 2**16))
    weighted = draw(st.booleans())
    bs = draw(st.sampled_from(TILES))
    bn = draw(st.sampled_from(TILES))
    return n, d, s, bs, bn, seed, weighted


@SWEEP
@given(case=segsum_cases())
def test_segment_sum_parity(case):
    n, d, s, bs, bn, seed, weighted = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    # ids straddle the legal range: -1 and s are out of range -> dropped
    ids = jnp.asarray(rng.integers(-1, s + 1, size=n), jnp.int32)
    w = jnp.asarray(rng.random(n), jnp.float32) if weighted else None
    gs, gm = segment_sum(x, ids, s, w, block_s=bs, block_n=bn,
                         interpret=True)
    ws, wm = ref.segment_sum(x, ids, s, weights=w)
    np.testing.assert_allclose(gs, ws, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gm, wm, rtol=1e-4, atol=1e-4)


def dyadic(rng, shape, scale=0.25, lim=16):
    """Random points on an exact dyadic grid (multiples of ``scale`` in
    ``[-lim*scale, lim*scale]``): every square, cross product and partial
    sum in the sq-L2 distance is exactly representable in f32, so the
    distance is EXACT under any summation order or FMA contraction. That
    makes bit-equality across separately compiled graphs a mathematical
    guarantee rather than a bet on XLA:CPU emitting the same roundings —
    with continuous data the composed reference itself drifts 1 ulp
    between eager and jitted execution (LLVM contracts ``a*b+c``). The
    grid also makes distance TIES common, hammering the part of the
    contract that is genuinely structural: merge order, index
    tie-breaking, masking and padding."""
    return jnp.asarray(rng.integers(-lim, lim + 1, size=shape) * scale,
                       jnp.float32)


def composed_nearest(q, keys, k, valid=None, q_gidx=None):
    """The composed ``pairwise_sq_l2 + merge_topk`` reference the fused
    kernel must match *bit for bit* (DESIGN.md §16)."""
    nq, p = q.shape[0], keys.shape[0]
    d = ref.pairwise_sq_l2(q, keys, y_valid=valid)
    gidx = jnp.arange(p, dtype=jnp.int32)
    if q_gidx is not None:
        d = jnp.where(q_gidx[:, None] == gidx[None, :], jnp.inf, d)
    init_d = jnp.full((nq, k), jnp.inf, jnp.float32)
    init_i = jnp.full((nq, k), -1, jnp.int32)
    return ref.merge_topk(init_d, init_i, d,
                          jnp.broadcast_to(gidx, d.shape), k)


@st.composite
def fused_cases(draw):
    nq = draw(st.sampled_from(NS))
    p = draw(st.sampled_from(NS))
    d = draw(st.sampled_from(DS))
    seed = draw(st.integers(0, 2**16))
    masked = draw(st.booleans())
    self_excl = draw(st.booleans())
    k = draw(st.integers(1, min(p, 5)))
    bq = draw(st.sampled_from(TILES))
    bk = draw(st.sampled_from(TILES))
    return nq, p, d, k, bq, bk, seed, masked, self_excl


@SWEEP
@given(case=fused_cases())
def test_fused_topk_parity(case):
    """Fused streaming top-k — both the Pallas kernel (interpret) and the
    XLA fold — is BIT-identical to the composed reference path across
    awkward shapes (n/k indivisible by tiles, d=1, OOB padding, masks,
    traced self-exclusion). Exact-grid inputs (see :func:`dyadic`) make
    the bit-equality well-defined across compilations and flood the merge
    with distance ties."""
    nq, p, d, k, bq, bk, seed, masked, self_excl = case
    rng = np.random.default_rng(seed)
    q = dyadic(rng, (nq, d))
    keys = dyadic(rng, (p, d))
    valid = (jnp.asarray(rng.random(p) > 0.3) if masked else None)
    # q_gidx points some queries at key rows (self-exclusion), others at
    # indices beyond p (no-op) — the blocked-kNN usage pattern
    q_gidx = (jnp.asarray(rng.integers(0, 2 * p, size=nq), jnp.int32)
              if self_excl else None)
    wd, wi = composed_nearest(q, keys, k, valid, q_gidx)
    gd, gi = fused_topk(q, keys, k, valid, q_gidx=q_gidx,
                        block_q=bq, block_k=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    xd, xi = fused_topk_xla(q, keys, k, valid, q_gidx=q_gidx, block_k=bk)
    np.testing.assert_array_equal(np.asarray(xd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(wi))


# pinned worst cases for the fused kernel the random sweep might miss —
# run hypothesis-less so bare containers still execute them
@pytest.mark.parametrize("nq,p,d,k,bq,bk", [
    (7, 33, 1, 1, 32, 32),    # d=1, tiles overshoot both axes (OOB padding)
    (33, 17, 5, 3, 8, 16),    # neither axis divides its tile
    (9, 9, 2, 9, 8, 8),       # k = p: every slot needs the full key set
    (16, 8, 8, 2, 16, 8),     # aligned shapes (the acceptance criterion)
])
def test_fused_topk_pinned_edges(rng, nq, p, d, k, bq, bk):
    q = dyadic(rng, (nq, d))
    keys = dyadic(rng, (p, d))
    wd, wi = composed_nearest(q, keys, k)
    gd, gi = fused_topk(q, keys, k, block_q=bq, block_k=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    xd, xi = fused_topk_xla(q, keys, k, block_k=bk)
    np.testing.assert_array_equal(np.asarray(xd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(wi))


def test_fused_topk_aligned_continuous_bitwise(rng):
    """The acceptance criterion proper: on tile-aligned shapes with
    continuous (normal) data, both fused branches reproduce the composed
    reference bit for bit. Fixed seed — the claim is deterministic for
    this program/data pair; the portable any-data guarantee is covered by
    the dyadic-grid sweep above."""
    nq, p, d, k, bq, bk = 16, 8, 8, 2, 16, 8
    q = jnp.asarray(rng.normal(size=(nq, d)), jnp.float32)
    keys = jnp.asarray(rng.normal(size=(p, d)), jnp.float32)
    wd, wi = composed_nearest(q, keys, k)
    gd, gi = fused_topk(q, keys, k, block_q=bq, block_k=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    xd, xi = fused_topk_xla(q, keys, k, block_k=bk)
    np.testing.assert_array_equal(np.asarray(xd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(wi))


def test_fused_topk_all_invalid_keys(rng):
    """Every key masked out: every slot must come back (inf, -1) — in the
    kernel this exercises the all-inf merge (argmin over inf rows)."""
    q = jnp.asarray(rng.normal(size=(9, 3)), jnp.float32)
    keys = jnp.asarray(rng.normal(size=(17, 3)), jnp.float32)
    valid = jnp.zeros((17,), bool)
    for got in (fused_topk(q, keys, 2, valid, block_q=8, block_k=8,
                           interpret=True),
                fused_topk_xla(q, keys, 2, valid, block_k=8)):
        gd, gi = got
        assert np.isinf(np.asarray(gd)).all()
        assert (np.asarray(gi) == -1).all()


def test_fused_topk_int8_dequant_matches_host_dequant(rng):
    """The in-tile int8 dequantization must equal running the f32 kernel
    on the host-dequantized buffer — same math, just fused.

    Keys are built so the quantization itself is exact: every feature has
    its extremes pinned at ±127·2⁻⁵, so ``quantize_keys`` recovers
    scale = 2⁻⁵ exactly and zero-point 0, and ``q8·scale + zero`` is a
    dyadic value whether or not XLA contracts it into an FMA. That keeps
    the bit-equality claim well-defined across compilations (see
    :func:`dyadic`)."""
    c = 2.0 ** -5
    kq = rng.integers(-127, 128, size=(19, 4))
    kq[0, :] = -127
    kq[1, :] = 127
    keys = jnp.asarray(kq * c, jnp.float32)
    q = dyadic(rng, (11, 4))
    valid = jnp.asarray([True, True] + list(rng.random(17) > 0.2))
    q8, scale, zero = quantize_keys(keys, valid)
    np.testing.assert_array_equal(np.asarray(scale), np.full(4, c))
    np.testing.assert_array_equal(np.asarray(zero), np.zeros(4))
    np.testing.assert_array_equal(np.asarray(q8), kq)
    deq = q8.astype(jnp.float32) * scale[None, :] + zero[None, :]
    wd, wi = composed_nearest(q, deq, 3, valid)
    gd, gi = fused_topk(q, q8, 3, valid, keys_scale=scale, keys_zero=zero,
                        block_q=8, block_k=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    xd, xi = fused_topk_xla(q, q8, 3, valid, keys_scale=scale,
                            keys_zero=zero, block_k=8)
    np.testing.assert_array_equal(np.asarray(xd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(wi))


@pytest.mark.parametrize("impl", ["fused_bf16", "fused_int8"])
def test_quantized_assign_zero_label_disagreement(rng, impl):
    """On well-separated data the quantized shortlist + exact-f32 rescore
    must reproduce the exact path's labels with ZERO disagreement."""
    from repro.core.index import ClusterIndex

    c, d = 6, 4
    centers = jnp.asarray(rng.normal(size=(c, d)) * 50.0, jnp.float32)
    protos = jnp.repeat(centers, 5, axis=0) + jnp.asarray(
        rng.normal(size=(c * 5, d)) * 0.05, jnp.float32)
    labels = jnp.repeat(jnp.arange(c, dtype=jnp.int32), 5)
    queries = jnp.asarray(
        np.asarray(centers)[rng.integers(0, c, size=64)]
        + rng.normal(size=(64, d)) * 0.05, jnp.float32)
    idx = ClusterIndex.build(ClusterIndex(
        protos=protos, proto_mass=jnp.ones((c * 5,)),
        proto_valid=jnp.ones((c * 5,), bool), proto_labels=labels,
        n_prototypes=jnp.asarray(c * 5, jnp.int32),
    )).check_servable()
    exact = idx.assign(queries, impl="ref")
    quant = idx.assign(queries, impl=impl)
    assert int((np.asarray(exact) != np.asarray(quant)).sum()) == 0


# pinned worst cases the random sweep might skip in a given run: d=1
# columns, k exactly at the valid count, and a mask denser than k
@pytest.mark.parametrize("n,d,k,bq,bk", [
    (33, 1, 32, 8, 16),   # k = n-1 at d=1, blocks don't divide n
    (17, 1, 17, 16, 8),   # k = n: every slot needs the full candidate set
    (9, 5, 8, 8, 8),      # n just over one tile
])
def test_knn_topk_pinned_edges(rng, n, d, k, bq, bk):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    gd, gi = knn_topk(x, k, block_q=bq, block_k=bk, interpret=True)
    wd, wi = ref.knn(x, k)
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(gi, wi)


def test_knn_topk_k_exceeds_valid_count(rng):
    """k near/above n_valid: the 4 invalid rows force (inf, -1) slots."""
    x = jnp.asarray(rng.normal(size=(12, 3)), jnp.float32)
    valid = jnp.asarray([True] * 8 + [False] * 4)
    gd, gi = knn_topk(x, 9, valid, block_q=8, block_k=8, interpret=True)
    wd, wi = ref.knn(x, 9, valid=valid)
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(gi, wi)
    assert np.isinf(np.asarray(gd)[:, -1]).all()  # only 7 valid others
    assert (np.asarray(gi)[:, -1] == -1).all()
