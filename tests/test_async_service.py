"""Deterministic scheduler-simulation tests for the async continuous-
batching serve front-end (DESIGN.md §15).

Everything here drives the *real* scheduler in
``repro.serve.async_service`` through the virtual-time harness in
``tests/serve_sim.py`` — no real sleeps, no wall clock, bit-reproducible
schedules. The hypothesis sweep (parity with direct ``ClusterIndex
.assign`` under arbitrary arrival sequences) degrades to a pinned trace
set when hypothesis is absent (requirements-dev.txt; CI installs it).
"""
import asyncio
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.core.index import ClusterIndex
from repro.serve import async_service
from repro.serve.async_service import (
    AsyncClusterService,
    QueueFullError,
    ServiceClosedError,
    UnknownTenantError,
)

from serve_sim import (
    BatchInvariantChecker,
    SimExecutor,
    SimLoop,
    adversarial_trace,
    bursty_trace,
    materialize,
    run_trace,
    trickle_trace,
)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    given = None


def _blobs(seed: int, n_per: int = 60, spread: float = 0.6,
           shift: float = 0.0) -> np.ndarray:
    """Three well-separated 2-D blobs; ``shift`` relocates the centres so
    indexes fit on different seeds/shifts label queries differently."""
    rng = np.random.default_rng(seed)
    centers = np.array([[0.0, 0.0], [6.0, 0.0], [3.0, 6.0]]) + shift
    x = np.concatenate([c + rng.normal(scale=spread, size=(n_per, 2))
                        for c in centers])
    return x.astype(np.float32)


_INDEX_CACHE = {}


def _index(seed: int = 0, shift: float = 0.0) -> ClusterIndex:
    key = (seed, shift)
    if key not in _INDEX_CACHE:
        _INDEX_CACHE[key] = ClusterIndex.build(
            jnp.asarray(_blobs(seed, shift=shift)), 2, 1, "kmeans", k=3,
            key=jax.random.PRNGKey(seed))
    return _INDEX_CACHE[key]


def _queries(seed: int):
    pool = _blobs(seed + 100, n_per=80)
    rng = np.random.default_rng(seed)

    def data_fn(n: int) -> np.ndarray:
        idx = rng.integers(0, pool.shape[0], size=n)
        return pool[idx]

    return data_fn


def _service(indexes, loop, *, service_time=1.0, fail_when=None, **kw):
    executor = SimExecutor(loop, service_time=service_time,
                           fail_when=fail_when)
    svc = AsyncClusterService(indexes, loop=loop, executor=executor, **kw)
    return svc, executor


def _assert_parity(records, index_map, default_tenant="default"):
    """Every non-rejected request completed with labels bit-identical to a
    direct ClusterIndex.assign on the same points — nothing dropped,
    duplicated, cross-tenant-routed, or perturbed by batch co-tenants."""
    for rec in records:
        assert rec.error is None, f"unexpected rejection: {rec.error}"
        assert rec.future is not None and rec.future.done(), (
            f"request at t={rec.t_arrival} never completed")
        got = rec.future.result()
        assert got.dtype == np.int32
        if rec.queries.shape[0] == 0:
            assert got.shape == (0,)
            continue
        index = index_map[rec.tenant or default_tenant]
        want = np.asarray(index.assign(jnp.asarray(rec.queries)))
        np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------------------
# batch-fill invariants across arrival shapes


def test_bursty_trace_fills_batches_and_holds_invariants():
    loop = SimLoop()
    checker = BatchInvariantChecker(buckets=(4, 16), max_wait=5.0)
    svc, _ = _service(_index(0), loop, buckets=(4, 16), max_wait=5.0,
                      max_inflight=99, queue_depth=10_000,
                      observer=checker)
    trace = bursty_trace(n_bursts=6, burst_size=5, sizes=(8, 8, 5, 7, 4),
                         gap=20.0)
    records = run_trace(svc, loop, materialize(trace, _queries(1)))
    checker.check()
    _assert_parity(records, {"default": _index(0)})
    # bursts of 32 points into a 16-capacity ladder: real coalescing
    # happened (fewer batches than requests) and FIFO packing fills the
    # bucket exactly (8+8, then 5+7+4)
    assert svc.stats["batches"] < svc.stats["requests"]
    assert any(r.total == 16 for r in checker.records)
    assert svc.stats["completed"] == len(records)


def test_trickle_trace_flushes_on_deadline_not_fill():
    loop = SimLoop()
    checker = BatchInvariantChecker(buckets=(8, 32), max_wait=4.0)
    svc, _ = _service(_index(0), loop, buckets=(8, 32), max_wait=4.0,
                      max_inflight=99, queue_depth=10_000,
                      observer=checker)
    trace = trickle_trace(n_requests=7, gap=10.0, size=3)
    records = run_trace(svc, loop, materialize(trace, _queries(2)))
    checker.check()
    _assert_parity(records, {"default": _index(0)})
    # gap > max_wait: every request rode its own deadline-flushed batch,
    # dispatched exactly max_wait after admission (virtual time is exact)
    assert len(checker.records) == 7
    for rec in checker.records:
        (_rid, _n, t_admit), = rec.segments
        assert rec.t_dispatch - t_admit == pytest.approx(4.0)


def test_full_bucket_dispatches_immediately_without_waiting():
    loop = SimLoop()
    checker = BatchInvariantChecker(buckets=(4, 16), max_wait=50.0)
    svc, _ = _service(_index(0), loop, buckets=(4, 16), max_wait=50.0,
                      max_inflight=99, queue_depth=10_000,
                      observer=checker)
    arrivals = materialize([(3.0, None, 16)], _queries(3))
    records = run_trace(svc, loop, arrivals)
    checker.check()
    _assert_parity(records, {"default": _index(0)})
    (rec,) = checker.records
    assert rec.t_dispatch == pytest.approx(3.0)  # no deadline wait
    assert rec.total == rec.bucket == 16


def test_adversarial_trace_multi_tenant_invariants_and_parity():
    loop = SimLoop()
    index_map = {"a": _index(0), "b": _index(7, shift=1.5)}
    checker = BatchInvariantChecker(buckets=(4, 16), max_wait=5.0,
                                    expect_versions={1})
    svc, _ = _service(index_map, loop, buckets=(4, 16), max_wait=5.0,
                      max_inflight=99, queue_depth=100_000,
                      observer=checker)
    rng = np.random.default_rng(42)
    trace = adversarial_trace(rng, n_requests=60, capacity=16, max_wait=5.0,
                              tenants=("a", "b"))
    records = run_trace(svc, loop, materialize(trace, _queries(4)))
    checker.check()
    _assert_parity(records, index_map)
    st_ = svc.stats
    assert st_["completed"] == len(records) == st_["requests"]
    assert st_["points"] == sum(r.queries.shape[0] for r in records)
    # the two tenants' indexes disagree somewhere (else cross-tenant
    # routing would be invisible to the parity check)
    q = jnp.asarray(_queries(5)(64))
    assert np.any(np.asarray(index_map["a"].assign(q))
                  != np.asarray(index_map["b"].assign(q)))


def test_oversized_request_splits_into_fifo_segments():
    loop = SimLoop()
    checker = BatchInvariantChecker(buckets=(4, 16), max_wait=5.0)
    svc, _ = _service(_index(0), loop, buckets=(4, 16), max_wait=5.0,
                      max_inflight=99, queue_depth=10_000,
                      observer=checker)
    records = run_trace(svc, loop, materialize([(0.0, None, 53)],
                                               _queries(6)))
    checker.check()
    _assert_parity(records, {"default": _index(0)})
    # 53 rows through capacity 16: 3 full immediate batches + a 5-row tail
    totals = [r.total for r in checker.records]
    assert totals == [16, 16, 16, 5]


# ----------------------------------------------------------------------
# property test: async path ≡ direct assign for any arrival sequence

_SIZES = (0, 1, 2, 3, 5, 8, 13, 16, 17, 31)
_LADDERS = ((4, 16), (8,), (4, 8, 32))

_PINNED_CASES = [
    # (ladder_idx, max_wait, max_inflight, service_time, arrivals)
    (0, 2.0, 2, 1.0, [(0, "a", 3), (0, "b", 5), (1, "a", 16), (1, "a", 0),
                      (3, "b", 17), (9, "a", 31), (9, "b", 1), (9, "a", 2)]),
    (1, 0.0, 1, 3.0, [(0, "a", 8), (0, "a", 8), (2, "b", 13), (2, "a", 1),
                      (4, "b", 31), (5, "a", 5)]),
    (2, 5.0, 99, 0.5, [(i % 7, ("a", "b")[i % 2], _SIZES[i % len(_SIZES)])
                       for i in range(24)]),
]


def _run_parity_case(ladder_idx, max_wait, max_inflight, service_time,
                     arrivals):
    loop = SimLoop()
    buckets = _LADDERS[ladder_idx]
    index_map = {"a": _index(0), "b": _index(7, shift=1.5)}
    svc, _ = _service(index_map, loop, buckets=buckets, max_wait=max_wait,
                      max_inflight=max_inflight, queue_depth=1_000_000,
                      service_time=service_time)
    data_fn = _queries(8)
    records = run_trace(
        svc, loop,
        [(float(t), tenant, data_fn(n)) for t, tenant, n in arrivals])
    _assert_parity(records, index_map)
    stats = svc.stats
    assert stats["requests"] == len(arrivals)
    assert stats["completed"] == len(arrivals)  # none dropped
    assert stats["points"] == sum(n for _, _, n in arrivals)  # none duped
    assert stats["rejected"] == stats["cancelled"] == stats["failed"] == 0


def test_pinned_parity_cases():
    for case in _PINNED_CASES:
        _run_parity_case(*case)


if given is not None:
    @settings(max_examples=20, deadline=None)
    @given(
        ladder_idx=st.integers(0, len(_LADDERS) - 1),
        max_wait=st.sampled_from([0.0, 2.0, 5.0]),
        max_inflight=st.sampled_from([1, 2, 99]),
        service_time=st.sampled_from([0.5, 1.0, 3.0]),
        arrivals=st.lists(
            st.tuples(st.integers(0, 40),
                      st.sampled_from(["a", "b"]),
                      st.sampled_from(_SIZES)),
            max_size=30),
    )
    def test_hypothesis_any_arrival_sequence_matches_direct_assign(
            ladder_idx, max_wait, max_inflight, service_time, arrivals):
        """For ANY arrival sequence and bucket config the async path is
        bit-identical to direct ClusterIndex.assign on the same points —
        no request dropped, duplicated, or cross-tenant-routed."""
        _run_parity_case(ladder_idx, max_wait, max_inflight, service_time,
                         sorted(arrivals))
else:  # pragma: no cover - CI installs hypothesis
    @pytest.mark.skip(reason="property sweep needs hypothesis "
                             "(pip install -r requirements-dev.txt); "
                             "pinned cases above ran instead")
    def test_hypothesis_any_arrival_sequence_matches_direct_assign():
        pass


# ----------------------------------------------------------------------
# backpressure / faults / lifecycle


def test_queue_full_rejection_is_loud_and_bounded():
    loop = SimLoop()
    svc, _ = _service(_index(0), loop, buckets=(16,), max_wait=5.0,
                      max_inflight=1, queue_depth=32, service_time=50.0)
    ok1 = svc.submit(_queries(9)(16))   # dispatches (fills the bucket)
    ok2 = svc.submit(_queries(9)(16))   # queued (inflight slot busy)
    ok3 = svc.submit(_queries(9)(16))   # queued: 32/32 points
    with pytest.raises(QueueFullError) as ei:
        svc.submit(_queries(9)(4))
    assert "admission queue full" in str(ei.value)
    assert "32/32" in str(ei.value)
    # an over-depth request is called out as never admittable
    with pytest.raises(QueueFullError) as ei2:
        svc.submit(_queries(9)(33))
    assert "can never be admitted" in str(ei2.value)
    assert svc.stats["rejected"] == 2
    loop.run()
    # rejection cost nothing: every admitted request still completed
    assert all(f.done() and f.result().shape == (16,)
               for f in (ok1, ok2, ok3))
    assert svc.stats["completed"] == 3
    # bounded concurrency held across the backlog
    assert svc.stats["batches"] == 3


def test_max_inflight_is_respected():
    loop = SimLoop()
    svc, executor = _service(_index(0), loop, buckets=(8,), max_wait=0.0,
                             max_inflight=2, queue_depth=10_000,
                             service_time=10.0)
    for _ in range(6):
        svc.submit(_queries(10)(8))
    loop.run()
    assert executor.max_inflight_seen == 2
    assert svc.stats["completed"] == 6


def test_cancellation_of_queued_and_inflight_requests():
    loop = SimLoop()
    checker = BatchInvariantChecker(buckets=(16,), max_wait=5.0,
                                    check_wait=False)
    svc, _ = _service(_index(0), loop, buckets=(16,), max_wait=5.0,
                      max_inflight=1, queue_depth=10_000, service_time=10.0,
                      observer=checker)
    data = _queries(11)
    f_inflight = svc.submit(data(16))  # dispatches immediately
    f_queued = svc.submit(data(16))    # waits for the inflight slot
    f_kept = svc.submit(data(16))
    assert f_inflight.cancel()  # already on device: result discarded
    assert f_queued.cancel()    # still queued: never dispatched
    loop.run()
    assert f_inflight.cancelled() and f_queued.cancelled()
    assert f_kept.done() and f_kept.result().shape == (16,)
    assert svc.stats["cancelled"] == 2
    assert svc.stats["completed"] == 1
    # the queued-cancelled request never reached a batch
    dispatched_rids = [rid for rec in checker.records
                       for rid, _, _ in rec.segments]
    assert sorted(dispatched_rids) == [0, 2]


def test_batch_execution_fault_fails_only_its_requests():
    loop = SimLoop()
    svc, _ = _service(_index(0), loop, buckets=(16,), max_wait=0.0,
                      max_inflight=99, queue_depth=10_000,
                      fail_when=lambda ordinal: ordinal == 0)
    data = _queries(12)
    f_bad = svc.submit(data(16))
    f_good = svc.submit(data(16))
    loop.run()
    with pytest.raises(RuntimeError, match="injected batch fault"):
        f_bad.result()
    assert f_good.done() and f_good.exception() is None
    assert svc.stats["failed"] == 1 and svc.stats["completed"] == 1


def test_drain_completes_all_admitted_work_then_closes():
    loop = SimLoop()
    checker = BatchInvariantChecker(buckets=(4, 16), max_wait=100.0,
                                    check_wait=False)
    svc, executor = _service(_index(0), loop, buckets=(4, 16),
                             max_wait=100.0, max_inflight=1,
                             queue_depth=10_000, service_time=2.0,
                             observer=checker)
    data = _queries(13)
    futures = [svc.submit(data(n)) for n in (16, 7, 3, 16, 2)]
    loop.run(until=1.0)  # first batch in flight, the rest queued/waiting
    drain = svc.drain()
    with pytest.raises(ServiceClosedError):
        svc.submit(data(1))
    with pytest.raises(ServiceClosedError):
        svc.install_index("default", _index(0))
    loop.run()
    assert drain.done()
    final = drain.result()
    assert final["completed"] == len(futures)
    assert all(f.done() and f.exception() is None for f in futures)
    # the 100-virtual-ms deadline never fired: drain flushed the partial
    # batches immediately (total virtual time ≈ batches * service_time)
    assert loop.now() < 100.0
    checker.check()
    assert svc.closed
    # drain is idempotent: same future back
    assert svc.drain() is drain


def test_unknown_tenant_and_empty_request():
    loop = SimLoop()
    svc, _ = _service(_index(0), loop, buckets=(8,), max_wait=1.0)
    with pytest.raises(UnknownTenantError, match="unknown tenant 'nope'"):
        svc.submit(_queries(14)(4), tenant="nope")
    f = svc.submit(np.zeros((0, 2), np.float32))
    assert f.done() and f.result().shape == (0,)
    assert f.result().dtype == np.int32
    assert svc.stats["points"] == 0 and svc.stats["completed"] == 1


# ----------------------------------------------------------------------
# hot-swapped index versions


def test_hot_swap_is_atomic_and_pins_admitted_requests():
    loop = SimLoop()
    checker = BatchInvariantChecker(buckets=(16,), max_wait=50.0,
                                    check_wait=False,
                                    expect_versions={1, 2})
    v1, v2 = _index(0), _index(7, shift=1.5)
    svc, _ = _service(v1, loop, buckets=(16,), max_wait=50.0,
                      max_inflight=99, queue_depth=10_000, service_time=5.0,
                      observer=checker)
    data = _queries(15)
    q_old, q_new = data(7), data(7)
    f_old = svc.submit(q_old)       # pinned to v1, waiting to fill
    assert svc.version() == 1
    assert svc.install_index("default", v2) == 2
    f_new = svc.submit(q_new)       # admitted post-swap: pinned to v2
    loop.run()
    # the pre-swap request was NOT retargeted (served by v1), the post-swap
    # one by v2, and no batch mixed versions
    np.testing.assert_array_equal(f_old.result(),
                                  np.asarray(v1.assign(jnp.asarray(q_old))))
    np.testing.assert_array_equal(f_new.result(),
                                  np.asarray(v2.assign(jnp.asarray(q_new))))
    checker.check()
    versions = [rec.version for rec in checker.records]
    assert versions == [1, 2]
    # the superseded v1 batch flushed at the swap, not at its 50ms deadline
    assert checker.records[0].t_dispatch < 50.0
    assert svc.stats["swaps"] == 1
    assert svc.tenant_stats()["default"]["version"] == 2


def test_half_installed_artifact_is_never_served():
    loop = SimLoop()
    svc, _ = _service(_index(0), loop, buckets=(8,), max_wait=1.0)
    good = _index(0)
    torn = ClusterIndex(
        protos=good.protos,
        proto_mass=good.proto_mass[:3],  # torn artifact: wrong length
        proto_valid=good.proto_valid,
        proto_labels=good.proto_labels,
        n_prototypes=good.n_prototypes,
    )
    with pytest.raises(ValueError, match="proto_mass"):
        svc.install_index("default", torn)
    # a dim-changing swap is rejected too (live traffic would crash)
    wide = ClusterIndex.build(
        jnp.asarray(np.random.default_rng(0)
                    .normal(size=(60, 3)).astype(np.float32)),
        2, 1, "kmeans", k=2, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="feature dimension"):
        svc.install_index("default", wide)
    # both failed installs left version 1 serving, untouched
    assert svc.version() == 1
    q = _queries(16)(4)
    f = svc.submit(q)
    loop.run()
    assert f.done() and f.exception() is None
    np.testing.assert_array_equal(
        f.result(), np.asarray(_index(0).assign(jnp.asarray(q))))


# ----------------------------------------------------------------------
# real-asyncio adapter (correctness only — no timing assertions)


def test_asyncio_adapter_end_to_end():
    """The default (asyncio) bindings run the identical scheduler: submit
    under asyncio.run, await results, drain. Correctness-only — timing
    claims live in the simulated tests above."""
    index = _index(0)
    svc = AsyncClusterService(index, buckets=(4, 16), max_wait=0.001,
                              max_inflight=2, queue_depth=10_000)
    data = _queries(17)
    batches = [data(n) for n in (3, 16, 7, 0, 17)]

    async def main():
        futs = [svc.submit(q) for q in batches]
        results = await asyncio.gather(*futs)
        final = await svc.drain()
        return results, final

    results, final = asyncio.run(main())
    for q, got in zip(batches, results, strict=True):
        want = np.asarray(index.assign(jnp.asarray(q)))
        np.testing.assert_array_equal(got, want)
    assert final["completed"] == len(batches)
    with pytest.raises(ServiceClosedError):
        svc.submit(batches[0])


def test_scheduler_has_no_wall_clock_dependence():
    """The determinism contract, enforced structurally: the scheduler
    module never reaches for a wall clock or a real sleep — all timing
    goes through the injected loop seams."""
    src = inspect.getsource(async_service)
    for forbidden in ("time.sleep", "time.time", "perf_counter",
                      "monotonic", "sleep("):
        assert forbidden not in src, f"scheduler uses {forbidden}"


def test_runtime_config_defaults_flow_into_service():
    loop = SimLoop()
    with runtime.configure(serve_queue_depth=77, serve_max_inflight=3,
                           serve_max_wait_ms=250.0,
                           serve_default_tenant="main"):
        svc = AsyncClusterService(_index(0), loop=loop,
                                  executor=SimExecutor(loop),
                                  buckets=(8,), warmup=False)
        assert svc.queue_depth == 77
        assert svc.max_inflight == 3
        assert svc.max_wait == pytest.approx(0.25)  # ms knob → loop seconds
        assert svc.tenants == ("main",)
        f = svc.submit(_queries(18)(4))  # default tenant routing
        loop.run()
        assert f.done()
