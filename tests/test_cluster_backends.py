"""Backend clusterers vs brute-force references on small instances."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.dbscan import dbscan
from repro.cluster.hac import hac
from repro.cluster.kmeans import kmeans
from repro.cluster.metrics import bss_tss, clustering_accuracy


def three_blobs(rng, n=90, spread=0.3):
    centers = np.array([[0, 0], [6, 0], [3, 6]], float)
    comp = np.repeat(np.arange(3), n // 3)
    x = centers[comp] + rng.normal(scale=spread, size=(n, 2))
    return x.astype(np.float32), comp


def test_kmeans_recovers_blobs(rng):
    x, true = three_blobs(rng)
    r = kmeans(jnp.asarray(x), 3, key=jax.random.PRNGKey(0))
    acc = clustering_accuracy(true, np.asarray(r.labels), 3)
    assert acc == 1.0
    assert float(r.inertia) < 90 * 0.3**2 * 2 * 3


def test_kmeans_weighted_pulls_centers(rng):
    """A giant-mass point must dominate its cluster centroid."""
    x = jnp.asarray([[0.0, 0.0], [1.0, 0.0], [10.0, 0.0], [11.0, 0.0]])
    w = jnp.asarray([100.0, 1.0, 1.0, 1.0])
    r = kmeans(x, 2, weights=w, key=jax.random.PRNGKey(1))
    c = np.asarray(r.centers)
    left = c[np.argmin(c[:, 0])]
    assert abs(left[0] - (0 * 100 + 1) / 101) < 1e-3


def test_kmeans_masked(rng):
    x, true = three_blobs(rng)
    pad = np.zeros((10, 2), np.float32) + 99.0
    xp = jnp.asarray(np.vstack([x, pad]))
    valid = jnp.asarray([True] * 90 + [False] * 10)
    r = kmeans(xp, 3, valid=valid, key=jax.random.PRNGKey(0))
    lab = np.asarray(r.labels)
    assert np.all(lab[90:] == -1)
    assert clustering_accuracy(true, lab[:90], 3) == 1.0


@pytest.mark.parametrize("linkage", ["single", "complete", "average", "ward"])
def test_hac_recovers_blobs(rng, linkage):
    x, true = three_blobs(rng, n=45)
    r = hac(jnp.asarray(x), 3, linkage=linkage)
    acc = clustering_accuracy(true, np.asarray(r.labels), 3)
    assert acc == 1.0, (linkage, acc)


def test_hac_single_linkage_exact(rng):
    """Single linkage = MST clustering; verify against brute force."""
    x = rng.normal(size=(12, 2)).astype(np.float32)
    r = hac(jnp.asarray(x), 3, linkage="single")
    # brute force agglomeration
    d = np.sqrt(((x[:, None] - x[None]) ** 2).sum(-1))
    clusters = [{i} for i in range(12)]
    while len(clusters) > 3:
        best, bi, bj = np.inf, -1, -1
        for i, j in itertools.combinations(range(len(clusters)), 2):
            dd = min(d[a, b] for a in clusters[i] for b in clusters[j])
            if dd < best:
                best, bi, bj = dd, i, j
        clusters[bi] |= clusters[bj]
        del clusters[bj]
    want = np.zeros(12, int)
    for c, mem in enumerate(clusters):
        for i in mem:
            want[i] = c
    acc = clustering_accuracy(want, np.asarray(r.labels), 3)
    assert acc == 1.0


def test_dbscan_blobs_and_noise(rng):
    x, true = three_blobs(rng, n=90, spread=0.2)
    noise = rng.uniform(-3, 9, size=(5, 2)).astype(np.float32)
    xall = jnp.asarray(np.vstack([x, noise]))
    r = dbscan(xall, eps=0.6, min_pts=4.0)
    lab = np.asarray(r.labels)
    assert clustering_accuracy(true, lab[:90], 3) > 0.95
    # most of the uniform noise should be labelled -1
    assert (lab[90:] == -1).sum() >= 3


def test_dbscan_mass_weighted_density(rng):
    """A prototype with mass 10 should count as 10 points for core-ness."""
    x = jnp.asarray([[0.0, 0.0], [0.3, 0.0]])
    w = jnp.asarray([10.0, 1.0])
    r = dbscan(x, eps=0.5, min_pts=5.0, weights=w)
    assert bool(r.is_core[0]) and bool(r.is_core[1])
    r2 = dbscan(x, eps=0.5, min_pts=5.0)  # unweighted: only 2 pts in eps
    assert not bool(r2.is_core[0])


def test_bss_tss_range(rng):
    x, true = three_blobs(rng)
    ratio = float(bss_tss(jnp.asarray(x), jnp.asarray(true), 3))
    assert 0.9 < ratio <= 1.0


def test_bss_tss_degenerate_data_is_finite():
    """Regression: constant or single-point data has tss == 0 — the ratio
    must clamp to 0.0 like the other guarded divisions, not return NaN."""
    const = jnp.ones((10, 3), jnp.float32)
    labels = jnp.zeros((10,), jnp.int32)
    assert float(bss_tss(const, labels, 1)) == 0.0
    single = jnp.asarray([[1.0, 2.0]], jnp.float32)
    assert float(bss_tss(single, jnp.zeros((1,), jnp.int32), 1)) == 0.0
    # all rows masked out (-1): still finite
    masked = float(bss_tss(const, jnp.full((10,), -1, jnp.int32), 2))
    assert masked == masked  # not NaN
