import os
import sys

# tests must see ONE device (the dry-run alone requests 512)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def gmm_sample(n: int, rng: np.random.Generator):
    """The paper's §4 mixture: 3 bivariate gaussians, weights .5/.3/.2."""
    mus = np.array([[1, 2], [7, 8], [3, 5]], float)
    sds = np.array([[1, 0.5], [2, 1], [3, 4]], float) ** 0.5
    comp = rng.choice(3, size=n, p=[0.5, 0.3, 0.2])
    x = mus[comp] + rng.normal(size=(n, 2)) * sds[comp]
    return x.astype(np.float32), comp
