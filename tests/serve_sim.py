"""Simulated-time harness for the async serve scheduler (DESIGN.md §15).

The scheduler in ``repro.serve.async_service`` only touches time through
three injected seams (``loop.now`` / ``loop.call_later`` /
``loop.create_future``) plus an ``executor.submit``. This module provides
the virtual-time bindings: a deterministic event loop (:class:`SimLoop`)
whose clock advances exactly to the next scheduled callback, futures with
asyncio semantics but synchronous callbacks (:class:`SimFuture`), and an
executor that completes batches after a configurable *virtual* service
time (:class:`SimExecutor`). Driving the real scheduler through them runs
hours of traffic in milliseconds of wall time with **zero real sleeps** —
the tier-1 determinism contract — while the identical scheduler code runs
under real asyncio in production and in ``benchmarks/bench_serve_async.py``.

Also here: arrival-trace generators (bursty / trickle / adversarial), the
``run_trace`` driver, and :class:`BatchInvariantChecker`, an observer that
proves the batch-fill invariants (bounded wait, bounded batch, FIFO within
tenant, one index version per batch) over any recorded schedule.
"""
from __future__ import annotations

import asyncio
import dataclasses
import heapq
import itertools
from typing import Callable, List, Optional, Tuple

import numpy as np


class SimFuture:
    """Future with asyncio's state machine, minus the event loop: done
    callbacks run synchronously at completion (the sim loop is single-
    threaded and re-entrancy is prevented by the scheduler's own
    call_later(0) completion hop, so eager callbacks keep event order
    deterministic)."""

    _PENDING, _DONE, _CANCELLED = "pending", "done", "cancelled"

    def __init__(self):
        self._state = self._PENDING
        self._result = None
        self._exception = None
        self._callbacks: List[Callable] = []

    def done(self) -> bool:
        return self._state != self._PENDING

    def cancelled(self) -> bool:
        return self._state == self._CANCELLED

    def cancel(self) -> bool:
        if self.done():
            return False
        self._state = self._CANCELLED
        self._run_callbacks()
        return True

    def set_result(self, result) -> None:
        if self.done():
            raise RuntimeError(f"future already {self._state}")
        self._result = result
        self._state = self._DONE
        self._run_callbacks()

    def set_exception(self, exc: BaseException) -> None:
        if self.done():
            raise RuntimeError(f"future already {self._state}")
        self._exception = exc
        self._state = self._DONE
        self._run_callbacks()

    def result(self):
        if self._state == self._CANCELLED:
            raise asyncio.CancelledError()
        if self._state == self._PENDING:
            raise RuntimeError("result not ready (sim loop not drained?)")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self) -> Optional[BaseException]:
        if self._state == self._CANCELLED:
            raise asyncio.CancelledError()
        if self._state == self._PENDING:
            raise RuntimeError("result not ready (sim loop not drained?)")
        return self._exception

    def add_done_callback(self, fn: Callable) -> None:
        if self.done():
            fn(self)
        else:
            self._callbacks.append(fn)

    def _run_callbacks(self) -> None:
        callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            fn(self)


class SimHandle:
    """What ``call_later`` returns: a cancellable timer handle."""

    __slots__ = ("when", "callback", "cancelled")

    def __init__(self, when: float, callback: Callable[[], None]):
        self.when = when
        self.callback = callback
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True


class SimLoop:
    """Deterministic virtual-time event loop.

    Time is whatever unit the test says it is (the suite uses "virtual
    ms"). ``call_later`` pushes onto a (time, seq) heap; :meth:`run`
    pops in order, advancing :meth:`now` exactly to each callback's
    scheduled instant — identical inputs replay identical schedules,
    and nothing ever touches the wall clock.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._seq = itertools.count()
        self._heap: List[Tuple[float, int, SimHandle]] = []

    def now(self) -> float:
        return self._now

    def call_later(self, delay: float, callback: Callable[[], None]
                   ) -> SimHandle:
        handle = SimHandle(self._now + max(0.0, float(delay)), callback)
        heapq.heappush(self._heap, (handle.when, next(self._seq), handle))
        return handle

    def create_future(self) -> SimFuture:
        return SimFuture()

    def pending(self) -> int:
        """Live (uncancelled) scheduled callbacks."""
        return sum(1 for _, _, h in self._heap if not h.cancelled)

    def run(self, until: Optional[float] = None,
            max_events: int = 1_000_000) -> int:
        """Run every callback scheduled at time <= ``until`` (all of them
        when ``until`` is None); returns the number executed. Afterwards
        ``now`` is the last callback's instant — or exactly ``until``
        when one was given, so tests can advance the clock into a known
        quiet gap."""
        executed = 0
        while self._heap:
            when, _, handle = self._heap[0]
            if until is not None and when > until:
                break
            heapq.heappop(self._heap)
            if handle.cancelled:
                continue
            self._now = when
            handle.callback()
            executed += 1
            if executed > max_events:
                raise RuntimeError(
                    f"sim loop still busy after {max_events} events — "
                    f"scheduler livelock?")
        if until is not None and until > self._now:
            self._now = float(until)
        return executed


class SimExecutor:
    """Virtual-time batch executor: ``fn`` runs (and ``on_done`` fires)
    ``service_time`` after submit, so batches are genuinely *in flight*
    across virtual time — cancellation, max-inflight saturation and
    drain-while-busy all become schedulable scenarios. ``fail_when``
    (predicate over the 0-based batch ordinal) injects execution faults.
    """

    def __init__(self, loop: SimLoop, service_time: float = 1.0,
                 fail_when: Optional[Callable[[int], bool]] = None):
        self.loop = loop
        self.service_time = service_time
        self.fail_when = fail_when
        self.submitted = 0
        self.inflight = 0
        self.max_inflight_seen = 0

    def submit(self, fn, on_done) -> None:
        ordinal = self.submitted
        self.submitted += 1
        self.inflight += 1
        self.max_inflight_seen = max(self.max_inflight_seen, self.inflight)

        def complete():
            self.inflight -= 1
            if self.fail_when is not None and self.fail_when(ordinal):
                on_done(None, RuntimeError(f"injected batch fault "
                                           f"(ordinal {ordinal})"))
                return
            try:
                result, exc = fn(), None
            except Exception as e:
                result, exc = None, e
            on_done(result, exc)

        self.loop.call_later(self.service_time, complete)


# ----------------------------------------------------------------------
# arrival traces


@dataclasses.dataclass
class RequestRecord:
    """One arrival as the load driver saw it: what was sent, what came
    back, and when (virtual clock)."""

    t_arrival: float
    tenant: Optional[str]
    queries: np.ndarray
    future: object = None
    error: Optional[BaseException] = None
    t_done: Optional[float] = None


def run_trace(service, loop: SimLoop, arrivals,
              run: bool = True) -> List[RequestRecord]:
    """Submit ``arrivals`` — an iterable of ``(t, tenant, queries)`` in
    virtual time — through ``service`` and (by default) run the loop dry.
    Admission rejections land in ``record.error``; completions stamp
    ``record.t_done`` with the virtual instant the labels materialized."""
    records = []
    for t, tenant, queries in arrivals:
        record = RequestRecord(t_arrival=float(t), tenant=tenant,
                               queries=np.asarray(queries))

        def fire(record=record):
            try:
                fut = service.submit(record.queries, tenant=record.tenant)
            except Exception as e:
                record.error = e
                return
            record.future = fut
            fut.add_done_callback(
                lambda _f, record=record: setattr(record, "t_done",
                                                  loop.now()))

        loop.call_later(record.t_arrival - loop.now(), fire)
        records.append(record)
    if run:
        loop.run()
    return records


def trickle_trace(n_requests: int, gap: float, size: int,
                  tenant: Optional[str] = None, start: float = 0.0):
    """One lonely request per ``gap`` — with gap > max_wait every batch is
    a deadline flush, never a fill."""
    return [(start + i * gap, tenant, size) for i in range(n_requests)]


def bursty_trace(n_bursts: int, burst_size: int, sizes, gap: float,
                 tenant: Optional[str] = None, start: float = 0.0):
    """``n_bursts`` instantaneous bursts of ``burst_size`` arrivals (sizes
    cycled from ``sizes``), ``gap`` apart — exercises batch fill + the
    FIFO packing path."""
    sizes = list(sizes)
    return [(start + b * gap, tenant, sizes[(b * burst_size + i)
                                            % len(sizes)])
            for b in range(n_bursts) for i in range(burst_size)]


def adversarial_trace(rng: np.random.Generator, n_requests: int,
                      capacity: int, max_wait: float, tenants,
                      t_span: float = 50.0):
    """Randomized nastiness: sizes that never pack evenly (primes, exact
    capacity, capacity+1 so requests split into segments, zeros), arrival
    times clustered right around deadline multiples, tenants interleaved."""
    tenants = list(tenants)
    sizes = [1, 2, 3, 5, 7, 11, 13, capacity - 1, capacity, capacity + 1,
             2 * capacity + 3, 0]
    out = []
    for _ in range(n_requests):
        base = float(rng.uniform(0.0, t_span))
        # half the arrivals land a hair before/after a deadline boundary
        if rng.random() < 0.5 and max_wait > 0:
            k = max(1.0, base // max_wait)
            base = k * max_wait + float(rng.uniform(-1e-3, 1e-3))
        out.append((base, tenants[int(rng.integers(len(tenants)))],
                    int(sizes[int(rng.integers(len(sizes)))])))
    out.sort(key=lambda a: a[0])
    return out


def materialize(trace, data_fn):
    """Turn ``(t, tenant, n)`` size traces into ``(t, tenant, queries)``
    arrivals via ``data_fn(n) -> (n, d) array``."""
    return [(t, tenant, data_fn(n)) for t, tenant, n in trace]


# ----------------------------------------------------------------------
# invariants


class BatchInvariantChecker:
    """Observer proving the scheduler's batch-fill invariants over a run.

    Install as ``AsyncClusterService(..., observer=checker)``; call
    :meth:`check` after the loop runs dry. Asserts, per recorded batch:

      * bounded batch — total rows <= capacity and the padded bucket is a
        ladder member >= total;
      * bounded wait — no segment dispatched later than ``max_wait``
        after its request's admission (only sound when the run never
        saturated ``max_inflight``; pass ``check_wait=False`` for
        saturation scenarios, where eligibility — not dispatch — is
        bounded);
      * FIFO within tenant — request ids never go backwards across a
        tenant's dispatch sequence;
      * version purity — every batch serves exactly one installed index
        version (enforced structurally by BatchRecord, asserted against
        ``expect_versions`` when given).
    """

    def __init__(self, buckets, max_wait: float, *, check_wait: bool = True,
                 expect_versions=None):
        self.buckets = tuple(sorted(buckets))
        self.capacity = self.buckets[-1]
        self.max_wait = max_wait
        self.check_wait = check_wait
        self.expect_versions = expect_versions
        self.records = []

    def __call__(self, record) -> None:
        self.records.append(record)

    def check(self) -> None:
        last_rid = {}
        for rec in self.records:
            assert rec.total <= self.capacity, (
                f"batch of {rec.total} rows exceeds capacity "
                f"{self.capacity}: {rec}")
            assert rec.bucket in self.buckets and rec.bucket >= rec.total, (
                f"batch padded to non-ladder bucket: {rec}")
            assert rec.total == sum(n for _, n, _ in rec.segments)
            if self.check_wait:
                for rid, _n, t_admit in rec.segments:
                    waited = rec.t_dispatch - t_admit
                    assert waited <= self.max_wait + 1e-9, (
                        f"request {rid} waited {waited} > max_wait "
                        f"{self.max_wait} (virtual) before dispatch: {rec}")
            for rid, _n, _t in rec.segments:
                assert rid >= last_rid.get(rec.tenant, -1), (
                    f"FIFO violated for tenant {rec.tenant!r}: request "
                    f"{rid} dispatched after {last_rid[rec.tenant]}")
                last_rid[rec.tenant] = rid
            if self.expect_versions is not None:
                assert rec.version in self.expect_versions, (
                    f"batch served unexpected version: {rec}")
