"""Runtime-config dispatch, the backend registry, and the fitted
ClusterIndex / ClusterService online-assignment path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import gmm_sample
from repro import runtime
from repro.cluster.registry import (
    available_backends,
    register_backend,
    resolve_backend,
    validate_backend_fn,
)
from repro.core import ClusterIndex, ihtc, threshold_clustering
from repro.serve import ClusterService


# ---------------------------------------------------------------- runtime


def test_configure_scopes_nest_and_unwind():
    base = runtime.active()
    assert base.impl == "auto"
    with runtime.configure(impl="ref", knn_block=64) as cfg:
        assert cfg is runtime.active()
        assert runtime.active().impl == "ref"
        assert runtime.active().knn_block == 64
        with runtime.configure(n_blocks=4):
            inner = runtime.active()
            assert (inner.impl, inner.knn_block, inner.n_blocks) == ("ref", 64, 4)
        assert runtime.active().n_blocks == base.n_blocks
    assert runtime.active() == base


def test_configure_unwinds_on_exception():
    before = runtime.active()
    with pytest.raises(RuntimeError):
        with runtime.configure(impl="ref"):
            raise RuntimeError("boom")
    assert runtime.active() == before


def test_config_validation():
    with pytest.raises(ValueError):
        runtime.RuntimeConfig(impl="cuda")
    with pytest.raises(ValueError):
        runtime.RuntimeConfig(n_blocks=0)
    with pytest.raises(ValueError):
        runtime.RuntimeConfig(knn_block=-1)
    with pytest.raises(ValueError):
        runtime.RuntimeConfig(precision="float64")


def test_config_from_env():
    cfg = runtime.config_from_env(
        {"REPRO_IMPL": "ref", "REPRO_KNN_BLOCK": "4096",
         "REPRO_INTERPRET": "true", "REPRO_N_BLOCKS": "16"})
    assert cfg.impl == "ref"
    assert cfg.knn_block == 4096
    assert cfg.interpret is True
    assert cfg.n_blocks == 16
    # unknown/empty vars leave defaults untouched
    cfg2 = runtime.config_from_env({"REPRO_IMPL": "", "OTHER": "x"})
    assert cfg2 == runtime.RuntimeConfig()


def test_set_default_roundtrip():
    prev = runtime.set_default(runtime.RuntimeConfig(impl="ref"))
    try:
        assert runtime.active().impl == "ref"
        # scoped overrides stack on the new default
        with runtime.configure(knn_block=32):
            assert runtime.active().impl == "ref"
    finally:
        runtime.set_default(prev)
    assert runtime.active() == prev


def test_config_driven_dispatch_matches_explicit_kwargs(rng):
    """De-threading contract: resolving impl/knn_block via the config is
    the same computation as passing them explicitly (no behavior drift)."""
    x, _ = gmm_sample(600, rng)
    xj = jnp.asarray(x)
    explicit = ihtc(xj, 2, 2, "kmeans", k=3, key=jax.random.PRNGKey(5),
                    impl="ref", knn_block=128)
    with runtime.configure(impl="ref", knn_block=128):
        configured = ihtc(xj, 2, 2, "kmeans", k=3, key=jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(explicit.labels),
                                  np.asarray(configured.labels))
    np.testing.assert_array_equal(
        np.asarray(explicit.protos).view(np.uint32),
        np.asarray(configured.protos).view(np.uint32))


def test_config_change_retraces_inner_jit(rng):
    """Trace-time config reads (Pallas tile sizes, interpret) are pinned
    into the jit cache key via dispatch_key(): changing them between
    identical-shape calls must retrace, not reuse a stale entry."""
    from repro.core.tc import _threshold_clustering

    x, _ = gmm_sample(64, rng)
    xj = jnp.asarray(x)
    threshold_clustering(xj, 2, impl="ref")
    before = _threshold_clustering._cache_size()
    with runtime.configure(block_k=64):  # read only while tracing knn_topk
        threshold_clustering(xj, 2, impl="ref")
    assert _threshold_clustering._cache_size() == before + 1
    with runtime.configure(block_k=64):  # same key again: cached now
        threshold_clustering(xj, 2, impl="ref")
    assert _threshold_clustering._cache_size() == before + 1


def test_explicit_kwarg_overrides_config(rng):
    """An explicit kwarg must win over the active config."""
    x, _ = gmm_sample(200, rng)
    xj = jnp.asarray(x)
    want = threshold_clustering(xj, 3, impl="ref", knn_block=64)
    with runtime.configure(knn_block=9999):  # would be one-shot if used
        got = threshold_clustering(xj, 3, impl="ref", knn_block=64)
    np.testing.assert_array_equal(np.asarray(want.labels),
                                  np.asarray(got.labels))


# ---------------------------------------------------------------- registry


def test_builtin_backends_registered():
    assert {"kmeans", "hac", "dbscan"} <= set(available_backends())
    fn = resolve_backend("kmeans")
    assert callable(fn)


def test_resolve_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("spectral")


def test_validate_rejects_bad_signature():
    def missing_kwargs(x, *, valid=None):
        return x

    with pytest.raises(TypeError, match="weights"):
        validate_backend_fn(missing_kwargs)

    def no_positional(*, valid=None, weights=None, key=None, impl=None):
        return None

    with pytest.raises(TypeError, match="positional"):
        validate_backend_fn(no_positional)


def test_register_and_use_custom_backend(rng):
    @register_backend("_test_constant")
    def constant_backend(x, *, valid=None, weights=None, key=None,
                         impl=None, **_):
        del weights, key, impl
        v = jnp.ones((x.shape[0],), bool) if valid is None else valid
        return jnp.where(v, 0, -1).astype(jnp.int32)

    try:
        assert "_test_constant" in available_backends()
        with pytest.raises(ValueError, match="already registered"):
            register_backend("_test_constant")(lambda x, **kw: x)
        x, _ = gmm_sample(120, rng)
        res = ihtc(jnp.asarray(x), 2, 1, "_test_constant")
        lab = np.asarray(res.labels)
        assert (lab == 0).all()  # every unit backs out to the single cluster
    finally:
        from repro.cluster import registry

        registry._REGISTRY.pop("_test_constant", None)


# ------------------------------------------------------- ClusterIndex/serve


def _blobs(rng, n_per=100, spread=0.3):
    centers = np.array([[0, 0], [6, 0], [3, 6]], float)
    comp = np.repeat(np.arange(3), n_per)
    x = centers[comp] + rng.normal(scale=spread, size=(3 * n_per, 2))
    return jnp.asarray(x, jnp.float32), comp


def test_assign_reproduces_training_labels_exactly(rng):
    """Acceptance contract: nearest-valid-prototype assignment on the
    training points reproduces the fitted ihtc() labels for all valid rows
    (well-separated blobs: every point is nearer its own cluster's
    prototypes than any other cluster's)."""
    x, _ = _blobs(rng)
    res = ihtc(x, 2, 2, "kmeans", k=3, key=jax.random.PRNGKey(0))
    index = ClusterIndex.build(res)
    got = np.asarray(index.assign(x))
    np.testing.assert_array_equal(got, np.asarray(res.labels))


def test_assign_m0_is_exact_identity(rng):
    """m=0: the prototypes are the points themselves — assign must return
    each training point's own label (distance-0 self match)."""
    x, _ = gmm_sample(150, rng)
    xj = jnp.asarray(x)
    res = ihtc(xj, 2, 0, "kmeans", k=3, key=jax.random.PRNGKey(1))
    index = ClusterIndex.build(res)
    np.testing.assert_array_equal(np.asarray(index.assign(xj)),
                                  np.asarray(res.labels))


def test_assign_blocked_matches_one_shot(rng):
    x, _ = _blobs(rng)
    index = ClusterIndex.build(x, 2, 1, "kmeans", k=3,
                             key=jax.random.PRNGKey(2))
    q = jnp.asarray(rng.normal(size=(64, 2)), jnp.float32) * 3.0
    np.testing.assert_array_equal(np.asarray(index.assign(q)),
                                  np.asarray(index.assign(q, block=17)))


def test_assign_labels_new_queries_by_blob(rng):
    x, _ = _blobs(rng)
    res = ihtc(x, 2, 2, "kmeans", k=3, key=jax.random.PRNGKey(0))
    index = ClusterIndex.build(res)
    # fresh draws right on the blob centres must get the blobs' labels
    train = np.asarray(res.labels)
    blob_label = [np.bincount(train[i * 100:(i + 1) * 100]).argmax()
                  for i in range(3)]
    q = jnp.asarray([[0.0, 0.0], [6.0, 0.0], [3.0, 6.0]], jnp.float32)
    np.testing.assert_array_equal(np.asarray(index.assign(q)), blob_label)


def test_assign_respects_runtime_impl(rng):
    x, _ = _blobs(rng)
    index = ClusterIndex.build(x, 2, 1, "kmeans", k=3,
                             key=jax.random.PRNGKey(3))
    q = x[: 50]
    want = np.asarray(index.assign(q, impl="ref"))
    with runtime.configure(impl="pallas", interpret=True):
        got = np.asarray(index.assign(q))
    np.testing.assert_array_equal(want, got)


def test_cluster_service_buckets_and_chunking(rng):
    x, _ = _blobs(rng)
    index = ClusterIndex.build(x, 2, 2, "kmeans", k=3,
                             key=jax.random.PRNGKey(0))
    svc = ClusterService(index, buckets=(16, 64, 256))
    svc.warmup()
    assert svc.stats["requests"] == 0  # warmup is not traffic
    want = np.asarray(index.assign(x))
    # odd sizes pad to buckets; > top bucket chunks through it
    for n in (1, 16, 17, 100, 300):
        got = np.asarray(svc.assign(x[:n]))
        np.testing.assert_array_equal(got, want[:n], err_msg=f"n={n}")
    st = svc.stats
    assert st["requests"] == 5
    assert st["points"] == 1 + 16 + 17 + 100 + 300
    assert st["bucket_256"] >= 2  # the n=300 request used 256 + 64
    assert svc.assign(x[:0]).shape == (0,)


def test_cluster_service_rejects_bad_buckets(rng):
    x, _ = _blobs(rng, n_per=20)
    index = ClusterIndex.build(x, 2, 1, "kmeans", k=3)
    with pytest.raises(ValueError):
        ClusterService(index, buckets=())
    with pytest.raises(ValueError):
        ClusterService(index, buckets=(0, 8))


def test_cluster_service_top_bucket_boundaries(rng):
    """Requests exactly at and one over the top bucket: at the boundary the
    request is one chunk; one over must chunk as top + remainder, and the
    stats counters must account for every chunk exactly."""
    x, _ = _blobs(rng, n_per=30)
    index = ClusterIndex.build(x, 2, 1, "kmeans", k=3,
                             key=jax.random.PRNGKey(0))
    top = 64
    svc = ClusterService(index, buckets=(16, top))
    want = np.asarray(index.assign(x))

    got = np.asarray(svc.assign(x[:top]))  # exactly the top bucket
    np.testing.assert_array_equal(got, want[:top])
    st = svc.stats
    assert (st["chunks"], st[f"bucket_{top}"], st["bucket_16"]) == (1, 1, 0)

    got = np.asarray(svc.assign(x[:top + 1]))  # one over: top + 1 remainder
    np.testing.assert_array_equal(got, want[:top + 1])
    st = svc.stats
    assert st["chunks"] == 3  # 1 (boundary request) + 2 (chunked request)
    assert st[f"bucket_{top}"] == 2
    assert st["bucket_16"] == 1  # the 1-row remainder pads to the smallest
    assert st["requests"] == 2
    assert st["points"] == top + (top + 1)


def test_cluster_service_empty_request_under_mesh(rng):
    """Empty request after warmup with a mesh configured: must return an
    empty result without touching the mesh padding path or the counters'
    chunk accounting."""
    from repro.core.distributed import make_data_mesh

    x, _ = _blobs(rng, n_per=20)
    index = ClusterIndex.build(x, 2, 1, "kmeans", k=3,
                             key=jax.random.PRNGKey(1))
    svc = ClusterService(index, buckets=(8, 32))
    with runtime.configure(mesh=make_data_mesh()):
        svc.warmup()  # replicates the index onto the mesh
        out = svc.assign(x[:0])
        assert out.shape == (0,)
        assert out.dtype == jnp.int32
        st = svc.stats
        assert (st["requests"], st["points"], st["chunks"]) == (1, 0, 0)
        # and a real request still serves correctly under the mesh
        np.testing.assert_array_equal(np.asarray(svc.assign(x[:5])),
                                      np.asarray(index.assign(x[:5])))


def test_assign_with_zero_valid_prototypes(rng):
    """An index with no valid prototypes (e.g. restored from an all-noise
    fit) must label everything -1 — not garbage from the all-inf top-1
    merge — in both the one-shot and blocked paths, and via the service."""
    nmax, d = 16, 2
    index = ClusterIndex(
        protos=jnp.zeros((nmax, d), jnp.float32),
        proto_mass=jnp.zeros((nmax,), jnp.float32),
        proto_valid=jnp.zeros((nmax,), bool),
        proto_labels=jnp.full((nmax,), -1, jnp.int32),
        n_prototypes=jnp.int32(0),
    )
    q = jnp.asarray(rng.normal(size=(9, d)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(index.assign(q)), -1)
    np.testing.assert_array_equal(np.asarray(index.assign(q, block=4)), -1)
    svc = ClusterService(index, buckets=(4, 16))
    np.testing.assert_array_equal(np.asarray(svc.assign(q)), -1)


def test_assign_all_noise_backend_labels(rng):
    """Valid prototypes whose backend labelled everything noise: assign
    returns the noise label -1 for every query."""
    x, _ = _blobs(rng, n_per=20)
    # dbscan with an impossible density: every prototype is noise
    index = ClusterIndex.build(x, 2, 1, "dbscan", eps=1e-6, min_pts=1e9,
                             key=jax.random.PRNGKey(2))
    assert not bool(jnp.any(index.proto_labels >= 0))
    np.testing.assert_array_equal(np.asarray(index.assign(x[:7])), -1)


def test_knn_graph_k_exceeding_valid_count(rng):
    """k >= n_valid: the unfillable neighbour slots must come back as
    (-1, inf), never as indices of invalid rows."""
    from repro.core import knn_graph

    x = jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)
    valid = jnp.asarray([True, True, True] + [False] * 5)
    d, idx = knn_graph(x, 5, valid=valid)
    idx = np.asarray(idx)
    d = np.asarray(d)
    for row in range(3):  # each valid row: 2 real neighbours, 3 empty slots
        assert set(idx[row, :2]) <= {0, 1, 2} - {row}
        assert (idx[row, 2:] == -1).all()
        assert np.isinf(d[row, 2:]).all()
    with pytest.raises(ValueError, match="exceeds the number of rows"):
        knn_graph(x, 9)


# ------------------------------------------------- serve config + warmup


def test_serve_config_knobs_env_validation_and_dispatch_key():
    """The async serve front-end's knobs (DESIGN.md §15) live in the
    runtime config: REPRO_SERVE_* env overrides parse, invalid values
    fail at construction, and the numeric knobs participate in
    dispatch_key() (a serving reconfiguration never aliases the previous
    one) while the routing name does not."""
    cfg = runtime.config_from_env(
        {"REPRO_SERVE_QUEUE_DEPTH": "256", "REPRO_SERVE_MAX_INFLIGHT": "2",
         "REPRO_SERVE_MAX_WAIT_MS": "12.5",
         "REPRO_SERVE_DEFAULT_TENANT": "prod"})
    assert cfg.serve_queue_depth == 256
    assert cfg.serve_max_inflight == 2
    assert cfg.serve_max_wait_ms == 12.5
    assert cfg.serve_default_tenant == "prod"
    for bad in (dict(serve_queue_depth=0), dict(serve_max_inflight=0),
                dict(serve_max_wait_ms=-1.0), dict(serve_default_tenant="")):
        with pytest.raises(ValueError):
            runtime.RuntimeConfig(**bad)
    base = runtime.RuntimeConfig()
    assert base.replace(serve_queue_depth=99).dispatch_key() \
        != base.dispatch_key()
    assert base.replace(serve_max_inflight=9).dispatch_key() \
        != base.dispatch_key()
    assert base.replace(serve_max_wait_ms=1.0).dispatch_key() \
        != base.dispatch_key()
    assert base.replace(serve_default_tenant="x").dispatch_key() \
        == base.dispatch_key()


def test_ingest_config_knobs_env_validation_and_dispatch_key():
    """The §18 ingest-pipeline knobs live in the runtime config:
    REPRO_PREFETCH_DEPTH / REPRO_DONATE_STREAM parse from env, a negative
    depth fails at construction, and both participate in dispatch_key()
    (donation changes the compiled executable's aliasing)."""
    cfg = runtime.config_from_env(
        {"REPRO_PREFETCH_DEPTH": "3", "REPRO_DONATE_STREAM": "true"})
    assert cfg.prefetch_depth == 3
    assert cfg.donate_stream is True
    with pytest.raises(ValueError):
        runtime.RuntimeConfig(prefetch_depth=-1)
    base = runtime.RuntimeConfig()
    assert base.replace(prefetch_depth=2).dispatch_key() \
        != base.dispatch_key()
    assert base.replace(donate_stream=True).dispatch_key() \
        != base.dispatch_key()


def test_cluster_service_warmup_excludes_prior_traffic_from_stats(rng):
    """Regression: warmup() must leave the stats counters at zero even
    when probe traffic preceded it (deployment health checks routinely
    fire a few requests before the warmup sweep) — otherwise the
    warmup-phase traffic pollutes reported steady-state throughput."""
    x, _ = _blobs(rng, n_per=20)
    index = ClusterIndex.build(x, 2, 1, "kmeans", k=3,
                             key=jax.random.PRNGKey(0))
    svc = ClusterService(index, buckets=(8, 32))
    svc.assign(x[:5])   # pre-warmup probe
    svc.assign(x[:11])
    assert svc.stats["requests"] == 2
    svc.warmup()
    st = svc.stats
    assert all(v == 0 for v in st.values()), st  # warmup is not traffic
    svc.assign(x[:3])   # steady state counts from zero
    st = svc.stats
    assert (st["requests"], st["points"], st["chunks"]) == (1, 3, 1)
    svc.reset_stats()
    assert all(v == 0 for v in svc.stats.values())


def test_index_check_servable_and_n_valid(rng):
    x, _ = _blobs(rng, n_per=20)
    index = ClusterIndex.build(x, 2, 1, "kmeans", k=3,
                             key=jax.random.PRNGKey(1))
    assert index.check_servable() is index
    assert index.check_servable(expect_dim=2) is index
    assert 0 < index.n_valid <= index.protos.shape[0]
    with pytest.raises(ValueError, match="feature dimension"):
        index.check_servable(expect_dim=5)
    torn = index._replace(proto_labels=index.proto_labels[:2])
    with pytest.raises(ValueError, match="proto_labels"):
        torn.check_servable()
    bad_count = index._replace(
        n_prototypes=jnp.asarray(10**6, jnp.int32))
    with pytest.raises(ValueError, match="n_prototypes"):
        bad_count.check_servable()
