"""The fit planner/executor architecture (DESIGN.md §13): repro.fit
dispatch, the canonical FitResult, the executor registry, deprecation
aliases, and the knn_block sharded-dispatch regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import gmm_sample
import repro
from repro import runtime
from repro.core import ClusterIndex, ihtc, ihtc_streaming, make_data_mesh
from repro.core.ihtc import IHTCResult
from repro.core.plan import (
    FitResult,
    available_executors,
    plan_fit,
    register_executor,
)
from repro.core.streaming import StreamingIHTCResult
from repro.serve.cluster_service import ClusterService


# ----------------------------------------------------------- entry point


def test_fit_memory_matches_ihtc_bitwise(rng):
    """repro.fit on a resident array is the old ihtc() exactly."""
    x = jnp.asarray(gmm_sample(512, rng)[0])
    key = jax.random.PRNGKey(7)
    want = ihtc(x, 2, 2, "kmeans", k=3, key=key)
    got = repro.fit(x, 2, 2, "kmeans", k=3, key=key)
    assert got.executor == "memory"
    np.testing.assert_array_equal(np.asarray(want.labels),
                                  np.asarray(got.labels))
    np.testing.assert_array_equal(
        np.asarray(want.protos).view(np.uint32),
        np.asarray(got.protos).view(np.uint32))


def test_fit_streaming_matches_ihtc_streaming_bitwise(rng):
    """repro.fit on a chunk stream is the old ihtc_streaming() exactly —
    and, on the aligned single-buffer config, the memory executor too."""
    x, _ = gmm_sample(512, rng)
    key = jax.random.PRNGKey(7)
    mem = repro.fit(jnp.asarray(x), 2, 2, "kmeans", k=3, key=key)
    old = ihtc_streaming(iter([x]), 2, 2, "kmeans", k=3, key=key,
                         chunk_n=512, reservoir_n=1024)
    new = repro.fit(iter([x]), 2, 2, "kmeans", k=3, key=key,
                    chunk_n=512, reservoir_n=1024)
    assert new.executor == "streaming"
    np.testing.assert_array_equal(old.labels_for(0), new.labels_for(0))
    np.testing.assert_array_equal(new.labels_for(0),
                                  np.asarray(mem.labels))
    np.testing.assert_array_equal(
        np.asarray(new.protos).view(np.uint32),
        np.asarray(mem.protos).view(np.uint32))


def test_executor_auto_selection(rng):
    """Planner rule: chunk stream → streaming, mesh → sharded, both → the
    composed path; explicit executor= and the config both pin."""
    x, _ = gmm_sample(64, rng)
    xj = jnp.asarray(x)
    mesh = make_data_mesh()
    assert plan_fit(xj, 2, 1).executor == "memory"
    assert plan_fit(iter([x]), 2, 1).executor == "streaming"
    assert plan_fit(xj, 2, 1, mesh=mesh).executor == "sharded"
    assert plan_fit(iter([x]), 2, 1, mesh=mesh).executor == "streaming_sharded"
    with runtime.configure(mesh=mesh):
        assert plan_fit(xj, 2, 1).executor == "sharded"
        assert plan_fit(iter([x]), 2, 1).executor == "streaming_sharded"
    with runtime.configure(executor="memory"):
        assert plan_fit(xj, 2, 1, mesh=mesh).executor == "memory"
    assert plan_fit(xj, 2, 1, mesh=mesh,
                    executor="memory").executor == "memory"


def test_executor_input_type_mismatch_rejected(rng):
    x, _ = gmm_sample(64, rng)
    with pytest.raises(ValueError, match="iterable of host chunks"):
        repro.fit(jnp.asarray(x), 2, 1, executor="streaming")
    with pytest.raises(ValueError, match="chunk stream"):
        repro.fit(iter([x]), 2, 1, executor="memory")


def test_unknown_executor_rejected(rng):
    x, _ = gmm_sample(64, rng)
    with pytest.raises(ValueError, match="unknown executor"):
        repro.fit(jnp.asarray(x), 2, 1, executor="warp_drive")
    with pytest.raises(ValueError, match="executor must be"):
        runtime.RuntimeConfig(executor="warp_drive")


def test_register_executor_duplicate_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_executor("memory")(lambda plan, data: None)
    assert set(available_executors()) >= {
        "memory", "sharded", "streaming", "streaming_sharded"}


# ------------------------------------------------- knn_block regression


def test_knn_block_rejected_on_sharded_dispatch(rng):
    """Regression: ihtc() used to silently DROP an explicit knn_block when
    a mesh dispatched it to the sharded path (ring_knn has no blocked
    scan). The planner now rejects it loudly, on every sharded executor."""
    x, _ = gmm_sample(64, rng)
    xj = jnp.asarray(x)
    mesh = make_data_mesh()
    with pytest.raises(ValueError, match="knn_block"):
        ihtc(xj, 2, 1, "kmeans", k=2, mesh=mesh, knn_block=256)
    with pytest.raises(ValueError, match="knn_block"):
        plan_fit(xj, 2, 1, executor="sharded", mesh=mesh, knn_block=128)
    with pytest.raises(ValueError, match="knn_block"):
        plan_fit(iter([x]), 2, 1, executor="streaming_sharded", mesh=mesh,
                 knn_block=128)
    # explicit 0 ("auto") and a *configured* knn_block are not errors — the
    # config value simply does not apply to the ring path
    assert plan_fit(xj, 2, 1, executor="sharded", mesh=mesh,
                    knn_block=0).executor == "sharded"
    with runtime.configure(knn_block=64):
        res = ihtc(xj, 2, 1, "kmeans", k=2, mesh=mesh,
                   key=jax.random.PRNGKey(0))
    assert np.asarray(res.labels).shape == (64,)
    # ...and the memory executor still honours it (no behaviour change)
    want = ihtc(xj, 2, 1, "kmeans", k=2, key=jax.random.PRNGKey(0),
                knn_block=16)
    assert np.asarray(want.labels).shape == (64,)


def test_weights_and_valid_rejected_where_unsupported(rng):
    """Silently dropping a weight vector or validity mask would corrupt
    the fit; executors that cannot honour them must reject loudly."""
    x, _ = gmm_sample(64, rng)
    xj = jnp.asarray(x)
    w = jnp.full((64,), 5.0)
    mask = jnp.arange(64) < 32
    with pytest.raises(ValueError, match="weights"):
        repro.fit(iter([x]), 2, 1, weights=w)
    with pytest.raises(ValueError, match="valid"):
        repro.fit(xj, 2, 1, valid=mask)  # memory executor: itis has no mask
    with pytest.raises(ValueError, match="valid"):
        repro.fit(iter([x]), 2, 1, valid=mask)
    # the executors that do support them still accept them
    res = repro.fit(xj, 2, 1, "kmeans", k=2, weights=w,
                    key=jax.random.PRNGKey(0))
    mass = np.asarray(res.proto_mass)[np.asarray(res.proto_valid)]
    assert abs(mass.sum() - 64 * 5.0) < 1e-2
    mesh = make_data_mesh()
    res = repro.fit(xj, 2, 1, "kmeans", k=2, valid=mask, mesh=mesh,
                    key=jax.random.PRNGKey(0))
    lab = np.asarray(res.labels)
    assert (lab[32:] == -1).all() and lab[:32].min() >= 0


def test_ingest_knobs_resolve_and_reject_where_unsupported(rng):
    """§18 knob plumbing: plan_fit freezes prefetch_depth/donate_stream
    from kwargs or the runtime config (explicit wins), rejects a negative
    depth, and rejects explicit values loudly on executors that have no
    stream loop to apply them to (a configured value simply does not
    apply there)."""
    x, _ = gmm_sample(64, rng)
    xj = jnp.asarray(x)
    plan = plan_fit(iter([x]), 2, 1, prefetch_depth=3, donate_stream=True)
    assert (plan.prefetch_depth, plan.donate_stream) == (3, True)
    with runtime.configure(prefetch_depth=2, donate_stream=True):
        plan = plan_fit(iter([x]), 2, 1)
        assert (plan.prefetch_depth, plan.donate_stream) == (2, True)
        # explicit kwargs beat the configured values
        plan = plan_fit(iter([x]), 2, 1, prefetch_depth=0,
                        donate_stream=False)
        assert (plan.prefetch_depth, plan.donate_stream) == (0, False)
    with pytest.raises(ValueError, match="prefetch_depth must be >= 0"):
        plan_fit(iter([x]), 2, 1, prefetch_depth=-1)
    with pytest.raises(ValueError, match="prefetch_depth"):
        plan_fit(xj, 2, 1, executor="memory", prefetch_depth=2)
    with pytest.raises(ValueError, match="donate_stream"):
        plan_fit(xj, 2, 1, executor="memory", donate_stream=True)
    # explicit 0/False and configured values are not errors off-stream
    assert plan_fit(xj, 2, 1, prefetch_depth=0).prefetch_depth == 0
    with runtime.configure(prefetch_depth=2, donate_stream=True):
        assert plan_fit(xj, 2, 1).executor == "memory"


# ------------------------------------------------- canonical result type


def test_result_deprecation_aliases():
    assert IHTCResult is FitResult
    assert StreamingIHTCResult is FitResult


def test_fit_result_uniform_api(rng):
    """One artifact shape for both families: chunk iteration works on
    in-memory results, array conversion works on streamed results."""
    x, _ = gmm_sample(300, rng)
    key = jax.random.PRNGKey(1)
    mem = repro.fit(jnp.asarray(x), 2, 2, "kmeans", k=3, key=key)
    stream = repro.fit(iter([x[:150], x[150:]]), 2, 2, "kmeans", k=3,
                       key=key, chunk_n=150)
    # in-memory result exposes the stream API degenerately
    assert mem.n_chunks == 1 and mem.n_total == 300 and mem.n_cascades == 0
    np.testing.assert_array_equal(mem.labels_for(0), np.asarray(mem.labels))
    np.testing.assert_array_equal(np.concatenate(list(mem.iter_labels())),
                                  np.asarray(mem.labels))
    with pytest.raises(IndexError):
        mem.labels_for(1)
    # streamed result exposes the array API lazily
    assert stream.n_chunks == 2 and stream.n_total == 300
    np.testing.assert_array_equal(np.asarray(stream.labels),
                                  stream.labels())
    np.testing.assert_array_equal(
        stream.labels(), np.concatenate(list(stream.iter_labels())))
    # both freeze into the same servable index type
    q = jnp.asarray(gmm_sample(32, rng)[0])
    assert mem.to_index().assign(q).shape == (32,)
    assert stream.to_index().assign(q).shape == (32,)


def test_cluster_service_from_fit(rng):
    """ClusterService consumes any FitResult uniformly."""
    x, _ = gmm_sample(256, rng)
    key = jax.random.PRNGKey(2)
    mem = repro.fit(jnp.asarray(x), 2, 2, "kmeans", k=3, key=key)
    stream = repro.fit(iter([x]), 2, 2, "kmeans", k=3, key=key,
                       chunk_n=256, reservoir_n=512)
    svc_m = ClusterService.from_fit(mem, buckets=(32, 128))
    svc_s = ClusterService.from_fit(stream, buckets=(32, 128))
    q = jnp.asarray(gmm_sample(100, rng)[0])
    np.testing.assert_array_equal(np.asarray(svc_m.assign(q)),
                                  np.asarray(svc_s.assign(q)))
    assert svc_m.stats["requests"] == 1


def test_cluster_index_build_takes_chunk_streams(rng):
    """ClusterIndex.build routes through the planner: a chunk iterable
    streams instead of erroring, and freezes the same artifact as the
    explicit streaming fit."""
    x, _ = gmm_sample(256, rng)
    key = jax.random.PRNGKey(3)
    via_build = ClusterIndex.build(iter([x]), 2, 2, "kmeans", k=3, key=key,
                                   chunk_n=256, reservoir_n=512)
    via_streaming = ClusterIndex.build(
        ihtc_streaming(iter([x]), 2, 2, "kmeans", k=3, key=key,
                       chunk_n=256, reservoir_n=512))
    np.testing.assert_array_equal(
        np.asarray(via_build.protos).view(np.uint32),
        np.asarray(via_streaming.protos).view(np.uint32))
    np.testing.assert_array_equal(np.asarray(via_build.proto_labels),
                                  np.asarray(via_streaming.proto_labels))


# ------------------------------------------------------------- dispatch


def test_dispatch_key_contains_executor():
    """Plan changes must retrace instead of hitting stale jit caches."""
    base = runtime.RuntimeConfig()
    pinned = runtime.RuntimeConfig(executor="streaming")
    assert base.dispatch_key() != pinned.dispatch_key()
    cfg = runtime.config_from_env({"REPRO_EXECUTOR": "sharded"})
    assert cfg.executor == "sharded"


def test_backend_kwargs_flow_through_fit(rng):
    """Unknown fit() keywords reach the backend clusterer."""
    x = jnp.asarray(gmm_sample(200, rng)[0])
    res = repro.fit(x, 2, 1, "hac", k=3, linkage="ward",
                    key=jax.random.PRNGKey(0))
    lab = np.asarray(res.labels)
    assert lab.shape == (200,) and lab.min() >= 0
    assert len(np.unique(lab)) <= 3
