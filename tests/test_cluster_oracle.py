"""Small-n oracle tests: the JAX HAC / DBSCAN backends vs naive pure-Python
references built from first principles (member sets and brute-force scans,
no Lance–Williams recurrence, no label propagation), including the
weighted/mass cases that the prototype pipeline depends on."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.dbscan import dbscan
from repro.cluster.hac import hac


def partition(labels):
    """Canonical form of a flat clustering: set of frozensets of indices."""
    labels = np.asarray(labels)
    return {
        frozenset(np.flatnonzero(labels == c).tolist())
        for c in np.unique(labels[labels >= 0])
    }


# ------------------------------------------------------------------ HAC


def _cluster_dist(a, b, d, w, cents, linkage):
    """Dissimilarity between member-index sets a and b, from scratch."""
    if linkage == "single":
        return min(d[i, j] for i in a for j in b)
    if linkage == "complete":
        return max(d[i, j] for i in a for j in b)
    wa = sum(w[i] for i in a)
    wb = sum(w[j] for j in b)
    if linkage == "average":  # mass-weighted mean pairwise dissimilarity
        return sum(w[i] * w[j] * d[i, j] for i in a for j in b) / (wa * wb)
    # ward: (Wa Wb / (Wa + Wb)) ||centroid_a - centroid_b||^2
    ca = sum(cents[i] * w[i] for i in a) / wa
    cb = sum(cents[j] * w[j] for j in b) / wb
    return wa * wb / (wa + wb) * float(((ca - cb) ** 2).sum())


def naive_hac(x, k, linkage, weights=None):
    """Greedy agglomeration over explicit member sets (O(n^4), tiny n)."""
    x = np.asarray(x, np.float64)
    n = len(x)
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    sq = ((x[:, None] - x[None]) ** 2).sum(-1)
    d = sq if linkage == "ward" else np.sqrt(sq)
    clusters = [{i} for i in range(n)]
    while len(clusters) > k:
        best, bi, bj = np.inf, -1, -1
        for i, j in itertools.combinations(range(len(clusters)), 2):
            dd = _cluster_dist(clusters[i], clusters[j], d, w, x, linkage)
            if dd < best:
                best, bi, bj = dd, i, j
        clusters[bi] |= clusters[bj]
        del clusters[bj]
    labels = np.zeros(n, int)
    for c, members in enumerate(clusters):
        for i in members:
            labels[i] = c
    return labels


@pytest.mark.parametrize("linkage", ["single", "complete", "average", "ward"])
def test_hac_matches_naive_reference(rng, linkage):
    x = rng.normal(size=(14, 3)).astype(np.float32)
    got = hac(jnp.asarray(x), 4, linkage=linkage).labels
    want = naive_hac(x, 4, linkage)
    assert partition(got) == partition(want), linkage


@pytest.mark.parametrize("linkage", ["average", "ward"])
def test_hac_weighted_matches_naive_reference(rng, linkage):
    """Mass-weighted linkages (the prototype-clustering case): HAC on
    weighted points must agree with the from-scratch weighted oracle."""
    x = rng.normal(size=(12, 2)).astype(np.float32)
    w = rng.integers(1, 6, size=12).astype(np.float32)
    got = hac(jnp.asarray(x), 3, linkage=linkage,
              weights=jnp.asarray(w)).labels
    want = naive_hac(x, 3, linkage, weights=w)
    assert partition(got) == partition(want), linkage


@pytest.mark.parametrize("linkage", ["average", "ward"])
def test_hac_mass_equals_replication(rng, linkage):
    """A point with mass q must cluster like q coincident unit points — the
    invariant that makes HAC-on-prototypes approximate HAC-on-units."""
    x = rng.normal(size=(8, 2)).astype(np.float32)
    w = np.array([3, 1, 1, 2, 1, 1, 1, 1], np.float32)
    got = hac(jnp.asarray(x), 3, linkage=linkage,
              weights=jnp.asarray(w)).labels
    # replicate each point w_i times and cluster unweighted, from scratch
    rep = np.repeat(np.arange(8), w.astype(int))
    want_rep = naive_hac(x[rep], 3, linkage)
    # replicas of one point always end up together; map back
    want = np.array([want_rep[np.flatnonzero(rep == i)[0]] for i in range(8)])
    for i in range(8):
        assert len(set(want_rep[rep == i])) == 1
    assert partition(got) == partition(want), linkage


def test_hac_masked_rows_are_inert(rng):
    x = rng.normal(size=(10, 2)).astype(np.float32)
    pad = np.full((4, 2), 37.0, np.float32)
    xp = jnp.asarray(np.vstack([x, pad]))
    valid = jnp.asarray([True] * 10 + [False] * 4)
    got = hac(xp, 3, linkage="complete", valid=valid).labels
    lab = np.asarray(got)
    assert (lab[10:] == -1).all()
    assert partition(lab[:10]) == partition(naive_hac(x, 3, "complete"))


# ---------------------------------------------------------------- DBSCAN


def naive_dbscan(x, eps, min_pts, weights=None):
    """Brute-force DBSCAN matching the backend's labelling conventions:
    components carry the min core index as representative; borders adopt the
    neighbouring core component with the smallest representative; labels are
    representative ranks; noise is -1."""
    x = np.asarray(x, np.float64)
    n = len(x)
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    d = np.sqrt(((x[:, None] - x[None]) ** 2).sum(-1))
    neigh = [set(np.flatnonzero(d[i] <= eps).tolist()) for i in range(n)]
    density = np.array([sum(w[j] for j in neigh[i]) for i in range(n)])
    core = density >= min_pts

    rep = -np.ones(n, int)  # component representative (min core index)
    for i in range(n):  # BFS per unvisited core
        if not core[i] or rep[i] >= 0:
            continue
        stack, members = [i], []
        seen = {i}
        while stack:
            u = stack.pop()
            members.append(u)
            for v in neigh[u]:
                if core[v] and v not in seen:
                    seen.add(v)
                    stack.append(v)
        r = min(members)
        for u in members:
            rep[u] = r

    full = -np.ones(n, int)
    for i in range(n):
        if core[i]:
            full[i] = rep[i]
        else:  # border: neighbouring core component with smallest rep
            cands = [rep[j] for j in neigh[i] if core[j]]
            if cands:
                full[i] = min(cands)
    reps = sorted({r for r in full if r >= 0})
    rank = {r: c for c, r in enumerate(reps)}
    return np.array([rank[r] if r >= 0 else -1 for r in full])


def test_dbscan_matches_naive_reference(rng):
    x = rng.normal(size=(24, 2)).astype(np.float32)
    got = np.asarray(dbscan(jnp.asarray(x), eps=0.8, min_pts=3.0).labels)
    want = naive_dbscan(x, 0.8, 3.0)
    np.testing.assert_array_equal(got, want)


def test_dbscan_weighted_matches_naive_reference(rng):
    """Weighted density (prototype masses): exact agreement with the naive
    oracle, including which points become core."""
    x = rng.normal(size=(20, 2)).astype(np.float32)
    w = rng.integers(1, 5, size=20).astype(np.float32)
    r = dbscan(jnp.asarray(x), eps=0.7, min_pts=4.0, weights=jnp.asarray(w))
    want = naive_dbscan(x, 0.7, 4.0, weights=w)
    np.testing.assert_array_equal(np.asarray(r.labels), want)
    # core flags agree too
    d = np.sqrt(((x[:, None] - x[None]) ** 2).sum(-1))
    dens = (w[None, :] * (d <= 0.7)).sum(1)
    np.testing.assert_array_equal(np.asarray(r.is_core), dens >= 4.0)


def test_dbscan_mass_equals_replication(rng):
    """DBSCAN on weighted points == DBSCAN on the replicated unit points."""
    x = rng.normal(scale=0.5, size=(10, 2)).astype(np.float32)
    w = np.array([4, 1, 1, 1, 2, 1, 1, 1, 1, 1], np.float32)
    got = np.asarray(
        dbscan(jnp.asarray(x), eps=0.6, min_pts=3.0,
               weights=jnp.asarray(w)).labels)
    rep = np.repeat(np.arange(10), w.astype(int))
    want_rep = naive_dbscan(x[rep], 0.6, 3.0)
    want = np.array([want_rep[np.flatnonzero(rep == i)[0]] for i in range(10)])
    assert partition(got) == partition(want)
    # noise sets match as well
    np.testing.assert_array_equal(got == -1, want == -1)


def test_dbscan_masked_rows_are_inert(rng):
    x = rng.normal(size=(15, 2)).astype(np.float32)
    pad = np.zeros((5, 2), np.float32)  # would be dense if not masked
    xp = jnp.asarray(np.vstack([x, pad]))
    valid = jnp.asarray([True] * 15 + [False] * 5)
    r = dbscan(xp, eps=0.8, min_pts=3.0, valid=valid)
    lab = np.asarray(r.labels)
    assert (lab[15:] == -1).all()
    np.testing.assert_array_equal(lab[:15], naive_dbscan(x, 0.8, 3.0))
