"""End-to-end training behaviour: loss descent, fault-tolerant loop with
injected failures, checkpoint save/restore/resume equivalence."""
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, smoke_config
from repro.data import make_batch
from repro.models import build
from repro.train import (CheckpointManager, OptConfig, init_opt_state,
                         make_train_step)
from repro.train.fault_tolerance import StepGuard, TransientError, run_training


def _setup(name="qwen2.5-32b", lr=1e-2):
    cfg = smoke_config(ARCHS[name])
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        bundle, OptConfig(peak_lr=lr, warmup_steps=5, decay_steps=60)))
    bfs = lambda s: make_batch(cfg, SHAPES["train_4k"], s, batch_override=8,
                               seq_override=32)
    return cfg, bundle, params, opt, step, bfs


def test_loss_decreases():
    _, _, params, opt, step, bfs = _setup()
    losses = []
    p, o, _ = run_training(
        train_step=step, init_state=(params, opt), batch_for_step=bfs,
        n_steps=20, on_metrics=lambda s, m: losses.append(float(m["loss"])))
    assert np.mean(losses[-4:]) < np.mean(losses[:4]) * 0.92, losses


def test_failure_injection_and_retry():
    _, _, params, opt, step, bfs = _setup()
    injected = []

    def hook(s, attempt):
        if s in (2, 5) and attempt == 0:
            injected.append(s)
            return True
        return False

    _, _, stats = run_training(
        train_step=step, init_state=(params, opt), batch_for_step=bfs,
        n_steps=8, guard_kwargs={"failure_hook": hook})
    assert injected == [2, 5]
    assert stats.retries == 2 and stats.failures == 2
    assert len(stats.times) == 8  # every step eventually succeeded


def test_retry_exhaustion_raises():
    def always_fail(s, attempt):
        return True

    guard = StepGuard(lambda *a: None, max_retries=2,
                      failure_hook=always_fail)
    with pytest.raises(TransientError):
        guard(0)
    assert guard.stats.failures == 3  # initial + 2 retries


def test_checkpoint_resume_is_exact():
    """Train 10 steps straight vs 5 + checkpoint + restore + 5 — identical
    (the data pipeline is a pure function of step, so resume is exact)."""
    _, _, params, opt, step, bfs = _setup()
    pA, oA, _ = run_training(train_step=step, init_state=(params, opt),
                             batch_for_step=bfs, n_steps=10)
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d)
        p5, o5, _ = run_training(train_step=step, init_state=(params, opt),
                                 batch_for_step=bfs, n_steps=5)
        ck.save(5, {"params": p5, "opt": o5})
        rest = ck.restore(5, {"params": p5, "opt": o5})
        pB, oB, _ = run_training(
            train_step=step, init_state=(rest["params"], rest["opt"]),
            batch_for_step=bfs, n_steps=10, start_step=5)
    for a, b in zip(jax.tree_util.tree_leaves(pA),
                    jax.tree_util.tree_leaves(pB), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_async():
    _, _, params, opt, step, bfs = _setup()
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, {"p": params}, async_=True)
        ck.wait()
        assert ck.all_steps() == [3, 4]
        assert ck.latest_step() == 4


def test_straggler_detection():
    import time

    calls = {"n": 0}

    def slow_step():
        calls["n"] += 1
        if calls["n"] == 7:
            time.sleep(0.25)
        return None

    guard = StepGuard(lambda: slow_step())
    for s in range(8):
        guard(s)
    assert guard.stats.stragglers(factor=5.0) >= 1
