"""Property-based tests (hypothesis) for the paper's core invariants.

TC (Higgins et al. 2016) guarantees, which our Luby-parallel adaptation must
preserve (DESIGN.md §2):
  P1  every valid point gets a cluster (spanning);
  P2  clusters are disjoint with size ≥ t*;
  P3  seeds are independent at graph distance ≤ 2 in NG_{t*-1};
  P4  TC's bottleneck objective ≤ 4λ* (brute-forced optimum, tiny n).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.cluster.metrics import bottleneck_objective, optimal_bottleneck
from repro.core import threshold_clustering
from repro.core.knn import knn_graph

points = st.integers(min_value=0, max_value=10_000)


@st.composite
def point_sets(draw, d=2, sizes=(8, 16, 24, 40)):
    # n drawn from a fixed bucket set to bound jit-compilation count
    n = draw(st.sampled_from(sizes))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    # mix of cluster-y and uniform data
    k = draw(st.integers(1, 4))
    centers = rng.normal(scale=5.0, size=(k, d))
    comp = rng.integers(0, k, size=n)
    x = centers[comp] + rng.normal(scale=draw(st.floats(0.1, 2.0)), size=(n, d))
    return x.astype(np.float32)


@settings(max_examples=20, deadline=None)
@given(x=point_sets(), t=st.integers(2, 4))
def test_tc_partition_and_size(x, t):
    n = len(x)
    if n < 2 * t:
        return
    r = threshold_clustering(jnp.asarray(x), t, key=jax.random.PRNGKey(0))
    lab = np.asarray(r.labels)
    nc = int(r.n_clusters)
    assert lab.min() >= 0, "P1: spanning"
    assert lab.max() == nc - 1 and nc >= 1
    sizes = np.bincount(lab, minlength=nc)
    assert sizes.min() >= t, f"P2: size guarantee {sizes.min()} < {t}"


@settings(max_examples=10, deadline=None)
@given(x=point_sets(sizes=(12, 24)), t=st.integers(2, 3))
def test_tc_seed_independence(x, t):
    """P3: no two seeds within undirected graph distance 2 of NG_{t-1}."""
    n = len(x)
    if n < 2 * t:
        return
    xj = jnp.asarray(x)
    r = threshold_clustering(xj, t, key=jax.random.PRNGKey(1))
    _, idx = knn_graph(xj, t - 1)
    idx = np.asarray(idx)
    adj = np.zeros((n, n), bool)
    for i in range(n):
        for j in idx[i]:
            if j >= 0:
                adj[i, j] = adj[j, i] = True
    two_hop = adj | (adj @ adj)
    seeds = np.flatnonzero(np.asarray(r.is_seed))
    for a in seeds:
        for b in seeds:
            if a < b:
                assert not two_hop[a, b], f"seeds {a},{b} within distance 2"


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([8, 9]),
    t=st.integers(2, 3),
)
def test_tc_four_approximation(seed, n, t):
    """P4: TC bottleneck ≤ 4·optimal (exact brute force, n ≤ 9)."""
    if n < 2 * t:
        return
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 2)).astype(np.float32)
    r = threshold_clustering(jnp.asarray(x), t, key=jax.random.PRNGKey(2))
    got = bottleneck_objective(x, np.asarray(r.labels))
    opt = optimal_bottleneck(x, t)
    assert got <= 4.0 * opt + 1e-5, f"bottleneck {got} > 4×{opt}"


def test_tc_masked_invariants(rng):
    """Masked (padded) points are excluded and transmit no edges."""
    x = jnp.asarray(rng.normal(size=(50, 2)), jnp.float32)
    valid = jnp.asarray(rng.random(50) > 0.3)
    r = threshold_clustering(x, 2, valid=valid, key=jax.random.PRNGKey(3))
    lab = np.asarray(r.labels)
    v = np.asarray(valid)
    assert np.all(lab[~v] == -1)
    if v.sum() >= 4:
        assert np.all(lab[v] >= 0)
        sizes = np.bincount(lab[v])
        assert sizes[sizes > 0].min() >= 2


def test_tc_determinism(rng):
    x = jnp.asarray(rng.normal(size=(64, 3)), jnp.float32)
    r1 = threshold_clustering(x, 3, key=jax.random.PRNGKey(5))
    r2 = threshold_clustering(x, 3, key=jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(r1.labels), np.asarray(r2.labels))


def test_tc_t1_degenerate(rng):
    x = jnp.asarray(rng.normal(size=(10, 2)), jnp.float32)
    r = threshold_clustering(x, 1)
    assert int(r.n_clusters) == 10
