"""AdamW vs a NumPy reference, LR schedule, ZeRO spec derivation, int8
quantization round-trip, and the HLO collective-bytes parser."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.train.compression import dequantize_int8, quantize_int8
from repro.train.optimizer import (
    OptConfig,
    adamw_update,
    init_opt_state,
    lr_at,
    zero_opt_specs,
)
from repro.utils.hlo import collective_bytes, collective_op_counts


def _np_adamw(p, g, m, v, step, cfg: OptConfig):
    gn = np.sqrt((g**2).sum())
    g = g * min(1.0, cfg.clip_norm / max(gn, 1e-12))
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g**2
    mh = m / (1 - cfg.b1**step)
    vh = v / (1 - cfg.b2**step)
    # lr at `step` (warmup phase for this test)
    lr = cfg.peak_lr * step / cfg.warmup_steps
    return p - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p), m, v


def test_adamw_matches_numpy_reference(rng):
    cfg = OptConfig(peak_lr=1e-2, warmup_steps=100, decay_steps=1000)
    p = rng.normal(size=(13,)).astype(np.float32)
    g = rng.normal(size=(13,)).astype(np.float32)
    params = {"w": jnp.asarray(p)}
    grads = {"w": jnp.asarray(g)}
    opt = init_opt_state(params)
    got, opt, mets = adamw_update(grads, opt, params, cfg)
    want, _, _ = _np_adamw(p, g, np.zeros(13), np.zeros(13), 1, cfg)
    np.testing.assert_allclose(np.asarray(got["w"]), want, rtol=1e-5, atol=1e-6)
    assert abs(float(mets["grad_norm"]) - np.sqrt((g**2).sum())) < 1e-4


def test_lr_schedule_shape():
    cfg = OptConfig(peak_lr=1e-3, min_lr=1e-4, warmup_steps=10, decay_steps=110)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in (0, 5, 10, 60, 110, 500)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 5e-4) < 1e-9          # linear warmup
    assert abs(lrs[2] - 1e-3) < 1e-6          # peak
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]
    assert abs(lrs[4] - 1e-4) < 1e-6          # floor
    assert abs(lrs[5] - 1e-4) < 1e-6


def test_zero_specs_fold_data_axes():
    pspecs = {"w": P(None, "model"), "b": P("model"), "tiny": P(None)}
    shapes = {
        "w": jax.ShapeDtypeStruct((64, 32), jnp.float32),
        "b": jax.ShapeDtypeStruct((128,), jnp.float32),
        "tiny": jax.ShapeDtypeStruct((3,), jnp.float32),
    }
    specs = zero_opt_specs(pspecs, shapes, ("pod", "data"),
                           {"pod": 2, "data": 4, "model": 8})
    # w dim0 (64) divisible by 8 -> gets the data axes
    assert specs["m"]["w"] == P(("pod", "data"), "model")
    # b dim0 already model-sharded: 128 % (8·8) == 0 -> merged axes
    assert specs["m"]["b"] == P(("model", "pod", "data"))
    # tiny (3) not divisible -> left as-is
    assert specs["m"]["tiny"] == P(None)
    assert specs["step"] == P()


def test_int8_quantization_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=(256,)) * 3.7, jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    err = float(jnp.max(jnp.abs(back - x)))
    assert err <= float(s) * 0.5 + 1e-6  # half-ULP of the int8 grid
    assert q.dtype == jnp.int8


SAMPLE_HLO = """
HloModule test
  %p = f32[128,64]{1,0} parameter(0)
  %ag = f32[128,512]{1,0} all-gather(%p), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={1}
  %ar = f32[128,64]{1,0} all-reduce(%p), replica_groups=[64,8]<=[512], to_apply=%add
  %rs = f32[16,64]{1,0} reduce-scatter(%p), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %cp = f32[128,64]{1,0} collective-permute(%p), source_target_pairs={{0,1},{1,0}}
  %a2a = f32[128,64]{1,0} all-to-all(%p), replica_groups={{0,1,2,3}}
  %start = f32[32,32]{1,0} all-reduce-start(%p), replica_groups={{0,1}}
  %done = f32[32,32]{1,0} all-reduce-done(%start)
"""


def test_collective_bytes_parser():
    out = collective_bytes(SAMPLE_HLO)
    ag = 128 * 512 * 4 * (7 / 8)
    ar = 128 * 64 * 4 * 2 * (7 / 8)
    rs = 16 * 64 * 4 * 7
    cp = 128 * 64 * 4
    a2a = 128 * 64 * 4 * (3 / 4)
    st = 32 * 32 * 4 * 2 * (1 / 2)
    np.testing.assert_allclose(out["all-gather"], ag)
    np.testing.assert_allclose(out["all-reduce"], ar + st)
    np.testing.assert_allclose(out["reduce-scatter"], rs)
    np.testing.assert_allclose(out["collective-permute"], cp)
    np.testing.assert_allclose(out["all-to-all"], a2a)
    np.testing.assert_allclose(out["total"], ag + ar + rs + cp + a2a + st)
    counts = collective_op_counts(SAMPLE_HLO)
    assert counts["all-reduce"] == 2  # plain + start (done not re-counted)


def test_collective_bytes_ignores_singleton_groups():
    hlo = "%ar = f32[8,8]{1,0} all-reduce(%p), replica_groups={{0}}"
    assert collective_bytes(hlo).get("total", 0.0) == 0.0
