"""Prefill + decode must reproduce the full-forward logits for every family
(validates KV caches, SSD recurrence, cross-attn caches, position handling)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import build
from repro.models.frontends import VISION_PREFIX_TOKENS

FAMILIES = ["qwen2.5-32b", "gemma2-2b", "mamba2-370m", "jamba-v0.1-52b",
             "deepseek-moe-16b", "seamless-m4t-large-v2", "phi-3-vision-4.2b",
             "granite-20b"]


@pytest.mark.parametrize("name", FAMILIES)
def test_decode_matches_forward(name, rng):
    cfg = smoke_config(ARCHS[name])
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)
    B, S = 2, 12
    params = bundle.init(key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    kw = {}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, VISION_PREFIX_TOKENS, cfg.d_model)),
            jnp.float32) * 0.02
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32) * 0.02
        kw = {"enc_len": S}
    full_logits, _ = bundle.forward(params, batch)
    npre = S - 3
    pre = dict(batch)
    pre["tokens"] = toks[:, :npre]
    caches = bundle.init_caches(B, S, **kw)
    lg, caches = bundle.prefill(params, caches, pre)
    outs = [lg[:, -1]]
    for t in range(npre, S - 1):
        lg, caches = bundle.decode_step(params, caches,
                                        {"tokens": toks[:, t:t + 1]})
        outs.append(lg[:, -1])
    dec = jnp.stack(outs, axis=1)
    ref = full_logits[:, npre - 1:S - 1]
    err = float(jnp.max(jnp.abs(dec.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < 0.05, err
