"""Distribution correctness on a real multi-device (8× CPU) mesh.

These tests run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_
device_count=8 (conftest keeps the main test process at 1 device), and
assert numerical equality between sharded and single-device execution for:
pjit'd train step, ring-kNN vs exact kNN, compressed psum, sharded TC, the
end-to-end sharded IHTC pipeline (bit-for-bit label parity), and streamed
multi-device ingestion.
"""
import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_SCRIPT_COMMON = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
assert len(jax.devices()) == 8
"""


def _run(body: str):
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT_COMMON + body],
        capture_output=True, text=True, timeout=420,
        env=dict(os.environ, PYTHONPATH=SRC),
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    out = _run("""
from repro.configs import ARCHS, SHAPES, smoke_config
from repro.models import build
from repro.models.transformer import ShardingPlan
from repro.data import make_batch
from repro.train import OptConfig, init_opt_state, make_train_step
from repro.launch.mesh import make_debug_mesh

cfg = smoke_config(ARCHS["qwen2.5-32b"])
bundle = build(cfg)
params = bundle.init(jax.random.PRNGKey(0))
opt = init_opt_state(params)
batch = make_batch(cfg, SHAPES["train_4k"], 0, batch_override=4, seq_override=16)
ocfg = OptConfig(peak_lr=1e-2, warmup_steps=2, decay_steps=10)

# single device
step1 = jax.jit(make_train_step(bundle, ocfg))
p1, _, m1 = step1(params, opt, batch)

# 2x4 mesh, fully sharded
mesh = make_debug_mesh(2, 4)
pspecs = bundle.param_specs(tp="model", tp_size=4)
plan = ShardingPlan(resid=P("data", None, None), logits=P("data", None, "model"))
shard = lambda tree, specs: jax.tree_util.tree_map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs,
    is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape"))
with mesh:
    ps = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, pspecs)
    bs = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, NamedSharding(mesh, P("data"))), batch)
    step2 = jax.jit(make_train_step(bundle, ocfg, plan=plan))
    p2, _, m2 = step2(ps, opt, bs)
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2, (m1["loss"], m2["loss"])
for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
    # bf16 matmuls reduce in different orders across shardings: tolerate
    # ~1 bf16 ulp of drift on a handful of elements
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               atol=1.5e-2)
print("TRAIN-STEP-PARITY-OK")
""")
    assert "TRAIN-STEP-PARITY-OK" in out


def test_ring_knn_matches_exact():
    out = _run("""
from functools import partial
from jax.experimental.shard_map import shard_map
from repro.core.knn import ring_knn, knn_graph

mesh = jax.make_mesh((8,), ("data",))
n, d, k = 64, 3, 4
x = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)

fn = shard_map(
    partial(ring_knn, k=k, axis_name="data", impl="ref"),
    mesh=mesh, in_specs=P("data", None), out_specs=P("data", None),
)
rd, ri = fn(x)
wd, wi = knn_graph(x, k, impl="ref")
np.testing.assert_allclose(np.asarray(rd), np.asarray(wd), rtol=1e-5, atol=1e-5)
np.testing.assert_array_equal(np.asarray(ri), np.asarray(wi))
print("RING-KNN-OK")
""")
    assert "RING-KNN-OK" in out


def test_compressed_psum_error_feedback():
    out = _run("""
from functools import partial
from jax.experimental.shard_map import shard_map
from repro.train.compression import compressed_psum, psum_with_error_feedback

mesh = jax.make_mesh((8,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 128), jnp.float32)

# one-shot compressed mean close to the true mean
got = shard_map(partial(compressed_psum, axis_name="pod"), mesh=mesh,
                in_specs=P("pod", None), out_specs=P("pod", None))(x)
want = jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)
rel = float(jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
assert rel < 0.02, rel

# error feedback: accumulated mean over steps converges (bias ~ O(q^2))
def step(x, err):
    return shard_map(partial(psum_with_error_feedback, axis_name="pod"),
                     mesh=mesh, in_specs=(P("pod", None), P("pod", None)),
                     out_specs=(P("pod", None), P("pod", None)))(x, err)
err = jnp.zeros_like(x)
tot = jnp.zeros_like(x)
for _ in range(16):
    o, err = step(x, err)
    tot = tot + o
avg_err = float(jnp.max(jnp.abs(tot / 16 - want)))
one_err = float(jnp.max(jnp.abs(got - want)))
assert avg_err < one_err * 0.6, (avg_err, one_err)
print("COMPRESSED-PSUM-OK")
""")
    assert "COMPRESSED-PSUM-OK" in out


def test_sharded_itis_pipeline():
    """Per-shard TC → prototype all-gather (hierarchical ITIS) preserves the
    size guarantee and the reduction factor on an 8-way mesh."""
    out = _run("""
from functools import partial
from jax.experimental.shard_map import shard_map
from repro.core import threshold_clustering, itis

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(256, 2)), jnp.float32)

def shard_tc(x_local):
    r = threshold_clustering(x_local, 2, key=jax.random.PRNGKey(0))
    return r.labels, r.n_clusters.reshape(1)

# check_rep=False: the MIS while-loop has no replication rule on jax 0.4.x
labels, ncs = shard_map(shard_tc, mesh=mesh, in_specs=P("data", None),
                        out_specs=(P("data"), P("data")), check_rep=False)(x)
labels = np.asarray(labels).reshape(8, 32)
for s in range(8):
    lab = labels[s]
    sizes = np.bincount(lab[lab >= 0])
    assert sizes[sizes > 0].min() >= 2, s
assert int(np.asarray(ncs).sum()) <= 128
print("SHARDED-TC-OK")
""")
    assert "SHARDED-TC-OK" in out


def test_sharded_ihtc_matches_single_device():
    """The tentpole parity contract (DESIGN.md §4.3): the end-to-end sharded
    IHTC — ring-kNN TC, distributed Luby MIS, folded prototype reduce,
    mesh-aware k-means — produces labels *bit-for-bit identical* to the
    single-device ihtc() at t=3, m=2 on an 8-device mesh. n=576 divides
    evenly through both levels (576 → 192 → 64), so both paths compute in
    identical buffers."""
    out = _run("""
from repro.core import ihtc
from repro.core.distributed import ihtc_sharded, make_data_mesh

rng = np.random.default_rng(0)
mus = np.array([[1, 2], [7, 8], [3, 5]], float)
sds = np.array([[1, 0.5], [2, 1], [3, 4]], float) ** 0.5
comp = rng.choice(3, size=576, p=[0.5, 0.3, 0.2])
x = jnp.asarray(mus[comp] + rng.normal(size=(576, 2)) * sds[comp], jnp.float32)

res1 = ihtc(x, 3, 2, "kmeans", k=3, key=jax.random.PRNGKey(7))
res2 = ihtc_sharded(x, 3, 2, "kmeans", k=3, key=jax.random.PRNGKey(7),
                    mesh=make_data_mesh())
l1, l2 = np.asarray(res1.labels), np.asarray(res2.labels)
assert l1.min() >= 0
assert np.array_equal(l1, l2), (l1 != l2).sum()
p1, p2 = np.asarray(res1.protos), np.asarray(res2.protos)
assert np.array_equal(p1.view(np.uint32), p2.view(np.uint32))
assert int(res1.n_prototypes) == int(res2.n_prototypes)
# the mesh= kwarg on the public API dispatches to the same path
res3 = ihtc(x, 3, 2, "kmeans", k=3, key=jax.random.PRNGKey(7),
            mesh=make_data_mesh())
assert np.array_equal(l1, np.asarray(res3.labels))
# dispatch resolved via RuntimeConfig (no kwargs): same bits again, and a
# configured mesh shards the plain ihtc() call
from repro import runtime
with runtime.configure(mesh=make_data_mesh()):
    res4 = ihtc(x, 3, 2, "kmeans", k=3, key=jax.random.PRNGKey(7))
assert np.array_equal(l1, np.asarray(res4.labels))
assert np.array_equal(p1.view(np.uint32),
                      np.asarray(res4.protos).view(np.uint32))
# the fitted index serves the mesh-fitted result identically; batch 100
# is not divisible by the 8 devices (exercises the shard-pad path), and
# assign under a configured mesh matches the single-device assign
from repro.core import ClusterIndex
idx1 = ClusterIndex.build(res1)
idx2 = ClusterIndex.build(res2)
q = x[:100]
want = np.asarray(idx1.assign(q))
assert np.array_equal(want, np.asarray(idx2.assign(q)))
with runtime.configure(mesh=make_data_mesh()):
    got = np.asarray(idx2.replicate(make_data_mesh()).assign(q))
assert np.array_equal(want, got)
print("SHARDED-IHTC-PARITY-OK")
""")
    assert "SHARDED-IHTC-PARITY-OK" in out


def test_sharded_ihtc_padded_sizes_and_guarantee():
    """Non-divisible n exercises the validity-masked level padding: the
    (t*)^m size guarantee and mass conservation must still hold."""
    out = _run("""
from repro.core.distributed import ihtc_sharded, itis_sharded, make_data_mesh

rng = np.random.default_rng(1)
x = jnp.asarray(rng.normal(size=(500, 3)), jnp.float32)
mesh = make_data_mesh()
r = itis_sharded(x, 2, 3, mesh=mesh)
assert abs(float(jnp.sum(jnp.where(r.valid, r.mass, 0.0))) - 500) < 1e-3
res = ihtc_sharded(x, 2, 3, "kmeans", k=3, mesh=mesh)
lab = np.asarray(res.labels)
assert lab.shape == (500,) and lab.min() >= 0
sizes = np.bincount(lab)
assert sizes[sizes > 0].min() >= 2 ** 3
print("SHARDED-IHTC-PADDED-OK")
""")
    assert "SHARDED-IHTC-PADDED-OK" in out


def test_streamed_ingestion_feeds_sharded_pipeline():
    """data.stream_to_mesh places host-sized chunks shard-by-shard; the
    assembled array equals the direct concatenation and drives IHTC."""
    out = _run("""
from repro.data import PointStreamConfig, point_chunks, stream_to_mesh
from repro.core.distributed import ihtc_sharded, make_data_mesh

mesh = make_data_mesh()
cfg = PointStreamConfig(n=5000, d=2, chunk=700, seed=3, kind="gmm")
x, valid = stream_to_mesh(point_chunks(cfg), mesh, cfg.n, cfg.d)
assert x.shape[0] % 8 == 0 and x.shape[1] == 2
full = np.concatenate([c for c in point_chunks(cfg)])
assert np.array_equal(np.asarray(x)[np.asarray(valid)], full)
res = ihtc_sharded(x, 2, 2, "kmeans", k=3, valid=valid, mesh=mesh)
lab = np.asarray(res.labels)
v = np.asarray(valid)
assert lab[v].min() >= 0 and (lab[~v] == -1).all()
print("STREAM-INGEST-OK")
""")
    assert "STREAM-INGEST-OK" in out


def test_fit_executor_matrix_bit_identical():
    """The planner's equivalence contract (DESIGN.md §13): on an aligned
    config — one chunk-aligned level-0 buffer, a non-overflowing reservoir,
    every level size dividing the 8-way shard multiple — all four executors
    (memory / sharded / streaming / streaming_sharded) produce bit-identical
    labels, prototypes and masses through one repro.fit() entry point."""
    out = _run("""
import repro
from repro.core import make_data_mesh

rng = np.random.default_rng(0)
mus = np.array([[1, 2], [7, 8], [3, 5]], float)
sds = np.array([[1, 0.5], [2, 1], [3, 4]], float) ** 0.5
comp = rng.choice(3, size=512, p=[0.5, 0.3, 0.2])
x_np = (mus[comp] + rng.normal(size=(512, 2)) * sds[comp]).astype(np.float32)
x = jnp.asarray(x_np)
mesh = make_data_mesh()
key = jax.random.PRNGKey(7)

r_mem = repro.fit(x, 2, 2, "kmeans", k=3, key=key, executor="memory")
r_sh = repro.fit(x, 2, 2, "kmeans", k=3, key=key, executor="sharded",
                 mesh=mesh)
r_st = repro.fit(iter([x_np]), 2, 2, "kmeans", k=3, key=key,
                 executor="streaming", chunk_n=512, reservoir_n=1024)
r_co = repro.fit(iter([x_np]), 2, 2, "kmeans", k=3, key=key,
                 executor="streaming_sharded", chunk_n=512,
                 reservoir_n=1024, mesh=mesh)
assert [r.executor for r in (r_mem, r_sh, r_st, r_co)] == [
    "memory", "sharded", "streaming", "streaming_sharded"]

want = np.asarray(r_mem.labels)
assert want.min() >= 0
assert np.array_equal(want, np.asarray(r_sh.labels))
assert np.array_equal(want, r_st.labels_for(0))
assert np.array_equal(want, r_co.labels_for(0))
pm = np.asarray(r_mem.protos).view(np.uint32)
mm = np.asarray(r_mem.proto_mass).view(np.uint32)
for r in (r_sh, r_st, r_co):
    assert np.array_equal(pm, np.asarray(r.protos).view(np.uint32))
    assert np.array_equal(mm, np.asarray(r.proto_mass).view(np.uint32))
    assert int(r.n_prototypes) == int(r_mem.n_prototypes)

# the frozen artifact serves identically from every executor's result
q = x[:100]
want_q = np.asarray(r_mem.to_index().assign(q))
for r in (r_sh, r_st, r_co):
    assert np.array_equal(want_q, np.asarray(r.to_index().assign(q)))
print("FIT-MATRIX-OK")
""")
    assert "FIT-MATRIX-OK" in out


def test_pipelined_ingest_matrix_bit_identical():
    """The §18 extension of the executor matrix: the streaming executors
    stay bit-identical to the in-memory reference on the aligned config —
    and to their own serial loop on a cascading multi-chunk stream — for
    every prefetch_depth in {0, 1, 3} x donation on/off, on a real 8-way
    mesh."""
    out = _run("""
import repro
from repro.core import make_data_mesh

rng = np.random.default_rng(0)
mus = np.array([[1, 2], [7, 8], [3, 5]], float)
sds = np.array([[1, 0.5], [2, 1], [3, 4]], float) ** 0.5
comp = rng.choice(3, size=512, p=[0.5, 0.3, 0.2])
x_np = (mus[comp] + rng.normal(size=(512, 2)) * sds[comp]).astype(np.float32)
mesh = make_data_mesh()
key = jax.random.PRNGKey(7)
GRID = [(dep, don) for dep in (0, 1, 3) for don in (False, True)]

# aligned single-buffer stream: every cell == the in-memory bits
want = repro.fit(jnp.asarray(x_np), 2, 2, "kmeans", k=3, key=key,
                 executor="memory")
wl = np.asarray(want.labels)
wp = np.asarray(want.protos).view(np.uint32)
wm = np.asarray(want.proto_mass).view(np.uint32)
for ex, kw in (("streaming", {}), ("streaming_sharded", {"mesh": mesh})):
    for dep, don in GRID:
        r = repro.fit(iter([x_np]), 2, 2, "kmeans", k=3, key=key,
                      executor=ex, chunk_n=512, reservoir_n=1024,
                      prefetch_depth=dep, donate_stream=don, **kw)
        assert np.array_equal(wl, r.labels_for(0)), (ex, dep, don)
        assert np.array_equal(wp, np.asarray(r.protos).view(np.uint32)), (ex, dep, don)
        assert np.array_equal(wm, np.asarray(r.proto_mass).view(np.uint32)), (ex, dep, don)

# cascading multi-chunk stream: every cell == that executor's serial loop
n, chunk = 4096, 512
comp2 = rng.choice(3, size=n, p=[0.5, 0.3, 0.2])
y = (mus[comp2] + rng.normal(size=(n, 2)) * sds[comp2]).astype(np.float32)
mk = lambda: iter([y[lo:lo + chunk] for lo in range(0, n, chunk)])
for ex, kw in (("streaming", {}), ("streaming_sharded", {"mesh": mesh})):
    ref = repro.fit(mk(), 2, 2, "kmeans", k=3, key=key, executor=ex,
                    chunk_n=chunk, reservoir_n=640, prefetch_depth=0, **kw)
    assert ref.n_cascades >= 1
    rl = ref.labels()
    rp = np.asarray(ref.protos).view(np.uint32)
    for dep, don in GRID[2:]:
        r = repro.fit(mk(), 2, 2, "kmeans", k=3, key=key, executor=ex,
                      chunk_n=chunk, reservoir_n=640, prefetch_depth=dep,
                      donate_stream=don, **kw)
        assert np.array_equal(rl, r.labels()), (ex, dep, don)
        assert np.array_equal(rp, np.asarray(r.protos).view(np.uint32)), (ex, dep, don)
print("PIPELINED-MATRIX-OK")
""")
    assert "PIPELINED-MATRIX-OK" in out


def test_mesh_place_slab_reshards_device_resident():
    """Satellite regression: _MeshPlacement.place_slab must reshard
    device-resident slabs directly (device_put on a jax array) instead of
    round-tripping through jnp.asarray — an already-replicated slab passes
    through untouched (the device_put no-op fast path), a row-sharded slab
    reshards to the replicated layout bit-for-bit, and host numpy slabs
    still place."""
    out = _run("""
from repro.core.plan import plan_fit
from repro.core.streaming import _MeshPlacement
from repro.core import make_data_mesh

mesh = make_data_mesh()
plan = plan_fit(None, 2, 2, "kmeans", k=3, executor="streaming_sharded",
                chunk_n=64, reservoir_n=128, mesh=mesh)
pl = _MeshPlacement(plan, d=2)
rng = np.random.default_rng(0)
px = rng.normal(size=(64, 2)).astype(np.float32)
pm = np.ones((64,), np.float32)
pv = np.ones((64,), bool)

# host slabs place and replicate
hx, hm, hv = pl.place_slab(px, pm, pv)
assert hx.sharding == pl._rep and hm.sharding == pl._rep
assert np.array_equal(np.asarray(hx), px)

# an already-replicated device slab passes through as the same object
gx, gm, gv = pl.place_slab(hx, hm, hv)
assert gx is hx and gm is hm and gv is hv

# a row-sharded device slab (a sharded level-step output) reshards
# device-to-device, bit-for-bit
sx = jax.device_put(jnp.asarray(px), pl._row)
rx, rm, rv = pl.place_slab(sx, hm, hv)
assert rx.sharding == pl._rep
assert np.array_equal(np.asarray(rx).view(np.uint32), px.view(np.uint32))
print("PLACE-SLAB-OK")
""")
    assert "PLACE-SLAB-OK" in out


def test_composed_executor_multichunk_invariants():
    """The composed streaming+sharded path under real cascade pressure:
    host chunks reduced by sharded level steps into a bounded mesh-sharded
    reservoir must hold coverage, mass conservation, the (t*)^m size
    guarantee and GMM accuracy — and a configured mesh must select it
    automatically for chunk-stream inputs."""
    out = _run("""
import repro
from repro import runtime
from repro.core import make_data_mesh
from repro.cluster.metrics import clustering_accuracy

rng = np.random.default_rng(0)
mus = np.array([[1, 2], [7, 8], [3, 5]], float)
sds = np.array([[1, 0.5], [2, 1], [3, 4]], float) ** 0.5
n, chunk, t, m = 4096, 512, 2, 2
comp = rng.choice(3, size=n, p=[0.5, 0.3, 0.2])
x = (mus[comp] + rng.normal(size=(n, 2)) * sds[comp]).astype(np.float32)
chunks = [x[lo:lo + chunk] for lo in range(0, n, chunk)]

with runtime.configure(mesh=make_data_mesh()):
    res = repro.fit(iter(chunks), t, m, "kmeans", k=3, chunk_n=chunk,
                    reservoir_n=640, key=jax.random.PRNGKey(0))
assert res.executor == "streaming_sharded"
assert res.n_chunks == n // chunk
assert res.n_cascades >= 1  # the bounded reservoir actually cascaded
lab = res.labels()
assert lab.shape == (n,)
assert lab.min() >= 0
mass = np.asarray(res.proto_mass)[np.asarray(res.proto_valid)]
assert abs(mass.sum() - n) < 1e-2
sizes = np.bincount(lab)
assert sizes[sizes > 0].min() >= t ** m
assert clustering_accuracy(comp, lab, 3) > 0.85
# ragged tail + (chunk, n_valid) pair + empty chunk through the same path
pairs = [(x[:256], 256), x[256:512], np.zeros((0, 2), np.float32),
         x[512:700]]
res2 = repro.fit(iter(pairs), 2, 2, "kmeans", k=3, chunk_n=256,
                 mesh=make_data_mesh(), key=jax.random.PRNGKey(2))
assert [len(l) for l in res2.iter_labels()] == [256, 256, 0, 188]
assert res2.labels().min() >= 0
print("COMPOSED-INVARIANTS-OK")
""")
    assert "COMPOSED-INVARIANTS-OK" in out
