"""Distribution correctness on a real multi-device (8× CPU) mesh.

These tests run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_
device_count=8 (conftest keeps the main test process at 1 device), and
assert numerical equality between sharded and single-device execution for:
pjit'd train step, ring-kNN vs exact kNN, compressed psum, sharded TC.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_SCRIPT_COMMON = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
assert len(jax.devices()) == 8
"""


def _run(body: str):
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT_COMMON + body],
        capture_output=True, text=True, timeout=420,
        env=dict(os.environ, PYTHONPATH=SRC),
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    out = _run("""
from repro.configs import ARCHS, SHAPES, smoke_config
from repro.models import build
from repro.models.transformer import ShardingPlan
from repro.data import make_batch
from repro.train import OptConfig, init_opt_state, make_train_step
from repro.launch.mesh import make_debug_mesh

cfg = smoke_config(ARCHS["qwen2.5-32b"])
bundle = build(cfg)
params = bundle.init(jax.random.PRNGKey(0))
opt = init_opt_state(params)
batch = make_batch(cfg, SHAPES["train_4k"], 0, batch_override=4, seq_override=16)
ocfg = OptConfig(peak_lr=1e-2, warmup_steps=2, decay_steps=10)

# single device
step1 = jax.jit(make_train_step(bundle, ocfg))
p1, _, m1 = step1(params, opt, batch)

# 2x4 mesh, fully sharded
mesh = make_debug_mesh(2, 4)
pspecs = bundle.param_specs(tp="model", tp_size=4)
plan = ShardingPlan(resid=P("data", None, None), logits=P("data", None, "model"))
shard = lambda tree, specs: jax.tree_util.tree_map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs,
    is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape"))
with mesh:
    ps = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, pspecs)
    bs = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, NamedSharding(mesh, P("data"))), batch)
    step2 = jax.jit(make_train_step(bundle, ocfg, plan=plan))
    p2, _, m2 = step2(ps, opt, bs)
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2, (m1["loss"], m2["loss"])
for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
    # bf16 matmuls reduce in different orders across shardings: tolerate
    # ~1 bf16 ulp of drift on a handful of elements
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               atol=1.5e-2)
print("TRAIN-STEP-PARITY-OK")
""")
    assert "TRAIN-STEP-PARITY-OK" in out


def test_ring_knn_matches_exact():
    out = _run("""
from functools import partial
from jax.experimental.shard_map import shard_map
from repro.core.knn import ring_knn, knn_graph

mesh = jax.make_mesh((8,), ("data",))
n, d, k = 64, 3, 4
x = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)

fn = shard_map(
    partial(ring_knn, k=k, axis_name="data", impl="ref"),
    mesh=mesh, in_specs=P("data", None), out_specs=P("data", None),
)
rd, ri = fn(x)
wd, wi = knn_graph(x, k, impl="ref")
np.testing.assert_allclose(np.asarray(rd), np.asarray(wd), rtol=1e-5, atol=1e-5)
np.testing.assert_array_equal(np.asarray(ri), np.asarray(wi))
print("RING-KNN-OK")
""")
    assert "RING-KNN-OK" in out


def test_compressed_psum_error_feedback():
    out = _run("""
from functools import partial
from jax.experimental.shard_map import shard_map
from repro.train.compression import compressed_psum, psum_with_error_feedback

mesh = jax.make_mesh((8,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 128), jnp.float32)

# one-shot compressed mean close to the true mean
got = shard_map(partial(compressed_psum, axis_name="pod"), mesh=mesh,
                in_specs=P("pod", None), out_specs=P("pod", None))(x)
want = jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)
rel = float(jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
assert rel < 0.02, rel

# error feedback: accumulated mean over steps converges (bias ~ O(q^2))
def step(x, err):
    return shard_map(partial(psum_with_error_feedback, axis_name="pod"),
                     mesh=mesh, in_specs=(P("pod", None), P("pod", None)),
                     out_specs=(P("pod", None), P("pod", None)))(x, err)
err = jnp.zeros_like(x)
tot = jnp.zeros_like(x)
for _ in range(16):
    o, err = step(x, err)
    tot = tot + o
avg_err = float(jnp.max(jnp.abs(tot / 16 - want)))
one_err = float(jnp.max(jnp.abs(got - want)))
assert avg_err < one_err * 0.6, (avg_err, one_err)
print("COMPRESSED-PSUM-OK")
""")
    assert "COMPRESSED-PSUM-OK" in out


def test_sharded_itis_pipeline():
    """Per-shard TC → prototype all-gather (hierarchical ITIS) preserves the
    size guarantee and the reduction factor on an 8-way mesh."""
    out = _run("""
from functools import partial
from jax.experimental.shard_map import shard_map
from repro.core import threshold_clustering, itis

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(256, 2)), jnp.float32)

def shard_tc(x_local):
    r = threshold_clustering(x_local, 2, key=jax.random.PRNGKey(0))
    return r.labels, r.n_clusters.reshape(1)

labels, ncs = shard_map(shard_tc, mesh=mesh, in_specs=P("data", None),
                        out_specs=(P("data"), P("data")))(x)
labels = np.asarray(labels).reshape(8, 32)
for s in range(8):
    lab = labels[s]
    sizes = np.bincount(lab[lab >= 0])
    assert sizes[sizes > 0].min() >= 2, s
assert int(np.asarray(ncs).sum()) <= 128
print("SHARDED-TC-OK")
""")
    assert "SHARDED-TC-OK" in out
