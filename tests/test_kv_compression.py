"""IHTC KV-cache prototype compression (serve/kv_compression.py).

Key exactness property: if every cluster's keys are IDENTICAL, attention
over prototypes with +log(mass) bias equals attention over the raw cache
exactly (softmax mass correction) — the paper's bottleneck objective bounds
the error in the general case."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.kernels import ref
from repro.models import build
from repro.serve import ServeConfig, ServeEngine
from repro.serve.kv_compression import compress_cache, compress_model_caches


def test_duplicate_keys_exactness(rng):
    """Duplicated KV entries compress losslessly (log-mass bias is exact)."""
    hd, n_unique, dup = 8, 16, 2
    k_unique = rng.normal(size=(n_unique, hd)).astype(np.float32)
    v_unique = rng.normal(size=(n_unique, hd)).astype(np.float32)
    k_full = np.repeat(k_unique, dup, axis=0)  # 32 entries, clusters of 2
    v_full = np.repeat(v_unique, dup, axis=0)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)

    cache = {
        "k": jnp.asarray(k_full)[None, None],
        "v": jnp.asarray(v_full)[None, None],
        "pos": jnp.asarray(n_unique * dup, jnp.int32),
    }
    comp = compress_cache(cache, t=2, m=1, tail=4, impl="ref")
    assert comp["k"].shape[2] == n_unique + 4

    out_full = ref.flash_attention(
        q, cache["k"][:, :1], cache["v"][:, :1], causal=False)
    # mask the unwritten tail slots (the serving path does this through the
    # position mask; calling ref directly we must do it ourselves)
    total = comp["k"].shape[2]
    tail_mask = jnp.where(jnp.arange(total) < int(comp["pos"]), 0.0, -1e30)
    bias = comp["bias"][:, :1] + tail_mask[None, None, :]
    out_comp = ref.flash_attention(
        q, comp["k"][:, :1], comp["v"][:, :1], causal=False, kv_bias=bias)
    np.testing.assert_allclose(np.asarray(out_comp), np.asarray(out_full),
                               rtol=2e-3, atol=2e-3)


def test_compressed_cache_mass_conserved(rng):
    S, hd = 64, 8
    cache = {
        "k": jnp.asarray(rng.normal(size=(2, 2, S, hd)), jnp.float32),
        "v": jnp.asarray(rng.normal(size=(2, 2, S, hd)), jnp.float32),
        "pos": jnp.asarray(S, jnp.int32),
    }
    comp = compress_cache(cache, t=2, m=2, tail=8, impl="ref")
    P = S // 4
    assert comp["k"].shape[2] == P + 8
    mass = np.asarray(comp["mass"])[:, :, :P]
    bias = np.asarray(comp["bias"])[:, :, :P]
    got = mass[bias > -1e29].sum(axis=-1) if mass.ndim == 1 else None
    total = np.where(bias > -1e29, mass, 0.0).sum(axis=-1)
    np.testing.assert_allclose(total, S, atol=1e-3)


def test_decode_quality_on_clustered_keys(rng):
    """Keys with genuine cluster structure: compressed decode must stay close
    (error bounded by cluster radius — the TC bottleneck objective)."""
    cfg = smoke_config(ARCHS["qwen2.5-32b"])
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, S = 1, 48
    # token stream with heavy repetition → clusterable K vectors
    toks = jnp.asarray(rng.integers(0, 6, size=(B, S)), jnp.int32)
    caches = bundle.init_caches(B, S + 8)
    lg, caches = bundle.prefill(params, caches, {"tokens": toks})
    comp = compress_model_caches(caches, 2, 1, tail=8, impl="ref")
    nxt = jnp.argmax(lg[:, -1], -1)[:, None]
    l1, _ = bundle.decode_step(params, caches, {"tokens": nxt})
    l2, _ = bundle.decode_step(params, comp, {"tokens": nxt})
    p1 = jax.nn.softmax(l1[:, -1].astype(jnp.float32), -1)
    p2 = jax.nn.softmax(l2[:, -1].astype(jnp.float32), -1)
    tv = 0.5 * float(jnp.sum(jnp.abs(p1 - p2)))
    assert tv < 0.25, tv
    # random-init logits are near-flat, so exact argmax is brittle; require
    # the exact top-1 to stay in the compressed top-5
    top5 = jnp.argsort(-p2[0])[:5]
    assert int(jnp.argmax(p1)) in [int(i) for i in top5]


def test_engine_generates_with_recompression(rng):
    cfg = smoke_config(ARCHS["gemma2-2b"])
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 24)), jnp.int32)
    eng = ServeEngine(bundle, params, ServeConfig(
        max_new_tokens=16, compress=True, compress_t=2, compress_m=1,
        compress_tail=8))
    out = eng.generate({"tokens": toks})
    assert out["tokens"].shape == (2, 16)
    assert out["compressions"] >= 1
    assert not bool(jnp.any(out["tokens"] < 0))


def test_engine_plain_greedy_deterministic(rng):
    cfg = smoke_config(ARCHS["minitron-8b"])
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 8)), jnp.int32)
    eng = ServeEngine(bundle, params, ServeConfig(max_new_tokens=8))
    a = eng.generate({"tokens": toks})["tokens"]
    b = eng.generate({"tokens": toks})["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
