"""Per-kernel allclose validation: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in repro.kernels.ref, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,m,d", [(7, 9, 3), (64, 64, 8), (130, 257, 33), (1, 5, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2(rng, n, m, d, dtype):
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    y = jnp.asarray(rng.normal(size=(m, d)), dtype)
    got = ops.pairwise_sq_l2(x, y, impl="pallas")
    want = ref.pairwise_sq_l2(x, y)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_pairwise_l2_valid_mask(rng):
    x = jnp.asarray(rng.normal(size=(20, 4)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(31, 4)), jnp.float32)
    v = jnp.asarray(rng.random(31) > 0.4)
    got = ops.pairwise_sq_l2(x, y, y_valid=v, impl="pallas")
    want = ref.pairwise_sq_l2(x, y, y_valid=v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,d,k", [(16, 2, 1), (50, 3, 4), (129, 5, 8)])
def test_knn_topk(rng, n, d, k):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    gd, gi = ops.knn(x, k, impl="pallas")
    wd, wi = ref.knn(x, k)
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(gi, wi)


def test_knn_topk_masked(rng):
    x = jnp.asarray(rng.normal(size=(40, 3)), jnp.float32)
    valid = jnp.asarray(rng.random(40) > 0.5)
    gd, gi = ops.knn(x, 3, valid=valid, impl="pallas")
    wd, wi = ref.knn(x, 3, valid=valid)
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(gi, wi)


def test_knn_insufficient_candidates(rng):
    """Fewer valid points than k: unfilled slots must be (inf, -1)."""
    x = jnp.asarray(rng.normal(size=(5, 2)), jnp.float32)
    gd, gi = ops.knn(x, 8, impl="pallas")
    assert np.all(np.asarray(gi[:, 4:]) == -1)
    assert np.all(np.isinf(np.asarray(gd[:, 4:])))


@pytest.mark.parametrize("n,bq,bk", [
    (300, 256, 512),   # regression: pad=max(212, 0) left 512 % 300 != 0
    (300, 512, 256),   # bq clamps to 300; bk must shrink to a divisor
    (260, 256, 96),    # bk does not divide the bq-padded count
    (7, 256, 512),     # sub-minimum n pads to the floor of 8 rows
])
def test_knn_topk_ragged_blocks(rng, n, bq, bk):
    """Awkward (n, block) combinations must still tile the BlockSpec grid
    exactly (both grid axes cover the padded rows with zero remainder)."""
    from repro.kernels.knn_topk import knn_topk

    x = jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)
    k = min(4, n - 1)
    gd, gi = knn_topk(x, k, block_q=bq, block_k=bk, interpret=True)
    wd, wi = ref.knn(x, k)
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(gi, wi)


def test_unknown_impl_rejected_loudly(rng):
    """Regression: an unknown impl string used to fall through silently to
    the XLA reference path — a typo'd impl= would quietly benchmark (or
    ship) the wrong kernel. Every ops entry point must reject it with the
    registered list."""
    x = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
    ids = jnp.zeros((8,), jnp.int32)
    with pytest.raises(ValueError, match=r"registered impls.*pallas"):
        ops.knn(x, 2, impl="palas")  # the typo that motivated this
    with pytest.raises(ValueError, match="unknown impl 'xla'"):
        ops.pairwise_sq_l2(x, x, impl="xla")
    with pytest.raises(ValueError, match="unknown impl"):
        ops.segment_sum(x, ids, 2, impl="cuda")
    with pytest.raises(ValueError, match="unknown impl"):
        ops.blocked_segment_sum(x, ids, 2, n_blocks=2, impl="bogus")
    # the valid spellings still resolve (auto included)
    for impl in ("auto", "ref"):
        ops.knn(x, 2, impl=impl)


@pytest.mark.parametrize("n,d,s", [(10, 3, 4), (100, 7, 13), (257, 2, 64)])
def test_segment_sum(rng, n, d, s):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(-1, s + 1, size=n), jnp.int32)  # incl. OOB
    w = jnp.asarray(rng.random(n), jnp.float32)
    gs, gm = ops.segment_sum(x, ids, s, weights=w, impl="pallas")
    ws, wm = ref.segment_sum(x, ids, s, weights=w)
    np.testing.assert_allclose(gs, ws, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gm, wm, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d,s,n_blocks", [(64, 3, 8, 8), (100, 2, 5, 8),
                                            (30, 4, 6, 4), (16, 2, 3, 1)])
def test_blocked_segment_sum_matches_plain(rng, n, d, s, n_blocks):
    """The fixed-fold variant is the same function as plain segment_sum up
    to float summation order (exact on integer-valued masses)."""
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(-1, s + 1, size=n), jnp.int32)  # incl. OOB
    w = jnp.asarray(rng.random(n), jnp.float32)
    gs, gm = ops.blocked_segment_sum(x, ids, s, weights=w, n_blocks=n_blocks,
                                     impl="ref")
    ws, wm = ref.segment_sum(x, ids, s, weights=w)
    np.testing.assert_allclose(gs, ws, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gm, wm, rtol=1e-5, atol=1e-5)


def test_blocked_segment_sum_shard_fold_identity(rng):
    """Bitwise contract used by the distributed pipeline (DESIGN.md §4.3):
    per-block partials folded left in block order == blocked_segment_sum."""
    n, d, s, B = 64, 3, 7, 8
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, s, size=n), jnp.int32)
    w = jnp.asarray(rng.random(n), jnp.float32)
    gs, gm = ops.blocked_segment_sum(x, ids, s, weights=w, n_blocks=B,
                                     impl="ref")
    nb = n // B
    acc_s = acc_m = None
    for b in range(B):  # what each shard computes, folded in shard order
        sl = slice(b * nb, (b + 1) * nb)
        ps, pm = ops.segment_sum(x[sl], ids[sl], s, weights=w[sl], impl="ref")
        acc_s = ps if acc_s is None else acc_s + ps
        acc_m = pm if acc_m is None else acc_m + pm
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(acc_s))
    np.testing.assert_array_equal(np.asarray(gm), np.asarray(acc_m))


@pytest.mark.parametrize("lq,lk", [(8, 8), (1, 33), (17, 64), (64, 17)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(rng, lq, lk, causal):
    if causal and lq > lk:
        pytest.skip("causal requires lq <= lk")
    b, h, dh = 2, 4, 16
    q = jnp.asarray(rng.normal(size=(b, h, lq, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, lk, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, lk, dh)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, impl="pallas")
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_gqa_bias_softcap(rng):
    b, hq, hkv, l, dh = 2, 8, 2, 24, 16
    q = jnp.asarray(rng.normal(size=(b, hq, l, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, l, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, l, dh)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(b, hkv, l)), jnp.float32)
    got = ops.flash_attention(q, k, v, kv_bias=bias, logit_softcap=30.0,
                              impl="pallas")
    want = ops.flash_attention(q, k, v, kv_bias=bias, logit_softcap=30.0,
                               impl="ref")
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_xla_chunked_attention_matches_ref(rng):
    """The production XLA flash path (grouped GQA, chunked) vs oracle."""
    from repro.models.attention import chunked_attention

    b, hq, hkv, lq, lk, dh = 2, 6, 2, 33, 70, 8
    q = jnp.asarray(rng.normal(size=(b, hq, lq, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, lk, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, lk, dh)), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, chunk=16)
    kr = jnp.repeat(k, 3, axis=1)
    vr = jnp.repeat(v, 3, axis=1)
    want = ref.flash_attention(q, kr, vr, causal=True)
    # production path keeps the PV matmul in bf16 (see attention.py) ⇒ ~1e-2
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_xla_chunked_attention_window(rng):
    from repro.models.attention import chunked_attention

    b, h, l, dh = 1, 2, 40, 8
    q = jnp.asarray(rng.normal(size=(b, h, l, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, l, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, l, dh)), jnp.float32)
    w = 8
    got = chunked_attention(q, k, v, causal=True, window=w, chunk=16)
    # brute force windowed-causal reference
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / (dh**0.5)
    iq = jnp.arange(l)
    mask = (iq[None, :] <= iq[:, None]) & (iq[None, :] > iq[:, None] - w)
    logits = jnp.where(mask, logits, -1e30)
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(logits, -1), vf)
    # bf16 PV matmul in the production path ⇒ ~1e-2 agreement
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
