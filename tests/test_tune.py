"""The empirical autotuner (DESIGN.md §14): cache round-trips, the tune
policy in RuntimeConfig/dispatch_key, plan_fit + ops consulting measured
winners, onthefly population, and the management CLI."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
import repro.tune as tune
from repro import runtime
from repro.core.knn import AUTO_KNN_BLOCK, resolve_auto_block
from repro.core.plan import plan_fit
from repro.kernels import ops, ref
from repro.runtime.config import RuntimeConfig, config_from_env
from repro.tune.autotune import current_device_kind
from repro.tune.cache import TuningCache, make_key, split_key


@pytest.fixture
def cache(tmp_path):
    """Point the process-global cache at a throwaway file; restore after."""
    prev = tune.get_cache()
    c = tune.set_cache(str(tmp_path / "tune_cache.json"))
    yield c
    tune.set_cache(prev)


DK = current_device_kind()


# ----------------------------------------------------------- cache layer


def test_pow2_bucket_and_shape_bucket():
    assert [tune.pow2_bucket(v) for v in (1, 2, 3, 1000, 1024, 1025)] == \
        [1, 2, 4, 1024, 1024, 2048]
    assert tune.shape_bucket(n=3000, d=5) == "d8,n4096"
    assert tune.shape_bucket(n=4096, d=8) == "d8,n4096"  # same bucket
    assert tune.shape_bucket() == "any"  # shape-free cells (stream)


def test_cache_roundtrip_and_key_layout(tmp_path):
    path = str(tmp_path / "c.json")
    c = TuningCache(path)
    assert c.lookup(DK, "knn", "d8,n4096") is None
    c.record(DK, "knn", "d8,n4096", {"impl": "ref", "block_q": 128},
             seconds=0.002, candidates=9)
    assert c.lookup(DK, "knn", "d8,n4096") == {"impl": "ref", "block_q": 128}
    # a different device kind / bucket / dtype never aliases
    assert c.lookup("TPU v4", "knn", "d8,n4096") is None
    assert c.lookup(DK, "knn", "d8,n8192") is None
    assert c.lookup(DK, "knn", "d8,n4096", dtype="bfloat16") is None
    # persisted eagerly: a fresh instance reads the same winner from disk
    assert TuningCache(path).lookup(DK, "knn", "d8,n4096")["block_q"] == 128
    blob = json.load(open(path))
    assert blob["version"] == 1
    key = next(iter(blob["entries"]))
    assert split_key(key) == (DK, "knn", "d8,n4096", "float32")
    assert make_key(DK, "knn", "d8,n4096", "float32") == key


def test_cache_prune_clear_and_entries(tmp_path):
    c = TuningCache(str(tmp_path / "c.json"))
    c.record(DK, "knn", "d8,n4096", {"impl": "ref"})
    c.record(DK, "segment_sum", "d8,n4096,s512", {"impl": "ref"})
    c.record("TPU v4", "knn", "d8,n4096", {"block_q": 512})
    assert len(c) == 3
    assert [k[1] for k, _ in c.entries()].count("knn") == 2
    assert c.prune(kernel="segment_sum") == 1
    assert c.prune(device_kind="TPU v4") == 1
    # age-based prune: backdate the survivor, then drop it
    key = make_key(DK, "knn", "d8,n4096", "float32")
    c._load()[key]["recorded_unix"] = 0.0
    assert c.prune(max_age_days=1.0) == 1
    c.record(DK, "knn", "d8,n4096", {"impl": "ref"})
    assert c.clear() == 1 and len(c) == 0


# ------------------------------------------------- config + dispatch_key


def test_tune_policy_validation_and_env():
    assert RuntimeConfig().tune == "off"
    assert RuntimeConfig(tune="cached").tune == "cached"
    with pytest.raises(ValueError, match="tune must be one of"):
        RuntimeConfig(tune="always")
    assert config_from_env({"REPRO_TUNE": "onthefly"}).tune == "onthefly"
    assert config_from_env({"REPRO_TUNE": "off"}) == RuntimeConfig()


def test_dispatch_key_carries_cache_epoch(cache):
    off = runtime.dispatch_key()
    cache.record(DK, "knn", "d8,n4096", {"impl": "ref"}, save=False)
    assert runtime.dispatch_key() == off  # tune off: cache churn is free
    with runtime.configure(tune="cached"):
        k1 = runtime.dispatch_key()
        assert k1 != off
        cache.record(DK, "knn", "d8,n8192", {"impl": "ref"}, save=False)
        k2 = runtime.dispatch_key()
    assert k2 != k1  # a mutated cache must retrace tuned programs


# --------------------------------------------------- plan_fit resolution


def test_plan_fit_consults_cache(rng, cache):
    """The acceptance contract: a populated cache changes the resolved
    block_q/knn_block frozen into the FitPlan; tune=off restores today's
    constants bit-for-bit."""
    x = jnp.asarray(rng.normal(size=(512, 4)), jnp.float32)
    cache.record(DK, "knn", tune.shape_bucket(n=512, d=4, k=1),
                 {"impl": "ref", "block_q": 128, "block_k": 1024})
    cache.record(DK, "knn_block", tune.shape_bucket(n=512, d=4, k=1),
                 {"knn_block": 4096})
    with runtime.configure(tune="cached"):
        tuned = plan_fit(x, 2, 1)
        assert (tuned.block_q, tuned.block_k) == (128, 1024)
        assert tuned.knn_block == 4096
        # explicit kwargs still beat the tuner
        pinned = plan_fit(x, 2, 1, block_q=64, knn_block=256)
        assert (pinned.block_q, pinned.knn_block) == (64, 256)
    with runtime.configure(tune="off"):
        off = plan_fit(x, 2, 1)
    default = plan_fit(x, 2, 1)  # process default: tune is off
    for plan in (off, default):
        assert (plan.block_q, plan.block_k) == (256, 512)
        assert plan.knn_block == 0


def test_fit_with_tuned_plan_matches_untuned_labels(rng, cache):
    """Tuned dispatch values change *where* work happens, never the
    result: a cached-tuned fit reproduces the untuned labels."""
    x = jnp.asarray(rng.normal(size=(256, 4)), jnp.float32)
    cache.record(DK, "knn", tune.shape_bucket(n=256, d=4, k=1),
                 {"impl": "ref", "block_q": 128, "block_k": 256})
    cache.record(DK, "knn_block", tune.shape_bucket(n=256, d=4, k=1),
                 {"knn_block": 2048})
    key = jax.random.PRNGKey(3)
    want = repro.fit(x, 2, 1, "kmeans", k=3, key=key)
    with runtime.configure(tune="cached"):
        got = repro.fit(x, 2, 1, "kmeans", k=3, key=key)
    np.testing.assert_array_equal(np.asarray(want.labels),
                                  np.asarray(got.labels))


def test_plan_fit_streaming_consults_stream_cell(rng, cache):
    x = rng.normal(size=(64, 3)).astype(np.float32)
    cache.record(DK, "stream", "any", {"chunk_n": 2048, "reservoir_n": 8192,
                                       "prefetch_depth": 2})
    with runtime.configure(tune="cached"):
        plan = plan_fit(iter([x]), 2, 1)
        assert (plan.chunk_n, plan.reservoir_n) == (2048, 8192)
        # depth 0 is the serial default, treated as auto: the measured
        # winner applies unless the caller pins a depth explicitly
        assert plan.prefetch_depth == 2
        assert plan_fit(iter([x]), 2, 1, prefetch_depth=0).prefetch_depth \
            == 0
        assert plan_fit(iter([x]), 2, 1, prefetch_depth=1).prefetch_depth \
            == 1
        # donation is never tuned
        assert plan.donate_stream is False
        # explicit values beat the tuned budget
        assert plan_fit(iter([x]), 2, 1, chunk_n=64).chunk_n == 64
    assert plan_fit(iter([x]), 2, 1).chunk_n == 0  # off: auto stays auto
    assert plan_fit(iter([x]), 2, 1).prefetch_depth == 0


def test_resolve_auto_block(cache):
    assert resolve_auto_block(100_000, 8, 3) == AUTO_KNN_BLOCK
    cache.record(DK, "knn_block",
                 tune.shape_bucket(n=100_000, d=8, k=3), {"knn_block": 4096})
    with runtime.configure(tune="cached"):
        assert resolve_auto_block(100_000, 8, 3) == 4096
        assert resolve_auto_block(50, 8, 3) == AUTO_KNN_BLOCK  # other bucket
    assert resolve_auto_block(100_000, 8, 3) == AUTO_KNN_BLOCK  # off


# ------------------------------------------------------ ops consultation


def test_ops_uses_tuned_impl_and_tiles(rng, cache):
    """A cached pallas winner (with tile sizes) flows through ops.knn and
    still matches the oracle — tuning redirects dispatch, not results."""
    x = jnp.asarray(rng.normal(size=(24, 3)), jnp.float32)
    cache.record(DK, "knn", tune.shape_bucket(n=24, d=3, k=2),
                 {"impl": "pallas", "block_q": 8, "block_k": 8})
    wd, wi = ref.knn(x, 2)
    with runtime.configure(tune="cached"):
        gd, gi = ops.knn(x, 2)  # impl="auto" -> tuned winner "pallas"
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(gi, wi)
    # an explicit impl= kwarg overrides the tuned winner
    with runtime.configure(tune="cached"):
        gd2, _ = ops.knn(x, 2, impl="ref")
    np.testing.assert_array_equal(np.asarray(gd2), np.asarray(wd))


def test_onthefly_measures_and_persists(rng, cache):
    x = jnp.asarray(rng.normal(size=(32, 3)), jnp.float32)
    assert len(cache) == 0
    with runtime.configure(tune="onthefly"):
        ops.knn(x, 2)
    params = cache.lookup(DK, "knn", tune.shape_bucket(n=32, d=3, k=2))
    assert params is not None and params["impl"] in ("pallas", "ref")
    # the winner survives a process restart (fresh instance, same file)
    assert TuningCache(cache.path).lookup(
        DK, "knn", tune.shape_bucket(n=32, d=3, k=2)) == params


def test_autotune_cell_records_winner(cache):
    params, sec = tune.autotune_cell(
        "knn", {"n": 32, "d": 3, "k": 2}, cache=cache, repeats=1)
    assert params == {"impl": "ref"}  # CPU: the reference always wins
    assert sec > 0
    rec = dict(cache.entries())[(DK, "knn", "d4,k2,n32", "float32")]
    assert rec["candidates"] == 1 and rec["params"] == params


# ----------------------------------------------------------------- CLI


def test_tune_cli_roundtrip(tmp_path, capsys):
    from repro.tune.__main__ import main

    path = str(tmp_path / "cli_cache.json")
    assert main(["--cache", path, "populate", "--kernels", "knn",
                 "--shapes", "32x3x2", "--repeats", "1"]) == 0
    assert main(["--cache", path, "show"]) == 0
    out = capsys.readouterr().out
    assert "knn" in out and "d4,k2,n32" in out
    assert main(["--cache", path, "prune", "--kernel", "knn"]) == 0
    assert main(["--cache", path, "clear"]) == 0
    assert main(["--cache", path, "populate", "--kernels", "bogus"]) == 2
    assert len(TuningCache(path)) == 0


# ------------------------------------------- stale-entry hardening (§14)


def test_stale_cache_unknown_impl_ignored_and_pruned(rng, cache):
    """A hand-corrupted cache file naming a deregistered/typo'd impl used
    to raise ValueError from ops._resolve mid-fit; it must now be ignored
    (constants win), warned about, and pruned from the file."""
    bucket = tune.shape_bucket(n=24, d=3, k=2)
    # corrupt the file by hand, bypassing record(): the entry survives a
    # reload exactly as a stale on-disk winner would
    blob = {"version": 1, "entries": {
        make_key(DK, "knn", bucket, "float32"):
            {"params": {"impl": "palas"}, "seconds": 0.001, "candidates": 9,
             "recorded_unix": 0},
    }}
    json.dump(blob, open(cache.path, "w"))
    cache.reload()
    x = jnp.asarray(rng.normal(size=(24, 3)), jnp.float32)
    wd, wi = ref.knn(x, 2)
    with runtime.configure(tune="cached"):
        with pytest.warns(RuntimeWarning, match="stale tuning-cache"):
            gd, gi = ops.knn(x, 2)  # no ValueError: falls back to constants
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    # pruned from memory AND from the file
    assert cache.lookup(DK, "knn", bucket) is None
    assert TuningCache(cache.path).lookup(DK, "knn", bucket) is None


def test_stale_cache_bad_tile_ignored_and_pruned(rng, cache):
    """A tile size that cannot divide a pow2 shape bucket (here 300) is
    rejected by the same gate instead of reaching the kernel."""
    bucket = tune.shape_bucket(n=24, d=3, k=2)
    cache.record(DK, "knn", bucket,
                 {"impl": "pallas", "block_q": 300, "block_k": 8})
    x = jnp.asarray(rng.normal(size=(24, 3)), jnp.float32)
    with runtime.configure(tune="cached"):
        with pytest.warns(RuntimeWarning, match="power of two"):
            gd, gi = ops.knn(x, 2)
    wd, wi = ref.knn(x, 2)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    assert cache.lookup(DK, "knn", bucket) is None


def test_stale_reason_catalogue():
    from repro.tune import _stale_reason

    assert _stale_reason({"impl": "ref"}) is None
    assert _stale_reason({"impl": "fused_int8", "block_k": 1024}) is None
    assert _stale_reason({"knn_block": 4096}) is None
    assert _stale_reason({"impl": "palas"}) is not None
    assert _stale_reason({"impl": "auto"}) is not None
    assert _stale_reason({"block_k": 300}) is not None
    assert _stale_reason({"block_q": 0}) is not None
    assert _stale_reason({"chunk_n": "big"}) is not None
    assert _stale_reason("not-a-dict") is not None
    # prefetch_depth is a queue depth, not a pow2 tile: 0 and 3 are fine,
    # negatives / non-ints are stale
    assert _stale_reason({"chunk_n": 2048, "prefetch_depth": 0}) is None
    assert _stale_reason({"chunk_n": 2048, "prefetch_depth": 3}) is None
    assert _stale_reason({"prefetch_depth": -1}) is not None
    assert _stale_reason({"prefetch_depth": True}) is not None
    assert _stale_reason({"prefetch_depth": "deep"}) is not None


def test_stale_prune_warning_points_at_the_caller(cache):
    """The prune warning carries ``stacklevel=2`` (WN601): its reported
    location must be the code that consulted the cache — this file — not
    a line inside ``repro/tune/__init__.py``, or ``-W error`` CI jobs and
    users chasing the warning land in library internals."""
    import warnings as _warnings

    bucket = tune.shape_bucket(n=24, d=3, k=2)
    cache.record(DK, "knn", bucket, {"impl": "not-an-impl"})
    with runtime.configure(tune="cached"):
        with _warnings.catch_warnings(record=True) as caught:
            _warnings.simplefilter("always")
            params = tune.tuned_params("knn", n=24, d=3, k=2)
    assert params == {}
    stale = [w for w in caught
             if "stale tuning-cache" in str(w.message)]
    assert len(stale) == 1
    assert stale[0].filename == __file__


# ------------------------------------------------- the "assign" cell (§16)


def test_autotune_assign_cell_records_and_serves(rng, cache):
    """The assign cell measures the fused + quantized candidates on any
    backend and the recorded winner drives ClusterIndex.assign dispatch
    without changing labels."""
    from repro.core.index import ClusterIndex

    dims = {"nq": 16, "p": 32, "d": 4, "k": 1}
    params, sec = tune.autotune_cell("assign", dims, cache=cache, repeats=1)
    assert params["impl"] in ("ref", "fused", "fused_bf16", "fused_int8")
    assert sec > 0

    protos = jnp.asarray(rng.normal(size=(32, 4)) * 10.0, jnp.float32)
    idx = ClusterIndex.build(ClusterIndex(
        protos=protos, proto_mass=jnp.ones((32,)),
        proto_valid=jnp.ones((32,), bool),
        proto_labels=jnp.arange(32, dtype=jnp.int32),
        n_prototypes=jnp.asarray(32, jnp.int32)))
    q = jnp.asarray(rng.normal(size=(16, 4)) * 10.0, jnp.float32)
    want = idx.assign(q, impl="ref")
    # pin a fused winner for this bucket and let auto dispatch pick it up
    cache.record(DK, "assign", tune.shape_bucket(**dims),
                 {"impl": "fused", "block_k": 16})
    with runtime.configure(tune="cached"):
        got = idx.assign(q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_plan_fit_freezes_fused_assign_winner(rng, cache):
    """A fused winner in the assign cell freezes impl="fused" into the
    FitPlan (auto policy only), and the fused fit reproduces the untuned
    labels bit-for-bit."""
    x = jnp.asarray(rng.normal(size=(64, 3)), jnp.float32)
    cache.record(DK, "assign", tune.shape_bucket(nq=64, p=64, d=3, k=1),
                 {"impl": "fused_int8", "block_k": 16})
    with runtime.configure(tune="cached"):
        plan = plan_fit(x, 2, 1, "kmeans", k=3)
    assert plan.impl == "fused"  # quantized winners freeze as plain fused
    # explicit impl always wins over the tuned winner
    with runtime.configure(tune="cached"):
        plan2 = plan_fit(x, 2, 1, "kmeans", k=3, impl="ref")
    assert plan2.impl == "ref"
    want = repro.fit(x, 2, 1, "kmeans", k=3).labels
    with runtime.configure(tune="cached"):
        got = repro.fit(x, 2, 1, "kmeans", k=3).labels
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
