"""benchmarks/gate.py: the noise-aware perf-regression gate. The
load-bearing self-test — an injected synthetic 2x slowdown on a copied
artifact must make the gate exit nonzero, while the committed baselines
gate clean against themselves."""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import gate  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "results")
FIT_MATRIX = os.path.join(RESULTS, "BENCH_fit_matrix.json")


@pytest.fixture
def baseline():
    with open(FIT_MATRIX) as f:
        return json.load(f)


def test_identical_artifacts_pass(baseline):
    report = gate.compare(baseline, baseline)
    assert report["regressions"] == []
    assert report["checked"] > 0
    assert report["missing"] == [] and report["unmatched"] == []


def test_injected_slowdown_is_flagged(baseline):
    slowed = gate.inject_slowdown(baseline, factor=2.0)
    report = gate.compare(baseline, slowed)
    assert report["regressions"]
    metrics = {f["metric"] for f in report["regressions"]}
    # both directions trip: times/memory up AND throughput down
    assert "seconds" in metrics and "points_per_sec" in metrics
    # ...and the injected values really are 2x / 0.5x
    for f in report["regressions"]:
        want = 2.0 if f["direction"] == "lower" else 0.5
        assert f["fresh"] == pytest.approx(f["baseline"] * want)


def test_cli_exits_nonzero_on_injected_regression(baseline, tmp_path):
    """The satellite contract: copied artifact + synthetic 2x slowdown →
    gate exits nonzero; the untouched copy → exit 0."""
    slow = tmp_path / "slow.json"
    slow.write_text(json.dumps(gate.inject_slowdown(baseline, 2.0)))
    same = tmp_path / "same.json"
    same.write_text(json.dumps(baseline))
    assert gate.main(["--baseline", FIT_MATRIX, "--fresh", str(slow)]) == 1
    assert gate.main(["--baseline", FIT_MATRIX, "--fresh", str(same)]) == 0


def test_self_test_mode():
    assert gate.main(["--self-test"]) == 0


def test_generous_ci_tolerance(baseline):
    """--default-tol 1.0 (the CI quick-mode setting) only fails on >2x:
    exactly 2x squeaks by, 2.5x does not."""
    at_2x = gate.compare(baseline, gate.inject_slowdown(baseline, 2.0),
                         default_tol=1.0)
    assert at_2x["regressions"] == []
    past_2x = gate.compare(baseline, gate.inject_slowdown(baseline, 2.5),
                           default_tol=1.0)
    assert past_2x["regressions"]


def test_per_metric_tolerance_override(baseline):
    mild = copy.deepcopy(baseline)
    for row in mild["rows"]:
        row["seconds"] = row["seconds"] * 1.4  # within the 0.5 default
    assert gate.compare(baseline, mild)["regressions"] == []
    tight = gate.compare(baseline, mild, tols={"seconds": 0.2})
    assert tight["regressions"]
    assert all(f["metric"] == "seconds" for f in tight["regressions"])


def test_noise_floor_skips_tiny_baselines():
    base = {"name": "x", "rows": [{"n": 1, "seconds": 0.01,
                                   "peak_mb": 0.005}]}
    fresh = {"name": "x", "rows": [{"n": 1, "seconds": 0.05,
                                    "peak_mb": 0.025}]}
    report = gate.compare(base, fresh)
    assert report["checked"] == 0 and report["regressions"] == []


def test_row_matching_not_positional(baseline):
    """Reordered rows and new sweep points must not misalign the gate."""
    shuffled = copy.deepcopy(baseline)
    shuffled["rows"] = list(reversed(shuffled["rows"]))
    shuffled["rows"].append({"n": 999_999, "executor": "memory",
                             "devices": 8, "seconds": 1e9})
    report = gate.compare(baseline, shuffled)
    assert report["regressions"] == []
    assert len(report["unmatched"]) == 1
    dropped = copy.deepcopy(baseline)
    dropped["rows"] = dropped["rows"][1:]
    assert len(gate.compare(baseline, dropped)["missing"]) == 1


SERVE_ASYNC = os.path.join(RESULTS, "BENCH_serve_async.json")


@pytest.fixture
def serve_async_baseline():
    with open(SERVE_ASYNC) as f:
        return json.load(f)


def test_serve_async_rows_are_gated(serve_async_baseline):
    """The committed serve_async artifact must expose gateable latency +
    throughput cells, keyed on offered_qps (not position)."""
    report = gate.compare(serve_async_baseline, serve_async_baseline)
    assert report["regressions"] == [] and report["checked"] > 0
    keys = [gate.row_key(r) for r in serve_async_baseline["rows"]]
    assert all(("offered_qps", r["offered_qps"]) in k
               for r, k in zip(serve_async_baseline["rows"], keys, strict=True))
    assert len(set(keys)) == len(keys)
    # floors must sit below the recorded baselines or latency cells
    # silently drop out of the gate
    for row in serve_async_baseline["rows"]:
        for m in ("p50_ms", "p99_ms"):
            assert row[m] > gate.METRIC_RULES[m][2], (m, row)


def test_latency_only_regression_is_flagged(serve_async_baseline):
    """A pure tail-latency regression — throughput untouched — must trip
    the gate on the p50/p99 metrics alone."""
    slowed = gate.inject_slowdown(serve_async_baseline, factor=3.0,
                                  metrics=["p50_ms", "p99_ms"])
    for base_row, slow_row in zip(serve_async_baseline["rows"],
                                  slowed["rows"], strict=True):
        assert slow_row["qps"] == base_row["qps"]  # metrics= filtered
    report = gate.compare(serve_async_baseline, slowed)
    metrics = {f["metric"] for f in report["regressions"]}
    assert metrics and metrics <= {"p50_ms", "p99_ms"}


def test_throughput_collapse_is_flagged(serve_async_baseline):
    dropped = gate.inject_slowdown(serve_async_baseline, factor=2.5,
                                   metrics=["qps"])
    report = gate.compare(serve_async_baseline, dropped)
    assert {f["metric"] for f in report["regressions"]} == {"qps"}


def test_self_test_covers_latency_injection(capsys):
    """--self-test must run (and pass) the latency-only injection leg on
    the serve_async artifact."""
    assert gate.main(["--self-test"]) == 0
    out = capsys.readouterr().out
    assert "latency-only" in out


def test_median_artifact_merges_repeats(baseline):
    runs = [copy.deepcopy(baseline) for _ in range(3)]
    key0 = gate.row_key(baseline["rows"][0])
    # one noisy outlier run: the median must shrug it off
    for factor, run in zip((1.0, 10.0, 1.1), runs, strict=True):
        for row in run["rows"]:
            if gate.row_key(row) == key0:
                row["seconds"] = row["seconds"] * factor
    merged = gate.median_artifact(runs)
    merged_row = next(r for r in merged["rows"]
                      if gate.row_key(r) == key0)
    assert merged_row["seconds"] == pytest.approx(
        baseline["rows"][0]["seconds"] * 1.1)
    assert gate.compare(baseline, merged)["regressions"] == []
