"""Fused assign path wiring (DESIGN.md §16): ClusterIndex freeze-time
low-precision buffers, fused/quantized impl dispatch through assign and
the blocked kNN inner loop, impl-registry growth, and servability checks
for the packed buffers."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import runtime
from repro.core.index import ClusterIndex, nearest_valid_prototype
from repro.core.knn import knn_graph_blocked
from repro.kernels import ops


def _index(rng, p=48, d=5, c=7, spread=20.0):
    protos = jnp.asarray(rng.normal(size=(p, d)) * spread, jnp.float32)
    return ClusterIndex(
        protos=protos,
        proto_mass=jnp.ones((p,), jnp.float32),
        proto_valid=jnp.asarray(rng.random(p) > 0.2),
        proto_labels=jnp.asarray(rng.integers(0, c, size=p), jnp.int32),
        n_prototypes=jnp.asarray(p, jnp.int32),
    )


# ----------------------------------------------- freeze-time packed buffers


def test_from_result_packs_low_precision_buffers(rng):
    """Freezing a fit precomputes the bf16 + int8 prototype buffers, so
    per-request assign work only touches the queries (satellite: no more
    per-call re-cast inside jit)."""
    x = jnp.asarray(rng.normal(size=(64, 3)), jnp.float32)
    idx = ClusterIndex.build(x, 2, 1, "kmeans", k=3)
    assert idx.protos_bf16 is not None
    assert idx.protos_bf16.dtype == jnp.bfloat16
    assert idx.protos_q8 is not None and idx.protos_q8.dtype == jnp.int8
    assert idx.q8_scale.shape == (idx.dim,)
    assert idx.q8_zero.shape == (idx.dim,)
    # the bf16 buffer is exactly the in-jit cast the old path did per call
    np.testing.assert_array_equal(
        np.asarray(idx.protos_bf16, dtype=np.float32),
        np.asarray(idx.protos.astype(jnp.bfloat16), dtype=np.float32))
    idx.check_servable()


def test_hand_built_index_defaults_and_on_the_fly_quantization(rng):
    """Five-field construction keeps working (packed fields default None)
    and the quantized impls pack on the fly, matching the packed index."""
    idx = _index(rng)
    assert idx.protos_bf16 is None and idx.protos_q8 is None
    q = jnp.asarray(rng.normal(size=(17, 5)) * 20.0, jnp.float32)
    packed = ClusterIndex.build(idx)
    for impl in ("fused_bf16", "fused_int8"):
        np.testing.assert_array_equal(
            np.asarray(idx.assign(q, impl=impl)),
            np.asarray(packed.assign(q, impl=impl)))


def test_bfloat16_precision_uses_packed_buffer_bitwise(rng):
    """precision="bfloat16" serves from the frozen bf16 buffer when
    present — bitwise identical to the old per-call in-jit cast (which
    the unpacked index still exercises)."""
    idx = _index(rng)
    q = jnp.asarray(rng.normal(size=(9, 5)), jnp.float32)
    with runtime.configure(precision="bfloat16"):
        want = idx.assign(q)                       # in-jit cast fallback
        got = ClusterIndex.build(idx).assign(q)    # frozen buffer
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_check_servable_rejects_mismatched_packed_buffers(rng):
    idx = ClusterIndex.build(_index(rng))
    bad = idx._replace(protos_bf16=idx.protos_bf16[:-1])
    with pytest.raises(ValueError, match="protos_bf16"):
        bad.check_servable()
    bad = idx._replace(q8_scale=None)
    with pytest.raises(ValueError, match="q8_scale"):
        bad.check_servable()
    bad = idx._replace(protos_q8=idx.protos_q8[:, :-1])
    with pytest.raises(ValueError, match="protos_q8"):
        bad.check_servable()


# -------------------------------------------------------- fused dispatch


def test_assign_fused_matches_ref_bitwise(rng):
    idx = _index(rng)
    q = jnp.asarray(rng.normal(size=(23, 5)) * 20.0, jnp.float32)
    want = idx.assign(q, impl="ref")
    np.testing.assert_array_equal(np.asarray(idx.assign(q, impl="fused")),
                                  np.asarray(want))
    # default (auto) stays bit-for-bit the composed path off-TPU
    np.testing.assert_array_equal(np.asarray(idx.assign(q)),
                                  np.asarray(want))
    # blocked composed streaming also unchanged
    np.testing.assert_array_equal(np.asarray(idx.assign(q, block=16)),
                                  np.asarray(want))


def test_nearest_valid_prototype_fused_branch(rng):
    q = jnp.asarray(rng.normal(size=(11, 4)), jnp.float32)
    protos = jnp.asarray(rng.normal(size=(37, 4)), jnp.float32)
    valid = jnp.asarray(rng.random(37) > 0.3)
    wd, wi = nearest_valid_prototype(q, protos, valid, impl="ref")
    gd, gi = nearest_valid_prototype(q, protos, valid, impl="fused",
                                     block_k=16)
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_zero_valid_index_fused_variants(rng):
    idx = _index(rng)._replace(proto_valid=jnp.zeros((48,), bool))
    q = jnp.asarray(rng.normal(size=(5, 5)), jnp.float32)
    for impl in ("fused", "fused_bf16", "fused_int8"):
        assert (np.asarray(idx.assign(q, impl=impl)) == -1).all()


def test_blocked_knn_fused_inner_loop_bitwise(rng):
    """The TC inner loop (blocked kNN) through the fused path reproduces
    the composed driver bit-for-bit, including the self-exclusion mask
    carried as a traced global-index array."""
    x = jnp.asarray(rng.normal(size=(130, 4)), jnp.float32)
    valid = jnp.asarray(rng.random(130) > 0.15)
    wd, wi = knn_graph_blocked(x, 3, valid=valid, block=32)
    gd, gi = knn_graph_blocked(x, 3, valid=valid, block=32, impl="fused")
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_fit_with_fused_impl_matches_default_labels(rng):
    """An end-to-end fit pinned to the fused family reproduces the default
    fit's labels — ops without a fused path degrade it to auto."""
    x = jnp.asarray(rng.normal(size=(96, 3)), jnp.float32)
    want = repro.fit(x, 2, 1, "kmeans", k=3).labels
    with runtime.configure(impl="fused"):
        got = repro.fit(x, 2, 1, "kmeans", k=3).labels
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------- registry plumbing


def test_impl_registry_accepts_fused_family():
    for impl in ("fused", "fused_bf16", "fused_int8"):
        runtime.RuntimeConfig(impl=impl)  # __post_init__ validates
    with pytest.raises(ValueError):
        runtime.RuntimeConfig(impl="fused_fp4")


def test_unknown_impl_still_rejected_loudly(rng):
    q = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
    keys = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
    with pytest.raises(ValueError, match="registered impls"):
        ops.nearest_topk(q, keys, 1, impl="palas")
    # quantized names degrade to fused at the stateless ops layer
    gd, gi = ops.nearest_topk(q, keys, 1, impl="fused_int8")
    wd, wi = ops.nearest_topk(q, keys, 1, impl="ref")
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_non_fused_ops_degrade_fused_impl(rng):
    """pairwise/segment_sum under a process-wide impl="fused" degrade to
    the auto resolution instead of raising."""
    x = jnp.asarray(rng.normal(size=(12, 3)), jnp.float32)
    with runtime.configure(impl="fused"):
        d = ops.pairwise_sq_l2(x, x)
        s, m = ops.segment_sum(x, jnp.zeros((12,), jnp.int32), 2)
    np.testing.assert_allclose(np.asarray(d),
                               np.asarray(ops.pairwise_sq_l2(x, x)),
                               rtol=0, atol=0)
