"""Per-arch smoke tests (deliverable f): REDUCED same-family configs, one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, smoke_config
from repro.data import make_batch
from repro.models import build
from repro.train import OptConfig, init_opt_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


@pytest.fixture(scope="module")
def smoke_bundles():
    return {name: build(smoke_config(ARCHS[name])) for name in ALL_ARCHS}


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_no_nans(name, smoke_bundles):
    bundle = smoke_bundles[name]
    cfg = bundle.cfg
    params = bundle.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SHAPES["train_4k"], 0, batch_override=2,
                       seq_override=16)
    logits, aux = bundle.forward(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_no_nans(name, smoke_bundles):
    bundle = smoke_bundles[name]
    cfg = bundle.cfg
    params = bundle.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(bundle, OptConfig(warmup_steps=2,
                                                     decay_steps=10)))
    batch = make_batch(cfg, SHAPES["train_4k"], 0, batch_override=2,
                       seq_override=16)
    new_params, new_opt, mets = step(params, opt, batch)
    assert float(mets["loss"]) > 0 and np.isfinite(float(mets["loss"]))
    assert np.isfinite(float(mets["grad_norm"]))
    assert int(new_opt["step"]) == 1
    # params actually moved
    a = jax.tree_util.tree_leaves(params)[0]
    b = jax.tree_util.tree_leaves(new_params)[0]
    assert not np.allclose(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ["qwen2.5-32b", "jamba-v0.1-52b"])
def test_microbatched_train_matches_full(name, smoke_bundles):
    """Gradient accumulation must equal the one-shot gradient step."""
    from repro.configs.base import ParallelConfig

    bundle = smoke_bundles[name]
    cfg = bundle.cfg
    params = bundle.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = make_batch(cfg, SHAPES["train_4k"], 0, batch_override=4,
                       seq_override=16)
    ocfg = OptConfig(warmup_steps=2, decay_steps=10)
    s1 = jax.jit(make_train_step(bundle, ocfg, ParallelConfig(microbatches=1)))
    s2 = jax.jit(make_train_step(bundle, ocfg, ParallelConfig(microbatches=2)))
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    # losses equal (mean over microbatches) and params close
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2), strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_param_counts_full_configs():
    """Full (unreduced) configs must land near their published sizes."""
    import numpy as np
    from repro.utils.tree import tree_size

    expected = {  # total params, ±25% (embedding conventions differ)
        "deepseek-moe-16b": 16.4e9,
        "mamba2-370m": 0.37e9,
        "gemma2-2b": 2.6e9,
        "granite-20b": 20e9,
        "qwen2.5-32b": 32e9,
        "minitron-8b": 8e9,
        "jamba-v0.1-52b": 52e9,
        "phi-3-vision-4.2b": 3.8e9,  # backbone only (CLIP tower is stubbed)
    }
    for name, want in expected.items():
        bundle = build(ARCHS[name])
        got = tree_size(jax.eval_shape(bundle.init, jax.random.PRNGKey(0)))
        assert 0.75 * want < got < 1.3 * want, (name, got, want)
