"""End-to-end system behaviour: the paper's full IHTC pipeline on its own
GMM benchmark, plus the LM-framework integration path (instance-selected
weighted training) — the two headline flows of this repo."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import gmm_sample
from repro.cluster.metrics import clustering_accuracy
from repro.configs import ARCHS, smoke_config
from repro.core import ihtc
from repro.data.instance_selection import (SelectionConfig, reduced_batch,
                                           select_instances)
from repro.models import build
from repro.train import OptConfig, init_opt_state, make_train_step


def test_paper_headline_claim(rng):
    """Paper §4: IHTC preprocessing preserves k-means accuracy (~0.92) while
    reducing the data ≥ (t*)^m fold — the run-time/memory claim follows from
    the reduction factor, which we assert directly."""
    x, true = gmm_sample(4000, rng)
    xj = jnp.asarray(x)
    accs, protos = {}, {}
    for m in (0, 1, 2):
        r = ihtc(xj, 2, m, "kmeans", k=3, key=jax.random.PRNGKey(1))
        accs[m] = clustering_accuracy(true, np.asarray(r.labels), 3)
        protos[m] = int(r.n_prototypes)
    assert protos[1] <= 2000 and protos[2] <= 1000       # ≥ t^m reduction
    assert accs[1] > accs[0] - 0.015                     # accuracy preserved
    assert accs[2] > accs[0] - 0.02
    assert accs[0] > 0.9                                 # sanity: the task works


def test_lm_training_on_selected_instances(rng):
    """Framework integration: ITIS-select a corpus, train on the weighted
    prototypes, verify the loss still descends."""
    cfg = smoke_config(ARCHS["minitron-8b"])
    bundle = build(cfg)
    n, s = 64, 17
    topics = rng.integers(0, 4, size=n)
    corpus = jnp.asarray(
        (topics[:, None] * (cfg.vocab_size // 4)
         + rng.integers(0, cfg.vocab_size // 4, size=(n, s))).astype(np.int32))
    sel = select_instances(corpus, cfg.vocab_size,
                           SelectionConfig(threshold=2, iterations=1,
                                           feature_dim=16))
    batch = reduced_batch(corpus, sel)
    assert batch["tokens"].shape[0] <= n // 2

    params = bundle.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        bundle, OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=30)))
    losses = []
    for _ in range(12):
        params, opt, mets = step(params, opt, batch)
        losses.append(float(mets["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses
