"""Online index lifecycle (DESIGN.md §19): incremental refit, versioned
artifacts, zero-downtime refresh, and the consolidated build API.

Covers the ISSUE-10 acceptance surface:

* ``OnlineFitter`` purity — zero observes then ``snapshot()`` is
  bit-identical to the one-shot batch fit of the same stream, repeated
  snapshots are bit-identical, and a snapshot survives later donated
  folds untouched;
* ``IndexStore`` — save→load→``assign`` bitwise parity (packed bf16/int8
  buffers and streaming-spill indexes included), torn/truncated artifact
  rejection;
* the end-to-end refresh loop — an ``AsyncClusterService`` under
  virtual-clock traffic (tests/serve_sim.py) survives a hot-swap with
  zero failed requests, every response attributable to exactly one index
  version, and the refreshed index measurably reducing mean assign
  distance on drifted traffic;
* the deprecated four-way constructor surface still works (and warns).
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core.index import ClusterIndex, nearest_valid_prototype
from repro.serve import (
    ArtifactError,
    AsyncClusterService,
    IndexStore,
    OnlineFitter,
    RefreshDriver,
    RefreshPolicy,
)

from serve_sim import SimExecutor, SimLoop, run_trace


def _blobs(seed: int, n_per: int = 60, shift: float = 0.0,
           spread: float = 0.5) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = np.array([[0.0, 0.0], [6.0, 0.0], [3.0, 6.0]]) + shift
    x = np.concatenate([c + rng.normal(scale=spread, size=(n_per, 2))
                        for c in centers])
    return x.astype(np.float32)


def _bits(a) -> np.ndarray:
    a = np.asarray(a)
    if a.dtype == np.float32:
        return a.view(np.uint32)
    if str(a.dtype) == "bfloat16":
        return a.view(np.uint16)
    return a


def _assert_index_bitwise(a: ClusterIndex, b: ClusterIndex) -> None:
    for name in ClusterIndex._fields:
        fa, fb = getattr(a, name), getattr(b, name)
        assert (fa is None) == (fb is None), name
        if fa is not None:
            np.testing.assert_array_equal(_bits(fa), _bits(fb),
                                          err_msg=name)


# ----------------------------------------------------------------------
# OnlineFitter purity


def test_zero_observe_snapshot_matches_batch_fit(rng):
    x = rng.normal(size=(600, 6)).astype(np.float32)
    chunks = [x[i:i + 200] for i in range(0, 600, 200)]
    batch = repro.fit(iter(chunks), 4, 2)
    fitter = OnlineFitter(iter(chunks), 4, 2)
    snap = fitter.snapshot()
    np.testing.assert_array_equal(_bits(batch.protos), _bits(snap.protos))
    np.testing.assert_array_equal(np.asarray(batch.proto_labels),
                                  np.asarray(snap.proto_labels))
    np.testing.assert_array_equal(np.asarray(batch.labels),
                                  np.asarray(snap.labels))


def test_repeated_snapshots_bitwise_identical(rng):
    x = rng.normal(size=(400, 4)).astype(np.float32)
    fitter = OnlineFitter(x, 3, 2, chunk_n=100)
    a, b = fitter.snapshot(), fitter.snapshot()
    np.testing.assert_array_equal(_bits(a.protos), _bits(b.protos))
    np.testing.assert_array_equal(np.asarray(a.labels),
                                  np.asarray(b.labels))


def test_snapshot_survives_later_donated_folds(rng):
    """The §19 clone contract: a snapshot's buffers must stay valid (and
    unchanged) after further observes donate the live reservoir away."""
    x = rng.normal(size=(300, 5)).astype(np.float32)
    fitter = OnlineFitter(x, 3, 2, chunk_n=100, donate_stream=True)
    snap = fitter.snapshot()
    before = _bits(snap.protos).copy()
    for _ in range(4):  # enough folds to cascade and recycle buffers
        fitter.observe(rng.normal(size=(250, 5)).astype(np.float32))
    np.testing.assert_array_equal(_bits(snap.protos), before)
    assert fitter.n_points == 300 + 4 * 250


def test_observe_slicing_matches_prechunked_stream(rng):
    """An oversized observe() batch folds exactly like the same data
    pre-chunked: the key schedule is index-bound, not batch-bound."""
    x = rng.normal(size=(800, 4)).astype(np.float32)
    chunks = [x[i:i + 200] for i in range(0, 800, 200)]
    a = OnlineFitter(iter(chunks), 3, 2)
    b = OnlineFitter(x[:200], 3, 2)
    b.observe(x[200:])  # 600 rows -> sliced into chunks 1..3
    assert a.n_chunks == b.n_chunks == 4
    sa, sb = a.snapshot(), b.snapshot()
    np.testing.assert_array_equal(_bits(sa.protos), _bits(sb.protos))
    np.testing.assert_array_equal(np.asarray(sa.labels_for(0)),
                                  np.asarray(sb.labels_for(0)))


def test_observe_masked_pair_and_counts(rng):
    fitter = OnlineFitter(rng.normal(size=(200, 3)).astype(np.float32),
                          3, 1)
    arr = rng.normal(size=(50, 3)).astype(np.float32)
    assert fitter.observe((arr, 20)) == 20
    assert fitter.observe(np.zeros((0, 3), np.float32)) == 0
    assert fitter.n_points == 220
    stats = fitter.stats
    assert stats["executor"] == "streaming"
    assert stats["n_snapshots"] == 0


def test_online_fitter_rejects_memory_executor(rng):
    with pytest.raises(ValueError, match="chunk stream|streaming"):
        OnlineFitter(rng.normal(size=(100, 3)).astype(np.float32),
                     3, 1, executor="memory")


# ----------------------------------------------------------------------
# IndexStore artifacts


def test_artifact_roundtrip_bitwise_parity(rng, tmp_path):
    x = _blobs(0)
    index = ClusterIndex.build(x, 2, 1, k=3)  # packed: bf16 + int8
    store = IndexStore(tmp_path)
    version = store.save(index, metadata={"note": "first"})
    assert version == 1
    loaded = store.load()
    _assert_index_bitwise(index, loaded)
    q = _blobs(7)
    np.testing.assert_array_equal(
        np.asarray(index.assign(jnp.asarray(q))),
        np.asarray(loaded.assign(jnp.asarray(q))))


def test_artifact_roundtrip_streaming_spill_index(rng, tmp_path):
    """A streaming fit's FitResult (labels behind the spill view) saves
    through the same path; the frozen index round-trips bitwise."""
    x = rng.normal(size=(500, 4)).astype(np.float32)
    result = repro.fit(iter([x[:250], x[250:]]), 3, 2)
    store = IndexStore(tmp_path)
    store.save(result)  # FitResult accepted directly (frozen on the way in)
    loaded = store.load()
    _assert_index_bitwise(ClusterIndex.build(result), loaded)


def test_artifact_versions_are_ordered_and_isolated(tmp_path):
    store = IndexStore(tmp_path)
    with pytest.raises(ArtifactError, match="empty"):
        store.load()
    a = ClusterIndex.build(_blobs(0), 2, 1, k=3)
    b = ClusterIndex.build(_blobs(1, shift=2.0), 2, 1, k=3)
    assert store.save(a) == 1
    assert store.save(b) == 2
    assert store.list_versions() == [1, 2]
    assert store.latest() == 2
    _assert_index_bitwise(a, store.load(1))
    _assert_index_bitwise(b, store.load())


def test_artifact_rejects_torn_and_truncated(tmp_path):
    store = IndexStore(tmp_path)
    store.save(ClusterIndex.build(_blobs(0), 2, 1, k=3))
    vdir = store.path(1)

    # truncated manifest
    mpath = os.path.join(vdir, "manifest.json")
    with open(mpath) as f:
        good = f.read()
    with open(mpath, "w") as f:
        f.write(good[: len(good) // 2])
    with pytest.raises(ArtifactError, match="torn manifest"):
        store.load(1)
    with open(mpath, "w") as f:
        f.write(good)
    store.load(1)  # restored: loads again

    # flipped bytes in an array file -> checksum mismatch
    apath = os.path.join(vdir, "protos.npy")
    raw = bytearray(open(apath, "rb").read())
    raw[-1] ^= 0xFF
    with open(apath, "wb") as f:
        f.write(raw)
    with pytest.raises(ArtifactError, match="checksum mismatch"):
        store.load(1)

    # missing array file
    os.remove(apath)
    with pytest.raises(ArtifactError, match="missing"):
        store.load(1)


def test_artifact_rejects_wrong_dim_and_bad_manifest(tmp_path):
    store = IndexStore(tmp_path)
    store.save(ClusterIndex.build(_blobs(0), 2, 1, k=3))
    with pytest.raises(ArtifactError, match="not servable"):
        store.load(1, expect_dim=7)

    mpath = os.path.join(store.path(1), "manifest.json")
    manifest = json.load(open(mpath))
    manifest["format"] = 99
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(ArtifactError, match="unknown artifact format"):
        store.load(1)


def test_artifact_save_rejects_non_index(tmp_path):
    with pytest.raises(TypeError, match="ClusterIndex or FitResult"):
        IndexStore(tmp_path).save(np.zeros((3, 2), np.float32))


# ----------------------------------------------------------------------
# RefreshPolicy


def test_refresh_policy_triggers():
    p = RefreshPolicy(max_points=100, max_cascades=2, drift_ratio=0.5)
    assert p.enabled
    no = dict(points_since=0, cascades_since=0, drift=None)
    assert p.should_refresh(**no) is None
    assert p.should_refresh(**{**no, "points_since": 100}) == "max_points"
    assert p.should_refresh(**{**no, "cascades_since": 2}) == "max_cascades"
    assert p.should_refresh(**{**no, "drift": 1.49}) is None
    assert p.should_refresh(**{**no, "drift": 1.5}) == "drift_ratio"
    assert not RefreshPolicy().enabled
    assert RefreshPolicy().should_refresh(
        points_since=10**9, cascades_since=10**9, drift=99.0) is None


def test_refresh_policy_from_config():
    with repro.runtime.configure(refresh_max_points=64,
                                 refresh_drift_ratio=0.25):
        p = RefreshPolicy.from_config()
    assert p == RefreshPolicy(max_points=64, max_cascades=0,
                              drift_ratio=0.25)
    with pytest.raises(ValueError, match="disables the trigger"):
        repro.runtime.RuntimeConfig(refresh_max_points=-1)


# ----------------------------------------------------------------------
# end-to-end: virtual-clock traffic across a zero-downtime refresh


def test_lifecycle_refresh_under_traffic(tmp_path):
    """The ISSUE-10 acceptance loop: fit -> serve -> observe drifted
    traffic -> policy fires -> snapshot/save/hot-swap, all while the
    virtual-clock scheduler keeps serving. Zero failures, every response
    attributable to exactly one version, and the refreshed index
    measurably better on the drifted distribution."""
    x0 = _blobs(0)                      # what the index was fitted on
    drifted = _blobs(1, shift=8.0)      # where traffic moved

    fitter = OnlineFitter(x0, 2, 1, k=3)
    stale = fitter.build_index()

    batches = []
    loop = SimLoop()
    executor = SimExecutor(loop, service_time=1.0)
    svc = AsyncClusterService(stale, loop=loop, executor=executor,
                              max_wait=2.0, observer=batches.append)
    store = IndexStore(tmp_path)
    driver = RefreshDriver(
        svc, fitter, store=store,
        policy=RefreshPolicy(max_points=120))

    qrng = np.random.default_rng(3)
    arrivals = []
    for i in range(40):
        pool = x0 if i < 10 else drifted  # traffic drifts at t=10
        rows = pool[qrng.integers(0, pool.shape[0], size=8)]
        arrivals.append((float(i), None, rows))

    # feed observations mid-trace: three batches of drifted points, the
    # second crossing the policy's 120-point threshold -> refresh fires
    # while requests are in flight
    for k, t_obs in enumerate((12.0, 18.0, 24.0)):
        chunk = drifted[qrng.integers(0, drifted.shape[0], size=60)]
        loop.call_later(t_obs, lambda c=chunk: driver.observe(c))

    records = run_trace(svc, loop, arrivals)

    # zero failed / dropped requests across the swap
    for rec in records:
        assert rec.error is None
        assert rec.future is not None and rec.future.done()
        assert rec.future.exception() is None

    # the refresh actually happened, exactly once per threshold crossing
    assert [v for v, _ in driver.history] == [2]
    assert driver.history[0][1] == "max_points"
    assert store.list_versions() == [1]
    assert svc.version() == 2
    stats = svc.stats_snapshot()
    assert stats["scheduler"]["swaps"] == 1
    assert stats["scheduler"]["failed"] == 0
    assert stats["scheduler"]["rejected"] == 0

    # every response attributable to exactly ONE index version
    seen = {}
    for b in batches:
        for rid, _rows, _t in b.segments:
            seen.setdefault(rid, set()).add(b.version)
    assert len(seen) == len(records)
    assert all(len(vs) == 1 for vs in seen.values())
    assert {v for vs in seen.values() for v in vs} == {1, 2}

    # the refreshed index measurably reduces mean assign distance on the
    # drifted distribution vs the stale one
    fresh = store.load(1)

    def mean_dist(index):
        d, _ = nearest_valid_prototype(jnp.asarray(drifted), index.protos,
                                       index.proto_valid)
        return float(jnp.mean(jnp.sqrt(jnp.maximum(d, 0.0))))

    assert mean_dist(fresh) < 0.5 * mean_dist(stale)
    assert driver.stats["refreshes"] == 1
    assert driver.stats["points_since_install"] == 60  # post-swap observe


def test_refresh_driver_drift_trigger(tmp_path):
    """The drift proxy alone (no volume trigger) detects distribution
    shift: baseline on in-distribution traffic, then drifted batches push
    the EMA ratio over 1 + drift_ratio and a refresh fires."""
    x0 = _blobs(0)
    drifted = _blobs(2, shift=9.0)
    fitter = OnlineFitter(x0, 2, 1, k=3)
    loop = SimLoop()
    svc = AsyncClusterService(fitter.build_index(), loop=loop,
                              executor=SimExecutor(loop))
    driver = RefreshDriver(svc, fitter,
                           policy=RefreshPolicy(drift_ratio=1.0),
                           drift_alpha=1.0)
    assert driver.drift is None
    assert driver.observe(x0[:40]) is None          # baseline: ratio 1.0
    assert 0.99 < driver.drift < 1.01
    version = driver.observe(drifted[:40])          # far away: fires
    assert version == 2 and driver.history[0][1] == "drift_ratio"
    assert driver.drift is None                     # re-baselined


# ----------------------------------------------------------------------
# consolidated build API + deprecated aliases


def test_build_dispatches_on_source_type(rng):
    x = _blobs(0)
    result = repro.fit(jnp.asarray(x), 2, 1, k=3)
    from_result = ClusterIndex.build(result)
    from_raw = ClusterIndex.build(x, 2, 1, k=3)
    _assert_index_bitwise(from_result, from_raw)
    assert from_result.protos_bf16 is not None     # packed by default
    bare = ClusterIndex.build(result, pack=False)
    assert bare.protos_bf16 is None
    repacked = ClusterIndex.build(bare)            # index -> (re)pack
    _assert_index_bitwise(from_result, repacked)

    chunks = iter([x[:90], x[90:]])
    from_stream = ClusterIndex.build(chunks, 2, 1, k=3)
    assert from_stream.dim == 2 and from_stream.protos_q8 is not None

    with pytest.raises(TypeError, match="t/m only apply"):
        ClusterIndex.build(result, 2, 1)
    with pytest.raises(TypeError, match="needs t and m"):
        ClusterIndex.build(x)


def test_deprecated_aliases_warn_and_match_build(rng):
    x = _blobs(0)
    result = repro.fit(jnp.asarray(x), 2, 1, k=3)
    want = ClusterIndex.build(result)
    with pytest.warns(DeprecationWarning, match="ClusterIndex.build"):
        got = ClusterIndex.from_result(result)
    _assert_index_bitwise(want, got)
    with pytest.warns(DeprecationWarning, match="ClusterIndex.build"):
        got = ClusterIndex.build(x, 2, 1, k=3, pack=False).with_packed_protos()
    _assert_index_bitwise(want, got)
    with pytest.warns(DeprecationWarning, match="ClusterIndex.build"):
        got = ClusterIndex.fit(jnp.asarray(x), 2, 1, "kmeans", k=3)
    _assert_index_bitwise(want, got)
    with pytest.warns(DeprecationWarning, match="ClusterIndex.build"):
        streamed = ClusterIndex.fit_streaming(iter([x[:90], x[90:]]),
                                              2, 1, "kmeans", k=3)
    assert streamed.dim == 2


def test_serve_surface_exports():
    import repro.serve as serve

    for name in serve.__all__:
        assert getattr(serve, name) is not None
    assert serve.OnlineFitter is OnlineFitter
    assert repro.AsyncClusterService is AsyncClusterService
    assert repro.IndexStore is IndexStore
    with pytest.raises(AttributeError):
        serve.not_a_thing
