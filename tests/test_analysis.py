"""The static analyzer (DESIGN.md §17): rule registry contract, golden
violating/clean/pragma-suppressed snippets per family, whole-repo
call-graph resolution, pragma grammar failures, baseline add/expire
semantics, CLI smoke, and the repo-is-clean gate."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (
    Baseline,
    available_rules,
    load_baseline,
    run_check,
    run_selftest,
    save_baseline,
)
from repro.analysis.baseline import extend_baseline, prune_baseline
from repro.analysis.registry import register_rule
from repro.analysis.selftest import CASES


def check_one(path, src, rule=None):
    only = [rule] if rule else None
    return run_check({path: textwrap.dedent(src).strip("\n") + "\n"},
                     only=only)


# ------------------------------------------------------------- registry


def test_every_family_has_rules_and_selftest_coverage():
    rules = available_rules()
    fams = {"".join(c for c in r if c.isalpha()) for r in rules}
    assert fams == {"RC", "HS", "RT", "PK", "DT", "WN"}
    assert {c.rule for c in CASES} == set(rules)


def test_register_rule_rejects_unknown_family_and_bad_signature():
    with pytest.raises(ValueError, match="unknown family"):
        register_rule("ZZ999", title="t", explain="e")(lambda ctx: [])
    with pytest.raises(ValueError, match="already registered"):
        register_rule("RC101", title="t", explain="e")(lambda ctx: [])
    with pytest.raises(TypeError, match="exactly one positional"):
        register_rule("RC199", title="t", explain="e")(lambda a, b: [])


# --------------------------------------- golden snippets, per rule family


@pytest.mark.parametrize("case", CASES, ids=[c.rule for c in CASES])
def test_golden_bad_flags_clean_passes(case):
    """Every rule's canonical violation flags; the repaired idiom does
    not. (The pragma'd variant is covered by run_selftest below — these
    are the committed golden fixtures.)"""
    bad = check_one(case.path, case.bad, rule=case.rule)
    assert any(f.rule == case.rule for f in bad.new), \
        f"{case.rule}: bad snippet produced {bad.new}"
    clean = check_one(case.path, case.clean, rule=case.rule)
    assert not clean.new, \
        f"{case.rule}: clean snippet flagged {clean.new}"


def test_selftest_passes():
    ok, lines = run_selftest()
    assert ok, "\n".join(lines)


def test_rc102_links_the_call_graph_across_files():
    """The §10 hazard one file away: a jitted function traces a helper
    from another module that reads the config — exactly the
    kv_compression shape this rule exists for."""
    helper = '''
        from repro import runtime

        def pick_impl(x):
            return runtime.active().impl
        '''
    user = '''
        import jax

        from repro.models.helper import pick_impl

        @jax.jit
        def step(x):
            return pick_impl(x)
        '''
    sources = {
        "src/repro/models/helper.py":
            textwrap.dedent(helper).strip("\n") + "\n",
        "src/repro/models/user.py":
            textwrap.dedent(user).strip("\n") + "\n",
    }
    report = run_check(sources, only=["RC102"])
    assert [f.rule for f in report.new] == ["RC102"]
    assert report.new[0].path == "src/repro/models/user.py"
    assert "pick_impl" in report.new[0].message


def test_scope_restricts_hot_path_rules():
    """np.asarray outside kernels/core/serve is nobody's business."""
    src = '''
        import numpy as np

        def f(x):
            return np.asarray(x)
        '''
    assert check_one("src/repro/core/x.py", src, rule="HS201").new
    assert not check_one("src/repro/train/x.py", src, rule="HS201").new
    assert not check_one("benchmarks/x.py", src, rule="HS201").new


# --------------------------------------------------------------- pragmas


BAD_HS = '''
    import numpy as np

    def f(x):
        return np.asarray(x)
    '''


def test_pragma_same_line_and_preceding_line_both_suppress():
    trailing = '''
        import numpy as np

        def f(x):
            return np.asarray(x)  # repro: allow[HS201]: test spill
        '''
    standalone = '''
        import numpy as np

        def f(x):
            # repro: allow[HS201]: test spill
            return np.asarray(x)
        '''
    for src in (trailing, standalone):
        rep = check_one("src/repro/core/x.py", src, rule="HS201")
        assert not rep.new and len(rep.suppressed_pragma) == 1
        _, supp = rep.suppressed_pragma[0]
        assert supp.reason == "test spill"


def test_pragma_without_reason_is_a_check_failure():
    src = '''
        import numpy as np

        def f(x):
            return np.asarray(x)  # repro: allow[HS201]
        '''
    rep = check_one("src/repro/core/x.py", src, rule="HS201")
    assert not rep.ok
    assert any("no reason" in e.message for e in rep.pragma_errors)


def test_pragma_with_unknown_rule_is_a_check_failure():
    src = '''
        def f(x):
            return x  # repro: allow[XX123]: whatever
        '''
    rep = check_one("src/repro/core/x.py", src)
    assert any("unknown rule" in e.message for e in rep.pragma_errors)


def test_pragma_for_wrong_rule_does_not_suppress():
    src = '''
        import numpy as np

        def f(x):
            return np.asarray(x)  # repro: allow[DT501]: wrong family
        '''
    rep = check_one("src/repro/core/x.py", src, rule="HS201")
    assert [f.rule for f in rep.new] == ["HS201"]


def test_unused_pragma_reported_but_not_fatal():
    src = '''
        def f(x):
            return x  # repro: allow[HS201]: nothing here anymore
        '''
    rep = check_one("src/repro/core/x.py", src)
    assert rep.ok
    assert len(rep.unused_pragmas) == 1


def test_pragma_inside_docstring_is_inert():
    src = '''
        import numpy as np

        def f(x):
            """Docs may show `# repro: allow[HS201]: example` verbatim."""
            return np.asarray(x)
        '''
    rep = check_one("src/repro/core/x.py", src, rule="HS201")
    # the docstring mention neither suppresses nor errors
    assert [f.rule for f in rep.new] == ["HS201"]
    assert not rep.pragma_errors and not rep.suppressed_pragma


# -------------------------------------------------------------- baseline


def _hs_finding():
    rep = check_one("src/repro/core/x.py", BAD_HS, rule="HS201")
    assert rep.new
    return rep.new[0]


def test_baseline_matches_by_line_text_not_line_number(tmp_path):
    f = _hs_finding()
    bl = Baseline()
    extend_baseline(bl, [f], "accepted for the test")
    # same violation, pushed three lines down by an unrelated edit
    shifted = "\n\n\n" + textwrap.dedent(BAD_HS).strip("\n") + "\n"
    rep = run_check({"src/repro/core/x.py": shifted},
                    baseline=bl, only=["HS201"])
    assert rep.ok
    assert len(rep.suppressed_baseline) == 1
    assert not rep.stale_baseline


def test_baseline_entry_expires_when_the_line_changes(tmp_path):
    f = _hs_finding()
    bl = Baseline()
    extend_baseline(bl, [f], "accepted for the test")
    fixed = '''
        def f(x):
            return x
        '''
    rep = check_one("src/repro/core/x.py", fixed)
    rep = run_check(
        {"src/repro/core/x.py":
         textwrap.dedent(fixed).strip("\n") + "\n"}, baseline=bl)
    assert rep.ok  # stale entries don't fail check...
    assert len(rep.stale_baseline) == 1  # ...but are reported
    assert prune_baseline(bl, rep.all_findings()) == 1
    assert len(bl) == 0


def test_baseline_requires_reason_and_roundtrips(tmp_path):
    bl = Baseline()
    with pytest.raises(ValueError, match="reason"):
        extend_baseline(bl, [_hs_finding()], "   ")
    extend_baseline(bl, [_hs_finding()], "why not")
    path = str(tmp_path / "bl.json")
    save_baseline(path, bl)
    loaded = load_baseline(path)
    assert len(loaded) == 1
    assert loaded.match(_hs_finding())
    # a hand-edited entry with the reason blanked refuses to load
    blob = json.load(open(path))
    blob["entries"][0]["reason"] = ""
    json.dump(blob, open(path, "w"))
    with pytest.raises(ValueError, match="no reason"):
        load_baseline(path)


def test_missing_baseline_file_is_empty():
    assert len(load_baseline("/nonexistent/baseline.json")) == 0


# ------------------------------------------------------------- CLI smoke


def _cli(args, cwd):
    import os
    import pathlib

    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis"] + args,
        capture_output=True, text=True, cwd=cwd, env=env)


@pytest.fixture
def mini_repo(tmp_path):
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    (pkg / "x.py").write_text(
        textwrap.dedent(BAD_HS).strip("\n") + "\n")
    return tmp_path


def test_cli_check_flags_then_baseline_then_clean(mini_repo):
    r = _cli(["check", "src", "--no-baseline"], str(mini_repo))
    assert r.returncode == 1
    assert "HS201" in r.stdout

    r = _cli(["baseline", "src", "--write",
              "--reason", "smoke-test debt"], str(mini_repo))
    assert r.returncode == 0, r.stderr
    assert (mini_repo / "analysis-baseline.json").exists()

    r = _cli(["check", "src"], str(mini_repo))
    assert r.returncode == 0, r.stdout
    assert "0 new finding" in r.stdout


def test_cli_baseline_write_requires_reason(mini_repo):
    r = _cli(["baseline", "src", "--write"], str(mini_repo))
    assert r.returncode == 2
    assert "--reason" in r.stderr


def test_cli_check_json_output(mini_repo):
    r = _cli(["check", "src", "--no-baseline", "--json"], str(mini_repo))
    assert r.returncode == 1
    payload = json.loads(r.stdout)
    assert payload["ok"] is False
    assert payload["new"][0]["rule"] == "HS201"


def test_cli_explain(tmp_path):
    r = _cli(["explain"], str(tmp_path))
    assert r.returncode == 0
    for rid in available_rules():
        assert rid in r.stdout
    r = _cli(["explain", "RC101"], str(tmp_path))
    assert r.returncode == 0
    assert "dispatch" in r.stdout
    assert _cli(["explain", "NOPE99"], str(tmp_path)).returncode == 2


def test_cli_self_test(tmp_path):
    r = _cli(["--self-test"], str(tmp_path))
    assert r.returncode == 0, r.stdout
    assert "self-test: PASS" in r.stdout


# ----------------------------------------------------- the repo is clean


def test_repo_passes_its_own_analyzer(repo_root):
    """The acceptance gate CI enforces: no new findings, valid pragmas."""
    r = _cli(["check"], str(repo_root))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


@pytest.fixture
def repo_root(tmp_path_factory):
    import pathlib

    root = pathlib.Path(__file__).resolve().parent.parent
    assert (root / "src" / "repro").is_dir()
    return root
