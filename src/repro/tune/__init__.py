"""repro.tune — empirical kernel autotuning with a persistent cache.

The hot-path dispatch knobs (Pallas tile sizes, the pallas-vs-XLA impl
choice, the blocked-kNN row block, the streaming chunk budget) default to
hand-picked constants. This package measures candidates per hardware and
shape bucket and persists the winners, so dispatch can tune itself to the
machine instead of to the author's laptop.

Policy (``RuntimeConfig.tune`` / ``REPRO_TUNE``):

  * ``"off"``      — default; every constant exactly as hand-picked.
  * ``"cached"``   — consult the cache, fall back to the constants on a
    miss; never measures (production mode: deterministic given the file).
  * ``"onthefly"`` — consult the cache and **measure on a miss**, persisting
    the winner (warmup mode — first call per new bucket pays the sweep).

:func:`tuned_params` is the one policy gate every consumer goes through
(``ops._resolve``/the ops entry points, ``core.knn.resolve_auto_block``,
``plan_fit``); with the policy off it returns ``{}`` without touching the
cache, so the off path costs one config read. Cache mutations bump
:func:`repro.tune.cache.cache_epoch`, which ``dispatch_key()`` folds in
whenever tuning is active — tuned values read at trace time can never be
served from a jit program compiled under older winners (DESIGN.md §14).

CLI: ``python -m repro.tune populate|show|prune|clear`` manages the cache.
"""
from __future__ import annotations

from typing import Any, Dict

from repro import runtime
from repro.tune.cache import (  # noqa: F401  (re-exported API)
    CACHE_ENV,
    TuningCache,
    cache_epoch,
    default_cache_path,
    get_cache,
    pow2_bucket,
    set_cache,
    shape_bucket,
)

__all__ = [
    "CACHE_ENV", "TuningCache", "autotune_cell", "cache_epoch",
    "default_cache_path", "get_cache", "pow2_bucket", "set_cache",
    "shape_bucket", "tuned_params",
]


def tuned_params(kernel: str, *, dtype: str = "float32",
                 **dims: int) -> Dict[str, Any]:
    """Winning params for ``kernel`` at the bucket of ``dims``, or ``{}``.

    Honours the active tune policy: ``off`` never looks, ``cached`` looks
    but never measures, ``onthefly`` measures (and persists) on a miss.
    Callers treat a missing key in the result as "use the constant", so a
    partial dict — e.g. ``{"impl": "ref"}`` with no tile sizes — is valid.
    """
    mode = runtime.active().tune
    if mode == "off":
        return {}
    from repro.tune.autotune import current_device_kind  # lazy: jax

    bucket = shape_bucket(**dims)
    cache = get_cache()
    params = cache.lookup(current_device_kind(), kernel, bucket, dtype)
    if params is None and mode == "onthefly":
        from repro.tune.autotune import autotune_cell

        params, _ = autotune_cell(kernel, dims, dtype=dtype, cache=cache)
    return dict(params or {})


def autotune_cell(*args, **kwargs):
    """Measure one cell now — see :func:`repro.tune.autotune.autotune_cell`
    (lazy re-export so ``import repro.tune`` never pulls jax)."""
    from repro.tune import autotune

    return autotune.autotune_cell(*args, **kwargs)
