"""repro.tune — empirical kernel autotuning with a persistent cache.

The hot-path dispatch knobs (Pallas tile sizes, the pallas-vs-XLA impl
choice, the blocked-kNN row block, the streaming chunk budget) default to
hand-picked constants. This package measures candidates per hardware and
shape bucket and persists the winners, so dispatch can tune itself to the
machine instead of to the author's laptop.

Policy (``RuntimeConfig.tune`` / ``REPRO_TUNE``):

  * ``"off"``      — default; every constant exactly as hand-picked.
  * ``"cached"``   — consult the cache, fall back to the constants on a
    miss; never measures (production mode: deterministic given the file).
  * ``"onthefly"`` — consult the cache and **measure on a miss**, persisting
    the winner (warmup mode — first call per new bucket pays the sweep).

:func:`tuned_params` is the one policy gate every consumer goes through
(``ops._resolve``/the ops entry points, ``core.knn.resolve_auto_block``,
``plan_fit``); with the policy off it returns ``{}`` without touching the
cache, so the off path costs one config read. Cache mutations bump
:func:`repro.tune.cache.cache_epoch`, which ``dispatch_key()`` folds in
whenever tuning is active — tuned values read at trace time can never be
served from a jit program compiled under older winners (DESIGN.md §14).

CLI: ``python -m repro.tune populate|show|prune|clear`` manages the cache.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, Optional

from repro import runtime
from repro.tune.cache import (  # noqa: F401  (re-exported API)
    CACHE_ENV,
    TuningCache,
    cache_epoch,
    default_cache_path,
    get_cache,
    pow2_bucket,
    set_cache,
    shape_bucket,
)

__all__ = [
    "CACHE_ENV", "TuningCache", "autotune_cell", "cache_epoch",
    "default_cache_path", "get_cache", "pow2_bucket", "set_cache",
    "shape_bucket", "tuned_params",
]


# cached params that size a block/tile/budget. Every candidate the tuner
# ever emits for these is a power of two, and shape buckets round up to
# powers of two (repro.tune.cache.pow2_bucket) — so "is a positive power
# of two" is exactly "still divides some bucket edge". Anything else is a
# stale or hand-mangled entry and must not reach the kernels.
_SIZE_PARAMS = ("block_q", "block_k", "block_s", "block_n", "knn_block",
                "chunk_n", "reservoir_n")


def _stale_reason(params: Any) -> Optional[str]:
    """Why a cached winner can no longer be honoured (None = fine).

    The cache file outlives code changes: an impl that was deregistered,
    or a tile size that no longer divides its shape bucket, used to sail
    through to ``ops._resolve``/the kernels and raise ``ValueError`` in
    the middle of a fit. The gate catches those here so the caller can
    fall back to the hand-picked constants instead.
    """
    if not isinstance(params, dict):
        return f"params is {type(params).__name__}, not a dict"
    impl = params.get("impl")
    if impl is not None:
        from repro.runtime.config import _IMPLS  # the single impl registry

        if not isinstance(impl, str) or impl not in _IMPLS or impl == "auto":
            return f"impl {impl!r} is not a registered impl"
    for name in _SIZE_PARAMS:
        if name not in params:
            continue
        v = params[name]
        if not isinstance(v, int) or isinstance(v, bool) or v < 1 \
                or (v & (v - 1)) != 0:
            return (f"{name}={v!r} is not a positive power of two and "
                    f"cannot tile a pow2 shape bucket")
    # prefetch_depth is a queue depth, not a tile: 0 (serial) and small
    # non-pow2 depths are all legal — only reject non-ints/negatives
    if "prefetch_depth" in params:
        v = params["prefetch_depth"]
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            return (f"prefetch_depth={v!r} is not a non-negative int")
    return None


def tuned_params(kernel: str, *, dtype: str = "float32",
                 **dims: int) -> Dict[str, Any]:
    """Winning params for ``kernel`` at the bucket of ``dims``, or ``{}``.

    Honours the active tune policy: ``off`` never looks, ``cached`` looks
    but never measures, ``onthefly`` measures (and persists) on a miss.
    Callers treat a missing key in the result as "use the constant", so a
    partial dict — e.g. ``{"impl": "ref"}`` with no tile sizes — is valid.

    Stale entries — a winner naming a now-unregistered impl, or a tile
    size that no longer divides its shape bucket — are ignored AND pruned
    from the cache (with a warning) rather than handed to the kernels,
    where they would raise ``ValueError`` mid-fit. The prune bumps the
    cache epoch, so compiled programs never pin the rejected entry.
    """
    mode = runtime.active().tune
    if mode == "off":
        return {}
    from repro.tune.autotune import current_device_kind  # lazy: jax

    device_kind = current_device_kind()
    bucket = shape_bucket(**dims)
    cache = get_cache()
    params = cache.lookup(device_kind, kernel, bucket, dtype)
    if params is not None:
        reason = _stale_reason(params)
        if reason is not None:
            warnings.warn(
                f"ignoring stale tuning-cache entry "
                f"{device_kind}|{kernel}|{bucket}|{dtype}: {reason}; "
                f"pruned — falling back to the built-in constants "
                f"(re-run `python -m repro.tune populate` to re-measure)",
                RuntimeWarning, stacklevel=2)
            cache.discard(device_kind, kernel, bucket, dtype)
            params = None
    if params is None and mode == "onthefly":
        from repro.tune.autotune import autotune_cell

        params, _ = autotune_cell(kernel, dims, dtype=dtype, cache=cache)
    return dict(params or {})


def autotune_cell(*args, **kwargs):
    """Measure one cell now — see :func:`repro.tune.autotune.autotune_cell`
    (lazy re-export so ``import repro.tune`` never pulls jax)."""
    from repro.tune import autotune

    return autotune.autotune_cell(*args, **kwargs)
