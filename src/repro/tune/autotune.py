"""Empirical measurement of dispatch candidates — the autotuner proper.

Each registered cell name maps to (a) a candidate generator and (b) a
runner that times one candidate on synthetic inputs **at the bucket edge**
(dims rounded up by :func:`repro.tune.cache.pow2_bucket`), so the recorded
winner is measured at the worst case of the bucket it will serve.

Cells and what they tune (DESIGN.md §14):

  * ``"knn"`` / ``"pairwise_sq_l2"`` / ``"segment_sum"`` — the kernel
    entry points in :mod:`repro.kernels.ops`: the impl choice
    (pallas vs the jnp reference) and, for the Pallas winner, its tile
    sizes (``block_q``/``block_k``, ``block_s``/``block_n``). Pallas
    candidates only join the sweep on a real TPU (or with
    ``include_pallas=True``): interpret mode is orders slower and would
    never win, so measuring it is wasted time.
  * ``"knn_block"`` — the executor-level blocked-kNN row block that
    ``knn_block=0`` ("auto") resolves to (today's hand-picked constant is
    ``repro.core.knn.AUTO_KNN_BLOCK``).
  * ``"stream"`` — the streaming-fit chunk budget ``chunk_n`` and ingest
    ``prefetch_depth`` (shape-free cell: one winner per device kind,
    bucket ``"any"``). Every depth is bit-identical (DESIGN.md §18), so
    tuning it is a pure latency choice; ``donate_stream`` stays manual —
    donation changes buffer ownership, not a tile size.
  * ``"assign"`` — the nearest/top-k hot path (serve-side
    ``ClusterIndex.assign`` and the fused blocked-kNN inner loop,
    DESIGN.md §16): composed ref vs the fused streaming family incl. the
    quantized shortlist+rescore variants, plus fused tile sizes. Pallas
    composed candidates keep the TPU-only default; the fused XLA fold and
    the quantized variants run everywhere, so this cell is worth
    populating on CPU too.

Deliberately **not** tuned: ``n_blocks``, the canonical fixed-reduction
width. It pins the summation order that makes single-device, sharded and
streaming executors bit-comparable (DESIGN.md §4.3); tuning it would trade
the parity contract for a constant factor.

Timing discipline: first call discarded (compile), then the median of
``repeats`` synced runs — the same noise treatment the perf gate applies
(benchmarks/gate.py).
"""
from __future__ import annotations

import statistics
import time
from typing import Any, Dict, List, Optional, Tuple

from repro import runtime
from repro.tune.cache import (
    TuningCache,
    get_cache,
    pow2_bucket,
    shape_bucket,
)

#: cells the autotuner knows how to measure (CLI ``populate`` default set)
KERNELS = ("knn", "pairwise_sq_l2", "segment_sum", "knn_block", "stream",
           "assign")

# hardware-aligned Pallas tile candidates (sublane/lane multiples only —
# misaligned tiles are a known Mosaic footgun, see the Pallas guide)
_QK_TILES = [(bq, bk) for bq in (128, 256, 512) for bk in (256, 512, 1024)]
_SEG_TILES = [(bs, bn) for bs in (256, 512, 1024) for bn in (512, 1024, 2048)]
_KNN_BLOCKS = (2048, 4096, 8192, 16384)
_CHUNKS = (1024, 2048, 4096)
_PREFETCH_DEPTHS = (0, 2)  # serial vs pipelined ingest (§18); bit-identical
_ASSIGN_BKS = (512, 1024, 2048)  # fused key-block tiles (pow2, lane-aligned)

#: synthetic dims a cell is measured at when the caller gives none
DEFAULT_DIMS: Dict[str, Dict[str, int]] = {
    "knn": {"n": 8192, "d": 8, "k": 3},
    "pairwise_sq_l2": {"n": 4096, "m": 4096, "d": 8},
    "segment_sum": {"n": 8192, "d": 8, "s": 1024},
    "knn_block": {"n": 16384, "d": 8, "k": 3},
    "stream": {},
    "assign": {"nq": 1024, "p": 8192, "d": 8, "k": 1},
}


def current_device_kind() -> str:
    import jax

    return jax.devices()[0].device_kind


def _include_pallas_default() -> bool:
    import jax

    return jax.default_backend() == "tpu"


def candidates_for(kernel: str, dims: Dict[str, int],
                   include_pallas: bool) -> List[Dict[str, Any]]:
    """The candidate parameter dicts swept for one cell."""
    if kernel in ("knn", "pairwise_sq_l2"):
        cands: List[Dict[str, Any]] = [{"impl": "ref"}]
        if include_pallas:
            cands += [{"impl": "pallas", "block_q": bq, "block_k": bk}
                      for bq, bk in _QK_TILES]
        return cands
    if kernel == "segment_sum":
        cands = [{"impl": "ref"}]
        if include_pallas:
            cands += [{"impl": "pallas", "block_s": bs, "block_n": bn}
                      for bs, bn in _SEG_TILES]
        return cands
    if kernel == "knn_block":
        ceiling = pow2_bucket(dims.get("n", _KNN_BLOCKS[-1]))
        blocks = [b for b in _KNN_BLOCKS if b <= ceiling] or [ceiling]
        return [{"knn_block": b} for b in blocks]
    if kernel == "stream":
        return [{"chunk_n": c, "prefetch_depth": p}
                for c in _CHUNKS for p in _PREFETCH_DEPTHS]
    if kernel == "assign":
        # composed ref + the fused streaming family (XLA fold off-TPU, so
        # it is measurable everywhere); Pallas composed candidates keep
        # the TPU-only default — interpret mode would never win
        cands = [{"impl": "ref"}]
        cands += [{"impl": "fused", "block_k": bk} for bk in _ASSIGN_BKS]
        cands += [{"impl": "fused_bf16", "block_k": bk}
                  for bk in _ASSIGN_BKS]
        cands += [{"impl": "fused_int8", "block_k": bk}
                  for bk in _ASSIGN_BKS]
        if include_pallas:
            cands += [{"impl": "pallas", "block_q": bq, "block_k": bk}
                      for bq, bk in _QK_TILES]
        return cands
    raise ValueError(f"unknown tunable kernel {kernel!r}; have {KERNELS}")


def _median_seconds(fn, repeats: int) -> float:
    import jax

    out = fn()  # compile + warm caches; excluded from the median
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


def _runner(kernel: str, dims: Dict[str, int], dtype: str):
    """Build synthetic bucket-edge inputs once; return fn(params) that
    runs one candidate end to end."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops
    from repro.kernels import knn_topk as _knn
    from repro.kernels import pairwise_l2 as _pw
    from repro.kernels import segment_sum as _ss

    rng = np.random.default_rng(0)
    jdt = jnp.dtype(dtype)

    if kernel == "knn":
        n, d, k = (pow2_bucket(dims[a]) for a in ("n", "d", "k"))
        x = jnp.asarray(rng.normal(size=(n, d)), jdt)

        def run(params):
            if params.get("impl") == "pallas":
                return _knn.knn_topk(
                    x, k, block_q=params["block_q"],
                    block_k=params["block_k"], interpret=ops._interpret())
            return ops.knn(x, k, impl="ref")

        return run

    if kernel == "pairwise_sq_l2":
        n, m, d = (pow2_bucket(dims[a]) for a in ("n", "m", "d"))
        x = jnp.asarray(rng.normal(size=(n, d)), jdt)
        y = jnp.asarray(rng.normal(size=(m, d)), jdt)

        def run(params):
            if params.get("impl") == "pallas":
                return _pw.pairwise_sq_l2(
                    x, y, None, block_q=params["block_q"],
                    block_k=params["block_k"], interpret=ops._interpret())
            return ops.pairwise_sq_l2(x, y, impl="ref")

        return run

    if kernel == "segment_sum":
        n, d, s = (pow2_bucket(dims[a]) for a in ("n", "d", "s"))
        x = jnp.asarray(rng.normal(size=(n, d)), jdt)
        ids = jnp.asarray(rng.integers(0, s, size=n), jnp.int32)

        def run(params):
            if params.get("impl") == "pallas":
                return _ss.segment_sum(
                    x, ids, s, None, block_s=params["block_s"],
                    block_n=params["block_n"], interpret=ops._interpret())
            return ops.segment_sum(x, ids, s, impl="ref")

        return run

    if kernel == "knn_block":
        from repro.core.knn import knn_graph_blocked

        n, d, k = (pow2_bucket(dims[a]) for a in ("n", "d", "k"))
        x = jnp.asarray(rng.normal(size=(n, d)), jdt)

        def run(params):
            return knn_graph_blocked(x, k, block=params["knn_block"])

        return run

    if kernel == "assign":
        from repro.core.index import ClusterIndex

        nq, p, d = (pow2_bucket(dims[a]) for a in ("nq", "p", "d"))
        protos = jnp.asarray(rng.normal(size=(p, d)), jnp.float32)
        idx = ClusterIndex.build(ClusterIndex(
            protos=protos,
            proto_mass=jnp.ones((p,), jnp.float32),
            proto_valid=jnp.ones((p,), bool),
            proto_labels=jnp.asarray(np.arange(p) % 16, jnp.int32),
            n_prototypes=jnp.asarray(p, jnp.int32),
        ))
        q = jnp.asarray(rng.normal(size=(nq, d)), jdt)

        def run(params):
            return idx.assign(q, impl=params["impl"],
                              block_q=params.get("block_q"),
                              block_k=params.get("block_k"))

        return run

    if kernel == "stream":
        import repro

        d = pow2_bucket(dims.get("d", 8))
        n = 4 * max(_CHUNKS)
        x = rng.normal(size=(n, d)).astype(dtype)

        def run(params):
            c = params["chunk_n"]
            chunks = (x[i:i + c] for i in range(0, n, c))
            res = repro.fit(chunks, 2, 1, "kmeans", k=3,
                            executor="streaming", chunk_n=c,
                            prefetch_depth=params["prefetch_depth"])
            return res.proto_labels

        return run

    raise ValueError(f"unknown tunable kernel {kernel!r}; have {KERNELS}")


def autotune_cell(
    kernel: str,
    dims: Optional[Dict[str, int]] = None,
    *,
    dtype: str = "float32",
    cache: Optional[TuningCache] = None,
    repeats: int = 3,
    include_pallas: Optional[bool] = None,
    save: bool = True,
    verbose: bool = False,
) -> Tuple[Dict[str, Any], float]:
    """Measure every candidate of one cell; record + return the winner.

    Runs under a ``tune="off"`` scope so the kernels being measured never
    recursively consult the cache being populated. Returns
    ``(winning params, median seconds)``.
    """
    dims = dict(DEFAULT_DIMS[kernel] if dims is None else dims)
    if include_pallas is None:
        include_pallas = _include_pallas_default()
    cache = get_cache() if cache is None else cache
    cands = candidates_for(kernel, dims, include_pallas)

    best: Optional[Dict[str, Any]] = None
    best_sec = float("inf")
    with runtime.configure(tune="off"):
        run = _runner(kernel, dims, dtype)
        for params in cands:
            sec = _median_seconds(lambda params=params: run(params), repeats)
            if verbose:
                print(f"#   {kernel} {params} -> {sec * 1e3:.3f} ms")
            if sec < best_sec:
                best, best_sec = params, sec
    assert best is not None
    bucket = shape_bucket(**dims)
    cache.record(current_device_kind(), kernel, bucket, best, dtype=dtype,
                 seconds=round(best_sec, 6), candidates=len(cands),
                 save=save)
    return best, best_sec
