"""``python -m repro.tune`` — manage the persistent tuning cache.

Subcommands:

  populate  measure the registered cells on this machine and persist the
            winners (``--kernels``, ``--shapes NxD[xK]``, ``--repeats``,
            ``--include-pallas``)
  show      print every cache entry (``--kernel`` / ``--device-kind``
            filters)
  prune     drop stale entries (``--max-age-days``) and/or everything for
            a device kind or kernel
  clear     empty the cache

``--cache PATH`` (or ``$REPRO_TUNE_CACHE``) selects the file; the default
is ``~/.cache/repro/tune_cache.json``.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.tune.cache import TuningCache, default_cache_path


def _parse_shapes(spec: str) -> List[dict]:
    """Two spellings, comma-separated:

    positional ``8192x8[x3]`` → ``{"n": 8192, "d": 8, "k": 3}``;
    named ``n8192:m512:d8`` → any bucket dim (``m``, ``s``, ...) that the
    positional NxD[xK] form cannot address.
    """
    out = []
    for part in spec.split(","):
        part = part.strip().lower()
        if not part:
            continue
        if part[0].isalpha() or ":" in part:
            dims = {}
            for item in part.split(":"):
                name = item.rstrip("0123456789")
                if not name or name == item:
                    raise SystemExit(
                        f"--shapes: bad named dim {item!r} in {part!r} "
                        f"(want e.g. n8192:m512:d8)")
                dims[name] = int(item[len(name):])
            out.append(dims)
        else:
            vals = [int(v) for v in part.split("x")]
            names = ("n", "d", "k")[: len(vals)]
            out.append(dict(zip(names, vals, strict=False)))  # >3 dims: extras are deliberately dropped
    return out


def _cmd_populate(args) -> int:
    from repro.tune.autotune import DEFAULT_DIMS, KERNELS, autotune_cell

    cache = TuningCache(args.cache)
    kernels = ([k.strip() for k in args.kernels.split(",") if k.strip()]
               if args.kernels else list(KERNELS))
    shapes = _parse_shapes(args.shapes) if args.shapes else [None]
    for kernel in kernels:
        if kernel not in KERNELS:
            print(f"unknown kernel {kernel!r}; have {list(KERNELS)}",
                  file=sys.stderr)
            return 2
        for dims in shapes:
            cell_dims = dims
            if dims is not None:
                # keep only the dims this cell is bucketed by — and say so
                # when a requested dim doesn't apply, rather than silently
                # measuring a different bucket than the user asked for
                cell_dims = {k: v for k, v in dims.items()
                             if k in DEFAULT_DIMS[kernel]}
                dropped = sorted(set(dims) - set(cell_dims))
                defaulted = sorted(set(DEFAULT_DIMS[kernel]) - set(cell_dims))
                if dropped or defaulted:
                    print(f"# note: {kernel} is bucketed on "
                          f"{sorted(DEFAULT_DIMS[kernel]) or 'no dims'}"
                          + (f"; ignoring {dropped} from --shapes"
                             if dropped else "")
                          + (f"; using built-in defaults for {defaulted}"
                             if defaulted else ""),
                          file=sys.stderr)
                cell_dims = {**DEFAULT_DIMS[kernel], **cell_dims}
            params, sec = autotune_cell(
                kernel, cell_dims, dtype=args.dtype, cache=cache,
                repeats=args.repeats,
                include_pallas=args.include_pallas or None,
                verbose=args.verbose)
            print(f"# tuned {kernel} dims={cell_dims or 'default'} -> "
                  f"{params} ({sec * 1e3:.3f} ms median)")
    print(f"# cache: {cache.path} ({len(cache)} entries)")
    return 0


def _cmd_show(args) -> int:
    cache = TuningCache(args.cache)
    shown = 0
    print(f"# tuning cache {cache.path}")
    for (dk, kernel, bucket, dtype), rec in cache.entries():
        if args.kernel and kernel != args.kernel:
            continue
        if args.device_kind and dk != args.device_kind:
            continue
        sec = rec.get("seconds")
        ms = f"{sec * 1e3:.3f} ms" if sec is not None else "-"
        print(f"{dk} | {kernel} | {bucket} | {dtype} -> {rec['params']} "
              f"({ms}, {rec.get('candidates', 0)} candidates)")
        shown += 1
    print(f"# {shown} of {len(cache)} entries shown")
    return 0


def _cmd_prune(args) -> int:
    cache = TuningCache(args.cache)
    if args.max_age_days is None and not args.device_kind and not args.kernel:
        print("prune needs --max-age-days and/or --device-kind/--kernel "
              "(use clear to drop everything)", file=sys.stderr)
        return 2
    n = cache.prune(max_age_days=args.max_age_days,
                    device_kind=args.device_kind or None,
                    kernel=args.kernel or None)
    print(f"# pruned {n} entries; {len(cache)} remain in {cache.path}")
    return 0


def _cmd_clear(args) -> int:
    cache = TuningCache(args.cache)
    n = cache.clear()
    print(f"# cleared {n} entries from {cache.path}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="manage the persistent kernel-tuning cache")
    ap.add_argument("--cache", default=None,
                    help=f"cache file (default {default_cache_path()})")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("populate", help="measure cells, persist winners")
    p.add_argument("--kernels", default="",
                   help="comma list (default: every registered cell)")
    p.add_argument("--shapes", default="",
                   help="comma list of synthetic shapes: NxD[xK] "
                        "positional, or named dims like n8192:m512:d8 "
                        "or nq1024:p8192:d8 for cells bucketed on "
                        "m/s/nq/p (default: one built-in shape per cell)")
    p.add_argument("--repeats", type=int, default=3,
                   help="timed runs per candidate (median taken)")
    p.add_argument("--dtype", default="float32",
                   help="element type to measure and key the cells with "
                        "(runtime lookups key by the data's actual dtype)")
    p.add_argument("--include-pallas", action="store_true",
                   help="sweep Pallas tile candidates off-TPU too "
                        "(interpret mode: slow, for plumbing tests)")
    p.add_argument("--verbose", action="store_true")
    p.set_defaults(fn=_cmd_populate)

    p = sub.add_parser("show", help="print cache entries")
    p.add_argument("--kernel", default="")
    p.add_argument("--device-kind", default="")
    p.set_defaults(fn=_cmd_show)

    p = sub.add_parser("prune", help="drop stale/filtered entries")
    p.add_argument("--max-age-days", type=float, default=None)
    p.add_argument("--device-kind", default="")
    p.add_argument("--kernel", default="")
    p.set_defaults(fn=_cmd_prune)

    p = sub.add_parser("clear", help="empty the cache")
    p.set_defaults(fn=_cmd_clear)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
