"""Persistent tuning cache: measured dispatch winners, keyed by hardware.

One JSON file maps ``device_kind / kernel / shape_bucket / dtype`` to the
winning parameter dict the autotuner measured for that cell (plus the
measurement metadata needed to judge staleness). The file is the *only*
state the tuning subsystem owns — deleting it restores the hand-picked
constants everywhere, and committing it pins a machine's tuned dispatch
for reproducibility.

Key layout (DESIGN.md §14):

  * ``device_kind`` — ``jax.devices()[0].device_kind`` ("cpu",
    "TPU v4", ...): tuned winners never leak across hardware;
  * ``kernel``      — the registered entry-point name ("knn",
    "pairwise_sq_l2", "segment_sum", "knn_block", "stream");
  * ``shape_bucket`` — every shape dimension rounded **up** to a power of
    two (:func:`shape_bucket`), so one measurement covers a bucket of
    nearby problem sizes instead of an unbounded key space;
  * ``dtype``       — the input element type name.

This module is deliberately stdlib-only (no jax import): the runtime
config's ``dispatch_key()`` pulls :func:`cache_epoch` from here on every
public entry-point call, and the CLI's inspect/prune paths must work on a
machine where jax is broken or absent.

Epoch contract: :func:`cache_epoch` returns a process-wide counter bumped
on every mutation or (re)load of the active cache. ``RuntimeConfig.
dispatch_key()`` folds it in whenever the tune policy is active, so a
cache update can never be masked by a jit program traced under the
previous winners (the §10 no-stale-cache contract, extended to tuning).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Iterator, Optional, Tuple

SCHEMA_VERSION = 1

#: env var naming the cache file (the CLI's --cache flag wins over it)
CACHE_ENV = "REPRO_TUNE_CACHE"

_KEY_SEP = "|"


def default_cache_path() -> str:
    """``$REPRO_TUNE_CACHE`` or ``~/.cache/repro/tune_cache.json``."""
    env = os.environ.get(CACHE_ENV, "")
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro", "tune_cache.json")


def make_key(device_kind: str, kernel: str, shape_bucket: str,
             dtype: str) -> str:
    for part in (device_kind, kernel, shape_bucket, dtype):
        if _KEY_SEP in part:
            raise ValueError(f"cache key part {part!r} contains {_KEY_SEP!r}")
    return _KEY_SEP.join((device_kind, kernel, shape_bucket, dtype))


def split_key(key: str) -> Tuple[str, str, str, str]:
    device_kind, kernel, shape_bucket, dtype = key.split(_KEY_SEP)
    return device_kind, kernel, shape_bucket, dtype


def pow2_bucket(v: int) -> int:
    """Smallest power of two >= max(v, 1) — the bucket edge a dimension
    rounds up to, so a winner measured at the edge covers the bucket."""
    v = max(int(v), 1)
    return 1 << (v - 1).bit_length()


def shape_bucket(**dims: int) -> str:
    """Canonical bucket string: dims sorted by name, each pow2-rounded.

    ``shape_bucket(n=3000, d=5)`` → ``"d8,n4096"``; no dims → ``"any"``
    (used by shape-free cells like the streaming chunk budget).
    """
    if not dims:
        return "any"
    return ",".join(f"{k}{pow2_bucket(v)}" for k, v in sorted(dims.items()))


# --------------------------------------------------------------------------
# the cache object + the process-global active instance
# --------------------------------------------------------------------------


class TuningCache:
    """On-disk JSON map of measured winners. Load-lazily, save-eagerly:
    every :meth:`record` persists (atomic rename), so a crashed tuning run
    keeps everything measured so far."""

    def __init__(self, path: Optional[str] = None):
        self.path = default_cache_path() if path is None else path
        self._entries: Optional[Dict[str, dict]] = None

    # ---- persistence ------------------------------------------------------

    def _load(self) -> Dict[str, dict]:
        if self._entries is None:
            try:
                with open(self.path) as f:
                    blob = json.load(f)
                if blob.get("version") != SCHEMA_VERSION:
                    self._entries = {}
                else:
                    self._entries = dict(blob.get("entries", {}))
            except (OSError, ValueError):
                self._entries = {}
        return self._entries

    def save(self) -> None:
        entries = self._load()
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": SCHEMA_VERSION, "entries": entries},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    def reload(self) -> None:
        """Drop the in-memory view and re-read the file on next access."""
        self._entries = None
        bump_epoch()

    # ---- lookup / record --------------------------------------------------

    def lookup(self, device_kind: str, kernel: str, shape_bucket: str,
               dtype: str = "float32") -> Optional[Dict[str, Any]]:
        """The winning params dict for one cell, or None on a miss."""
        rec = self._load().get(make_key(device_kind, kernel, shape_bucket,
                                        dtype))
        return dict(rec["params"]) if rec else None

    def record(self, device_kind: str, kernel: str, shape_bucket: str,
               params: Dict[str, Any], *, dtype: str = "float32",
               seconds: Optional[float] = None, candidates: int = 0,
               save: bool = True) -> None:
        """Store one measured winner (and persist unless ``save=False``)."""
        entries = self._load()
        entries[make_key(device_kind, kernel, shape_bucket, dtype)] = {
            "params": dict(params),
            "seconds": seconds,
            "candidates": int(candidates),
            "recorded_unix": round(time.time(), 1),
        }
        bump_epoch()
        if save:
            self.save()

    # ---- maintenance ------------------------------------------------------

    def discard(self, device_kind: str, kernel: str, shape_bucket: str,
                dtype: str = "float32", *, save: bool = True) -> bool:
        """Drop one entry by exact key (used to prune stale winners the
        validation gate rejects — see :func:`repro.tune.tuned_params`).
        Returns whether anything was removed."""
        entries = self._load()
        key = make_key(device_kind, kernel, shape_bucket, dtype)
        if key not in entries:
            return False
        del entries[key]
        bump_epoch()
        if save:
            self.save()
        return True

    def entries(self) -> Iterator[Tuple[Tuple[str, str, str, str], dict]]:
        """((device_kind, kernel, shape_bucket, dtype), record) pairs."""
        for key, rec in sorted(self._load().items()):
            yield split_key(key), rec

    def __len__(self) -> int:
        return len(self._load())

    def prune(self, *, max_age_days: Optional[float] = None,
              device_kind: Optional[str] = None,
              kernel: Optional[str] = None, save: bool = True) -> int:
        """Drop entries older than ``max_age_days`` and/or matching the
        given device kind / kernel filters; returns the dropped count."""
        entries = self._load()
        cutoff = (time.time() - max_age_days * 86400.0
                  if max_age_days is not None else None)
        drop = []
        for key, rec in entries.items():
            dk, kn, _, _ = split_key(key)
            if cutoff is not None and rec.get("recorded_unix", 0) >= cutoff:
                continue
            if cutoff is None:
                # pure filter mode: only drop what the filters name
                if device_kind is None and kernel is None:
                    continue
            if device_kind is not None and dk != device_kind:
                continue
            if kernel is not None and kn != kernel:
                continue
            drop.append(key)
        for key in drop:
            del entries[key]
        if drop:
            bump_epoch()
            if save:
                self.save()
        return len(drop)

    def clear(self, save: bool = True) -> int:
        entries = self._load()
        n = len(entries)
        entries.clear()
        bump_epoch()
        if save:
            self.save()
        return n


# process-global active cache + the epoch counter dispatch_key() reads
_lock = threading.Lock()
_active: Optional[TuningCache] = None
_epoch = 0


def bump_epoch() -> int:
    global _epoch
    with _lock:
        _epoch += 1
        return _epoch


def cache_epoch() -> int:
    """Monotonic fingerprint of the active cache's mutation history —
    folded into ``RuntimeConfig.dispatch_key()`` when tuning is active."""
    return _epoch


def get_cache() -> TuningCache:
    """The process-global cache every tuned lookup consults."""
    global _active
    with _lock:
        if _active is None:
            _active = TuningCache()
        return _active


def set_cache(cache_or_path) -> TuningCache:
    """Swap the active cache (a TuningCache or a path); returns it.
    Bumps the epoch so compiled programs traced under the old cache
    retrace."""
    global _active
    cache = (cache_or_path if isinstance(cache_or_path, TuningCache)
             else TuningCache(cache_or_path))
    with _lock:
        _active = cache
    bump_epoch()
    return cache
