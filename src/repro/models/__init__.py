"""Composable model zoo: dense / MoE / SSD / hybrid / enc-dec / VLM backbones."""
from repro.models.registry import ModelBundle, build  # noqa: F401
from repro.models.transformer import ShardingPlan  # noqa: F401
