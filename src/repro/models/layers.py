"""Shared neural-net layers (pure functional JAX; params are dict pytrees).

Init functions are `jax.eval_shape`-safe (the dry-run materializes parameter
ShapeDtypeStructs without ever allocating), and every init has a sibling
`*_specs` builder producing the matching PartitionSpec pytree.

Sharding conventions (Megatron-style TP over the 'model' axis, DP over
('pod','data')): column-parallel up/QKV, row-parallel down/out, vocab-sharded
embedding/unembedding.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

Dtype = jnp.dtype
COMPUTE_DTYPE = jnp.bfloat16


def _dense_init(key, shape, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = (1.0 / fan_in) ** 0.5 if scale is None else scale
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(jnp.float32)


# ---------------------------------------------------------------- norms
def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(dt)


def init_norm(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)  # (1 + w) parameterization (gemma-style)


# ---------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, n_heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- mlp
def init_mlp(key, d: int, ff: int, kind: str) -> dict:
    ks = jax.random.split(key, 3)
    p = {"down": _dense_init(ks[2], (ff, d))}
    if kind == "swiglu":
        p["gate"] = _dense_init(ks[0], (d, ff))
        p["up"] = _dense_init(ks[1], (d, ff))
    else:
        p["up"] = _dense_init(ks[1], (d, ff))
    return p


def mlp_specs(kind: str, tp: str = "model") -> dict:
    p = {"down": P(tp, None), "up": P(None, tp)}
    if kind == "swiglu":
        p["gate"] = P(None, tp)
    return p


def mlp_apply(params: dict, x: jax.Array, kind: str) -> jax.Array:
    # activations stay in the compute dtype (bf16): non-linearities are
    # numerically benign and an fp32 upcast doubles the FFN's HBM traffic
    dt = x.dtype
    up = x @ params["up"].astype(dt)
    if kind == "swiglu":
        gate = x @ params["gate"].astype(dt)
        h = jax.nn.silu(gate) * up
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(up))
    else:  # gelu
        h = jax.nn.gelu(up)
    return h @ params["down"].astype(dt)


# ---------------------------------------------------------------- embedding
def init_embed(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    v = cfg.padded_vocab_size  # TP-shardable; pad logits masked at the head
    p = {"table": _dense_init(k1, (v, cfg.d_model), scale=1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(k2, (cfg.d_model, v))
    return p


def embed_specs(cfg: ModelConfig, tp: str = "model") -> dict:
    p = {"table": P(tp, None)}
    if not cfg.tie_embeddings:
        p["unembed"] = P(None, tp)
    return p


def embed_apply(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = params["table"].astype(COMPUTE_DTYPE)[tokens]
    if cfg.family in ("dense",) and cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model**0.5, COMPUTE_DTYPE)
    return x


def unembed_apply(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    if cfg.tie_embeddings:
        logits = x @ params["table"].astype(dt).T
    else:
        logits = x @ params["unembed"].astype(dt)
    logits = logits.astype(jnp.float32)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    if cfg.padded_vocab_size != cfg.vocab_size:  # mask the padding columns
        col = jnp.arange(cfg.padded_vocab_size)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits
