"""GQA attention: chunked-flash XLA path, Pallas path, KV cache, local/global.

The XLA path implements the flash algorithm with `lax.scan` over kv chunks
(online softmax), so even 32k-token prefill never materializes (S, S) logits
— this is both the production non-TPU path and the path the dry-run lowers
for faithful roofline accounting (DESIGN.md §7). On TPU, `impl='pallas'`
switches the inner loop to the fused kernel.

Decode attends one query against the cache with a position mask folded into
``kv_bias`` — the same slot the IHTC prototype ``log(mass)`` correction uses
(serve/kv_compression.py), so compressed and raw caches share one code path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import COMPUTE_DTYPE, _dense_init, rope

_MASKED = -1e30


# ------------------------------------------------------------- params
def init_attention(key, cfg: ModelConfig) -> dict:
    hd, hq, hkv, d = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, hq * hd)),
        "wk": _dense_init(ks[1], (d, hkv * hd)),
        "wv": _dense_init(ks[2], (d, hkv * hd)),
        "wo": _dense_init(ks[3], (hq * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    return p


def attention_specs(cfg: ModelConfig, tp: str = "model", tp_size: int = 1) -> dict:
    kv_dim = cfg.n_kv_heads * cfg.head_dim
    kv_spec = P(None, tp) if kv_dim % max(tp_size, 1) == 0 else P(None, None)
    kv_bias_spec = kv_spec[1] if isinstance(kv_spec[1], str) else None
    p = {
        "wq": P(None, tp),
        "wk": kv_spec,
        "wv": kv_spec,
        "wo": P(tp, None),
    }
    if cfg.qkv_bias:
        p["bq"] = P(tp)
        p["bk"] = P(kv_bias_spec)
        p["bv"] = P(kv_bias_spec)
    return p


# ------------------------------------------------------------- core attend
def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    kv_bias: Optional[jax.Array] = None,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    chunk: int = 1024,
) -> jax.Array:
    """Flash-style GQA attention in pure XLA: scan over kv chunks, online
    softmax, grouped-query einsums (kv heads are NEVER repeated/materialized
    at full query-head width — that costs b·hq·lk·dh bytes on long context).

    q: (b, hq, lq, dh); k/v: (b, hkv, lk, dh); kv_bias: (b, hkv, lk).
    Peak intermediate is (b, hq, lq, chunk).
    """
    b, hq, lq, dh = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    g = hq // hkv
    s = (1.0 / (dh**0.5)) if scale is None else scale
    qf = (q.astype(jnp.float32) * s).reshape(b, hkv, g, lq, dh)

    ck = min(chunk, lk)
    pad = (-lk) % ck
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if kv_bias is None:
            kv_bias = jnp.zeros((b, hkv, lk), jnp.float32)
        kv_bias = jnp.pad(kv_bias, ((0, 0), (0, 0), (0, pad)), constant_values=_MASKED)
    nc = (lk + pad) // ck
    qpos = jnp.arange(lq) + (lk - lq)  # global query positions

    # NOTE: the chunk loop is UNROLLED (nc is static and small), not a
    # lax.scan: (a) HloCostAnalysis is blind to while-loop trip counts, so an
    # unrolled loop keeps the dry-run roofline exact; (b) XLA pipelines the
    # chunks better without a loop carrier. Fully-masked chunks (causal:
    # kpos > max qpos; local window: kpos < min qpos - window) are SKIPPED —
    # that is the block-sparsity win of flash attention.
    m = jnp.full((b, hkv, g, lq), _MASKED, jnp.float32)
    l = jnp.zeros((b, hkv, g, lq), jnp.float32)
    acc = jnp.zeros((b, hkv, g, lq, dh), jnp.float32)
    for j in range(nc):
        k0 = j * ck
        if causal and k0 > (lk - 1):
            continue  # chunk entirely in the future of the last query
        if window > 0 and (k0 + ck) <= (lk - lq) - window + 1:
            continue  # chunk entirely outside every query's window
        kj = jax.lax.slice_in_dim(k, k0, k0 + ck, axis=2).astype(jnp.float32)
        vj = jax.lax.slice_in_dim(v, k0, k0 + ck, axis=2).astype(jnp.float32)
        bj = (jax.lax.slice_in_dim(kv_bias, k0, k0 + ck, axis=2)
              if kv_bias is not None else None)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kj)
        if softcap > 0.0:
            logits = softcap * jnp.tanh(logits / softcap)
        if bj is not None:
            logits = logits + bj[:, :, None, None, :]
        kpos = k0 + jnp.arange(ck)
        if causal:
            logits = jnp.where(
                kpos[None, None, None, None, :] <= qpos[None, None, None, :, None],
                logits, _MASKED,
            )
        if window > 0:
            logits = jnp.where(
                kpos[None, None, None, None, :]
                > qpos[None, None, None, :, None] - window,
                logits, _MASKED,
            )
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        alpha = jnp.exp(m - m_new)
        pl_ = jnp.exp(logits - m_new[..., None])
        l = l * alpha + jnp.sum(pl_, axis=-1)
        # probabilities in bf16 for the PV matmul: the (bq, ck) prob tile is
        # the largest attention buffer; halving it halves attention HBM
        # traffic at <1e-3 output error (stats m/l stay fp32)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", pl_.astype(jnp.bfloat16),
            vj.astype(jnp.bfloat16)).astype(jnp.float32)
        m = m_new
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, lq, dh).astype(q.dtype)


def attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    kv_bias: Optional[jax.Array] = None,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    impl: str = "xla",
    chunk: int = 1024,
) -> jax.Array:
    """GQA dispatcher: picks XLA-flash (grouped, no kv repeat) / Pallas."""
    if impl == "pallas" and window == 0:
        return ops.flash_attention(
            q, k, v, causal=causal, scale=scale, kv_bias=kv_bias,
            logit_softcap=softcap, impl="pallas",
        )
    if q.shape[2] == 1:  # decode: single query, direct einsum is optimal
        return chunked_attention(
            q, k, v, causal=causal, window=window, kv_bias=kv_bias,
            softcap=softcap, scale=scale, chunk=k.shape[2],
        )
    return chunked_attention(
        q, k, v, causal=causal, window=window, kv_bias=kv_bias,
        softcap=softcap, scale=scale, chunk=chunk,
    )


# ------------------------------------------------------------- module apply
def attention_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    layer: int,
    positions: jax.Array,
    cache: Optional[dict] = None,
    kv_bias: Optional[jax.Array] = None,
    causal: bool = True,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    act_spec: Optional[P] = None,
    kv_spec: Optional[P] = None,
    impl: str = "xla",
) -> Tuple[jax.Array, Optional[dict]]:
    """One attention block (self or cross). x: (b, s, d).

    cache: {"k": (b, hkv, S, hd), "v": ..., "pos": ()} — decode writes the
    new kv at `pos` and attends over the whole buffer with a position mask.
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype

    q = x @ params["wq"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
    q = q.reshape(b, s, hq, hd)

    if cross_kv is None:
        k = x @ params["wk"].astype(dt)
        v = x @ params["wv"].astype(dt)
        if cfg.qkv_bias:
            k = k + params["bk"].astype(dt)
            v = v + params["bv"].astype(dt)
        k = k.reshape(b, s, hkv, hd)
        v = v.reshape(b, s, hkv, hd)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    else:
        k, v = cross_kv  # (b, s_enc, hkv, hd) — already projected, no rope

    q = q.transpose(0, 2, 1, 3)  # (b, hq, s, hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    window = cfg.local_window if cfg.attn_type(layer) == "local" else 0
    new_cache = None
    if cache is not None:
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, pos, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, pos, 0))
        new_cache = {"k": ck, "v": cv, "pos": pos + s}
        if "bias" in cache:  # IHTC-compressed cache: log-mass prototype bias
            new_cache["bias"] = cache["bias"]
            new_cache["mass"] = cache["mass"]
        if s == 1:  # decode: attend over the whole buffer with a position mask
            S = ck.shape[2]
            kpos = jnp.arange(S)
            ok = kpos <= pos
            if window > 0:
                ok = ok & (kpos > pos - window)
            pos_mask = jnp.where(ok, 0.0, _MASKED)  # (S,)
            pm = jnp.broadcast_to(pos_mask, (b, hkv, S)).astype(jnp.float32)
            if "bias" in cache:
                pm = pm + cache["bias"]
            kv_bias = pm if kv_bias is None else kv_bias + pm
            k, v = ck, cv
            causal = False  # position mask subsumes causality (and the window)
            window = 0
        # prefill (s > 1): attend causally over the fresh k/v; cache is only
        # written (assumes prefill starts at pos == 0, as the serve engine does)
    scale = 1.0 / (hd**0.5)
    if cfg.name.startswith("gemma2"):
        scale = 1.0 / (256.0**0.5)  # query_pre_attn_scalar

    if act_spec is not None:
        q = jax.lax.with_sharding_constraint(q, act_spec)
    if kv_spec is not None and cache is None and cross_kv is None:
        k = jax.lax.with_sharding_constraint(k, kv_spec)
        v = jax.lax.with_sharding_constraint(v, kv_spec)
    out = attend(
        q, k.astype(dt), v.astype(dt), causal=causal, window=window,
        kv_bias=kv_bias, softcap=cfg.attn_logit_softcap, scale=scale, impl=impl,
        chunk=cfg.attn_chunk,
    )
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    out = out @ params["wo"].astype(dt)
    return out, new_cache


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, layer: int, dtype=COMPUTE_DTYPE
) -> dict:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, hkv, max_len, hd), dtype),
        "v": jnp.zeros((batch, hkv, max_len, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
