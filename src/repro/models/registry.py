"""Model registry: binds every arch family to a uniform bundle of callables
used by the trainer, server, dry-run and tests.

Batch conventions (all inputs produced by data/pipeline.py or input_specs):
  decoder-only:  {"tokens": (b,s) i32, "labels": (b,s) i32[, "weights": (b,)]}
  vlm:           + "patch_embeds": (b, 256, d)
  audio enc-dec: {"frames": (b,s,d), "tokens": (b,s), "labels": (b,s)}
  decode step:   {"tokens": (b,1)}
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.frontends import VISION_PREFIX_TOKENS
from repro.models.transformer import ShardingPlan


@dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable[..., Any]
    forward: Callable[..., Tuple[jax.Array, jax.Array]]         # (logits, aux)
    prefill: Callable[..., Tuple[jax.Array, Any]]               # (logits, caches)
    decode_step: Callable[..., Tuple[jax.Array, Any]]           # (logits, caches)
    init_caches: Callable[..., Any]
    param_specs: Callable[..., Any]
    cache_specs: Callable[..., Any]


def _lm_bundle(cfg: ModelConfig) -> ModelBundle:
    is_vlm = cfg.frontend == "vision"

    def init(key):
        return transformer.init_lm(key, cfg)

    def forward(params, batch, *, plan=ShardingPlan(), impl="xla", remat="none"):
        prefix = batch.get("patch_embeds") if is_vlm else None
        logits, _, aux = transformer.lm_apply(
            params, batch["tokens"], cfg, prefix_embeds=prefix,
            plan=plan, impl=impl, remat=remat,
        )
        if prefix is not None:
            logits = logits[:, prefix.shape[1]:]
        return logits, aux

    def prefill(params, caches, batch, *, plan=ShardingPlan(), impl="xla"):
        prefix = batch.get("patch_embeds") if is_vlm else None
        logits, caches, _ = transformer.lm_apply(
            params, batch["tokens"], cfg, prefix_embeds=prefix, caches=caches,
            plan=plan, impl=impl,
        )
        return logits[:, -1:], caches

    def decode_step(params, caches, batch, *, plan=ShardingPlan(), impl="xla"):
        start = _cache_pos(cfg, caches)
        logits, caches, _ = transformer.lm_apply(
            params, batch["tokens"], cfg, caches=caches, start_pos=start,
            plan=plan, impl=impl,
        )
        return logits, caches

    def init_caches(batch, max_len, dtype=None):
        kw = {} if dtype is None else {"dtype": dtype}
        if is_vlm:  # room for the patch-embedding prefix
            max_len = max_len + VISION_PREFIX_TOKENS
        return transformer.init_lm_caches(cfg, batch, max_len, **kw)

    def param_specs(tp="model", tp_size=1):
        return transformer.lm_specs(cfg, tp, tp_size)

    def cache_specs(plan=ShardingPlan(), tp_size=1):
        return transformer.cache_specs(cfg, plan, tp_size)

    return ModelBundle(cfg, init, forward, prefill, decode_step, init_caches,
                       param_specs, cache_specs)


def _cache_pos(cfg: ModelConfig, caches) -> jax.Array:
    if "self" in caches:  # stacked enc-dec caches
        return caches["self"]["pos"][0]
    return transformer.cache_start_pos(caches)


def _encdec_bundle(cfg: ModelConfig) -> ModelBundle:
    def init(key):
        return encdec.init_encdec(key, cfg)

    def forward(params, batch, *, plan=ShardingPlan(), impl="xla", remat="none"):
        enc_out = encdec.encode(
            params, batch["frames"], cfg, plan=plan, impl=impl, remat=remat
        )
        logits, _ = encdec.decode(
            params, batch["tokens"], enc_out, cfg, plan=plan, impl=impl, remat=remat
        )
        return logits, jnp.zeros((), jnp.float32)

    def prefill(params, caches, batch, *, plan=ShardingPlan(), impl="xla"):
        enc_out = encdec.encode(params, batch["frames"], cfg, plan=plan, impl=impl)
        logits, caches = encdec.decode(
            params, batch["tokens"], enc_out, cfg, caches=caches,
            plan=plan, impl=impl,
        )
        return logits[:, -1:], caches

    def decode_step(params, caches, batch, *, plan=ShardingPlan(), impl="xla"):
        start = _cache_pos(cfg, caches)
        enc_out = jnp.zeros(  # unused: cross kv comes from the cache
            (batch["tokens"].shape[0], caches["cross_k"].shape[2], cfg.d_model),
            jnp.bfloat16,
        )
        logits, caches = encdec.decode(
            params, batch["tokens"], enc_out, cfg, caches=caches, start_pos=start,
            plan=plan, impl=impl,
        )
        return logits, caches

    def init_caches(batch, max_len, enc_len=None, dtype=None):
        kw = {} if dtype is None else {"dtype": dtype}
        return encdec.init_encdec_caches(
            cfg, batch, max_len, enc_len or max_len, **kw
        )

    def param_specs(tp="model", tp_size=1):
        return encdec.encdec_specs(cfg, tp, tp_size)

    def cache_specs(plan=ShardingPlan(), tp_size=1):
        return encdec.encdec_cache_specs(cfg, plan, tp_size)

    return ModelBundle(cfg, init, forward, prefill, decode_step, init_caches,
                       param_specs, cache_specs)


def build(cfg: ModelConfig) -> ModelBundle:
    if cfg.family == "encdec-audio":
        return _encdec_bundle(cfg)
    return _lm_bundle(cfg)
