"""Encoder-decoder assembly (seamless-m4t backbone), scanned layer stacks.

Encoder consumes precomputed frame embeddings (the speech frontend is a stub
per the assignment); decoder is a causal LM with cross-attention into the
encoder output. Both stacks are uniform, so parameters are stacked on a
leading axis and driven by ``lax.scan`` (see transformer.py for why).
Cross-attention K/V are projected once per sequence and live in the cache.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    COMPUTE_DTYPE,
    embed_apply,
    embed_specs,
    init_embed,
    init_mlp,
    init_norm,
    mlp_apply,
    mlp_specs,
    rms_norm,
    unembed_apply,
)
from repro.models.transformer import ShardingPlan, _prepend_none


def init_enc_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "ln1": init_norm(d), "attn": attn.init_attention(k1, cfg),
        "ln2": init_norm(d), "mlp": init_mlp(k2, d, cfg.d_ff, cfg.mlp),
    }


def init_dec_layer(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": init_norm(d), "self_attn": attn.init_attention(k1, cfg),
        "ln_x": init_norm(d), "cross_attn": attn.init_attention(k2, cfg),
        "ln2": init_norm(d), "mlp": init_mlp(k3, d, cfg.d_ff, cfg.mlp),
    }


def init_encdec(key, cfg: ModelConfig) -> dict:
    ke, kd, kx = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: init_enc_layer(k, cfg))(
        jax.random.split(ke, cfg.n_enc_layers)
    )
    dec = jax.vmap(lambda k: init_dec_layer(k, cfg))(
        jax.random.split(kd, cfg.n_layers)
    )
    return {
        "embed": init_embed(kx, cfg),
        "enc": enc, "dec": dec,
        "ln_enc": init_norm(cfg.d_model), "ln_f": init_norm(cfg.d_model),
    }


def encdec_specs(cfg: ModelConfig, tp: str = "model", tp_size: int = 1) -> dict:
    a = attn.attention_specs(cfg, tp, tp_size)
    m = mlp_specs(cfg.mlp, tp)
    stack = lambda tree: jax.tree_util.tree_map(
        _prepend_none, tree, is_leaf=lambda x: isinstance(x, P)
    )
    enc = stack({"ln1": P(None), "attn": a, "ln2": P(None), "mlp": m})
    dec = stack({"ln1": P(None), "self_attn": a, "ln_x": P(None),
                 "cross_attn": a, "ln2": P(None), "mlp": m})
    return {
        "embed": embed_specs(cfg, tp), "enc": enc, "dec": dec,
        "ln_enc": P(None), "ln_f": P(None),
    }


def encode(
    params: dict, frames: jax.Array, cfg: ModelConfig,
    *, plan: ShardingPlan = ShardingPlan(), impl: str = "xla", remat: str = "none",
) -> jax.Array:
    """frames: (b, s_enc, d) precomputed frontend embeddings → (b, s_enc, d)."""
    x = frames.astype(COMPUTE_DTYPE)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def enc_block(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, _ = attn.attention_apply(
            lp["attn"], h, cfg, layer=0, positions=positions, causal=False,
            act_spec=plan.heads, impl=impl,
        )
        x = x + y
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h2, cfg.mlp)
        if plan.resid is not None:
            x = jax.lax.with_sharding_constraint(x, plan.resid)
        return x, None

    body = jax.checkpoint(enc_block) if remat != "none" else enc_block
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc"])
    else:  # unrolled (cost-accounting probes)
        for i in range(cfg.n_enc_layers):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i], params["enc"])
            x, _ = body(x, lp)
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _project_cross_kv(lp: dict, enc_out: jax.Array, cfg: ModelConfig):
    b, s, _ = enc_out.shape
    dt = enc_out.dtype
    k = (enc_out @ lp["cross_attn"]["wk"].astype(dt)).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ lp["cross_attn"]["wv"].astype(dt)).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def decode(
    params: dict,
    tokens: jax.Array,
    enc_out: jax.Array,
    cfg: ModelConfig,
    *,
    caches: Optional[List[dict]] = None,
    start_pos: Optional[jax.Array] = None,
    plan: ShardingPlan = ShardingPlan(),
    impl: str = "xla",
    remat: str = "none",
) -> Tuple[jax.Array, Optional[dict]]:
    """Decoder forward. caches (stacked): {"self": attn-cache, "cross_k",
    "cross_v"} with leading n_layers dim on every leaf."""
    x = embed_apply(params["embed"], tokens, cfg).astype(COMPUTE_DTYPE)
    b, s, _ = x.shape
    if start_pos is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    else:
        positions = jnp.broadcast_to(start_pos + jnp.arange(s), (b, s))

    def dec_block(x, lp, cache):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, new_self = attn.attention_apply(
            lp["self_attn"], h, cfg, layer=0, positions=positions,
            cache=cache["self"] if cache is not None else None,
            act_spec=plan.heads, impl=impl,
        )
        x = x + y
        hx = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        if cache is not None and s == 1:
            cross_kv = (cache["cross_k"], cache["cross_v"])  # decode: reuse
        else:
            cross_kv = _project_cross_kv(lp, enc_out, cfg)   # prefill: project
        yx, _ = attn.attention_apply(
            lp["cross_attn"], hx, cfg, layer=0, positions=positions,
            causal=False, cross_kv=cross_kv, act_spec=plan.heads, impl=impl,
        )
        x = x + yx
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h2, cfg.mlp)
        if plan.resid is not None:
            x = jax.lax.with_sharding_constraint(x, plan.resid)
        new_cache = (
            {"self": new_self, "cross_k": cross_kv[0], "cross_v": cross_kv[1]}
            if cache is not None else None
        )
        return x, new_cache

    if caches is None:
        def body(x, lp):
            x, _ = dec_block(x, lp, None)
            return x, None
        body = jax.checkpoint(body) if remat != "none" else body
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["dec"])
        else:  # unrolled (cost-accounting probes)
            for i in range(cfg.n_layers):
                lp = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                            params["dec"])
                x, _ = body(x, lp)
        new_caches = None
    else:
        def body(x, xs):
            lp, cache = xs
            return dec_block(x, lp, cache)
        if cfg.scan_layers:
            x, new_caches = jax.lax.scan(body, x, (params["dec"], caches))
        else:
            outs = []
            for i in range(cfg.n_layers):
                sl = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                            (params["dec"], caches))
                x, nc = body(x, sl)
                outs.append(nc)
            new_caches = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *outs)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed_apply(params["embed"], x, cfg)
    if plan.logits is not None:
        logits = jax.lax.with_sharding_constraint(logits, plan.logits)
    return logits, new_caches


def init_encdec_caches(
    cfg: ModelConfig, batch: int, max_len: int, enc_len: int, dtype=COMPUTE_DTYPE
) -> dict:
    one = {
        "self": attn.init_cache(cfg, batch, max_len, 0, dtype),
        "cross_k": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "cross_v": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one
    )


def encdec_cache_specs(cfg: ModelConfig, plan: ShardingPlan, tp_size: int = 1):
    from repro.models.transformer import _layer_cache_spec

    dp = plan.resid[0] if plan.resid is not None else None
    one = {
        "self": _layer_cache_spec(cfg, 0, plan, tp_size),
        "cross_k": P(dp, None, None, None),
        "cross_v": P(dp, None, None, None),
    }
    return jax.tree_util.tree_map(
        _prepend_none, one, is_leaf=lambda x: isinstance(x, P)
    )
