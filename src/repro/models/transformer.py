"""Decoder-only LM assembly — covers dense / moe / ssm / hybrid / vlm families.

Layer stacks are **scanned, not unrolled**: the config's layer sequence is
factored into (prefix, period, repeats) — e.g. gemma2 = 13 repeats of a
(local, global) pair, jamba = 4 repeats of its 8-layer block, deepseek =
1 dense prefix + 27 MoE repeats — and the repeated group's parameters are
stacked on a leading axis and driven by ``lax.scan``. This keeps the HLO
O(period) instead of O(n_layers): ~20-50× smaller programs, which is what
makes compiling 30B-class configs for a 512-chip mesh tractable (and is the
standard production pattern, cf. MaxText).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2, moe as moe_mod
from repro.models.layers import (
    COMPUTE_DTYPE,
    embed_apply,
    embed_specs,
    init_embed,
    init_mlp,
    init_norm,
    mlp_apply,
    mlp_specs,
    rms_norm,
    unembed_apply,
)


@dataclass(frozen=True)
class ShardingPlan:
    """Activation sharding constraints (None ⇒ leave to the compiler)."""
    resid: Optional[P] = None        # (b, s, d)
    heads: Optional[P] = None        # (b, h, s, hd) — query tensor
    kv: Optional[P] = None           # (b, hkv, s, hd) — fresh k/v
    mamba_heads: Optional[P] = None  # (b, s, h, p)
    ep: Optional[P] = None           # (g, e, c, d) MoE dispatch buffer
    cache: Optional[P] = None        # (b, hkv, S, hd)
    logits: Optional[P] = None       # (b, s, v)


def _dense_ff(cfg: ModelConfig, layer: int) -> int:
    if cfg.dense_d_ff and layer < cfg.first_dense_layers:
        return cfg.dense_d_ff
    return cfg.d_ff


# ------------------------------------------------------------------ stacking
def _signature(cfg: ModelConfig, layer: int) -> tuple:
    kind = cfg.layer_kind(layer)
    return (
        kind,
        cfg.layer_is_moe(layer),
        cfg.attn_type(layer) if kind == "attn" else "",
        _dense_ff(cfg, layer),
    )


def stack_plan(cfg: ModelConfig, max_period: int = 8) -> Tuple[int, int, int]:
    """(n_prefix, period, n_repeats): layers [0, n_prefix) run unrolled;
    the rest is `n_repeats` scanned copies of a `period`-layer group."""
    sigs = [_signature(cfg, l) for l in range(cfg.n_layers)]
    n = len(sigs)
    if not cfg.scan_layers:
        return n, 1, 0  # fully unrolled (cost-accounting probes use this)
    for prefix in range(0, min(n, 4)):
        rest = sigs[prefix:]
        for period in range(1, min(len(rest), max_period) + 1):
            if len(rest) % period:
                continue
            if all(rest[i] == rest[i % period] for i in range(len(rest))):
                if len(rest) // period >= 2:
                    return prefix, period, len(rest) // period
    return n, 1, 0  # fallback: fully unrolled


# ------------------------------------------------------------------ init
def init_layer(key, cfg: ModelConfig, layer: int) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"ln1": init_norm(d), "ln2": init_norm(d)}
    if cfg.layer_kind(layer) == "attn":
        p["attn"] = attn.init_attention(ks[0], cfg)
    else:
        p["mamba"] = mamba2.init_mamba(ks[0], cfg)
    if cfg.layer_is_moe(layer):
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    elif _dense_ff(cfg, layer) > 0:
        p["mlp"] = init_mlp(ks[1], d, _dense_ff(cfg, layer), cfg.mlp)
    else:
        del p["ln2"]  # pure-mamba block (mamba2): no FFN sub-block
    if cfg.post_norm:
        p["ln1_post"] = init_norm(d)
        p["ln2_post"] = init_norm(d)
    return p


def init_lm(key, cfg: ModelConfig) -> dict:
    prefix, period, rep = stack_plan(cfg)
    k_embed, k_pre, k_stack = jax.random.split(key, 3)
    params: dict = {
        "embed": init_embed(k_embed, cfg),
        "prefix": [
            init_layer(jax.random.fold_in(k_pre, l), cfg, l) for l in range(prefix)
        ],
        "ln_f": init_norm(cfg.d_model),
    }
    if rep:
        def init_group(k):
            ks = jax.random.split(k, period)
            return [init_layer(ks[j], cfg, prefix + j) for j in range(period)]

        params["stack"] = jax.vmap(init_group)(jax.random.split(k_stack, rep))
    return params


def _prepend_none(spec: P) -> P:
    return P(*((None,) + tuple(spec)))


def layer_specs(cfg: ModelConfig, layer: int, tp: str, tp_size: int) -> dict:
    p = {"ln1": P(None), "ln2": P(None)}
    if cfg.layer_kind(layer) == "attn":
        p["attn"] = attn.attention_specs(cfg, tp, tp_size)
    else:
        p["mamba"] = mamba2.mamba_specs(cfg, tp, tp_size)
    if cfg.layer_is_moe(layer):
        p["moe"] = moe_mod.moe_specs(cfg, tp, tp_size)
    elif _dense_ff(cfg, layer) > 0:
        p["mlp"] = mlp_specs(cfg.mlp, tp)
    else:
        del p["ln2"]
    if cfg.post_norm:
        p["ln1_post"] = P(None)
        p["ln2_post"] = P(None)
    return p


def lm_specs(cfg: ModelConfig, tp: str = "model", tp_size: int = 1) -> dict:
    prefix, period, rep = stack_plan(cfg)
    specs: dict = {
        "embed": embed_specs(cfg, tp),
        "prefix": [layer_specs(cfg, l, tp, tp_size) for l in range(prefix)],
        "ln_f": P(None),
    }
    if rep:
        group = [layer_specs(cfg, prefix + j, tp, tp_size) for j in range(period)]
        specs["stack"] = jax.tree_util.tree_map(
            _prepend_none, group, is_leaf=lambda x: isinstance(x, P)
        )
    return specs


# ------------------------------------------------------------------ apply
def block_apply(
    lp: dict,
    x: jax.Array,
    cfg: ModelConfig,
    layer: int,
    positions: jax.Array,
    cache: Optional[dict],
    plan: ShardingPlan,
    impl: str,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.layer_kind(layer) == "attn":
        y, new_cache = attn.attention_apply(
            lp["attn"], h, cfg, layer=layer, positions=positions, cache=cache,
            act_spec=plan.heads, kv_spec=plan.kv, impl=impl,
        )
    else:
        y, new_cache = mamba2.mamba_apply(
            lp["mamba"], h, cfg, cache=cache, act_spec=plan.mamba_heads
        )
    if cfg.post_norm:
        y = rms_norm(y, lp["ln1_post"], cfg.norm_eps)
    x = x + y
    if plan.resid is not None:
        x = jax.lax.with_sharding_constraint(x, plan.resid)

    if "ln2" in lp:  # pure-mamba blocks (mamba2) have no FFN sub-block
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.layer_is_moe(layer):
            y2, aux = moe_mod.moe_apply(lp["moe"], h2, cfg, ep_spec=plan.ep)
        else:
            y2 = mlp_apply(lp["mlp"], h2, cfg.mlp)
        if cfg.post_norm:
            y2 = rms_norm(y2, lp["ln2_post"], cfg.norm_eps)
        x = x + y2
        if plan.resid is not None:
            x = jax.lax.with_sharding_constraint(x, plan.resid)
    return x, new_cache, aux


def lm_apply(
    params: dict,
    tokens: jax.Array,                      # (b, s) int32
    cfg: ModelConfig,
    *,
    prefix_embeds: Optional[jax.Array] = None,  # (b, s_pre, d) vlm/audio stub
    caches: Optional[dict] = None,
    start_pos: Optional[jax.Array] = None,       # () decode offset
    plan: ShardingPlan = ShardingPlan(),
    impl: str = "xla",
    remat: str = "none",
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (logits (b, s_total, padded_vocab) fp32, caches, aux_loss).

    ``caches`` structure: {"prefix": [per-layer], "stack": [per-sublayer with
    stacked leading dim]} — built by init_lm_caches."""
    n_prefix, period, rep = stack_plan(cfg)
    x = embed_apply(params["embed"], tokens, cfg).astype(COMPUTE_DTYPE)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(COMPUTE_DTYPE), x], axis=1)
    b, s, _ = x.shape
    if start_pos is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    else:
        positions = jnp.broadcast_to(start_pos + jnp.arange(s), (b, s))
    if plan.resid is not None:
        x = jax.lax.with_sharding_constraint(x, plan.resid)

    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Optional[dict] = (
        {"prefix": [], "stack": None} if caches is not None else None
    )

    blk = block_apply
    if remat in ("block", "dots"):
        policy = (
            None if remat == "block"
            else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
        blk = jax.checkpoint(block_apply, static_argnums=(2, 3, 6, 7),
                             policy=policy)

    # ---- unrolled prefix ----
    for l in range(n_prefix):
        cache_l = caches["prefix"][l] if caches is not None else None
        x, nc, aux = blk(params["prefix"][l], x, cfg, l, positions, cache_l,
                         plan, impl)
        if new_caches is not None:
            new_caches["prefix"].append(nc)
        aux_total = aux_total + aux

    # ---- scanned stack ----
    if rep:
        def group(carry, xs):
            x, aux = carry
            gp, gc = xs
            new_gc = []
            for j in range(period):
                cj = gc[j] if gc is not None else None
                x, nc, a = block_apply(gp[j], x, cfg, n_prefix + j, positions,
                                       cj, plan, impl)
                new_gc.append(nc)
                aux = aux + a
            return (x, aux), (new_gc if gc is not None else 0)

        if remat in ("block", "dots"):
            policy = (
                None if remat == "block"
                else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
            group = jax.checkpoint(group, policy=policy)

        stack_caches = caches["stack"] if caches is not None else None
        xs = (params["stack"], stack_caches)
        if stack_caches is None:
            xs = (params["stack"], None)
            (x, aux_total), _ = jax.lax.scan(
                lambda c, gp: group(c, (gp, None)), (x, aux_total),
                params["stack"],
            )
        else:
            (x, aux_total), new_stack = jax.lax.scan(
                group, (x, aux_total), xs
            )
            new_caches["stack"] = new_stack

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed_apply(params["embed"], x, cfg)
    if plan.logits is not None:
        logits = jax.lax.with_sharding_constraint(logits, plan.logits)
    return logits, new_caches, aux_total


# ------------------------------------------------------------------ caches
def _layer_cache(cfg: ModelConfig, layer: int, batch: int, max_len: int, dtype):
    if cfg.layer_kind(layer) == "attn":
        return attn.init_cache(cfg, batch, max_len, layer, dtype)
    return mamba2.init_mamba_cache(cfg, batch, dtype)


def init_lm_caches(
    cfg: ModelConfig, batch: int, max_len: int, dtype=COMPUTE_DTYPE
) -> dict:
    n_prefix, period, rep = stack_plan(cfg)
    out: dict = {
        "prefix": [
            _layer_cache(cfg, l, batch, max_len, dtype) for l in range(n_prefix)
        ],
        "stack": None,
    }
    if rep:
        group = [
            _layer_cache(cfg, n_prefix + j, batch, max_len, dtype)
            for j in range(period)
        ]
        out["stack"] = jax.tree_util.tree_map(
            lambda a: jnp.zeros((rep,) + a.shape, a.dtype), group
        )
    return out


def _layer_cache_spec(cfg: ModelConfig, layer: int, plan: ShardingPlan,
                      tp_size: int) -> dict:
    dp = plan.resid[0] if plan.resid is not None else None
    if cfg.layer_kind(layer) == "attn":
        spec = plan.cache if plan.cache is not None else P(None)
        return {"k": spec, "v": spec, "pos": P()}
    _, h, _, _ = mamba2._dims(cfg)
    head_ok = h % max(tp_size, 1) == 0
    return {
        "ssm": P(dp, "model" if head_ok else None, None, None),
        "conv": P(dp, None, None),
    }


def cache_specs(cfg: ModelConfig, plan: ShardingPlan, tp_size: int = 1) -> dict:
    n_prefix, period, rep = stack_plan(cfg)
    out: dict = {
        "prefix": [
            _layer_cache_spec(cfg, l, plan, tp_size) for l in range(n_prefix)
        ],
        "stack": None,
    }
    if rep:
        group = [
            _layer_cache_spec(cfg, n_prefix + j, plan, tp_size)
            for j in range(period)
        ]
        out["stack"] = jax.tree_util.tree_map(
            _prepend_none, group, is_leaf=lambda x: isinstance(x, P)
        )
    return out


def cache_start_pos(caches: dict) -> jax.Array:
    """Current decode position from any attention cache in the tree."""
    for c in caches.get("prefix", []):
        if c is not None and "pos" in c:
            return c["pos"]
    stack = caches.get("stack")
    if stack is not None:
        for c in stack:
            if isinstance(c, dict) and "pos" in c:
                return c["pos"][0]
    return jnp.zeros((), jnp.int32)
