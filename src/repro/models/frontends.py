"""Modality frontend STUBS (per assignment: audio/vlm configs exercise the
transformer backbone; ``input_specs()`` provides precomputed frame/patch
embeddings as if a real speech encoder / CLIP tower had produced them)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import COMPUTE_DTYPE

VISION_PREFIX_TOKENS = 256   # CLIP-style patch-embedding prefix length


def frontend_embed_shape(cfg: ModelConfig, batch: int, seq_len: int):
    """Shape of the stubbed frontend output for this arch/shape."""
    if cfg.frontend == "audio":
        return (batch, seq_len, cfg.d_model)          # encoder frames
    if cfg.frontend == "vision":
        return (batch, VISION_PREFIX_TOKENS, cfg.d_model)  # patch prefix
    return None


def fake_frontend_embeddings(key, cfg: ModelConfig, batch: int, seq_len: int):
    shape = frontend_embed_shape(cfg, batch, seq_len)
    if shape is None:
        return None
    return jax.random.normal(key, shape, jnp.float32).astype(COMPUTE_DTYPE) * 0.02
