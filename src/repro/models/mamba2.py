"""Mamba-2 SSD (state-space duality) block — chunked train/prefill + O(1)
recurrent decode.

Chunked SSD (arXiv:2405.21060 §6): sequence split into chunks of Q tokens;
within-chunk term is a masked quadratic form (attention-shaped, MXU-friendly),
across-chunk term is a tiny recurrent scan over chunk states (b, h, p, n).
Per-token state is constant-size — this is why ssm/hybrid archs run the
`long_500k` shape that full attention cannot.

Jamba note (DESIGN.md §9): Jamba uses Mamba-1; we substitute the SSD block
with Jamba's dims (state 16) — per the SSD paper, Mamba-1 ≈ SSD with scalar
per-head decay, and SSD is the TPU-native formulation of the same insight.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, rms_norm

_CHUNK = 128


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, h, _, n = _dims(cfg)
    conv_dim = d_in + 2 * n
    ks = jax.random.split(key, 8)
    return {
        "wz": _dense_init(ks[0], (d, d_in)),
        "wx": _dense_init(ks[1], (d, d_in)),
        "wB": _dense_init(ks[2], (d, n)),
        "wC": _dense_init(ks[3], (d, n)),
        "wdt": _dense_init(ks[4], (d, h)),
        "conv_w": _dense_init(ks[5], (cfg.ssm_conv, conv_dim), scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus ≈ 0.12
        "norm": jnp.zeros((d_in,), jnp.float32),
        "out": _dense_init(ks[6], (d_in, d)),
    }


def mamba_specs(cfg: ModelConfig, tp: str = "model", tp_size: int = 1) -> dict:
    d_in, h, _, _ = _dims(cfg)
    ts = max(tp_size, 1)
    col = P(None, tp) if d_in % ts == 0 else P(None, None)
    head = P(tp) if h % ts == 0 else P(None)
    return {
        "wz": col, "wx": col,
        "wB": P(None, None), "wC": P(None, None),
        "wdt": P(None, tp) if h % ts == 0 else P(None, None),
        "conv_w": P(None, None), "conv_b": P(None),
        "A_log": head, "D": head, "dt_bias": head,
        "norm": P(None),
        "out": P(tp, None) if d_in % ts == 0 else P(None, None),
    }


def _segsum_exp(a: jax.Array) -> jax.Array:
    """exp of pairwise within-chunk decay sums. a: (..., q, h) per-step log
    decay → (..., h, q, q) lower-triangular L[i, j] = exp(Σ_{j<k≤i} a_k)."""
    q = a.shape[-2]
    cs = jnp.cumsum(a, axis=-2)  # (..., q, h)
    diff = cs[..., :, None, :] - cs[..., None, :, :]  # (..., q, q, h)
    iq = jnp.arange(q)
    mask = iq[:, None] >= iq[None, :]
    out = jnp.where(mask[..., None], jnp.exp(diff), 0.0)
    return jnp.moveaxis(out, -1, -3)  # (..., h, q, q)


def ssd_chunked(
    x_dt: jax.Array,   # (b, l, h, p)  inputs pre-multiplied by dt
    a_log: jax.Array,  # (b, l, h)     per-step log decay (dt * A, negative)
    B: jax.Array,      # (b, l, n)
    C: jax.Array,      # (b, l, n)
    init_state: Optional[jax.Array] = None,  # (b, h, p, n)
    chunk: int = _CHUNK,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (b, l, h, p), final_state (b, h, p, n)). fp32 internally."""
    b, l, h, p = x_dt.shape
    n = B.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x_dt = jnp.pad(x_dt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = (l + pad) // q
    xc = x_dt.reshape(b, nc, q, h, p).astype(jnp.float32)
    ac = a_log.reshape(b, nc, q, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, q, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, q, n).astype(jnp.float32)

    # 1. intra-chunk (quadratic, MXU-shaped)
    L = _segsum_exp(ac)  # (b, nc, h, q, q)
    G = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)  # (b, nc, q, q)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", G, L, xc)

    # 2. per-chunk output states
    a_cum = jnp.cumsum(ac, axis=2)  # (b, nc, q, h)
    decay_out = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (b, nc, q, h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_out, xc)

    # 3. inter-chunk recurrence (scan over chunk index)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (b, nc, h)
    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(s, inp):
        dec, st = inp  # (b, h), (b, h, p, n)
        s_next = s * dec[..., None, None] + st
        return s_next, s  # emit state *before* this chunk

    final, prev = jax.lax.scan(
        step, s0, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4))
    )
    prev = prev.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)

    # 4. contribution of carried-in state
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, prev, jnp.exp(a_cum))

    y = (y_diag + y_off).reshape(b, nc * q, h, p)[:, :l]
    return y, final


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 cache: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: (batch, l, c); w: (k, c). Returns
    (out (batch, l, c), new_cache (batch, k-1, c))."""
    k = w.shape[0]
    if cache is None:
        cache = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xin = jnp.concatenate([cache, x], axis=1)  # (batch, l+k-1, c)
    out = sum(
        xin[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    ) + b[None, None, :]
    new_cache = xin[:, -(k - 1):, :]
    return out, new_cache


def mamba_apply(
    params: dict,
    u: jax.Array,  # (b, s, d)
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
    act_spec: Optional[P] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Full mamba2 block. cache = {"ssm": (b,h,p,n) f32, "conv": (b,k-1,cdim)}."""
    b, s, d = u.shape
    dt_ = u.dtype
    d_in, h, p, n = _dims(cfg)

    z = u @ params["wz"].astype(dt_)
    x = u @ params["wx"].astype(dt_)
    Br = u @ params["wB"].astype(dt_)
    Cr = u @ params["wC"].astype(dt_)
    dt_raw = u @ params["wdt"].astype(dt_)

    xbc = jnp.concatenate([x, Br, Cr], axis=-1)
    conv_cache = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(
        xbc, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_), conv_cache
    )
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(dt_)
    x, Br, Cr = jnp.split(xbc, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (b,s,h)
    A = -jnp.exp(params["A_log"])  # (h,)
    xh = x.reshape(b, s, h, p)
    if act_spec is not None:
        xh = jax.lax.with_sharding_constraint(xh, act_spec)
    x_dt = xh.astype(jnp.float32) * dt[..., None]
    a_log = dt * A  # (b, s, h)

    new_cache = None
    if cache is not None and s == 1:  # recurrent decode step
        st = cache["ssm"].astype(jnp.float32)  # (b, h, p, n)
        dec = jnp.exp(a_log[:, 0, :])  # (b, h)
        outer = jnp.einsum("bn,bhp->bhpn", Br[:, 0].astype(jnp.float32), x_dt[:, 0])
        st = st * dec[..., None, None] + outer
        y = jnp.einsum("bn,bhpn->bhp", Cr[:, 0].astype(jnp.float32), st)[:, None]
        new_cache = {"ssm": st, "conv": new_conv}
    else:
        init = cache["ssm"] if cache is not None else None
        y, final = ssd_chunked(x_dt, a_log, Br, Cr, init_state=init)
        if cache is not None:
            new_cache = {"ssm": final, "conv": new_conv}

    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(dt_)
    gated = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    gated = rms_norm(gated, params["norm"], cfg.norm_eps)
    return gated @ params["out"].astype(dt_), new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    d_in, h, p, n = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in + 2 * n), dtype),
    }
