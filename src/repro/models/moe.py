"""Mixture-of-Experts layer: top-k routing, capacity dispatch, EP sharding.

Dispatch is the sort-based capacity scheme (MaxText-style): token slots are
ranked within their expert queue via one argsort, scattered into a static
(E, C, d) buffer (overflow drops — capacity_factor controls slack), the
expert GEMM runs as one grouped einsum ``(E, C, d) × (E, d, f)`` that shards
cleanly with experts over the 'model' axis (EP), and results gather back to
token order weighted by router probabilities. Compiled FLOPs are
``capacity_factor × active`` — not ``n_experts ×`` — which keeps the
roofline's MODEL_FLOPS/HLO_FLOPs ratio honest.

Supports deepseek-style fine-grained MoE: shared experts (always-on, fused
as one dense MLP of width n_shared·d_ff) + many small routed experts.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, init_mlp, mlp_apply, mlp_specs


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, e)),
        "gate": _dense_init(ks[1], (e, d, f)),
        "up": _dense_init(ks[2], (e, d, f)),
        "down": _dense_init(ks[3], (e, f, d)),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared_experts * f, "swiglu")
    return p


def moe_specs(cfg: ModelConfig, tp: str = "model", tp_size: int = 1) -> dict:
    ep = P(tp, None, None) if cfg.n_experts % max(tp_size, 1) == 0 else P(None, None, None)
    p = {"router": P(None, None), "gate": ep, "up": ep, "down": ep}
    if cfg.n_shared_experts:
        p["shared"] = mlp_specs("swiglu", tp)
    return p


def _dispatch_indices(expert_ids: jax.Array, n_experts: int, capacity: int):
    """Slot ranks within each expert queue (stable, one sort).

    expert_ids: (T*k,). Returns flat buffer indices (T*k,), with overflow and
    invalid slots pointing at E*C (out-of-range ⇒ dropped by scatter/gather).
    """
    tk = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)            # slots grouped by expert
    sorted_e = expert_ids[order]
    # rank within group = position − first position of this expert id
    first = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    rank_sorted = jnp.arange(tk) - first[jnp.clip(sorted_e, 0, n_experts - 1)]
    rank = jnp.zeros((tk,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    ok = (expert_ids >= 0) & (expert_ids < n_experts) & (rank < capacity)
    flat = jnp.where(ok, expert_ids * capacity + rank, n_experts * capacity)
    return flat, ok


def moe_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    capacity_factor: Optional[float] = None,
    ep_spec: Optional[P] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (b, s, d), aux load-balancing loss ())."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    b, s, d = x.shape
    dt = x.dtype
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    t = b * s
    xt = x.reshape(t, d)

    # --- route (router in fp32 for stability) ---
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    topv, topi = jax.lax.top_k(probs, k)                     # (T, k)
    topv = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True), 1e-9)

    # aux loss (Switch): E * Σ_e fraction_tokens_e · mean_prob_e
    counts = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    frac = counts / (t * k)
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))

    # --- dispatch to (G, E, C, d) ---
    # Grouped (shard-local) capacity: tokens are ranked within G independent
    # groups, so the slot computation and the scatter stay LOCAL to the data
    # shard that owns the group — without groups, the global argsort/scatter
    # forces XLA to all-gather the whole token buffer per MoE layer
    # (measured ~2.8 TB/chip/step on deepseek train_4k; EXPERIMENTS.md §Perf).
    ng = cfg.moe_groups if cfg.moe_groups > 0 else 1
    if t % ng != 0:
        ng = 1
    tg = t // ng
    if ep_spec is not None and len(tuple(ep_spec)) == 3:  # legacy 3-D spec
        ep_spec = P(*((None,) + tuple(ep_spec)))
    # floor prevents pathological drops at tiny token counts (decode steps)
    capacity = max(int(k * tg * capacity_factor / e), min(tg * k, 8))
    grp_e = topi.reshape(ng, tg * k)                          # (G, Tg*k)
    flat_idx, ok = jax.vmap(
        lambda ee: _dispatch_indices(ee, e, capacity))(grp_e)  # (G, Tg*k)
    tok_of_slot = jnp.repeat(jnp.arange(tg), k)               # (Tg*k,)
    xg = xt.reshape(ng, tg, d)
    buf = jax.vmap(
        lambda idx, xs: jnp.zeros((e * capacity + 1, d), dt)
        .at[idx].set(xs[tok_of_slot])
    )(flat_idx, xg)                                           # (G, E*C+1, d)
    buf = buf[:, : e * capacity].reshape(ng, e, capacity, d)
    if ep_spec is not None:
        buf = jax.lax.with_sharding_constraint(buf, ep_spec)

    # --- expert GEMMs (grouped einsum, EP over 'model', G over 'data') ---
    g = jnp.einsum("gecd,edf->gecf", buf, params["gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", buf, params["up"].astype(dt))
    h = jax.nn.silu(g) * u  # bf16 activation: halves the (G,E,C,f) traffic
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["down"].astype(dt))
    if ep_spec is not None:
        out_buf = jax.lax.with_sharding_constraint(out_buf, ep_spec)

    # --- combine back to token order ---
    out_flat = out_buf.reshape(ng, e * capacity, d)
    slot_out = jax.vmap(
        lambda ob, idx, okk: jnp.where(
            okk[:, None], ob[jnp.minimum(idx, e * capacity - 1)], 0.0)
    )(out_flat, flat_idx, ok)                                 # (G, Tg*k, d)
    weighted = (slot_out.reshape(t * k, d).astype(jnp.float32)
                * topv.reshape(-1)[:, None])
    out = jnp.sum(weighted.reshape(t, k, d), axis=1).astype(dt)

    if cfg.n_shared_experts:
        out = out + mlp_apply(params["shared"], xt, "swiglu")
    return out.reshape(b, s, d), aux.astype(jnp.float32)
