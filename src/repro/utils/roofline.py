"""Roofline-term computation from compiled dry-run artifacts.

Hardware model (TPU v5e-class, per assignment):
  197 TFLOP/s bf16 per chip · 819 GB/s HBM per chip · ~50 GB/s/link ICI.

  compute_term   = HLO_FLOPs       / (chips × PEAK_FLOPS)
  memory_term    = HLO_bytes       / (chips × HBM_BW)
  collective_term= collective_bytes/ (chips × LINK_BW)

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), D = tokens processed in
the step; the MODEL/HLO ratio flags remat- or dispatch-inflated compute.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # B/s per chip
LINK_BW = 50e9          # B/s per ICI link


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float          # total across chips
    hlo_gbytes: float
    collective_gbytes: float   # per-chip wire bytes × chips
    compute_term_s: float
    memory_term_s: float
    collective_term_s: float
    dominant: str
    model_gflops: float
    useful_ratio: float        # MODEL_FLOPS / HLO_FLOPs
    bytes_per_chip_gb: float   # peak live memory from memory_analysis
    step_time_bound_s: float   # max of the three terms
    mfu_bound: float           # model_flops / (chips·peak·step_time_bound)

    def row(self) -> str:
        return (
            f"| {self.arch} | {self.shape} | {self.mesh} | "
            f"{self.compute_term_s:.2e} | {self.memory_term_s:.2e} | "
            f"{self.collective_term_s:.2e} | {self.dominant} | "
            f"{self.useful_ratio:.2f} | {self.mfu_bound*100:.1f}% | "
            f"{self.bytes_per_chip_gb:.2f} |"
        )


def build_report(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    flops: float,
    hbm_bytes: float,
    collective_per_chip_bytes: float,
    model_flops: float,
    bytes_per_chip: float,
) -> RooflineReport:
    compute_term = flops / (chips * PEAK_FLOPS)
    memory_term = hbm_bytes / (chips * HBM_BW)
    collective_term = collective_per_chip_bytes / LINK_BW
    terms = {
        "compute": compute_term,
        "memory": memory_term,
        "collective": collective_term,
    }
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mfu = (model_flops / (chips * PEAK_FLOPS * bound)) if bound > 0 else 0.0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=hbm_bytes / 1e9,
        collective_gbytes=collective_per_chip_bytes * chips / 1e9,
        compute_term_s=compute_term, memory_term_s=memory_term,
        collective_term_s=collective_term, dominant=dominant,
        model_gflops=model_flops / 1e9,
        useful_ratio=(model_flops / flops) if flops else 0.0,
        bytes_per_chip_gb=bytes_per_chip / 1e9,
        step_time_bound_s=bound, mfu_bound=mfu,
    )


def model_flops_for(cfg, shape, n_active: Optional[int] = None) -> float:
    """6·N_active·D with D = tokens processed by the lowered step."""
    n = n_active if n_active is not None else cfg.active_param_count()
    if shape.kind == "decode":
        d = shape.global_batch * 1
        return 2.0 * n * d  # inference fwd only
    d = shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * d
    return 6.0 * n * d  # train: fwd + bwd
