"""HLO-text analysis: collective wire-byte accounting for the roofline.

``cost_analysis()`` reports FLOPs and HBM bytes but not collective traffic,
so we parse the optimized HLO: every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute contributes per-chip wire bytes using the
standard ring-algorithm cost model:

  all-gather        (k-1)/k × result_bytes
  reduce-scatter    (k-1)   × result_bytes          (operand = k × result)
  all-reduce        2(k-1)/k × result_bytes
  all-to-all        (k-1)/k × result_bytes
  collective-permute 1      × result_bytes

k = replica-group size parsed per op. Returns per-chip bytes (the roofline
divides total bytes by chips; per-chip × chips = total keeps both views).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9a-z]+)?)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[dims] shape literal in `text`."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    return default


def collective_bytes(hlo_text: str, *, default_group: int = 1) -> Dict[str, float]:
    """Per-chip collective wire bytes by op kind (+ 'total')."""
    out: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs, rhs = ls.split("=", 1)
        opm = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                opm = c
                break
        if opm is None:
            continue
        if f"{opm}-done(" in rhs:
            continue  # result of the -start op already counted
        result_bytes = _shape_bytes(lhs + rhs.split("(")[0])
        if opm == "collective-permute":  # pairwise: no replica_groups attr
            out[opm] += result_bytes
            out["total"] += result_bytes
            continue
        k = _group_size(rhs, default_group)
        if k <= 1:
            continue
        if opm == "all-gather":
            wire = result_bytes * (k - 1) / k
        elif opm == "reduce-scatter":
            wire = result_bytes * (k - 1)
        elif opm == "all-reduce":
            wire = 2 * result_bytes * (k - 1) / k
        elif opm == "all-to-all":
            wire = result_bytes * (k - 1) / k
        else:  # collective-permute
            wire = result_bytes
        out[opm] += wire
        out["total"] += wire
    return dict(out)


def collective_op_counts(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start)?\(", line):
                counts[c] += 1
    return dict(counts)
