"""Small pytree utilities used across the framework (no flax/optax on purpose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree (uses actual dtypes)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def global_norm(tree) -> jax.Array:
    """L2 norm over every leaf of a pytree (computed in fp32)."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_flatten_with_paths(tree):
    """Yield (dotted-path, leaf) pairs — used by the checkpointer manifest."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)
