"""The 10 assigned architectures, exact configs from public literature.

Each is selectable via ``--arch <id>`` in the launchers. Sources in brackets.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig

# [arXiv:2401.06066; hf] — fine-grained MoE: 2 shared + 64 routed top-6,
# first layer dense (d_ff 10944), expert dim 1408, MHA (kv=16).
DEEPSEEK_MOE_16B = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, dense_d_ff=10944, vocab_size=102_400,
    n_experts=64, n_experts_per_tok=6, n_shared_experts=2,
    first_dense_layers=1, rope_theta=10_000.0,
)

# [hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — 16 routed top-1 +
# 1 shared expert every layer; GQA kv=8.
LLAMA4_SCOUT_17B = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202_048,
    n_experts=16, n_experts_per_tok=1, n_shared_experts=1,
    rope_theta=500_000.0,
)

# [arXiv:2308.11596; hf] — enc-dec text backbone (speech frontend stubbed:
# input_specs provides precomputed frame embeddings), 24L each side, MHA.
SEAMLESS_M4T_LARGE_V2 = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec-audio",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256_206, frontend="audio",
    rope_theta=10_000.0, mlp="gelu",
)

# [arXiv:2405.21060; unverified] — SSD (state-space duality), attn-free,
# d_inner = 2*d, head_dim 64 -> 32 SSD heads, state 128.
MAMBA2_370M = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50_280,
    ssm_state=128, ssm_expand=2, ssm_conv=4, ssm_head_dim=64,
    tie_embeddings=True,
)

# [arXiv:2408.00118; hf] — alternating local(4096)/global attention,
# attn softcap 50, final softcap 30, head_dim 256, GeGLU, pre+post norms.
GEMMA2_2B = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256_000,
    attn_pattern=("local", "global"), local_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_norm=True, tie_embeddings=True, rope_theta=10_000.0,
)

# [arXiv:2405.04324; hf] — code model, MQA (kv=1), wide FFN.
GRANITE_20B = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49_152, mlp="gelu",
    rope_theta=10_000.0,
)

# [hf:Qwen/Qwen2.5-0.5B scaled per spec; hf] — GQA kv=8, QKV bias.
QWEN25_32B = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab_size=152_064,
    qkv_bias=True, rope_theta=1_000_000.0,
)

# [arXiv:2407.14679; hf] — pruned nemotron; squared-ReLU MLP.
MINITRON_8B = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab_size=256_000, mlp="relu2",
    rope_theta=10_000.0,
)

# [arXiv:2403.19887; hf] — Mamba+attn 1:7 interleave (attn at l%8==4),
# MoE 16e top-2 every other layer; mamba1-style state 16.
JAMBA_V01_52B = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65_536,
    n_experts=16, n_experts_per_tok=2,
    moe_layer_period=2, moe_layer_offset=1,
    ssm_state=16, ssm_expand=2, ssm_conv=4, ssm_head_dim=64,
    attn_layer_period=8, attn_layer_offset=4,
)

# [hf:microsoft/Phi-3-vision-128k-instruct; hf] — phi3-mini backbone + CLIP
# frontend (stubbed: input_specs provides patch embeddings), MHA kv=32.
PHI3_VISION_4B = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32_064, frontend="vision",
    rope_theta=10_000.0,
)

ARCHS = {
    c.name: c
    for c in (
        DEEPSEEK_MOE_16B, LLAMA4_SCOUT_17B, SEAMLESS_M4T_LARGE_V2, MAMBA2_370M,
        GEMMA2_2B, GRANITE_20B, QWEN25_32B, MINITRON_8B, JAMBA_V01_52B,
        PHI3_VISION_4B,
    )
}


def smoke_config(full: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (small layers/width/
    experts/vocab, same structural features)."""
    import dataclasses

    kw: dict = dict(
        n_layers=max(2, min(4, full.n_layers)),
        d_model=64,
        d_ff=128 if full.d_ff else 0,
        dense_d_ff=192 if full.dense_d_ff else 0,
        vocab_size=128,
        head_dim=16,
        local_window=8,
    )
    if full.n_heads:
        kw.update(n_heads=4, n_kv_heads=max(1, min(full.n_kv_heads, 2) if full.n_kv_heads < full.n_heads else 4))
    if full.n_experts:
        # generous capacity so smoke tests are drop-free (exact decode parity)
        kw.update(n_experts=4, n_experts_per_tok=min(2, full.n_experts_per_tok),
                  moe_capacity_factor=4.0)
    if full.ssm_state:
        kw.update(ssm_state=8, ssm_head_dim=16)
    if full.n_enc_layers:
        kw.update(n_enc_layers=2)
    if full.attn_layer_period:
        kw.update(attn_layer_period=2, attn_layer_offset=1)
    if full.first_dense_layers:
        kw.update(first_dense_layers=1)
    return dataclasses.replace(full, **kw)
