"""Config system: model architectures, input shapes, parallelism knobs."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec-audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int                        # dense-FFN hidden dim (per-expert dim for MoE)
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- attention flavour ---
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    attn_pattern: Tuple[str, ...] = ("global",)   # cycled over layers
    local_window: int = 4096
    # --- MoE ---
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    dense_d_ff: int = 0              # dense FFN dim of non-MoE layers (deepseek l0)
    moe_layer_period: int = 1        # MoE every k-th layer
    moe_layer_offset: int = 0
    first_dense_layers: int = 0      # leading dense layers (deepseek: 1)
    router_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25
    moe_groups: int = 1              # dispatch groups (shard-local capacity)
    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    attn_layer_period: int = 0       # hybrid: attention every k-th layer ...
    attn_layer_offset: int = 0       # ... at this offset (else mamba)
    # --- enc-dec / frontends ---
    n_enc_layers: int = 0
    frontend: str = ""               # "" | "audio" | "vision"
    mlp: str = "swiglu"              # swiglu | relu2 | gelu
    norm_eps: float = 1e-6
    post_norm: bool = False          # gemma2 style pre+post norms
    tie_embeddings: bool = False
    scan_layers: bool = True         # lax.scan over the layer stack (compile-time)
    attn_chunk: int = 1024           # flash-attention kv-chunk (XLA path)

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to 128 (Megatron-style) so embeddings TP-shard and
        the unembed GEMM stays MXU-aligned."""
        return ((self.vocab_size + 127) // 128) * 128

    # ---- layer-type helpers ----
    def layer_kind(self, layer: int) -> str:
        """'attn' or 'mamba' for decoder layer `layer`."""
        if self.family == "ssm":
            return "mamba"
        if self.attn_layer_period:
            return (
                "attn"
                if layer % self.attn_layer_period == self.attn_layer_offset
                else "mamba"
            )
        return "attn"

    def layer_is_moe(self, layer: int) -> bool:
        if not self.n_experts:
            return False
        if layer < self.first_dense_layers:
            return False
        return layer % self.moe_layer_period == self.moe_layer_offset

    def attn_type(self, layer: int) -> str:
        return self.attn_pattern[layer % len(self.attn_pattern)]

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd, hq, hkv = self.head_dim, self.n_heads, self.n_kv_heads
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        n_dec = self.n_layers

        def attn_params() -> int:
            return d * hq * hd + 2 * d * hkv * hd + hq * hd * d

        def mlp_params(dff: int) -> int:
            return (3 if self.mlp == "swiglu" else 2) * d * dff

        for layer in range(n_dec):
            kind = self.layer_kind(layer)
            if kind == "attn":
                total += attn_params()
            else:
                d_in = self.ssm_expand * d
                n_h = d_in // self.ssm_head_dim
                total += (
                    d * (2 * d_in + 2 * self.ssm_state + n_h)  # in_proj
                    + self.ssm_conv * (d_in + 2 * self.ssm_state)  # conv
                    + d_in * d  # out_proj
                    + 3 * n_h  # A, D, dt_bias
                )
            if self.layer_is_moe(layer):
                total += self.n_experts * mlp_params(ff)
                total += self.n_shared_experts * mlp_params(ff)
                total += d * self.n_experts  # router
            else:
                total += mlp_params(self.dense_d_ff or ff)
            total += 2 * d  # norms
        for _ in range(self.n_enc_layers):
            total += attn_params() + mlp_params(ff) + 2 * d
            total += attn_params() + d  # decoder cross-attn + its norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        d, ff = self.d_model, self.d_ff
        per_expert = (3 if self.mlp == "swiglu" else 2) * d * ff
        n_moe_layers = sum(self.layer_is_moe(l) for l in range(self.n_layers))
        inactive = n_moe_layers * (self.n_experts - self.n_experts_per_tok) * per_expert
        return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ParallelConfig:
    """Distribution knobs resolved by the launcher per (arch × shape × mesh)."""
    remat: str = "block"             # none | block | full
    microbatches: int = 1
    zero_stage: int = 1              # 0 = replicated opt state, 1 = sharded
    shard_kv_seq: bool = True        # decode: shard KV-cache sequence over 'model'
    compress_pod_grads: bool = True  # int8 error-feedback all-reduce on 'pod'
    seq_shard_activations: bool = False  # prefill: sequence-shard activations


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
