"""--arch config module (see archs.py for the full definition)."""
from repro.configs.archs import GEMMA2_2B as CONFIG  # noqa: F401
from repro.configs.archs import smoke_config

SMOKE = smoke_config(CONFIG)
