"""Config registry: ModelConfig per assigned architecture + input shapes."""
from repro.configs.archs import ARCHS, smoke_config  # noqa: F401
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ParallelConfig,
    RunConfig,
    ShapeConfig,
)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
