"""--arch config module (see archs.py for the full definition)."""
from repro.configs.archs import SEAMLESS_M4T_LARGE_V2 as CONFIG  # noqa: F401
from repro.configs.archs import smoke_config

SMOKE = smoke_config(CONFIG)
