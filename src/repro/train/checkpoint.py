"""Sharded checkpointing with elastic restore (no orbax — built here).

Layout per step:  <dir>/step_<n>/
    manifest.json          tree structure, shapes, dtypes, save metadata
    arrays.npz             one entry per leaf (path-keyed)

Restore is *elastic*: arrays are saved in logical (unsharded) form and
re-placed with whatever NamedSharding the restoring mesh dictates — restart
on a different pod count is a config flip, not a conversion job. Writes can
run on a background thread (async=True) so the train loop never blocks on
I/O; `wait()` joins before the next save (single-writer discipline).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.tree import tree_flatten_with_paths


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, async_: bool = False,
             extra: Optional[dict] = None) -> None:
        # fetch to host *now* (cheap on CPU, device-offload point on TPU);
        # the serialization happens on the worker thread if async.
        flat = tree_flatten_with_paths(tree)
        host = [(name, np.asarray(jax.device_get(leaf))) for name, leaf in flat]
        manifest = {
            "step": step,
            "leaves": [
                {"path": n, "shape": list(a.shape), "dtype": str(a.dtype)}
                for n, a in host
            ],
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
            "extra": extra or {},
        }

        def _write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{n: a for n, a in host})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(path):
                shutil.rmtree(path)
            os.replace(tmp, path)
            self._gc()

        self.wait()
        if async_:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, *, mesh=None, specs=None) -> Any:
        """Restore into the structure of ``like``; if (mesh, specs) given,
        leaves are placed with NamedSharding(mesh, spec) — elastic reshard."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            data = {k: z[k] for k in z.files}
        names = [n for n, _ in tree_flatten_with_paths(like)]
        leaves = [data[n] for n in names]
        tdef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(tdef, leaves)
        if mesh is not None and specs is not None:
            flat_specs = tdef.flatten_up_to(specs)
            placed = [
                jax.device_put(l, jax.sharding.NamedSharding(mesh, s))
                for l, s in zip(leaves, flat_specs, strict=True)
            ]
            tree = jax.tree_util.tree_unflatten(tdef, placed)
        return tree
