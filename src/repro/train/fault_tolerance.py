"""Fault tolerance: step retry, straggler telemetry, deterministic resume.

At 1000+-node scale the failure model is: (a) transient step failures
(preemptions, flaky ICI links) → bounded retry; (b) hard node loss → restart
from the last checkpoint, possibly on a *different* mesh (checkpoint.py
restores elastically); (c) stragglers → detect via step-time quantiles and
surface for the scheduler. The data pipeline is a pure function of
(step, shard), so any restart replays exactly — no data-state to recover.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np


class TransientError(RuntimeError):
    """Injected/recoverable failure (preemption, link flap)."""


@dataclass
class StepStats:
    times: List[float] = field(default_factory=list)
    retries: int = 0
    failures: int = 0

    def record(self, dt: float) -> None:
        self.times.append(dt)

    def quantiles(self) -> Dict[str, float]:
        if not self.times:
            return {}
        t = np.asarray(self.times)
        return {
            "p50": float(np.quantile(t, 0.5)),
            "p95": float(np.quantile(t, 0.95)),
            "p99": float(np.quantile(t, 0.99)),
            "max": float(t.max()),
        }

    def stragglers(self, factor: float = 3.0) -> int:
        """Steps slower than factor × median — the straggler signal that a
        real deployment feeds back to the job scheduler for node swap."""
        if len(self.times) < 4:
            return 0
        t = np.asarray(self.times)
        return int(np.sum(t > factor * np.median(t)))


class StepGuard:
    """Wraps a step function with retry + timing. ``failure_hook`` lets tests
    inject TransientError deterministically."""

    def __init__(
        self,
        step_fn: Callable,
        *,
        max_retries: int = 3,
        failure_hook: Optional[Callable[[int, int], bool]] = None,
    ):
        self.step_fn = step_fn
        self.max_retries = max_retries
        self.failure_hook = failure_hook
        self.stats = StepStats()

    def __call__(self, step: int, *args, **kwargs):
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                if self.failure_hook is not None and self.failure_hook(step, attempt):
                    raise TransientError(f"injected failure at step {step}")
                out = self.step_fn(*args, **kwargs)
                self.stats.record(time.perf_counter() - t0)
                return out
            except TransientError:
                self.stats.failures += 1
                attempt += 1
                if attempt > self.max_retries:
                    raise
                self.stats.retries += 1


def run_training(
    *,
    train_step: Callable,
    init_state: Any,                      # (params, opt_state)
    batch_for_step: Callable[[int], Any],  # pure: step -> batch
    n_steps: int,
    ckpt=None,
    ckpt_every: int = 0,
    start_step: int = 0,
    guard_kwargs: Optional[dict] = None,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
):
    """The canonical fault-tolerant loop: pure data, guarded step, periodic
    async checkpoints. Returns (params, opt_state, stats)."""
    params, opt_state = init_state
    guard = StepGuard(train_step, **(guard_kwargs or {}))
    for step in range(start_step, n_steps):
        batch = batch_for_step(step)
        params, opt_state, mets = guard(step, params, opt_state, batch)
        if on_metrics is not None:
            on_metrics(step, mets)
        if ckpt is not None and ckpt_every and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state}, async_=True)
    if ckpt is not None:
        ckpt.wait()
    return params, opt_state, guard.stats
