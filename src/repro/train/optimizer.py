"""AdamW + cosine schedule + global-norm clipping + ZeRO-1 sharding, from
scratch (no optax — every substrate is built here).

ZeRO-1 under GSPMD: optimizer moments get the parameter's sharding *plus* the
data axes folded into the first dimension that is unsharded and divisible —
state memory scales 1/|data| with zero code in the update (XLA keeps the
computation sharded end-to-end and re-gathers params only where consumed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.utils.tree import global_norm


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, *, master: bool = False) -> dict:
    """``master=True`` = mixed precision: params are stored bf16 while the
    optimizer carries an fp32 master copy (ZeRO-sharded with m/v); halves
    param HBM + read bandwidth on every fwd/bwd pass."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    out = {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master:
        out["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params)
    return out


def adamw_update(
    grads, opt_state: dict, params, cfg: OptConfig
) -> Tuple[Any, dict, dict]:
    """One AdamW step. Returns (params, opt_state, metrics). If the state
    carries fp32 ``master`` weights, the update applies to those and the
    (bf16) working params are re-cast from them."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    has_master = "master" in opt_state

    def upd(p, g, m, v, mast):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        base = mast if mast is not None else p.astype(jnp.float32)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * base
        new_master = base - lr * delta
        return new_master.astype(p.dtype), m, v, new_master

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_mast = (tdef.flatten_up_to(opt_state["master"]) if has_master
                 else [None] * len(flat_p))
    out = [upd(p, g, m, v, mt) for p, g, m, v, mt in
           zip(flat_p, flat_g, flat_m, flat_v, flat_mast, strict=True)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_state = {
        "m": tdef.unflatten([o[1] for o in out]),
        "v": tdef.unflatten([o[2] for o in out]),
        "step": step,
    }
    if has_master:
        new_state["master"] = tdef.unflatten([o[3] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, new_state, metrics


# ------------------------------------------------------------- ZeRO-1 specs
def _zero_spec_for(spec: P, shape, data_axes: Tuple[str, ...], mesh_shape: dict) -> P:
    """Fold the data axes into the first unsharded, divisible dimension."""
    dp = int(np.prod([mesh_shape[a] for a in data_axes])) if data_axes else 1
    if dp <= 1 or not shape:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, cur) in enumerate(zip(shape, parts, strict=True)):
        if cur is None and dim % dp == 0:
            parts[i] = data_axes if len(data_axes) > 1 else data_axes[0]
            return P(*parts)
        if cur is not None:
            # dimension already model-sharded; the *local* extent must divide
            sz = mesh_shape[cur] if isinstance(cur, str) else int(
                np.prod([mesh_shape[a] for a in cur])
            )
            if dim % (sz * dp) == 0:
                merged = (cur,) if isinstance(cur, str) else tuple(cur)
                parts[i] = merged + tuple(data_axes)
                return P(*parts)
    return spec  # nothing divisible: replicate over data (rare tiny leaves)


def zero_opt_specs(
    param_specs, params_shapes, data_axes: Tuple[str, ...], mesh_shape: dict,
    zero_stage: int = 1, master: bool = False,
):
    """PartitionSpec pytree for init_opt_state's {"m","v"[,"master"],"step"}."""
    if zero_stage == 0:
        mspec = param_specs
    else:
        mspec = jax.tree_util.tree_map(
            lambda s, p: _zero_spec_for(s, p.shape, data_axes, mesh_shape),
            param_specs,
            params_shapes,
            is_leaf=lambda x: isinstance(x, P),
        )
    out = {"m": mspec, "v": mspec, "step": P()}
    if master:
        out["master"] = mspec
    return out
