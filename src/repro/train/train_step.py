"""Train step factory: weighted CE loss, microbatch gradient accumulation
with compute/comm overlap, remat policies, AdamW/ZeRO update.

The loss supports per-example weights — that is where IHTC instance selection
enters training: prototype examples carry their cluster mass
(data/instance_selection.py), so training on the reduced corpus optimizes an
unbiased estimate of the full-corpus loss.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelConfig
from repro.models.registry import ModelBundle
from repro.models.transformer import ShardingPlan
from repro.train.optimizer import OptConfig, adamw_update


def cross_entropy(
    logits: jax.Array,          # (b, s, v) fp32
    labels: jax.Array,          # (b, s) int32, -1 = masked
    weights: Optional[jax.Array] = None,  # (b,) example weights (IHTC masses)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (mean weighted token loss, total weight)."""
    v = logits.shape[-1]
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.where(labels >= 0, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # gold logit via fused masked reduction, NOT take_along_axis: a gather
    # over the vocab axis breaks its TP sharding (forces an all-gather of the
    # fp32 logits — measured +13 GB/chip on qwen-32b-class vocabs).
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(cols == lab[..., None], logits, 0.0), axis=-1)
    tok_loss = (logz - gold) * mask
    if weights is not None:
        tok_loss = tok_loss * weights[:, None]
        mask = mask * weights[:, None]
    tot = jnp.maximum(jnp.sum(mask), 1e-6)
    return jnp.sum(tok_loss) / tot, tot


def make_loss_fn(bundle: ModelBundle, plan: ShardingPlan, impl: str, remat: str):
    cfg = bundle.cfg

    def loss_fn(params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = bundle.forward(
            params, batch, plan=plan, impl=impl, remat=remat
        )
        loss, tot = cross_entropy(logits, batch["labels"], batch.get("weights"))
        total = loss + cfg.router_aux_coef * aux
        return total, {"loss": loss, "aux_loss": aux, "weight": tot}

    return loss_fn


def make_train_step(
    bundle: ModelBundle,
    opt_cfg: OptConfig,
    parallel: ParallelConfig = ParallelConfig(),
    plan: ShardingPlan = ShardingPlan(),
    impl: str = "xla",
) -> Callable:
    """Builds train_step(params, opt_state, batch) -> (params, opt, metrics).

    Microbatching: the global batch is split on axis 0 into
    ``parallel.microbatches`` slices scanned sequentially; gradients
    accumulate in fp32. Under GSPMD the per-microbatch reduce-scatter of
    gradients overlaps with the next microbatch's compute (the scan body
    carries only the accumulator — XLA's latency-hiding scheduler does the
    interleave; see DESIGN.md §5).
    """
    loss_fn = make_loss_fn(bundle, plan, impl, parallel.remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    n_micro = max(parallel.microbatches, 1)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, mets), grads = grad_fn(params, batch)
        else:
            def micro(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g
                )
                return acc, (l, m)

            split = jax.tree_util.tree_map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                batch,
            )
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, (losses, mets_stack) = jax.lax.scan(micro, zero, split)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            loss = jnp.mean(losses)
            mets = jax.tree_util.tree_map(jnp.mean, mets_stack)

        params, opt_state, opt_mets = adamw_update(grads, opt_state, params, opt_cfg)
        mets = dict(mets, **opt_mets, total_loss=loss)
        return params, opt_state, mets

    return train_step


def make_eval_step(bundle: ModelBundle, plan: ShardingPlan = ShardingPlan(),
                   impl: str = "xla") -> Callable:
    loss_fn = make_loss_fn(bundle, plan, impl, "none")

    def eval_step(params, batch):
        _, mets = loss_fn(params, batch)
        return mets

    return eval_step
