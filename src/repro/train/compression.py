"""Gradient compression for the slow (cross-pod) axis: int8 quantized
all-reduce with error feedback.

Cross-pod links (DCN) are an order of magnitude slower than in-pod ICI, so
the pod-axis gradient reduction is the one collective worth compressing.
Scheme: per-tensor symmetric int8 quantization, psum of int32 accumulators,
dequantize, with an error-feedback buffer (residual of quantization added
back next step) — preserves convergence (Karimireddy et al., 2019).

``compressed_psum`` is written against named axes, i.e. for use inside
``shard_map``; the pure quantize/dequantize pair is also used standalone and
is what the unit tests sweep.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` with int8 on-the-wire payload (≈4× fewer
    bytes than f32). Scales are reconciled with a tiny f32 max-reduce."""
    n = jax.lax.psum(1, axis_name)
    q, scale = quantize_int8(x)
    # common scale so integer sums are exact: use the max scale across peers
    smax = jax.lax.pmax(scale, axis_name)
    requant = jnp.clip(
        jnp.round(dequantize_int8(q, scale) / smax), -127, 127
    ).astype(jnp.int8)
    total = jax.lax.psum(requant.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * smax / n


def psum_with_error_feedback(
    x: jax.Array, err: jax.Array, axis_name: str
) -> Tuple[jax.Array, jax.Array]:
    """Compressed mean-reduce of (x + carried error); returns (mean, new_err).

    new_err is the *local* quantization residual, fed back into the next
    step's gradient (error feedback keeps the bias O(q²) instead of O(q))."""
    y = x.astype(jnp.float32) + err
    q, scale = quantize_int8(y)
    smax = jax.lax.pmax(scale, axis_name)
    requant = jnp.clip(jnp.round(y / smax), -127, 127).astype(jnp.int8)
    local_deq = requant.astype(jnp.float32) * smax
    new_err = y - local_deq
    n = jax.lax.psum(1, axis_name)
    total = jax.lax.psum(requant.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * smax / n, new_err


def tree_compressed_psum(tree: Any, err_tree: Any, axis_name: str):
    flat, tdef = jax.tree_util.tree_flatten(tree)
    errs = tdef.flatten_up_to(err_tree)
    outs, new_errs = [], []
    for x, e in zip(flat, errs, strict=True):
        o, ne = psum_with_error_feedback(x, e, axis_name)
        outs.append(o.astype(x.dtype))
        new_errs.append(ne)
    return tdef.unflatten(outs), tdef.unflatten(new_errs)
