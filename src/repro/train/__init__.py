"""Training substrate: optimizer, step factory, checkpoint, fault tolerance."""
from repro.train.checkpoint import CheckpointManager  # noqa: F401
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state  # noqa: F401
from repro.train.train_step import make_eval_step, make_train_step  # noqa: F401
