"""Process-wide runtime configuration for kernel dispatch and sharding.

Every knob that used to be hand-threaded through the pipeline as a kwarg
(``impl=``, ``knn_block=``, ``n_blocks=``, Pallas block sizes, the mesh) now
has exactly one home: the active :class:`RuntimeConfig`. Call sites keep
their keyword arguments — an explicit kwarg always wins — but the *default*
for every one of them is pulled from here, so switching the whole pipeline
to a new backend / block size / mesh is one ``configure(...)`` instead of an
edit across 18 files (the alpa ``GlobalConfig`` idiom, adapted to an
immutable config + context stack so scoped overrides compose).

Three layers, last one wins:

  1. the built-in defaults of :class:`RuntimeConfig`;
  2. ``REPRO_*`` environment variables, read once at import into the
     process-global default (see ``_ENV_FIELDS``);
  3. ``with configure(impl="ref", knn_block=4096): ...`` — a thread-local
     override stack for scoped changes (nests; exceptions unwind it).

Dispatch contract (DESIGN.md §10): jitted entry points resolve their
``None`` defaults from the active config *before* tracing and pass concrete
values down as static arguments, so a config change can never be masked by
a stale jit cache — the cache key always contains the resolved values.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Any, Iterator, Mapping, Optional

# Kernel dispatch policies. The last three are the fused nearest-prototype
# family (DESIGN.md §16): ops with no fused path (pairwise, segment_sum,
# attention) degrade them to "auto", so configuring impl="fused" process-wide
# only changes the assign/kNN hot path.
_IMPLS = ("auto", "pallas", "ref", "fused", "fused_bf16", "fused_int8")

_TUNE_MODES = ("off", "cached", "onthefly")

# "auto" + the names of the built-in fit executors (repro.core.plan keeps
# the authoritative registry; this tuple only gates the config field so a
# typo'd REPRO_EXECUTOR fails at import, not mid-fit)
_EXECUTORS = ("auto", "memory", "sharded", "streaming", "streaming_sharded")


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Immutable snapshot of every dispatch/sharding knob.

    Fields (``None`` means "decide from the environment at use time"):
      impl: kernel dispatch policy — "auto" (Pallas on TPU, jnp reference
        elsewhere), "pallas" (force the kernel), "ref" (force the oracle),
        "fused" (streaming fused nearest/top-k for the assign/kNN hot path;
        Pallas on TPU, XLA fold elsewhere), "fused_bf16" / "fused_int8"
        (fused shortlist over the frozen low-precision prototype buffer +
        exact-f32 rescore; serve-side only — DESIGN.md §16). Ops without a
        fused path treat the fused family as "auto".
      interpret: force Pallas interpret mode on/off; None = interpret
        everywhere except real TPUs (the existing behaviour).
      knn_block: query/key block for the blocked kNN drivers; 0 = auto
        (one-shot below the O(n²)-HBM threshold, blocks of
        ``repro.core.knn.AUTO_KNN_BLOCK`` rows above — shared by
        ``threshold_clustering`` and ``knn_graph_blocked``).
      block_q / block_k: Pallas knn_topk tile sizes.
      n_blocks: width of the canonical fixed reduction tree used by every
        segment-sum accumulation (the single/multi-device parity contract,
        DESIGN.md §4.3).
      precision: dtype name ("float32" | "bfloat16") used by the serving
        path for query/prototype distance evaluation.
      mesh: default jax.sharding.Mesh for ``ihtc``/``ClusterIndex.assign``;
        None = single device unless a mesh is passed explicitly.
      axis_name: mesh axis the data dimension is sharded over.
      chunk_n: static per-chunk buffer rows for the out-of-core streaming fit
        (:func:`repro.core.streaming.ihtc_streaming`); 0 = auto (the first
        chunk's row count fixes the shape).
      reservoir_n: device-side prototype reservoir capacity for the streaming
        fit; 0 = auto (at least 4x the per-chunk prototype budget
        ``chunk_n // t``, raised to cover the feasibility bound of
        DESIGN.md §12).
      prefetch_depth: how many chunks the streaming executors stage ahead
        of the device (DESIGN.md §18): 0 = today's serial loop (normalize,
        stage, reduce, fold — one chunk at a time); >= 1 starts a bounded
        background prefetch thread that normalizes/validates chunk N+1..
        N+depth into a rotating staging-buffer pool while chunk N runs on
        device. Every depth is bit-identical to depth 0 (the chunk key
        schedule is indexed, not arrival-ordered).
      donate_stream: donate the reservoir operands of the streaming fold /
        cascade / compaction programs (``jax.jit`` ``donate_argnums``) so
        the reservoir updates in place instead of being copied every
        chunk. Results are bit-identical either way; donation only changes
        buffer reuse.
      executor: fit execution strategy for :func:`repro.fit`
        (:mod:`repro.core.plan`) — "auto" picks from the input type and the
        mesh ("memory" | "sharded" for resident arrays, "streaming" |
        "streaming_sharded" for chunk iterators; a mesh selects the sharded
        flavour); naming one pins every planned fit to that executor
        (DESIGN.md §13).
      tune: empirical-autotuning policy (:mod:`repro.tune`, DESIGN.md §14)
        — "off" (default: every dispatch constant exactly as hand-picked),
        "cached" (consult the persistent tuning cache, fall back to the
        constants on a miss), "onthefly" (measure + persist on a miss).
      serve_queue_depth: admission-control bound for the async serve
        front-end (:class:`repro.serve.AsyncClusterService`, DESIGN.md
        §15): maximum admitted-but-undispatched *points* across all
        tenants; a submit that would exceed it is rejected with
        ``QueueFullError`` instead of queueing unboundedly.
      serve_max_inflight: maximum concurrently dispatched (not yet
        completed) batches of the async serve front-end.
      serve_max_wait_ms: continuous-batching flush deadline — no admitted
        request sits undispatched longer than this many milliseconds
        waiting for its batch to fill (loop-time units; the simulated
        harness interprets it as virtual ms).
      serve_default_tenant: tenant a request routes to when the caller
        names none; also the tenant a bare single-index service hosts.
      refresh_max_points: online-lifecycle refresh trigger (DESIGN.md §19,
        :class:`repro.serve.lifecycle.RefreshPolicy`): refresh once the
        online fitter has folded this many new points since the last
        installed version; 0 disables the trigger.
      refresh_max_cascades: refresh once the fitter's reservoir has
        cascaded this many times since the last installed version; 0
        disables the trigger.
      refresh_drift_ratio: refresh once the drift proxy (EMA of mean
        nearest-prototype distance of observed traffic against the
        *served* index, normalized by the post-install baseline) exceeds
        this ratio; 0.0 disables the trigger.
    """

    impl: str = "auto"
    interpret: Optional[bool] = None
    knn_block: int = 0
    block_q: int = 256
    block_k: int = 512
    n_blocks: int = 8
    precision: str = "float32"
    mesh: Any = None
    axis_name: str = "data"
    chunk_n: int = 0
    reservoir_n: int = 0
    prefetch_depth: int = 0
    donate_stream: bool = False
    executor: str = "auto"
    tune: str = "off"
    serve_queue_depth: int = 8192
    serve_max_inflight: int = 4
    serve_max_wait_ms: float = 5.0
    serve_default_tenant: str = "default"
    refresh_max_points: int = 0
    refresh_max_cascades: int = 0
    refresh_drift_ratio: float = 0.0

    def __post_init__(self) -> None:
        if self.impl not in _IMPLS:
            raise ValueError(f"impl must be one of {_IMPLS}, got {self.impl!r}")
        for name in ("knn_block", "chunk_n", "reservoir_n", "prefetch_depth"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0, got {getattr(self, name)}")
        for name in ("block_q", "block_k", "n_blocks"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, got {getattr(self, name)}")
        if self.precision not in ("float32", "bfloat16"):
            raise ValueError(f"precision must be 'float32' or 'bfloat16', "
                             f"got {self.precision!r}")
        if self.executor not in _EXECUTORS:
            raise ValueError(
                f"executor must be one of {_EXECUTORS}, got {self.executor!r}")
        if self.tune not in _TUNE_MODES:
            raise ValueError(
                f"tune must be one of {_TUNE_MODES}, got {self.tune!r}")
        for name in ("serve_queue_depth", "serve_max_inflight"):
            if getattr(self, name) < 1:
                raise ValueError(
                    f"{name} must be >= 1, got {getattr(self, name)}")
        if self.serve_max_wait_ms < 0:
            raise ValueError(f"serve_max_wait_ms must be >= 0, "
                             f"got {self.serve_max_wait_ms}")
        if not self.serve_default_tenant:
            raise ValueError("serve_default_tenant must be non-empty")
        for name in ("refresh_max_points", "refresh_max_cascades",
                     "refresh_drift_ratio"):
            if getattr(self, name) < 0:
                raise ValueError(
                    f"{name} must be >= 0 (0 disables the trigger), "
                    f"got {getattr(self, name)}")

    def replace(self, **overrides: Any) -> "RuntimeConfig":
        return dataclasses.replace(self, **overrides)

    def dispatch_key(self) -> tuple:
        """Hashable fingerprint of every field a kernel wrapper may read at
        trace time. Jitted inner functions take this as an extra static
        argument, so a config change always retraces instead of hitting a
        cache entry compiled under the previous config — the §10
        no-stale-cache contract, extended to fields the outer jit does not
        itself resolve (``interpret``, Pallas tile sizes, ...). ``chunk_n``
        and ``reservoir_n`` participate because the streaming drivers derive
        static buffer shapes from them; ``donate_stream`` because donation
        is part of the compiled executable (input-output aliasing — a
        donating program must never be served where a non-donating one was
        requested, or vice versa) and ``prefetch_depth`` for the same
        completeness reason as ``executor`` below (it selects the stream
        loop's pipeline shape, not a traced program, but downstream
        consumers treat the key as a fingerprint of every
        behaviour-determining field); and ``executor`` because the fit
        planner (:mod:`repro.core.plan`) derives buffer placement and level
        shapes from the chosen executor — a plan change must retrace, never
        hit a program compiled for another executor's buffers. ``mesh`` /
        ``axis_name`` / ``precision`` are excluded: they are only consulted
        at the host-driver level and resolved into explicit statics, so
        including them would just force spurious recompiles.

        When the tuning policy is active the key also carries the tuning
        cache's mutation epoch (:func:`repro.tune.cache.cache_epoch`):
        tuned winners are read at trace time, so a cache update — a
        ``populate`` run, a prune, swapping the cache file — must retrace
        rather than hit programs compiled under the previous winners
        (DESIGN.md §14). With ``tune="off"`` the epoch is excluded, so
        cache churn costs untuned callers nothing.

        The ``serve_*`` knobs participate for the same completeness
        reason as ``executor``: the async serve front-end (DESIGN.md §15)
        freezes its admission/batch-formation plan from them at
        construction, and downstream consumers treat ``dispatch_key()``
        as a fingerprint of *every* behaviour-determining config field —
        a serving reconfiguration must never alias the previous one.
        They change only at deployment reconfiguration, so the retrace
        cost is nil. ``serve_default_tenant`` is excluded (pure host-side
        routing name, resolved per call like ``mesh``/``axis_name``).
        The ``refresh_*`` knobs (DESIGN.md §19) participate identically:
        the refresh driver freezes its policy from them, a lifecycle
        reconfiguration must never alias the previous one, and they too
        change only at deployment reconfiguration.
        """
        if self.tune == "off":
            tune_state: object = "off"
        else:
            from repro.tune.cache import cache_epoch  # lazy; stdlib-only

            tune_state = (self.tune, cache_epoch())
        return (self.impl, self.interpret, self.knn_block, self.block_q,
                self.block_k, self.n_blocks, self.chunk_n, self.reservoir_n,
                self.prefetch_depth, self.donate_stream,
                self.executor, tune_state, self.serve_queue_depth,
                self.serve_max_inflight, self.serve_max_wait_ms,
                self.refresh_max_points, self.refresh_max_cascades,
                self.refresh_drift_ratio)


def _parse_bool(s: str) -> bool:
    return s.strip().lower() in ("1", "true", "yes", "on")


# env var -> (field, parser); mesh has no env form (it is a live object)
_ENV_FIELDS = {
    "REPRO_IMPL": ("impl", str),
    "REPRO_INTERPRET": ("interpret", _parse_bool),
    "REPRO_KNN_BLOCK": ("knn_block", int),
    "REPRO_BLOCK_Q": ("block_q", int),
    "REPRO_BLOCK_K": ("block_k", int),
    "REPRO_N_BLOCKS": ("n_blocks", int),
    "REPRO_PRECISION": ("precision", str),
    "REPRO_AXIS_NAME": ("axis_name", str),
    "REPRO_CHUNK_N": ("chunk_n", int),
    "REPRO_RESERVOIR_N": ("reservoir_n", int),
    "REPRO_PREFETCH_DEPTH": ("prefetch_depth", int),
    "REPRO_DONATE_STREAM": ("donate_stream", _parse_bool),
    "REPRO_EXECUTOR": ("executor", str),
    "REPRO_TUNE": ("tune", str),
    "REPRO_SERVE_QUEUE_DEPTH": ("serve_queue_depth", int),
    "REPRO_SERVE_MAX_INFLIGHT": ("serve_max_inflight", int),
    "REPRO_SERVE_MAX_WAIT_MS": ("serve_max_wait_ms", float),
    "REPRO_SERVE_DEFAULT_TENANT": ("serve_default_tenant", str),
    "REPRO_REFRESH_MAX_POINTS": ("refresh_max_points", int),
    "REPRO_REFRESH_MAX_CASCADES": ("refresh_max_cascades", int),
    "REPRO_REFRESH_DRIFT_RATIO": ("refresh_drift_ratio", float),
}


def config_from_env(env: Optional[Mapping[str, str]] = None) -> RuntimeConfig:
    """Built-in defaults overridden by any ``REPRO_*`` variables in ``env``."""
    env = os.environ if env is None else env
    overrides = {}
    for var, (field, parse) in _ENV_FIELDS.items():
        if var in env and env[var] != "":
            overrides[field] = parse(env[var])
    return RuntimeConfig(**overrides)


# process-global default (layer 2) + per-thread override stack (layer 3)
_default = config_from_env()


class _Stack(threading.local):
    def __init__(self) -> None:
        self.frames: list = []


_stack = _Stack()


def active() -> RuntimeConfig:
    """The config governing dispatch right now (innermost override wins)."""
    return _stack.frames[-1] if _stack.frames else _default


def dispatch_key() -> tuple:
    """``active().dispatch_key()`` — the static cache-key fingerprint."""
    return active().dispatch_key()


def default_config() -> RuntimeConfig:
    """The process-global default (env-seeded; ignores ``configure`` scopes)."""
    return _default


def set_default(config: RuntimeConfig) -> RuntimeConfig:
    """Replace the process-global default; returns the previous one."""
    global _default
    if not isinstance(config, RuntimeConfig):
        raise TypeError(f"expected RuntimeConfig, got {type(config).__name__}")
    prev, _default = _default, config
    return prev


def update_default(**overrides: Any) -> RuntimeConfig:
    """Update fields of the process-global default in place (returns it)."""
    global _default
    _default = _default.replace(**overrides)
    return _default


@contextlib.contextmanager
def configure(**overrides: Any) -> Iterator[RuntimeConfig]:
    """Scoped override: ``with configure(impl="ref"): ...``.

    Overrides stack on top of the currently-active config (so nested scopes
    compose) and are popped on exit, including on exceptions. Thread-local:
    a scope opened on one thread never leaks into another.
    """
    cfg = active().replace(**overrides)
    _stack.frames.append(cfg)
    try:
        yield cfg
    finally:
        _stack.frames.pop()
