"""Runtime subsystem: one home for every dispatch/sharding knob.

``runtime.active()`` is what every kernel wrapper and driver consults for
its defaults; ``with runtime.configure(...)`` scopes an override. See
runtime/config.py and DESIGN.md §10 for the dispatch contract.
"""
from repro.runtime.config import (  # noqa: F401
    RuntimeConfig,
    active,
    config_from_env,
    configure,
    default_config,
    dispatch_key,
    set_default,
    update_default,
)
