"""ITIS instance selection as a first-class data-pipeline stage — the paper's
technique applied to LM training corpora.

Flow: featurize each training example (mean-pooled embedding — either the
model's own embedding table or a fixed random projection), run ITIS at
threshold t* for m iterations, keep one *representative example* per
prototype (the medoid: the member nearest the centroid) weighted by cluster
mass. The train step's weighted CE (train_step.cross_entropy) then optimizes
an unbiased estimate of the full-corpus loss on ≥(t*)^m-fold less data.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.itis import itis
from repro.core.prototypes import compose_assignments, standardize
from repro.kernels import ops


@dataclass(frozen=True)
class SelectionConfig:
    threshold: int = 2          # t*
    iterations: int = 2         # m  → ≥ 4× corpus reduction
    feature_dim: int = 64       # random-projection feature width
    standardize: bool = True
    weighted: bool = True       # mass-correct centroids through levels
    impl: str = "auto"


class SelectedCorpus(NamedTuple):
    indices: jax.Array   # (n_selected_max,) int32 example ids (-1 padding)
    weights: jax.Array   # (n_selected_max,) float32 cluster masses
    valid: jax.Array     # (n_selected_max,) bool
    assignment: jax.Array  # (n,) int32 — which selected example covers each original


def featurize(
    tokens: jax.Array,  # (n, s) int32
    vocab: int,
    dim: int,
    *,
    key: Optional[jax.Array] = None,
    embed_table: Optional[jax.Array] = None,
) -> jax.Array:
    """Mean-pooled embedding features (n, dim). Uses the model's embedding
    table when given, else a fixed random projection of token counts."""
    if embed_table is not None:
        emb = embed_table[tokens]                    # (n, s, d)
        feats = jnp.mean(emb.astype(jnp.float32), axis=1)
        return feats[:, :dim]
    if key is None:
        key = jax.random.PRNGKey(7)
    proj = jax.random.normal(key, (vocab, dim), jnp.float32) / (dim**0.5)
    # bag-of-tokens projection == mean of projected one-hots (cheap gather)
    return jnp.mean(proj[tokens], axis=1)


def select_instances(
    tokens: jax.Array,
    vocab: int,
    scfg: SelectionConfig = SelectionConfig(),
    *,
    key: Optional[jax.Array] = None,
    embed_table: Optional[jax.Array] = None,
) -> SelectedCorpus:
    """Run ITIS over example features; pick the medoid example per prototype."""
    if key is None:
        key = jax.random.PRNGKey(0)
    kf, ki = jax.random.split(key)
    feats = featurize(tokens, vocab, scfg.feature_dim, key=kf,
                      embed_table=embed_table)
    if scfg.standardize:
        feats = standardize(feats)

    r = itis(feats, scfg.threshold, scfg.iterations, key=ki,
             weighted=scfg.weighted, impl=scfg.impl)

    # back out: original example -> final prototype id
    n = feats.shape[0]
    if r.assignments:
        ident = jnp.arange(r.protos.shape[0], dtype=jnp.int32)
        assign = compose_assignments(r.assignments, ident)  # (n,) -> proto id
    else:
        assign = jnp.arange(n, dtype=jnp.int32)

    # medoid per prototype: member closest to the prototype centroid
    n_max = r.protos.shape[0]
    d = ops.pairwise_sq_l2(feats, r.protos, impl=scfg.impl)  # (n, n_max)
    dmem = d[jnp.arange(n), jnp.where(assign >= 0, assign, 0)]
    dmem = jnp.where(assign >= 0, dmem, jnp.inf)
    order = jnp.argsort(dmem)  # best members first
    # first occurrence of each prototype id along `order` is its medoid
    seen = jnp.zeros((n_max + 1,), bool)
    sel = jnp.full((n_max,), -1, jnp.int32)

    def body(i, carry):
        seen, sel = carry
        ex = order[i]
        pid = jnp.where(assign[ex] >= 0, assign[ex], n_max)
        take = (~seen[pid]) & (pid < n_max)
        sel = jnp.where(take, sel.at[jnp.minimum(pid, n_max - 1)].set(ex), sel)
        seen = seen.at[pid].set(True)
        return seen, sel

    _, sel = jax.lax.fori_loop(0, n, body, (seen, sel))
    return SelectedCorpus(sel, r.mass, r.valid & (sel >= 0), assign)


def reduced_batch(
    corpus_tokens: jax.Array, selected: SelectedCorpus
) -> Dict[str, jax.Array]:
    """Materialize the weighted reduced training set (padded rows weight 0)."""
    safe = jnp.where(selected.indices >= 0, selected.indices, 0)
    toks = corpus_tokens[safe]
    w = jnp.where(selected.valid, selected.weights, 0.0)
    return {
        "tokens": toks[:, :-1],
        "labels": jnp.where(selected.valid[:, None], toks[:, 1:], -1),
        "weights": w,
    }
