"""Deterministic, shardable synthetic data pipeline.

Every batch is a pure function of (seed, step) — the property fault
tolerance relies on: a restart at step k regenerates exactly the batches a
healthy run would have seen, with zero pipeline state to checkpoint. Tokens
follow a Zipfian unigram mixed with a hidden Markov structure so the LM loss
actually has signal to descend (integration tests assert loss decreases).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.frontends import VISION_PREFIX_TOKENS


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    n_states: int = 16          # HMM hidden states
    zipf_a: float = 1.3


def _batch_key(seed: int, step: int) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def synth_tokens(key, batch: int, seq: int, vocab: int, dcfg: DataConfig) -> jax.Array:
    """Markov-modulated Zipf tokens (b, s+1): learnable structure, stateless."""
    k1, k2, k3 = jax.random.split(key, 3)
    # hidden state per position: slow random walk
    steps = jax.random.bernoulli(k1, 0.1, (batch, seq + 1)).astype(jnp.int32)
    state = jnp.cumsum(steps, axis=1) % dcfg.n_states
    # per-state vocab offset makes next-token statistics state-dependent
    ranks = jax.random.pareto(k2, dcfg.zipf_a, (batch, seq + 1))
    base = jnp.clip(ranks * 7.0, 0, vocab // 2 - 1).astype(jnp.int32)
    offset = (state * (vocab // (2 * dcfg.n_states))).astype(jnp.int32)
    toks = (base + offset) % vocab
    return toks


def make_batch(
    cfg: ModelConfig,
    shape: ShapeConfig,
    step: int,
    *,
    dcfg: DataConfig = DataConfig(),
    batch_override: Optional[int] = None,
    seq_override: Optional[int] = None,
) -> Dict[str, jax.Array]:
    """Training batch for any arch family at `step` (pure function)."""
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    key = _batch_key(dcfg.seed, step)
    toks = synth_tokens(key, b, s, cfg.vocab_size, dcfg)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend == "vision":
        kp = jax.random.fold_in(key, 1)
        batch["patch_embeds"] = (
            jax.random.normal(kp, (b, VISION_PREFIX_TOKENS, cfg.d_model), jnp.float32)
            * 0.02
        ).astype(jnp.bfloat16)
    if cfg.frontend == "audio":
        kf = jax.random.fold_in(key, 2)
        batch["frames"] = (
            jax.random.normal(kf, (b, s, cfg.d_model), jnp.float32) * 0.02
        ).astype(jnp.bfloat16)
    return batch


def batch_iterator(
    cfg: ModelConfig, shape: ShapeConfig, *, start_step: int = 0,
    dcfg: DataConfig = DataConfig(), **kw,
) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield make_batch(cfg, shape, step, dcfg=dcfg, **kw)
        step += 1
