"""Deterministic, shardable synthetic data pipeline.

Every batch is a pure function of (seed, step) — the property fault
tolerance relies on: a restart at step k regenerates exactly the batches a
healthy run would have seen, with zero pipeline state to checkpoint. Tokens
follow a Zipfian unigram mixed with a hidden Markov structure so the LM loss
actually has signal to descend (integration tests assert loss decreases).

The same contract covers the clustering workload: ``point_chunks`` generates
a massive point cloud as a pure function of (seed, chunk index), and
``stream_to_mesh`` feeds those host-sized chunks shard-by-shard onto the
``data`` mesh axis — each device slab is placed as soon as it fills, so no
host- or device-side buffer ever holds the full dataset (DESIGN.md §4.4).
The result reuses the validity-mask padding scheme of the ITIS levels.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.frontends import VISION_PREFIX_TOKENS


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    n_states: int = 16          # HMM hidden states
    zipf_a: float = 1.3


def _batch_key(seed: int, step: int) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def synth_tokens(key, batch: int, seq: int, vocab: int, dcfg: DataConfig) -> jax.Array:
    """Markov-modulated Zipf tokens (b, s+1): learnable structure, stateless."""
    k1, k2, k3 = jax.random.split(key, 3)
    # hidden state per position: slow random walk
    steps = jax.random.bernoulli(k1, 0.1, (batch, seq + 1)).astype(jnp.int32)
    state = jnp.cumsum(steps, axis=1) % dcfg.n_states
    # per-state vocab offset makes next-token statistics state-dependent
    ranks = jax.random.pareto(k2, dcfg.zipf_a, (batch, seq + 1))
    base = jnp.clip(ranks * 7.0, 0, vocab // 2 - 1).astype(jnp.int32)
    offset = (state * (vocab // (2 * dcfg.n_states))).astype(jnp.int32)
    toks = (base + offset) % vocab
    return toks


def make_batch(
    cfg: ModelConfig,
    shape: ShapeConfig,
    step: int,
    *,
    dcfg: DataConfig = DataConfig(),
    batch_override: Optional[int] = None,
    seq_override: Optional[int] = None,
) -> Dict[str, jax.Array]:
    """Training batch for any arch family at `step` (pure function)."""
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    key = _batch_key(dcfg.seed, step)
    toks = synth_tokens(key, b, s, cfg.vocab_size, dcfg)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend == "vision":
        kp = jax.random.fold_in(key, 1)
        batch["patch_embeds"] = (
            jax.random.normal(kp, (b, VISION_PREFIX_TOKENS, cfg.d_model), jnp.float32)
            * 0.02
        ).astype(jnp.bfloat16)
    if cfg.frontend == "audio":
        kf = jax.random.fold_in(key, 2)
        batch["frames"] = (
            jax.random.normal(kf, (b, s, cfg.d_model), jnp.float32) * 0.02
        ).astype(jnp.bfloat16)
    return batch


def batch_iterator(
    cfg: ModelConfig, shape: ShapeConfig, *, start_step: int = 0,
    dcfg: DataConfig = DataConfig(), **kw,
) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield make_batch(cfg, shape, step, dcfg=dcfg, **kw)
        step += 1


# ---------------------------------------------------------------------------
# Massive point streams for the clustering pipeline
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PointStreamConfig:
    """A deterministic synthetic point cloud, generated chunk by chunk.

    ``kind="gmm"`` draws the paper's §4 mixture (3 bivariate Gaussians,
    weights .5/.3/.2, d forced to 2); ``kind="blobs"`` draws a ``k``-blob
    mixture in ``d`` dimensions (the Table-3 dataset analogs). Each chunk is
    a pure function of (seed, chunk index) — restartable, nothing to
    checkpoint, and chunks can be generated on different hosts.
    """
    n: int
    d: int = 2
    chunk: int = 65_536
    seed: int = 0
    kind: str = "gmm"
    k: int = 4


_GMM_MUS = np.array([[1, 2], [7, 8], [3, 5]], float)
_GMM_SDS = np.array([[1, 0.5], [2, 1], [3, 4]], float) ** 0.5


def point_chunk(cfg: PointStreamConfig, chunk_idx: int) -> np.ndarray:
    """Chunk ``chunk_idx`` of the stream (pure function; float32 (c, d))."""
    start = chunk_idx * cfg.chunk
    c = min(cfg.chunk, cfg.n - start)
    if c <= 0:
        return np.zeros((0, cfg.d), np.float32)
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, chunk_idx]))
    if cfg.kind == "gmm":
        comp = rng.choice(3, size=c, p=[0.5, 0.3, 0.2])
        x = _GMM_MUS[comp] + rng.normal(size=(c, 2)) * _GMM_SDS[comp]
    elif cfg.kind == "blobs":
        centers_rng = np.random.default_rng(cfg.seed)  # shared across chunks
        centers = centers_rng.normal(scale=4.0, size=(cfg.k, cfg.d))
        scales = centers_rng.uniform(0.5, 1.5, size=(cfg.k, cfg.d))
        comp = rng.integers(0, cfg.k, size=c)
        x = centers[comp] + rng.normal(size=(c, cfg.d)) * scales[comp]
    else:
        raise ValueError(f"unknown point-stream kind {cfg.kind!r}")
    return x.astype(np.float32)


def point_chunks(cfg: PointStreamConfig) -> Iterator[np.ndarray]:
    """All chunks of the stream, in order."""
    n_chunks = -(-cfg.n // cfg.chunk)
    for i in range(n_chunks):
        yield point_chunk(cfg, i)


def stream_to_mesh(
    chunks: Iterable[np.ndarray],
    mesh,
    n_total: int,
    d: int,
    *,
    axis_name: str = "data",
    pad_multiple: int = 0,
    dtype=jnp.float32,
) -> Tuple[jax.Array, jax.Array]:
    """Feed host-sized chunks onto the mesh without a full-size buffer.

    Fills one device-slab-sized host buffer at a time and places it on its
    device the moment it is full, then assembles the global row-sharded
    array with ``make_array_from_single_device_arrays``. Peak host memory is
    one slab + one chunk; peak per-device memory is one slab — so datasets
    larger than any single device's memory stream straight onto the mesh.

    Returns ``(x, valid)``: x is (n_pad, d) sharded ``P(axis_name, None)``,
    valid is the (n_pad,) row mask (padding rows False), the same scheme the
    ITIS level buffers use. ``pad_multiple`` defaults to the canonical
    reduction block count so the sharded ITIS driver needs no re-padding.
    """
    from repro.core.itis import round_up
    from repro.core.prototypes import REDUCE_BLOCKS

    p = mesh.shape[axis_name]
    mult = pad_multiple or max(REDUCE_BLOCKS, p)
    mult = round_up(mult, p)
    n_pad = round_up(n_total, mult)
    per = n_pad // p
    devices = list(np.asarray(mesh.devices).reshape(-1))

    x_shards, v_shards = [], []
    buf = np.zeros((per, d), np.float32)
    filled = 0

    def flush():
        nonlocal buf, filled
        dev = devices[len(x_shards)]
        row0 = len(x_shards) * per
        n_valid_rows = int(np.clip(n_total - row0, 0, per))
        v = np.zeros((per,), bool)
        v[:n_valid_rows] = True
        # device_put straight from the host numpy buffer: staging through
        # jnp.asarray would commit every slab to the default device first,
        # breaking the one-slab-per-device memory bound
        x_shards.append(jax.device_put(buf.astype(np.dtype(dtype)), dev))
        v_shards.append(jax.device_put(v, dev))
        buf = np.zeros((per, d), np.float32)
        filled = 0

    seen = 0
    for chunk in chunks:
        chunk = np.asarray(chunk, np.float32)
        pos = 0
        while pos < len(chunk):
            take = min(per - filled, len(chunk) - pos)
            buf[filled:filled + take] = chunk[pos:pos + take]
            filled += take
            pos += take
            seen += take
            if filled == per:
                flush()
    if seen != n_total:
        raise ValueError(f"stream yielded {seen} rows, expected {n_total}")
    while len(x_shards) < p:  # trailing padding slabs
        flush()

    x_sharding = NamedSharding(mesh, P(axis_name, None))
    v_sharding = NamedSharding(mesh, P(axis_name))
    x = jax.make_array_from_single_device_arrays((n_pad, d), x_sharding,
                                                 x_shards)
    valid = jax.make_array_from_single_device_arrays((n_pad,), v_sharding,
                                                     v_shards)
    return x, valid
