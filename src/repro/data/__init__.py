"""Data path: deterministic synthetic pipeline + ITIS instance selection."""
from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    PointStreamConfig,
    batch_iterator,
    make_batch,
    point_chunk,
    point_chunks,
    stream_to_mesh,
)
