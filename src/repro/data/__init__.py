"""Data path: deterministic synthetic pipeline + ITIS instance selection."""
from repro.data.pipeline import DataConfig, batch_iterator, make_batch  # noqa: F401
