"""Threshold Clustering (TC) — Higgins et al. (2016), TPU-native.

TC partitions n points into clusters of size ≥ t* while 4-approximating the
bottleneck (max within-cluster dissimilarity) objective:

  1. build the (t*−1)-NN graph ``NG``;
  2. pick seeds ``S``: a maximal independent set of ``NG²`` (no two seeds
     within graph distance 2; every non-seed within distance 2 of a seed);
  3. grow: cluster(seed) = seed + its NG-neighbours;
  4. assign each remaining unit (distance exactly 2 from ≥1 seed) to the seed
     with the smallest *direct* dissimilarity.

Hardware adaptation (see DESIGN.md §2): the paper's greedy sequential seed
scan is replaced by a **deterministic Luby/Blelloch parallel MIS** — every
active vertex draws a fixed random priority (rank of a hashed permutation);
a vertex becomes a seed iff its priority is the maximum over its *closed
2-hop* neighbourhood of active vertices; selected seeds deactivate their
2-hop neighbourhood; repeat until no vertex is active. O(log n) rounds w.h.p.
and every round is dense vectorized gather/scatter over a fixed-shape (n, k)
adjacency — exactly what a TPU wants. The 4-approximation proof only needs
*maximality + independence* of the seed set, both of which are invariants of
any MIS, so the bound is preserved (property-tested in
tests/test_tc_properties.py).

The undirected kNN graph is stored as the directed (n, k) index array plus
implicit reverse edges, handled by the gather (out) + scatter (in) pair in
``_push_max``. All ops are mask-aware so TC composes with padded/masked ITIS
iterations under fixed XLA shapes.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import runtime
from repro.core.knn import knn_graph, knn_graph_blocked, resolve_auto_block

_NEG = jnp.int32(-1)  # priorities are ranks in [0, n); -1 == "-inf"


class TCResult(NamedTuple):
    labels: jax.Array       # (n,) int32 cluster id in [0, n_clusters), -1 invalid
    seed_of: jax.Array      # (n,) int32 vertex index of the owning seed, -1 invalid
    is_seed: jax.Array      # (n,) bool
    n_clusters: jax.Array   # () int32


def _push_max(p: jax.Array, idx: jax.Array, idx_ok: jax.Array) -> jax.Array:
    """max over *undirected* neighbours of p (edges = directed idx ∪ reverse).

    p: (n,) int32 with -1 as -inf; idx: (n, k) int32 (-1 = no edge);
    idx_ok: (n, k) bool.
    """
    n = p.shape[0]
    safe = jnp.where(idx_ok, idx, 0)
    out_vals = jnp.where(idx_ok, p[safe], _NEG)          # gather: i <- p[nbr]
    out_max = jnp.max(out_vals, axis=1, initial=_NEG)
    src_vals = jnp.where(idx_ok, p[:, None], _NEG)       # scatter: nbr <- p[i]
    in_max = jnp.full((n,), _NEG).at[safe.ravel()].max(src_vals.ravel())
    return jnp.maximum(out_max, in_max)


def _closed2_max(p: jax.Array, idx: jax.Array, idx_ok: jax.Array) -> jax.Array:
    """max of p over the closed ≤2-hop neighbourhood of each vertex."""
    q1 = jnp.maximum(p, _push_max(p, idx, idx_ok))
    return jnp.maximum(q1, _push_max(q1, idx, idx_ok))


def luby_mis_rounds(priorities: jax.Array, active0: jax.Array, closed2_max) -> jax.Array:
    """Maximal independent set via parallel local-maxima rounds.

    ``closed2_max(p)`` must return, per vertex, the max of ``p`` over that
    vertex's closed ≤2-hop neighbourhood. The single-device path passes
    :func:`_closed2_max` over the local (n, k) adjacency; the sharded path
    (repro.core.distributed) passes a cross-shard pmax-combining operator —
    both run the *same* round structure here, which is what keeps the two
    executions seed-set-identical (DESIGN.md §4.2).
    """

    def cond(state):
        active, _ = state
        return jnp.any(active)

    def body(state):
        active, seed = state
        p_eff = jnp.where(active, priorities, _NEG)
        m2 = closed2_max(p_eff)
        newly = active & (p_eff == m2)
        seed = seed | newly
        # deactivate the closed 2-hop neighbourhood of the new seeds
        b = jnp.where(newly, jnp.int32(1), jnp.int32(0))
        covered = closed2_max(b) > 0
        active = active & ~covered & ~newly
        return active, seed

    # derive from active0 (not a fresh constant) so the carry keeps the same
    # varying-manual-axes type under shard_map
    seed0 = active0 & False
    _, seed = jax.lax.while_loop(cond, body, (active0, seed0))
    return seed


def _luby_mis_sq(
    priorities: jax.Array, idx: jax.Array, idx_ok: jax.Array, active0: jax.Array
) -> jax.Array:
    """MIS of NG² on one device: local-adjacency ``closed2`` plug-in."""
    return luby_mis_rounds(
        priorities, active0, lambda p: _closed2_max(p, idx, idx_ok)
    )


def seed_priorities(key: jax.Array, n: int) -> jax.Array:
    """Fixed random priorities: ranks of a hashed permutation (deterministic).

    Shared by the single-device and sharded TC paths — identical keys and
    buffer sizes give identical priorities, hence identical MIS seed sets.
    """
    u = jax.random.uniform(key, (n,))
    order = jnp.argsort(u)
    return jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))


def _sq_dist_rows(x: jax.Array, i_rows: jax.Array, j_rows: jax.Array) -> jax.Array:
    """||x[i] - x[j]||² for index arrays of equal shape (computed in f32)."""
    a = x[i_rows].astype(jnp.float32)
    b = x[j_rows].astype(jnp.float32)
    return jnp.sum(jnp.square(a - b), axis=-1)


def threshold_clustering(
    x: jax.Array,
    t: int,
    *,
    valid: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    knn_block: Optional[int] = None,
) -> TCResult:
    """Run TC with minimum cluster size ``t`` on (n, d) points.

    ``valid`` masks padded rows (ITIS levels); invalid rows get label -1 and
    transmit no graph edges. ``knn_block`` > 0 selects the blocked kNN path.
    ``impl``/``knn_block`` default to the active runtime config (DESIGN.md
    §10) — resolved *before* the jit boundary so the compiled-cache key
    always carries the concrete values. Deterministic given ``key``
    (default: PRNGKey(0)).
    """
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    knn_block = cfg.knn_block if knn_block is None else knn_block
    return _threshold_clustering(x, t, valid=valid, key=key, impl=impl,
                                 knn_block=knn_block,
                                 _dispatch=cfg.dispatch_key())


@functools.partial(
    jax.jit, static_argnames=("t", "impl", "knn_block", "_dispatch")
)
def _threshold_clustering(
    x: jax.Array,
    t: int,
    *,
    valid: Optional[jax.Array],
    key: Optional[jax.Array],
    impl: str,
    knn_block: int,
    _dispatch: tuple = (),  # cache-key pin for trace-time config reads (§10)
) -> TCResult:
    n = x.shape[0]
    if valid is None:
        # derived from x (not a fresh constant) so TC composes with shard_map
        # (keeps the varying-manual-axes type); x==x is all-true for finite x
        valid = x[:, 0] == x[:, 0]
    if key is None:
        key = jax.random.PRNGKey(0)

    if t <= 1:  # degenerate: singletons
        labels = jnp.where(valid, jnp.cumsum(valid) - 1, -1).astype(jnp.int32)
        seed_of = jnp.where(valid, jnp.arange(n), -1).astype(jnp.int32)
        return TCResult(labels, seed_of, valid, jnp.sum(valid).astype(jnp.int32))

    k = t - 1
    # auto: avoid O(n²) HBM at scale (tuned winner when the policy is on;
    # trace-time read, pinned by the _dispatch static above)
    block = knn_block or resolve_auto_block(n, x.shape[1], k,
                                            dtype=str(x.dtype))
    if n > block:
        _, idx = knn_graph_blocked(x, k, valid=valid, block=block, impl=impl)
    else:
        _, idx = knn_graph(x, k, valid=valid, impl=impl)
    idx = jnp.where(valid[:, None], idx, -1)           # invalid rows: no out-edges
    idx_ok = idx >= 0                                   # kNN never returns invalid keys

    priorities = seed_priorities(key, n)

    is_seed = _luby_mis_sq(priorities, idx, idx_ok, valid)

    # ---- grow: each vertex adjacent to a seed joins that seed (unique by MIS) ----
    n_arange = jnp.arange(n, dtype=jnp.int32)
    safe = jnp.where(idx_ok, idx, 0)
    out_lab = jnp.max(
        jnp.where(idx_ok & is_seed[safe], safe, -1), axis=1, initial=_NEG
    )  # i's out-neighbour that is a seed
    src = jnp.where(idx_ok & is_seed[:, None], n_arange[:, None], -1)
    in_lab = jnp.full((n,), _NEG).at[safe.ravel()].max(src.ravel())
    seed_of = jnp.maximum(out_lab, in_lab)
    seed_of = jnp.where(is_seed, n_arange, seed_of)

    # ---- assign leftovers (graph distance exactly 2) to nearest seed ----
    labeled = seed_of >= 0
    # out-direction candidates: s = seed_of[out-neighbour]
    cand_out = jnp.where(idx_ok, seed_of[safe], -1)                   # (n, k)
    cand_ok = cand_out >= 0
    d_out = jnp.where(
        cand_ok,
        _sq_dist_rows(x, jnp.broadcast_to(n_arange[:, None], cand_out.shape),
                      jnp.where(cand_ok, cand_out, 0)),
        jnp.inf,
    )
    best_out_d = jnp.min(d_out, axis=1)
    best_out_s = jnp.where(
        jnp.isfinite(best_out_d),
        jnp.take_along_axis(cand_out, jnp.argmin(d_out, axis=1)[:, None], axis=1)[:, 0],
        -1,
    )
    # in-direction: edge (v -> i): candidate seed_of[v] at distance ||x_i - x_seed||
    s_v = jnp.broadcast_to(seed_of[:, None], idx.shape)               # (n, k)
    edge_ok = idx_ok & (s_v >= 0)
    d_edge = jnp.where(
        edge_ok, _sq_dist_rows(x, safe, jnp.where(edge_ok, s_v, 0)), jnp.inf
    )
    tgt = safe.ravel()
    d_in = jnp.full((n,), jnp.inf).at[tgt].min(
        jnp.where(edge_ok, d_edge, jnp.inf).ravel()
    )
    winners = edge_ok & (d_edge <= d_in[safe])
    s_in = jnp.full((n,), _NEG).at[tgt].max(jnp.where(winners, s_v, -1).ravel())

    use_out = best_out_d <= d_in
    fallback = jnp.where(use_out, best_out_s, s_in)
    seed_of = jnp.where(labeled, seed_of, fallback)
    seed_of = jnp.where(valid, seed_of, -1)

    # ---- compact cluster ids: rank among seeds ----
    seed_rank = (jnp.cumsum(is_seed.astype(jnp.int32)) - 1).astype(jnp.int32)
    labels = jnp.where(seed_of >= 0, seed_rank[jnp.where(seed_of >= 0, seed_of, 0)], -1)
    n_clusters = jnp.sum(is_seed).astype(jnp.int32)
    return TCResult(labels.astype(jnp.int32), seed_of.astype(jnp.int32),
                    is_seed, n_clusters)
