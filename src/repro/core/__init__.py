"""The paper's primary contribution: TC / ITIS / IHTC, TPU-native in JAX.

``repro.core.plan.fit`` (re-exported as ``repro.fit``) is the single entry
point over every execution strategy; the per-strategy drivers survive as
deprecation aliases.
"""
from repro.core.distributed import (  # noqa: F401
    ihtc_sharded,
    itis_sharded,
    kmeans_sharded,
    make_data_mesh,
    tc_sharded,
)
from repro.core.ihtc import IHTCResult, ihtc  # noqa: F401
from repro.core.index import ClusterIndex, nearest_valid_prototype  # noqa: F401
from repro.core.itis import (  # noqa: F401
    ITISResult,
    itis,
    itis_step,
    level_sizes,
    validate_reduction_params,
)
from repro.core.knn import knn_graph, knn_graph_blocked, ring_knn  # noqa: F401
from repro.core.plan import (  # noqa: F401
    FitPlan,
    FitResult,
    LabelSpill,
    Reduction,
    available_executors,
    execute_plan,
    fit,
    plan_fit,
    register_executor,
    resolve_executor,
)
from repro.core.prototypes import (  # noqa: F401
    PrototypeSet,
    compose_assignments,
    reduce_to_prototypes,
    standardize,
)
from repro.core.streaming import (  # noqa: F401
    StreamingIHTCResult,
    ihtc_streaming,
)
from repro.core.tc import TCResult, threshold_clustering  # noqa: F401
