"""End-to-end sharded ITIS / IHTC over the ``data`` mesh axis.

Every ITIS level runs inside one ``shard_map`` program per level shape:

  1. **TC** — the kNN graph is built with :func:`repro.core.knn.ring_knn`
     (keys rotate around the ring, global neighbour indices), the
     Luby/Blelloch MIS runs the *same* round structure as the single-device
     path (:func:`repro.core.tc.luby_mis_rounds`) with a cross-shard
     ``closed2`` operator: each shard computes its local gather/scatter
     contribution over its (n_local, k) adjacency slice and the per-vertex
     max is combined with ``lax.pmax`` (ints — exact, order-free). Leftover
     units are assigned to their nearest seed using a replicated
     seed-coordinate table (built by exact psum-scatter of each shard's seed
     rows) plus a second ring pass that carries each shard's point block past
     every shard so in-edge distances ``||x_i − x_seed||²`` are evaluated
     where the edge lives. The large O(n·(d+k)) state — points, kNN graph,
     distance blocks — stays sharded; only O(n)-bit label/priority vectors
     and the O(n/t · d) seed table (= the *next* level's point set) are
     replicated.
  2. **Prototype reduce + rebalance** — per-shard blocked segment-sums are
     all-gathered and folded left-to-right in canonical block order
     (mirroring ``ops.blocked_segment_sum`` exactly), then each shard keeps
     its contiguous slice of the level-(l+1) buffer, so the next level stays
     evenly sharded in its static padded buffer.
  3. **Backend** — a mesh-aware weighted k-means: centroids (k, d) are
     replicated, rows stay sharded, assignment statistics are combined with
     the same ordered all-gather fold, and k-means++ draws from all-gathered
     global logits. The point set is never gathered to one device.

Determinism contract (DESIGN.md §4.3): every cross-shard combination is
either an exact operation (int/bool ``pmax``/``pmin``, float ``min``/``max``,
psum of disjoint one-hot contributions) or a float accumulation folded in the
canonical ``n_blocks`` order that the single-device path also uses. When the
level buffer sizes of :func:`repro.core.itis.level_sizes` already divide
evenly by the shard count (so no extra padding changes TC's priority draw),
``ihtc_sharded`` is **bit-identical** to single-device ``ihtc`` — asserted on
an 8-device CPU mesh in tests/test_distribution.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import runtime
from repro.cluster.registry import BackendFn
from repro.core.itis import ITISResult, level_sizes, validate_reduction_params
from repro.core.knn import _axis_size, ring_knn
from repro.core.plan import (
    FitPlan,
    FitResult,
    Reduction,
    fit,
    register_executor,
)
from repro.core.tc import _NEG, luby_mis_rounds, seed_priorities
from repro.kernels import ops


def make_data_mesh(n_data: Optional[int] = None):
    """1-D ``("data",)`` mesh over the first ``n_data`` (default all) devices."""
    devices = jax.devices()
    n = n_data or len(devices)
    return jax.sharding.Mesh(devices[:n], ("data",))


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map with replication checking off (the MIS while-loop has no
    replication rule on jax 0.4.x; correctness is covered by the parity
    tests instead)."""
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# ---------------------------------------------------------------------------
# sharded TC (runs inside shard_map)
# ---------------------------------------------------------------------------


def _gather1d(x_local: jax.Array, axis_name: str) -> jax.Array:
    """(n_local, ...) → replicated (n, ...) in shard order (exact copy)."""
    return jax.lax.all_gather(x_local, axis_name, tiled=True)


def _local_rows(vec: jax.Array, row0: jax.Array, n_local: int) -> jax.Array:
    """My shard's contiguous slice of a replicated per-vertex vector."""
    return jax.lax.dynamic_slice_in_dim(vec, row0, n_local, axis=0)


def tc_sharded(
    x_local: jax.Array,
    valid_local: jax.Array,
    t: int,
    key: jax.Array,
    *,
    axis_name: str,
    impl: Optional[str] = None,
):
    """Global TC on row-sharded points; returns (labels (n,) replicated,
    is_seed (n,) replicated, n_clusters ()).

    Computes the same function as single-device ``threshold_clustering`` on
    the concatenated rows — same kNN graph (ring pass), same MIS rounds,
    same leftover tie-breaking — with only per-vertex vectors replicated.
    """
    n_local, d = x_local.shape
    p = _axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    n = n_local * p
    row0 = me * n_local
    rows = row0 + jnp.arange(n_local, dtype=jnp.int32)

    valid = _gather1d(valid_local, axis_name)  # (n,) replicated

    if t <= 1:  # degenerate: singletons (replicated compute on (n,) bools)
        labels = jnp.where(valid, jnp.cumsum(valid) - 1, -1).astype(jnp.int32)
        is_seed = valid
        return labels, is_seed, jnp.sum(valid).astype(jnp.int32)

    k = t - 1
    _, idx = ring_knn(x_local, k, axis_name=axis_name, valid=valid_local,
                      impl=impl)
    idx = jnp.where(valid_local[:, None], idx, -1)  # invalid rows: no out-edges
    idx_ok = idx >= 0
    safe = jnp.where(idx_ok, idx, 0)

    def push_max(pvec):
        # max over undirected neighbours, assembled from this shard's directed
        # edge slice and combined across shards with an exact integer pmax.
        out_max = jnp.max(jnp.where(idx_ok, pvec[safe], _NEG), axis=1,
                          initial=_NEG)                      # (n_local,)
        part = jnp.full((n,), _NEG).at[rows].set(out_max)
        src = jnp.where(idx_ok, _local_rows(pvec, row0, n_local)[:, None], _NEG)
        part = part.at[safe.ravel()].max(src.ravel())
        return jax.lax.pmax(part, axis_name)

    def closed2(pvec):
        q1 = jnp.maximum(pvec, push_max(pvec))
        return jnp.maximum(q1, push_max(q1))

    priorities = seed_priorities(key, n)  # replicated; identical to 1-device
    is_seed = luby_mis_rounds(priorities, valid, closed2)

    # ---- grow: each vertex adjacent to a seed joins that seed ----
    n_arange = jnp.arange(n, dtype=jnp.int32)
    out_lab = jnp.max(jnp.where(idx_ok & is_seed[safe], safe, -1), axis=1,
                      initial=_NEG)
    part = jnp.full((n,), _NEG).at[rows].set(out_lab)
    src = jnp.where(idx_ok & is_seed[rows][:, None], rows[:, None], -1)
    part = part.at[safe.ravel()].max(src.ravel())
    seed_of = jax.lax.pmax(part, axis_name)
    seed_of = jnp.where(is_seed, n_arange, seed_of)

    # ---- leftover assignment: nearest seed at graph distance 2 ----
    labeled = seed_of >= 0
    seed_rank = (jnp.cumsum(is_seed.astype(jnp.int32)) - 1).astype(jnp.int32)
    n_seed_max = max(n // t, 1)  # TC guarantee: ≤ n/t disjoint size-≥t clusters

    # replicated seed-coordinate table: exact psum of disjoint one-hot rows
    slot = jnp.where(is_seed[rows], seed_rank[rows], n_seed_max)
    stbl = jnp.zeros((n_seed_max + 1, d), jnp.float32)
    stbl = stbl.at[slot].set(x_local.astype(jnp.float32))
    stbl = jax.lax.psum(stbl.at[n_seed_max].set(0.0), axis_name)

    def seed_coord(seed_vertex, ok):
        r = jnp.where(ok, seed_rank[jnp.where(ok, seed_vertex, 0)], n_seed_max)
        return stbl[r]

    # out-direction: my rows against their out-neighbours' seeds
    cand_out = jnp.where(idx_ok, seed_of[safe], -1)                 # (nl, k)
    cand_ok = cand_out >= 0
    a = x_local.astype(jnp.float32)[:, None, :]
    d_out = jnp.where(
        cand_ok,
        jnp.sum(jnp.square(a - seed_coord(cand_out, cand_ok)), axis=-1),
        jnp.inf,
    )
    best_out_d = jnp.min(d_out, axis=1)                             # (nl,)
    best_out_s = jnp.where(
        jnp.isfinite(best_out_d),
        jnp.take_along_axis(cand_out, jnp.argmin(d_out, axis=1)[:, None],
                            axis=1)[:, 0],
        -1,
    )

    # in-direction: edge (v -> i) carries candidate seed_of[v]; the distance
    # ||x_i - x_seed||² needs x_i, which lives on i's shard — a second ring
    # pass rotates every point block past every shard so each edge is
    # evaluated exactly once, where the edge (not the point) lives.
    s_v = jnp.broadcast_to(seed_of[rows][:, None], idx.shape)       # (nl, k)
    edge_ok = idx_ok & (s_v >= 0)
    c_coord = seed_coord(s_v, edge_ok)                              # (nl, k, d)
    perm = [(i, (i - 1) % p) for i in range(p)]

    def ring_body(s, carry):
        d_edge, xblk = carry
        blk = (me + s) % p  # owner of the visiting block
        in_blk = edge_ok & (safe // n_local == blk)
        pos = jnp.where(in_blk, safe - blk * n_local, 0)
        tgt_coord = xblk[pos].astype(jnp.float32)                   # (nl, k, d)
        de = jnp.sum(jnp.square(tgt_coord - c_coord), axis=-1)
        d_edge = jnp.where(in_blk, de, d_edge)
        return d_edge, jax.lax.ppermute(xblk, axis_name, perm)

    d_edge0 = jnp.full(idx.shape, jnp.inf, jnp.float32)
    d_edge, _ = jax.lax.fori_loop(0, p, ring_body, (d_edge0, x_local))

    part_d = jnp.full((n,), jnp.inf).at[safe.ravel()].min(
        jnp.where(edge_ok, d_edge, jnp.inf).ravel())
    d_in = jax.lax.pmin(part_d, axis_name)                          # exact
    winners = edge_ok & (d_edge <= d_in[safe])
    part_s = jnp.full((n,), _NEG).at[safe.ravel()].max(
        jnp.where(winners, s_v, -1).ravel())
    s_in = jax.lax.pmax(part_s, axis_name)

    # assemble the per-row out-direction winners into replicated vectors
    pd = jax.lax.pmin(jnp.full((n,), jnp.inf).at[rows].set(best_out_d),
                      axis_name)
    ps_ = jax.lax.pmax(jnp.full((n,), _NEG).at[rows].set(best_out_s),
                       axis_name)
    use_out = pd <= d_in
    fallback = jnp.where(use_out, ps_, s_in)
    seed_of = jnp.where(labeled, seed_of, fallback)
    seed_of = jnp.where(valid, seed_of, -1)

    labels = jnp.where(seed_of >= 0,
                       seed_rank[jnp.where(seed_of >= 0, seed_of, 0)], -1)
    return labels.astype(jnp.int32), is_seed, jnp.sum(is_seed).astype(jnp.int32)


# ---------------------------------------------------------------------------
# sharded prototype reduce (ordered-fold twin of ops.blocked_segment_sum)
# ---------------------------------------------------------------------------


def _folded_segment_sum(x_local, ids_local, n_out, weights_local, *,
                        axis_name, n_blocks, impl):
    """Cross-shard segment sum in the canonical ``n_blocks`` fold order.

    Each of P shards computes its ``n_blocks / P`` per-block partials; the
    all-gathered (n_blocks, ...) stack is folded left-to-right — bitwise the
    same accumulation as ``ops.blocked_segment_sum(n_blocks=...)`` over the
    concatenated rows (requires P | n_blocks and n_blocks | n, which the
    driver's level padding guarantees).
    """
    p = _axis_size(axis_name)
    sub = n_blocks // p
    nl = x_local.shape[0]
    pad = (-nl) % sub
    if pad:  # right-pad with dropped ids, like ops.blocked_segment_sum
        x_local = jnp.pad(x_local, ((0, pad), (0, 0)))
        ids_local = jnp.pad(ids_local, (0, pad), constant_values=n_out)
        if weights_local is not None:
            weights_local = jnp.pad(weights_local, (0, pad))
    nb = (nl + pad) // sub
    parts = []
    for b in range(sub):
        sl = slice(b * nb, (b + 1) * nb)
        parts.append(ops.segment_sum(
            x_local[sl], ids_local[sl], n_out,
            weights=None if weights_local is None else weights_local[sl],
            impl=impl))
    sums = jnp.stack([s for s, _ in parts])          # (sub, n_out, d)
    masses = jnp.stack([m for _, m in parts])        # (sub, n_out)
    sums = _gather1d(sums, axis_name)                # (n_blocks, n_out, d)
    masses = _gather1d(masses, axis_name)
    acc_s, acc_m = sums[0], masses[0]
    for b in range(1, n_blocks):                     # left fold in block order
        acc_s = acc_s + sums[b]
        acc_m = acc_m + masses[b]
    return acc_s, acc_m


def _reduce_sharded(x_local, labels_local, n_out, *, weights_local, weighted,
                    axis_name, n_blocks, impl):
    """Sharded twin of ``reduce_to_prototypes``: replicated (n_out, d) result."""
    safe_labels = jnp.where(labels_local >= 0, labels_local, n_out).astype(jnp.int32)
    w = weights_local.astype(jnp.float32)
    if weighted:
        sums, denom = _folded_segment_sum(
            x_local, safe_labels, n_out, w,
            axis_name=axis_name, n_blocks=n_blocks, impl=impl)
        mass = denom
    else:
        ones = jnp.where(labels_local >= 0, 1.0, 0.0).astype(jnp.float32)
        sums, denom = _folded_segment_sum(
            x_local, safe_labels, n_out, ones,
            axis_name=axis_name, n_blocks=n_blocks, impl=impl)
        _, mass = _folded_segment_sum(
            jnp.zeros((x_local.shape[0], 1), x_local.dtype), safe_labels,
            n_out, w, axis_name=axis_name, n_blocks=n_blocks, impl=impl)
    protos = sums / jnp.maximum(denom, 1e-12)[:, None]
    valid = denom > 0
    protos = jnp.where(valid[:, None], protos, 0.0).astype(x_local.dtype)
    return protos, mass, valid


# ---------------------------------------------------------------------------
# per-level shard_map program
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("t", "n_out", "weighted", "impl", "n_blocks",
                     "axis_name", "mesh", "_dispatch"),
)
def _itis_level_sharded(x, mass, valid, key, *, t, n_out, weighted, impl,
                        n_blocks, axis_name, mesh, _dispatch=()):
    def level(x_local, mass_local, valid_local, key):
        n_local = x_local.shape[0]
        p = _axis_size(axis_name)
        me = jax.lax.axis_index(axis_name)
        labels, _, n_clusters = tc_sharded(
            x_local, valid_local, t, key, axis_name=axis_name, impl=impl)
        labels_local = _local_rows(labels, me * n_local, n_local)
        protos, pmass, pvalid = _reduce_sharded(
            x_local, labels_local, n_out, weights_local=mass_local,
            weighted=weighted, axis_name=axis_name, n_blocks=n_blocks,
            impl=impl)
        # rebalance: level l+1 stays evenly sharded — every shard keeps its
        # contiguous slice of the replicated fold result (an exact copy)
        npl = n_out // p
        sl = me * npl
        return (
            jax.lax.dynamic_slice_in_dim(protos, sl, npl, axis=0),
            jax.lax.dynamic_slice_in_dim(pmass, sl, npl, axis=0),
            jax.lax.dynamic_slice_in_dim(pvalid, sl, npl, axis=0),
            labels_local,
            n_clusters.reshape(1),
        )

    return _shard_map(
        level, mesh,
        in_specs=(P(axis_name, None), P(axis_name), P(axis_name), P()),
        out_specs=(P(axis_name, None), P(axis_name), P(axis_name),
                   P(axis_name), P(axis_name)),
    )(x, mass, valid, key)


# ---------------------------------------------------------------------------
# mesh-aware weighted k-means (replicated centroids, psum'd statistics)
# ---------------------------------------------------------------------------


def kmeans_sharded(
    x,
    k: int,
    *,
    valid,
    weights,
    key,
    mesh,
    axis_name: Optional[str] = None,
    iters: int = 100,
    tol: float = 1e-6,
    impl: Optional[str] = None,
    n_blocks: Optional[int] = None,
):
    """Sharded twin of ``repro.cluster.kmeans.kmeans`` (labels only).

    Rows stay sharded; the (k, d) centroids are replicated; Lloyd statistics
    are combined with the canonical ordered fold; k-means++ samples from
    all-gathered global logits. Bit-identical to the single-device k-means
    when the row count divides evenly into the canonical blocks.
    ``impl``/``axis_name``/``n_blocks`` default to the runtime config.
    """
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    axis_name = cfg.axis_name if axis_name is None else axis_name
    n_blocks = cfg.n_blocks if n_blocks is None else n_blocks
    return _kmeans_sharded(x, k, valid=valid, weights=weights, key=key,
                           mesh=mesh, axis_name=axis_name, iters=iters,
                           tol=tol, impl=impl, n_blocks=n_blocks,
                           _dispatch=cfg.dispatch_key())


@functools.partial(
    jax.jit,
    static_argnames=("k", "iters", "impl", "n_blocks", "axis_name", "mesh",
                     "_dispatch"),
)
def _kmeans_sharded(
    x,
    k: int,
    *,
    valid,
    weights,
    key,
    mesh,
    axis_name: str,
    iters: int,
    tol: float,
    impl: str,
    n_blocks: int,
    _dispatch: tuple = (),  # cache-key pin for trace-time config reads (§10)
):

    def body_fn(x_local, valid_local, w_local, key):
        n_local, d = x_local.shape
        me = jax.lax.axis_index(axis_name)
        rows = me * n_local + jnp.arange(n_local, dtype=jnp.int32)
        w = jnp.where(valid_local, w_local.astype(jnp.float32), 0.0)

        def global_pick(key, logits_local):
            return jax.random.categorical(key, _gather1d(logits_local,
                                                         axis_name))

        def gather_row(i):
            hit = (rows == i)[:, None]
            return jax.lax.psum(
                jnp.sum(jnp.where(hit, x_local, 0), axis=0), axis_name)

        # ---- k-means++ (mirrors _plus_plus_init) ----
        key0, key_loop = jax.random.split(key)
        first = global_pick(key0, jnp.log(jnp.maximum(w, 1e-30)))
        centers0 = jnp.zeros((k, d), x_local.dtype).at[0].set(gather_row(first))

        def ppbody(i, carry):
            centers, key = carry
            key, sub = jax.random.split(key)
            dist = ops.pairwise_sq_l2(x_local, centers, impl=impl)
            slot_ok = jnp.arange(k)[None, :] < i
            dmin = jnp.min(jnp.where(slot_ok, dist, jnp.inf), axis=1)
            nxt = global_pick(sub, jnp.log(jnp.maximum(w * dmin, 1e-30)))
            return centers.at[i].set(gather_row(nxt)), key

        centers, _ = jax.lax.fori_loop(1, k, ppbody, (centers0, key_loop))

        # ---- Lloyd (mirrors kmeans.body with folded statistics) ----
        def assign(centers):
            dist = ops.pairwise_sq_l2(x_local, centers, impl=impl)
            return (jnp.argmin(dist, axis=1).astype(jnp.int32),
                    jnp.min(dist, axis=1))

        def cond(state):
            _, _, delta, it = state
            return (delta > tol) & (it < iters)

        def body(state):
            centers, _, _, it = state
            lab, _ = assign(centers)
            lab_safe = jnp.where(valid_local, lab, k)
            sums, mass = _folded_segment_sum(
                x_local, lab_safe, k, w,
                axis_name=axis_name, n_blocks=n_blocks, impl=impl)
            new = jnp.where(
                (mass > 0)[:, None], sums / jnp.maximum(mass, 1e-30)[:, None],
                centers).astype(x_local.dtype)
            delta = jnp.max(jnp.sum(jnp.square(new - centers), axis=1))
            return new, lab, delta, it + 1

        lab0, _ = assign(centers)
        state = (centers, lab0, jnp.asarray(jnp.inf, jnp.float32),
                 jnp.asarray(0))
        centers, _, _, _ = jax.lax.while_loop(cond, body, state)
        labels, _ = assign(centers)
        return jnp.where(valid_local, labels, -1).astype(jnp.int32)

    return _shard_map(
        body_fn, mesh,
        in_specs=(P(axis_name, None), P(axis_name), P(axis_name), P()),
        out_specs=P(axis_name),
    )(x, valid, weights, key)


# ---------------------------------------------------------------------------
# host drivers (mirror itis()/ihtc() including their key sequences)
# ---------------------------------------------------------------------------


def _place(arr, mesh, axis_name, spec):
    return jax.device_put(arr, NamedSharding(mesh, spec))


def itis_sharded(
    x: jax.Array,
    t: int,
    m: int,
    *,
    mesh=None,
    axis_name: Optional[str] = None,
    weights: Optional[jax.Array] = None,
    valid: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    weighted: bool = False,
    impl: Optional[str] = None,
    min_points: int = 4,
    n_blocks: Optional[int] = None,
) -> ITISResult:
    """Multi-device twin of :func:`repro.core.itis.itis`.

    Level buffers are padded (validity-masked) to a multiple of the canonical
    reduction block count so every level splits evenly across shards; the key
    sequence and early-stop rule match the single-device driver exactly.
    ``impl``/``axis_name``/``mesh`` default to the active runtime config.

    ``valid`` marks pre-padded inputs (e.g. from ``data.stream_to_mesh``,
    which pads to the same multiple) — rows marked False never transmit graph
    edges or mass.
    """
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    axis_name = cfg.axis_name if axis_name is None else axis_name
    validate_reduction_params(t, m, n=x.shape[0], driver="itis_sharded")
    if mesh is None:
        mesh = cfg.mesh if cfg.mesh is not None else make_data_mesh()
    if key is None:
        key = jax.random.PRNGKey(0)
    p = mesh.shape[axis_name]
    if n_blocks is None:
        # smallest multiple of p that is >= the configured reduction width
        # (default: the canonical REDUCE_BLOCKS), so defaults work on any
        # device count; parity with the single-device path needs the widths
        # equal, which holds whenever p divides the configured width
        n_blocks = -(-max(cfg.n_blocks, p) // p) * p
    if n_blocks % p:
        raise ValueError(f"n_blocks={n_blocks} must be a multiple of the "
                         f"'{axis_name}' axis size {p}")

    n = x.shape[0]
    mass = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    if valid is None:
        valid = jnp.ones((n,), bool)
    mass = jnp.where(valid, mass, 0.0)
    sizes = level_sizes(n, t, m, multiple=n_blocks)
    if sizes[0] != n:
        pad = sizes[0] - n
        x = jnp.pad(x, ((0, pad), (0, 0)))
        mass = jnp.pad(mass, (0, pad))
        valid = jnp.pad(valid, (0, pad))

    cur_x = _place(x, mesh, axis_name, P(axis_name, None))
    cur_m = _place(mass, mesh, axis_name, P(axis_name))
    cur_v = _place(valid, mesh, axis_name, P(axis_name))

    assignments = []
    n_protos = jnp.sum(cur_v).astype(jnp.int32)
    for level in range(m):
        # repro: allow[HS202]: deliberate per-level sync — the early-exit floor is a host decision, m times per fit
        n_valid = int(jnp.sum(cur_v))
        if n_valid < max(min_points, 2 * t):
            break
        key, sub = jax.random.split(key)
        cur_x, cur_m, cur_v, assignment, ncs = _itis_level_sharded(
            cur_x, cur_m, cur_v, sub, t=t, n_out=sizes[level + 1],
            weighted=weighted, impl=impl, n_blocks=n_blocks,
            axis_name=axis_name, mesh=mesh, _dispatch=cfg.dispatch_key())
        assignments.append(assignment)
        n_protos = ncs[0]
    return ITISResult(cur_x, cur_m, cur_v, assignments, n_protos)


@register_executor("sharded")
def _execute_sharded(plan: FitPlan, x: jax.Array) -> Reduction:
    """Mesh data-movement strategy: every level buffer is padded to the
    plan's shard multiple and row-sharded over ``axis_name``; the points
    are never gathered to one device. The planner's epilogue keeps the
    ``kmeans`` backend on the mesh (:func:`kmeans_sharded`) and runs any
    other backend single-device on the already-reduced prototype set."""
    key_itis, _ = plan.split_keys()
    r = itis_sharded(
        x, plan.t, plan.m, mesh=plan.mesh, axis_name=plan.axis_name,
        weights=plan.weights, valid=plan.valid, key=key_itis,
        weighted=plan.weighted, impl=plan.impl,
        min_points=plan.min_points, n_blocks=plan.shard_multiple(),
    )
    return Reduction(
        protos=r.protos, mass=r.mass, valid=r.valid,
        n_prototypes=r.n_prototypes, assignments=r.assignments,
        n0=x.shape[0],
    )


def ihtc_sharded(
    x: jax.Array,
    t: int,
    m: int,
    backend: Union[str, BackendFn] = "kmeans",
    *,
    mesh=None,
    axis_name: Optional[str] = None,
    weights: Optional[jax.Array] = None,
    valid: Optional[jax.Array] = None,
    weighted: bool = False,
    use_mass_in_backend: bool = True,
    key: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    n_blocks: Optional[int] = None,
    **backend_kwargs,
) -> FitResult:
    """Multi-device twin of :func:`repro.core.ihtc.ihtc` (deprecated alias
    of ``repro.fit(..., executor="sharded")``).

    ``backend="kmeans"`` runs the mesh-aware k-means (prototypes stay
    sharded). Other backends resolve through the registry and fall back to
    the single-device implementation on the final prototype set — which is
    n/(t*)^m-sized, i.e. already reduced by ITIS; the raw points are still
    never gathered. ``impl``/``axis_name``/``mesh`` default to the active
    runtime config.
    """
    return fit(
        x, t, m, backend, executor="sharded",
        mesh=mesh, axis_name=axis_name, weights=weights, valid=valid,
        weighted=weighted, use_mass_in_backend=use_mass_in_backend, key=key,
        impl=impl, n_blocks=n_blocks, driver="ihtc_sharded",
        **backend_kwargs,
    )
