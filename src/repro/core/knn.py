"""kNN-graph construction — the computational bottleneck of TC.

The paper uses kd-trees (serial, pointer-chasing). The TPU-native strategy is
brute force on the MXU, organized three ways by scale:

  * ``knn_graph``          — one-shot, n ≲ 32k (full tile set in one call).
  * ``knn_graph_blocked``  — query blocks × key blocks with a running top-k
    merge; HBM traffic O(n·d + n·k), never materializes (n, n).
  * ``ring_knn``           — multi-device: keys rotate around the ``data``
    mesh axis via ``lax.ppermute`` (ring all-gather overlap pattern), each
    shard folds the visiting block into its running top-k. Weak-scales to
    arbitrary pod counts.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import runtime
from repro.kernels import ops
from repro.kernels.ref import merge_topk as _ref_merge_topk

# what knn_block == 0 ("auto") means for every blocked-kNN entry point:
# one-shot below this row count, blocks of this size above (the O(n²) HBM
# threshold of the one-shot path). With the tuning policy active
# (RuntimeConfig.tune, DESIGN.md §14) the measured winner for this
# hardware + shape bucket replaces the constant — see resolve_auto_block.
AUTO_KNN_BLOCK = 8192


def resolve_auto_block(n: int, d: int = 0, k: int = 0,
                       dtype: str = "float32") -> int:
    """What ``knn_block == 0`` ("auto") resolves to for an (n, d) problem:
    the tuning cache's measured winner when the policy is active and has
    one for this bucket, else the hand-picked ``AUTO_KNN_BLOCK``.

    ``dtype`` must be the data's element type so this lookup and
    ``plan_fit``'s (which freezes the same cell into the FitPlan) key the
    cache identically — a mismatch would make execution dispatch diverge
    from the plan. Safe at trace time: callers are jitted drivers whose
    static ``_dispatch`` key carries the tune mode + cache epoch, so a
    changed winner always retraces (§10/§14).
    """
    if runtime.active().tune != "off":
        from repro import tune  # lazy: no import cycle through core

        tuned = tune.tuned_params("knn_block", dtype=dtype, n=n, d=d, k=k)
        if tuned.get("knn_block"):
            return int(tuned["knn_block"])
    return AUTO_KNN_BLOCK


def _pvary(x: jax.Array, axis_name: str) -> jax.Array:
    """Mark a replicated value as device-varying along ``axis_name``.

    jax ≥ 0.5 has ``jax.lax.pvary`` for this; on older releases shard_map's
    replication checker accepts the value as-is, so identity is correct.
    """
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, (axis_name,)) if fn is not None else x


def _axis_size(axis_name: str) -> int:
    """Static size of a shard_map mesh axis (works back to jax 0.4.x).

    ``jax.lax.axis_size`` only exists on newer releases; ``psum`` of the
    literal 1 constant-folds to the same static int everywhere.
    """
    fn = getattr(jax.lax, "axis_size", None)
    return fn(axis_name) if fn is not None else jax.lax.psum(1, axis_name)


def knn_graph(
    x: jax.Array,
    k: int,
    *,
    valid: Optional[jax.Array] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Exact (dists, idx) of the k nearest valid neighbours of each row.

    ``k`` may exceed the number of *valid* rows — unfillable slots come back
    with ``inf`` distance and index ``-1`` — but not the buffer size ``n``
    (XLA's top_k would fail with an opaque shape error deep in the trace).
    """
    if k > x.shape[0]:
        raise ValueError(
            f"knn_graph: k={k} exceeds the number of rows n={x.shape[0]}; "
            f"slots beyond the valid count are padded with -1, but k itself "
            f"must be <= n")
    return ops.knn(x, k, valid=valid, exclude_self=True, impl=impl)


# canonical streaming top-k merge — now shared with the fused assign kernel,
# so its single home is the kernels package (core keeps the old name alive
# for the blocked/ring drivers and external importers)
_merge_topk = _ref_merge_topk


def knn_graph_blocked(
    x: jax.Array,
    k: int,
    *,
    valid: Optional[jax.Array] = None,
    block: Optional[int] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Blocked exact kNN for n beyond one-tile range.

    Streams key blocks against each query block and keeps a (block, k)
    running best list, so peak memory is O(block² + n·k). ``block`` defaults
    to the runtime config's ``knn_block`` (``resolve_auto_block`` when that
    is 0 = auto — the same resolution threshold_clustering uses).
    """
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    if block is None:
        block = cfg.knn_block or resolve_auto_block(
            x.shape[0], x.shape[1], k, dtype=str(x.dtype))
    return _knn_graph_blocked(x, k, valid=valid, block=block, impl=impl,
                              _dispatch=cfg.dispatch_key())


@functools.partial(
    jax.jit, static_argnames=("k", "block", "impl", "_dispatch")
)
def _knn_graph_blocked(
    x: jax.Array,
    k: int,
    *,
    valid: Optional[jax.Array],
    block: int,
    impl: str,
    _dispatch: tuple = (),  # cache-key pin for trace-time config reads (§10)
) -> Tuple[jax.Array, jax.Array]:
    n, _ = x.shape
    if valid is None:
        valid = jnp.ones((n,), bool)
    pad = (-n) % block
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    vp = jnp.pad(valid, (0, pad))
    npad = xp.shape[0]
    nq = npad // block

    xq = xp.reshape(nq, block, -1)

    def per_query_block(qi):
        q = xq[qi]
        q_gidx = qi * block + jnp.arange(block)

        if impl in ops._FUSED_IMPLS:
            # fused inner loop: the kernel streams key blocks itself and
            # takes the self-exclusion as a traced global-index array, so
            # the (block, block) distance tile never exists outside VMEM
            return ops.nearest_topk(
                q, xp, k, key_valid=vp, q_gidx=q_gidx.astype(jnp.int32),
                impl="fused")

        def body(kb, carry):
            bd, bi = carry
            keys = jax.lax.dynamic_slice_in_dim(xp, kb * block, block, axis=0)
            kval = jax.lax.dynamic_slice_in_dim(vp, kb * block, block, axis=0)
            d = ops.pairwise_sq_l2(q, keys, y_valid=kval, impl=impl)
            k_gidx = kb * block + jnp.arange(block)
            d = jnp.where(q_gidx[:, None] == k_gidx[None, :], jnp.inf, d)
            return _merge_topk(bd, bi, d, jnp.broadcast_to(k_gidx, d.shape), k)

        init = (
            jnp.full((block, k), jnp.inf, jnp.float32),
            jnp.full((block, k), -1, jnp.int32),
        )
        return jax.lax.fori_loop(0, nq, body, init)

    bd, bi = jax.lax.map(per_query_block, jnp.arange(nq))
    return bd.reshape(npad, k)[:n], bi.reshape(npad, k)[:n]


def ring_knn(
    x_local: jax.Array,
    k: int,
    *,
    axis_name: str,
    valid: Optional[jax.Array] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Sharded exact kNN inside ``shard_map``: keys rotate around the ring.

    Each of P shards holds ``x_local`` (n_local, d). At step s the shard
    computes distances of its queries against the visiting key block (which
    originated on shard ``(my_id + s) % P``), folds them into its running
    top-k with *global* indices, then forwards the block to the next shard.
    Communication: P-1 permutes of the key block = one all-gather's bytes,
    but overlapped with compute and never materialized on one device.
    """
    n_local = x_local.shape[0]
    if valid is None:
        valid = _pvary(jnp.ones((n_local,), bool), axis_name)
    p = _axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    perm = [(i, (i - 1) % p) for i in range(p)]  # block travels to lower rank

    def body(s, carry):
        bd, bi, keys, kval = carry
        src = (me + s) % p  # owner of the visiting block
        d = ops.pairwise_sq_l2(x_local, keys, y_valid=kval, impl=impl)
        q_gidx = me * n_local + jnp.arange(n_local)
        k_gidx = src * n_local + jnp.arange(n_local)
        d = jnp.where(q_gidx[:, None] == k_gidx[None, :], jnp.inf, d)
        bd, bi = _merge_topk(bd, bi, d, jnp.broadcast_to(k_gidx, d.shape), k)
        keys = jax.lax.ppermute(keys, axis_name, perm)
        kval = jax.lax.ppermute(kval, axis_name, perm)
        return bd, bi, keys, kval

    init = (
        _pvary(jnp.full((n_local, k), jnp.inf, jnp.float32), axis_name),
        _pvary(jnp.full((n_local, k), -1, jnp.int32), axis_name),
        x_local,
        valid,
    )
    bd, bi, _, _ = jax.lax.fori_loop(0, p, body, init)
    return bd, bi
