"""Fitted ClusterIndex — the reduced representation as a servable product.

The paper treats the prototype set as an intermediate: ITIS shrinks n units
to prototypes, a backend labels them, labels are backed out, done. But the
reduced representation is *exactly* what an online deployment needs (the
TeraHAC observation): the final prototypes + their backend labels are a
complete, tiny (n/(t*)^m-sized) classifier for new points. ``fit`` freezes
that artifact out of any :class:`repro.core.plan.FitResult` (every executor
returns the same canonical type); ``assign`` labels query batches
by nearest-valid-prototype lookup — a jitted streamed top-1 over the same
``ops.pairwise_sq_l2`` / running-best-list machinery the kNN graph builder
uses, dispatched under the runtime config, so the serving path exercises the
same kernels (and the same mesh) as the offline fit.

The index is a NamedTuple of arrays — a JAX pytree — so it passes straight
through jit/shard_map and can be checkpointed with any pytree saver.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro import runtime
from repro.cluster.registry import BackendFn
from repro.core.knn import _merge_topk
from repro.core.plan import FitResult
from repro.core.plan import fit as _fit
from repro.kernels import ops
from repro.kernels.fused_assign import (
    RESCORE_K,
    fused_topk,
    fused_topk_xla,
    quantize_keys,
    rescore_top1,
)


class ClusterIndex(NamedTuple):
    """Frozen artifact of an IHTC fit: everything ``assign`` needs, nothing
    sized O(n).

    The trailing optional fields are the freeze-time low-precision
    prototype buffers the quantized fused assign variants serve from
    (DESIGN.md §16): a bf16 copy and a per-feature int8 quantization.
    They default to ``None`` so hand-built five-field indexes keep
    working (the quantized impls then pack on the fly inside the jitted
    assign — correct, but re-done per compiled shape; ``from_result``
    packs once at freeze time instead)."""

    protos: jax.Array        # (n_max, d) final-level prototypes (padded)
    proto_mass: jax.Array    # (n_max,) original-unit mass per prototype
    proto_valid: jax.Array   # (n_max,) bool — real prototype vs padding
    proto_labels: jax.Array  # (n_max,) int32 backend labels (-1 = pad/noise)
    n_prototypes: jax.Array  # () int32 — valid count
    protos_bf16: Optional[jax.Array] = None  # (n_max, d) bf16 copy
    protos_q8: Optional[jax.Array] = None    # (n_max, d) int8 quantized
    q8_scale: Optional[jax.Array] = None     # (d,) f32 per-feature scale
    q8_zero: Optional[jax.Array] = None      # (d,) f32 per-feature zero pt

    @classmethod
    def build(
        cls,
        source: Any,
        t: Optional[int] = None,
        m: Optional[int] = None,
        backend: Union[str, BackendFn] = "kmeans",
        *,
        pack: bool = True,
        **fit_kwargs,
    ) -> "ClusterIndex":
        """The one constructor: build a servable index from whatever you
        have, dispatching on the input type exactly like ``repro.fit()``
        dispatches executors.

        ``source`` is one of:

        * a fitted :class:`repro.core.plan.FitResult` (any executor —
          every one returns the same canonical artifact): freeze it;
        * an existing :class:`ClusterIndex` (e.g. hand-built from five
          arrays, or loaded from an artifact store): (re)pack it;
        * raw data — a resident (n, d) array or any chunk iterable: run
          the planned fit (``t``/``m`` required; the planner picks the
          executor from the input type and the mesh, all dispatch knobs
          default to the runtime config, and every :func:`repro.fit`
          keyword is accepted) and freeze the result. Use ``repro.fit``
          directly when the per-point training labels are also needed —
          ``build`` keeps only the O(n/(t*)^m) index.

        ``pack=True`` (default) also freezes the bf16/int8 low-precision
        prototype buffers the quantized assign variants serve from
        (DESIGN.md §16) — assigns are bitwise-identical either way, the
        packed form just does the one-time quantization at freeze time
        instead of per compiled shape. This subsumes the former four-way
        constructor surface (``fit`` / ``fit_streaming`` / ``from_result``
        / ``with_packed_protos``), which survive as deprecated aliases.
        """
        if isinstance(source, FitResult):
            if t is not None or m is not None:
                raise TypeError(
                    "ClusterIndex.build: t/m only apply when building from "
                    "raw data; the FitResult already fixed them")
            idx = cls(
                protos=source.protos,
                proto_mass=source.proto_mass,
                proto_valid=source.proto_valid,
                proto_labels=source.proto_labels,
                n_prototypes=source.n_prototypes,
            )
            return idx._packed() if pack else idx
        if isinstance(source, ClusterIndex):
            if t is not None or m is not None:
                raise TypeError(
                    "ClusterIndex.build: t/m only apply when building from "
                    "raw data; the index is already fitted")
            return source._packed() if pack else source
        if t is None or m is None:
            raise TypeError(
                "ClusterIndex.build from raw data needs t and m (got "
                f"t={t!r}, m={m!r}); pass a FitResult to freeze an "
                "already-run fit")
        return cls.build(_fit(source, t, m, backend, **fit_kwargs),
                         pack=pack)

    def _packed(self) -> "ClusterIndex":
        """Precompute the bf16 copy and the per-feature int8 quantization
        of the prototype buffer (scale/zero-point over valid rows only).
        Freeze-time work so per-request assign only touches queries —
        ``precision="bfloat16"`` and the ``fused_bf16``/``fused_int8``
        impls serve straight from these buffers."""
        q8, scale, zero = quantize_keys(self.protos, self.proto_valid)
        return self._replace(
            protos_bf16=self.protos.astype(jnp.bfloat16),
            protos_q8=q8, q8_scale=scale, q8_zero=zero,
        )

    # ---- deprecated constructor aliases (the pre-build surface) -----------

    @classmethod
    def from_result(cls, result: FitResult) -> "ClusterIndex":
        """Deprecated alias of ``ClusterIndex.build(result)``."""
        warnings.warn(
            "ClusterIndex.from_result is deprecated; use "
            "ClusterIndex.build(result)", DeprecationWarning, stacklevel=2)
        return cls.build(result)

    def with_packed_protos(self) -> "ClusterIndex":
        """Deprecated alias of ``ClusterIndex.build(index)`` (repack)."""
        warnings.warn(
            "ClusterIndex.with_packed_protos is deprecated; use "
            "ClusterIndex.build(index)", DeprecationWarning, stacklevel=2)
        return self._packed()

    @classmethod
    def fit(
        cls,
        x,
        t: int,
        m: int,
        backend: Union[str, BackendFn] = "kmeans",
        **fit_kwargs,
    ) -> "ClusterIndex":
        """Deprecated alias of ``ClusterIndex.build(x, t, m, backend)``."""
        warnings.warn(
            "ClusterIndex.fit is deprecated; use "
            "ClusterIndex.build(x, t, m, backend)",
            DeprecationWarning, stacklevel=2)
        return cls.build(x, t, m, backend, **fit_kwargs)

    @classmethod
    def fit_streaming(
        cls,
        chunks,
        t: int,
        m: int,
        backend: Union[str, BackendFn] = "kmeans",
        **streaming_kwargs,
    ) -> "ClusterIndex":
        """Deprecated alias of ``ClusterIndex.build(chunks, t, m, backend)``
        (the planner already streams chunk iterables — with a mesh
        configured it composes the ``streaming_sharded`` executor)."""
        warnings.warn(
            "ClusterIndex.fit_streaming is deprecated; use "
            "ClusterIndex.build(chunks, t, m, backend)",
            DeprecationWarning, stacklevel=2)
        return cls.build(chunks, t, m, backend, **streaming_kwargs)

    @property
    def dim(self) -> int:
        return self.protos.shape[1]

    @property
    def n_valid(self) -> int:
        """Count of real (non-padding) prototypes. Forces a device sync —
        a host-side inspection helper, not for use inside traced code."""
        # repro: allow[HS202]: documented host inspection helper — the docstring above is the contract
        return int(jnp.sum(self.proto_valid))

    def check_servable(self, expect_dim: Optional[int] = None
                       ) -> "ClusterIndex":
        """Validate the artifact's internal consistency before serving.

        The serve front-ends install indexes atomically (DESIGN.md §15):
        a hot-swap must never expose a half-installed artifact, so this
        runs *before* the swap and raises ``ValueError`` on any
        structural inconsistency — mismatched array lengths, a
        non-2D prototype buffer, an out-of-range valid count, or (when
        ``expect_dim`` is given, e.g. the dim the tenant's live traffic
        already uses) a feature-dimension change. Returns ``self`` so
        installs can chain. A zero-valid index is structurally fine
        (assign labels everything -1, exercised in tier-1) — that is a
        policy decision for the installer, not a broken artifact.
        """
        if self.protos.ndim != 2:
            raise ValueError(
                f"servable index needs (n_max, d) prototypes, got shape "
                f"{tuple(self.protos.shape)}")
        n_max = self.protos.shape[0]
        for name in ("proto_mass", "proto_valid", "proto_labels"):
            arr = getattr(self, name)
            if arr.ndim != 1 or arr.shape[0] != n_max:
                raise ValueError(
                    f"servable index is inconsistent: {name} has shape "
                    f"{tuple(arr.shape)}, want ({n_max},) to match protos")
        n = int(self.n_prototypes)
        if not 0 <= n <= n_max:
            raise ValueError(
                f"servable index is inconsistent: n_prototypes={n} outside "
                f"[0, {n_max}]")
        if expect_dim is not None and self.dim != expect_dim:
            raise ValueError(
                f"index dim {self.dim} != expected dim {expect_dim} "
                f"(a tenant's feature dimension cannot change across "
                f"hot-swapped versions)")
        # optional packed buffers (None = pack on the fly) must mirror the
        # f32 buffer's geometry — a stale bf16/int8 copy from a different
        # prototype set would serve silently-wrong shortlists
        for name in ("protos_bf16", "protos_q8"):
            arr = getattr(self, name)
            if arr is not None and tuple(arr.shape) != tuple(self.protos.shape):
                raise ValueError(
                    f"servable index is inconsistent: {name} has shape "
                    f"{tuple(arr.shape)}, want {tuple(self.protos.shape)} "
                    f"to mirror protos")
        if self.protos_q8 is not None:
            for name in ("q8_scale", "q8_zero"):
                arr = getattr(self, name)
                if arr is None or tuple(arr.shape) != (self.dim,):
                    got = None if arr is None else tuple(arr.shape)
                    raise ValueError(
                        f"servable index is inconsistent: protos_q8 needs "
                        f"{name} of shape ({self.dim},), got {got}")
        return self

    def replicate(self, mesh) -> "ClusterIndex":
        """A copy of the index replicated across every device of ``mesh``
        (axis-independent — the index is small). Placing it once up front,
        e.g. at service warmup, keeps the per-request assign path free of
        host→device index transfers."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        return jax.device_put(self, NamedSharding(mesh, P()))

    def _is_replicated_on(self, mesh) -> bool:
        from jax.sharding import NamedSharding, PartitionSpec as P

        sh = getattr(self.protos, "sharding", None)
        return (isinstance(sh, NamedSharding) and sh.mesh == mesh
                and sh.spec == P())

    def assign(
        self,
        queries: jax.Array,
        *,
        impl: Optional[str] = None,
        block: int = 0,
        block_q: Optional[int] = None,
        block_k: Optional[int] = None,
        rescore_k: int = RESCORE_K,
        mesh=None,
        axis_name: Optional[str] = None,
    ) -> jax.Array:
        """Label ``queries`` (nq, d) by their nearest valid prototype.

        Returns (nq,) int32 labels (the backend label of the owning
        prototype; -1 only if the index has no valid prototypes or the
        owning prototype was labelled noise). ``block`` > 0 streams the
        prototype set in blocks of that size (running top-1 — O(nq·block)
        peak memory); 0 evaluates one (nq, n_max) tile. The fused impl
        family ignores ``block`` (it always streams) and tiles with
        ``block_q``/``block_k`` — explicit kwargs win over the tuned
        ``"assign"`` cell, which wins over the config constants. The
        quantized impls (``fused_bf16``/``fused_int8``) shortlist
        ``rescore_k`` candidates over the packed low-precision buffer and
        rescore the shortlist in exact f32.

        ``impl``/``mesh``/``axis_name``/precision come from the runtime
        config unless given: with a mesh, queries are right-padded to a
        shard multiple and sharded over ``axis_name`` while the (small)
        index is replicated (a no-op if :meth:`replicate` already placed
        it), so the identical jitted program serves single-device and pod
        deployments.
        """
        cfg = runtime.active()
        impl = cfg.impl if impl is None else impl
        mesh = cfg.mesh if mesh is None else mesh
        axis_name = cfg.axis_name if axis_name is None else axis_name
        index = self
        nq = queries.shape[0]
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            pad = (-nq) % mesh.shape[axis_name]
            if pad:  # any batch size serves; padded rows are sliced off
                queries = jnp.pad(queries, ((0, pad), (0, 0)))
            queries = jax.device_put(
                queries, NamedSharding(mesh, P(axis_name, None)))
            if not self._is_replicated_on(mesh):
                index = self.replicate(mesh)
        labels = _assign(index, queries, impl=impl, block=block,
                         block_q=block_q, block_k=block_k,
                         rescore_k=rescore_k, precision=cfg.precision,
                         _dispatch=cfg.dispatch_key())
        return labels[:nq]


def nearest_valid_prototype(
    queries: jax.Array,
    protos: jax.Array,
    valid: jax.Array,
    *,
    impl: Optional[str] = None,
    block: int = 0,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """(dist, proto_id) of each query's nearest valid prototype (-1 if none).

    The fused family dispatches to the streaming fused kernel (the
    distance block never materializes; ``block`` is ignored — the kernel
    streams unconditionally, tiled by ``block_q``/``block_k``). The
    composed paths are unchanged: the blocked one folds prototype blocks
    into a running best list with the same merge the blocked/ring kNN
    drivers use, so serving inherits their memory ceiling — O(nq·block)
    live distances regardless of n_max.
    """
    nq = queries.shape[0]
    n_max = protos.shape[0]
    r, tp = ops.resolve_nearest(impl, dtype=queries.dtype, nq=nq, p=n_max,
                                d=queries.shape[1], k=1)
    if r in ops._FUSED_IMPLS:
        bq = block_q if block_q is not None else tp.get("block_q")
        bk = block_k if block_k is not None else tp.get("block_k")
        bd, bi = ops.nearest_topk(queries, protos, 1, key_valid=valid,
                                  impl="fused", block_q=bq, block_k=bk)
        return bd[:, 0], bi[:, 0]
    if block and block < n_max:
        pad = (-n_max) % block
        pp = jnp.pad(protos, ((0, pad), (0, 0)))
        vv = jnp.pad(valid, (0, pad))
        nb = (n_max + pad) // block

        def body(b, carry):
            bd, bi = carry
            keys = jax.lax.dynamic_slice_in_dim(pp, b * block, block, axis=0)
            kval = jax.lax.dynamic_slice_in_dim(vv, b * block, block, axis=0)
            d = ops.pairwise_sq_l2(queries, keys, y_valid=kval, impl=impl)
            gidx = b * block + jnp.arange(block, dtype=jnp.int32)
            return _merge_topk(bd, bi, d, jnp.broadcast_to(gidx, d.shape), 1)

        init = (
            jnp.full((nq, 1), jnp.inf, jnp.float32),
            jnp.full((nq, 1), -1, jnp.int32),
        )
        bd, bi = jax.lax.fori_loop(0, nb, body, init)
        return bd[:, 0], bi[:, 0]

    d = ops.pairwise_sq_l2(queries, protos, y_valid=valid, impl=impl)
    dmin = jnp.min(d, axis=1)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    return dmin, jnp.where(jnp.isfinite(dmin), idx, -1)


@functools.partial(
    jax.jit, static_argnames=("impl", "block", "block_q", "block_k",
                              "rescore_k", "precision", "_dispatch")
)
def _assign(
    index: ClusterIndex,
    queries: jax.Array,
    *,
    impl: str,
    block: int,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    rescore_k: int = RESCORE_K,
    precision: str = "float32",
    _dispatch: tuple = (),  # cache-key pin for trace-time config reads (§10)
) -> jax.Array:
    nq, d = queries.shape
    n_max = index.protos.shape[0]
    r, tp = ops.resolve_nearest(impl, dtype=queries.dtype, nq=nq, p=n_max,
                                d=d, k=1)
    bq = block_q if block_q is not None else tp.get("block_q")
    bk = block_k if block_k is not None else tp.get("block_k")

    if r in ("fused_bf16", "fused_int8"):
        # quantized shortlist over the packed buffer, exact-f32 rescore
        # (DESIGN.md §16); missing buffers pack on the fly (hand-built
        # index) — from_result froze them so serving only touches queries
        kw = {}
        if r == "fused_int8":
            if index.protos_q8 is not None:
                keys = index.protos_q8
                kw = dict(keys_scale=index.q8_scale,
                          keys_zero=index.q8_zero)
            else:
                keys, scale, zero = quantize_keys(index.protos,
                                                  index.proto_valid)
                kw = dict(keys_scale=scale, keys_zero=zero)
            qq = queries
        else:
            keys = (index.protos_bf16 if index.protos_bf16 is not None
                    else index.protos.astype(jnp.bfloat16))
            qq = queries.astype(jnp.bfloat16)
        shortlist = max(1, min(rescore_k, n_max))
        if ops._use_pallas_fused():
            _, cand = fused_topk(qq, keys, shortlist, index.proto_valid,
                                 block_q=bq, block_k=bk,
                                 interpret=ops._interpret(), **kw)
        else:
            _, cand = fused_topk_xla(qq, keys, shortlist, index.proto_valid,
                                     block_k=bk, **kw)
        _, pid = rescore_top1(queries, index.protos, index.proto_valid, cand)
    else:
        protos = index.protos
        if precision == "bfloat16":
            # serve-side cast; distances still fold in f32. The prototype
            # side comes from the freeze-time packed buffer when present
            # (bitwise-identical to casting here) so per-request work only
            # touches the queries.
            queries = queries.astype(jnp.bfloat16)
            protos = (index.protos_bf16 if index.protos_bf16 is not None
                      else index.protos.astype(jnp.bfloat16))
        _, pid = nearest_valid_prototype(
            queries, protos, index.proto_valid, impl=r, block=block,
            block_q=bq, block_k=bk)
    safe = jnp.where(pid >= 0, pid, 0)
    return jnp.where(pid >= 0, index.proto_labels[safe], -1).astype(jnp.int32)
