"""Out-of-core streaming IHTC executors — clustering data that never fits
at once, on one device or on every device of a mesh.

The paper's whole premise is data too massive for k-means/HAC, yet the
resident-array executors require the full (n, d) array in device memory.
These executors close that gap with the reduce-then-cluster aggregation
strategy of the Data Nuggets / hierarchical-aggregation line of work: every
host chunk is collapsed to weighted prototypes by one jitted ITIS level,
the prototypes fold into a bounded device-side **reservoir**, and the
reservoir cascades through a further ITIS level whenever it fills. Peak
device memory is O(chunk + reservoir) — independent of n.

Since the planner/executor split (DESIGN.md §13) the stream loop lives here
ONCE, parameterized by a *placement strategy* — the only thing the two
executors disagree on:

  * ``streaming`` (:class:`_DevicePlacement`) — chunk buffers and the
    reservoir live on the default device; levels run through the jitted
    single-device :func:`repro.core.itis.itis_step`.
  * ``streaming_sharded`` (:class:`_MeshPlacement`) — the composed path
    neither PR's driver could reach: chunk buffers and the reservoir are
    **row-sharded over the mesh**, per-chunk reduces and cascades run
    through the sharded level step of :mod:`repro.core.distributed`, and
    the slab fold is a per-shard ``shard_map`` write at the frontier. Every
    device works on every chunk while per-device memory stays
    O((chunk + reservoir) / shards).

Execution plan (DESIGN.md §12–§13):

  * **level 0, per chunk** — every chunk is padded to the static
    ``chunk_n`` shape (rounded to the shard multiple under a mesh) and
    reduced by one ITIS level (one compiled program for the whole stream).
    The chunk→prototype assignment map spills to host for the back-out.
  * **reservoir fold** — each chunk's prototype slab lands at the
    reservoir's write frontier; the frontier advances by host arithmetic,
    so the chunk loop never synchronizes with the device.
  * **cascade** — when the next fold would overflow, one ITIS level over
    the whole reservoir compacts it to ``reservoir_n // t`` slots (or, with
    too few valid prototypes to reduce, an identity hole-compaction); the
    reservoir-wide assignment map spills to host.
  * **finalize** — after the stream, the occupied reservoir prefix runs the
    remaining ``m - 1`` ITIS levels (the in-memory key schedule and
    early-stop rule); the planner's epilogue labels the survivors.

Ingest pipeline (DESIGN.md §18): the loop above is additionally pipelined
when the plan asks for it. ``prefetch_depth >= 1`` starts a bounded
background prefetch thread that normalizes/validates chunk N+1..N+depth
and writes them into a rotating pool of preallocated host staging buffers
while chunk N's level/fold runs on device; ``donate_stream=True`` donates
the reservoir operands of the fold/cascade/compaction programs so the
reservoir updates in place instead of being copied O(reservoir) every
chunk; and the per-chunk assignment spills are deferred as device buffers
and drained to host in batches off the critical path. All three are pure
scheduling changes: the chunk key schedule is bound to the chunk *index*
(``fold_in(key_level0, chunk_idx)``), never to arrival order — the
consumer asserts indices arrive monotonically — so every prefetch depth
and donation setting is bit-identical to the ``prefetch_depth=0`` serial
loop.

Labels stream *back out* chunk-by-chunk through the spilled maps
(:class:`repro.core.plan.LabelSpill`), entirely in host numpy — the device
never holds an O(n) label array.

Parity contract (tested): when the stream presents the dataset as a single
level-0 buffer (one chunk with ``chunk_n == n``) and the reservoir never
overflows mid-level, the fold degenerates to an identity placement and
every subsequent level runs in the exact buffers, with the exact keys, of
the in-memory executor — labels, prototypes and masses are bit-identical to
``repro.fit(x)``. The same holds between ``streaming_sharded`` and the
plain ``streaming`` executor when every buffer size already divides the
shard multiple (the DESIGN.md §4.3 alignment condition), which is what the
executor-equivalence matrix in tests/test_distribution.py asserts.
Multi-chunk streams are a *different estimator of the same family* (level
0's TC graph cannot cross chunk boundaries), so they are held to the
pipeline's invariants (mass conservation, the (t*)^m size guarantee,
accuracy on the §4 mixture) rather than bitwise equality — DESIGN.md §12
spells out why.
"""
from __future__ import annotations

import functools
import queue
import threading
import time
from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.cluster.registry import BackendFn
from repro.core.itis import (
    ITISLevelOut,
    itis_step,
    level_sizes,
    round_up,
    validate_reduction_params,
)
from repro.core.plan import (
    FitPlan,
    FitResult,
    LabelSpill,
    Reduction,
    fit,
    register_executor,
)

# fold_in tag separating the cascade key stream from the per-chunk stream
_CASCADE_KEY_TAG = 0x7FFFFFFF

# deferred spill maps accumulated on device before one batched host drain
# (§18); bounds the device-side spill backlog to a constant independent of
# the stream length, so the O(chunk + reservoir) memory contract holds
_SPILL_DRAIN_BATCH = 16

# thread name of the background prefetcher — the fault tests key on it to
# prove a mid-stream failure reaps the thread
_PREFETCH_THREAD_NAME = "repro-ingest-prefetch"

# deprecation alias: every executor returns the canonical FitResult now
StreamingIHTCResult = FitResult


def _normalize_chunk(item, driver: str) -> Tuple[np.ndarray, int]:
    """Accept bare (c, d) arrays or ``(chunk, n_valid)`` pairs."""
    if isinstance(item, (tuple, list)) and len(item) == 2:
        arr, n_valid = item
        # repro: allow[HS201]: chunk ingest — stream chunks are host data by contract (§12), coerced once before any device work
        arr = np.asarray(arr, np.float32)
        n_valid = int(n_valid)
        if not 0 <= n_valid <= arr.shape[0]:
            raise ValueError(
                f"{driver}: chunk n_valid={n_valid} outside "
                f"[0, {arr.shape[0]}]")
        return arr, n_valid
    # repro: allow[HS201]: chunk ingest — stream chunks are host data by contract (§12), coerced once before any device work
    arr = np.asarray(item, np.float32)
    return arr, arr.shape[0]


def _validate_chunk(arr: np.ndarray, chunk_idx: int, chunk_n: int, d: int,
                    driver: str) -> None:
    """Shape checks every chunk passes in stream order — inline in the
    serial loop, on the prefetch thread when pipelined (the error then
    travels the queue and is re-raised at the chunk's stream position, so
    both modes fail with the identical exception)."""
    if arr.shape[0] > chunk_n:
        raise ValueError(
            f"{driver}: chunk {chunk_idx} has {arr.shape[0]} rows "
            f"> chunk_n={chunk_n}; re-chunk the stream or raise chunk_n")
    if arr.ndim != 2 or arr.shape[1] != d:
        raise ValueError(
            f"{driver}: chunk {chunk_idx} has shape {arr.shape}, "
            f"expected (<= {chunk_n}, {d})")


# ---------------------------------------------------------------------------
# host staging pool + background prefetcher (DESIGN.md §18)
# ---------------------------------------------------------------------------


class _PoolClosed(Exception):
    """Raised inside the prefetch thread when the consumer shut the pool
    down mid-stage — a silent exit signal, never user-visible."""


class _StagingPool:
    """Rotating pool of preallocated host staging buffers.

    Ownership protocol (§18): a buffer index travels
    stage → (queue) → consumer → ``release`` → back to the free list; at
    most one owner ever writes a buffer. ``stage`` blocks for a free
    buffer, waits out the previous tenant's device dependency (the placed
    chunk array — on backends where host→device copies may complete
    asynchronously, overwriting the source before the transfer lands would
    corrupt the in-flight chunk; by recycle time the copy is long done, so
    the wait is ~free), then overwrites: rows [0, r) copied, the stale
    tail [r, prev_fill) re-zeroed, rows beyond prev_fill untouched (still
    zero). The contents are therefore bit-identical to a fresh
    ``np.zeros`` + fill without the per-chunk allocation churn, and a
    chunk spanning the full buffer skips the zero-fill entirely.
    """

    def __init__(self, n_bufs: int, rows: int, d: int):
        self._bufs = [np.zeros((rows, d), np.float32) for _ in range(n_bufs)]
        self._fill = [0] * n_bufs
        self._free: queue.Queue = queue.Queue()
        for i in range(n_bufs):
            self._free.put((i, None))

    def stage(self, arr: np.ndarray,
              stop: Optional[threading.Event] = None) -> int:
        """Copy ``arr`` into a free buffer; returns the buffer index."""
        while True:
            try:
                i, dep = self._free.get(timeout=0.05)
                break
            except queue.Empty:
                if stop is not None and stop.is_set():
                    raise _PoolClosed()
        if dep is not None:
            # repro: allow[HS201]: staging-pool recycle (§18) — the retired chunk's host→device copy must land before its source buffer is overwritten; waited depth+2 chunks later, so the transfer is long complete
            jax.block_until_ready(dep)
        buf = self._bufs[i]
        r = arr.shape[0]
        if r:
            buf[:r] = arr
        if self._fill[i] > r:
            buf[r:self._fill[i]] = 0.0
        self._fill[i] = r
        return i

    def buffer(self, i: int) -> np.ndarray:
        return self._bufs[i]

    def release(self, i: int, dep=None) -> None:
        """Hand a buffer back; ``dep`` is the device array placed from it
        (the next ``stage`` of this buffer waits on it before writing)."""
        self._free.put((i, dep))


class _Prefetcher:
    """Bounded background ingest: normalizes + validates chunks in stream
    order, stages them into the pool, and hands ``(chunk_idx, buf_idx,
    n_valid)`` records to the consumer through a depth-limited queue — at
    most ``depth`` chunks ever sit staged ahead of the device. Errors
    travel in-band: a bad chunk enqueues its exception at its stream
    position, so the consumer finishes every earlier chunk and then raises
    exactly what the serial loop would have. ``close()`` is idempotent and
    exception-safe: it stops the thread (unblocking a pending put or
    stage) and joins it, so no fit ever leaks the thread or a staged
    buffer."""

    def __init__(self, it, pool: _StagingPool, *, driver: str, chunk_n: int,
                 d: int, depth: int, start_idx: int):
        self._pool = pool
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, args=(it, driver, chunk_n, d, start_idx),
            name=_PREFETCH_THREAD_NAME, daemon=True)
        self._thread.start()

    def _run(self, it, driver: str, chunk_n: int, d: int, idx: int) -> None:
        try:
            for item in it:
                if self._stop.is_set():
                    return
                arr, n_valid = _normalize_chunk(item, driver)
                _validate_chunk(arr, idx, chunk_n, d, driver)
                buf_i = (self._pool.stage(arr, stop=self._stop)
                         if n_valid > 0 else None)
                self._put(("chunk", idx, buf_i, n_valid))
                idx += 1
            self._put(("end", None, None, None))
        except _PoolClosed:
            pass  # consumer shut us down; nothing to deliver
        except BaseException as exc:  # noqa: BLE001 — delivered in-band
            self._put(("err", exc, None, None))

    def _put(self, item) -> None:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return
            except queue.Full:
                continue

    def get(self):
        """Next record, in stream order (blocks; the thread always closes
        the stream with an ``end`` or ``err`` record while it is alive)."""
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        while True:  # unblock a producer stuck on a full queue
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=10.0)


# ---------------------------------------------------------------------------
# the jitted reservoir programs — each in a donating and a non-donating
# flavour (§18: donation aliases the reservoir operands into the outputs so
# the update happens in place; donating and plain calls are different
# executables, hence separate jit wrappers, selected once per plan)
# ---------------------------------------------------------------------------


def _compact_impl(res_x, res_m, res_v):
    """Gather the valid reservoir rows to the front (an identity level: no
    reduction, just squeezing out the masked holes between slabs). Returns
    the compacted buffers plus the old-slot → new-slot assignment map, in
    the same format an ITIS level emits."""
    n = res_v.shape[0]
    rank = (jnp.cumsum(res_v) - 1).astype(jnp.int32)
    dst = jnp.where(res_v, rank, n)  # invalid rows: out of range, dropped
    new_x = jnp.zeros_like(res_x).at[dst].set(res_x, mode="drop")
    new_m = jnp.zeros_like(res_m).at[dst].set(res_m, mode="drop")
    new_v = jnp.zeros_like(res_v).at[dst].set(res_v, mode="drop")
    assignment = jnp.where(res_v, rank, -1)
    return new_x, new_m, new_v, assignment


_compact = jax.jit(_compact_impl)
_COMPACT = {False: _compact,
            True: jax.jit(_compact_impl, donate_argnums=(0, 1, 2))}


def _fold_impl(res_x, res_m, res_v, px, pm, pv, offset, _dispatch: tuple = ()):
    """Write one prototype slab at the reservoir frontier (traced offset:
    a single compiled program serves the whole stream)."""
    res_x = jax.lax.dynamic_update_slice(res_x, px, (offset, 0))
    res_m = jax.lax.dynamic_update_slice(res_m, pm, (offset,))
    res_v = jax.lax.dynamic_update_slice(res_v, pv, (offset,))
    return res_x, res_m, res_v


_fold = jax.jit(_fold_impl, static_argnames=("_dispatch",))
_FOLD = {False: _fold,
         True: jax.jit(_fold_impl, static_argnames=("_dispatch",),
                       donate_argnums=(0, 1, 2))}


def _pad_into_impl(res_x, res_m, res_v, px, pm, pv):
    """Cascade absorb: pad the reduced slab back up to reservoir size. The
    outputs have exactly the donated reservoir buffers' shapes/dtypes, so
    under donation XLA aliases them and the pad is an in-place write."""
    pad = res_x.shape[0] - px.shape[0]
    return (jnp.pad(px, ((0, pad), (0, 0))),
            jnp.pad(pm, (0, pad)),
            jnp.pad(pv, (0, pad)))


_PAD_INTO_DONATED = jax.jit(_pad_into_impl, donate_argnums=(0, 1, 2))


@functools.lru_cache(maxsize=None)
def _mesh_donating_jits(mesh, axis_name: str):
    """Donating mesh twins of the compaction and cascade-absorb programs.

    The plain mesh path runs the shared programs and re-pins the layout
    with ``device_put`` afterwards; a donating program cannot do that (the
    input buffers are gone), so these twins pin the reservoir layout with
    sharding constraints *inside* the jit — the outputs keep the exact
    sharded shapes of the donated operands, which is what makes the
    donation aliasable per shard. Cached per (mesh, axis): a fresh
    ``jax.jit`` wrapper per fit would defeat the compile cache."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    row = NamedSharding(mesh, P(axis_name, None))
    vec = NamedSharding(mesh, P(axis_name))
    pin = jax.lax.with_sharding_constraint

    def compact(res_x, res_m, res_v):
        new_x, new_m, new_v, assignment = _compact_impl(res_x, res_m, res_v)
        return (pin(new_x, row), pin(new_m, vec), pin(new_v, vec),
                assignment)

    def pad_into(res_x, res_m, res_v, px, pm, pv):
        pad = res_x.shape[0] - px.shape[0]
        return (pin(jnp.pad(px, ((0, pad), (0, 0))), row),
                pin(jnp.pad(pm, (0, pad)), vec),
                pin(jnp.pad(pv, (0, pad)), vec))

    return (jax.jit(compact, donate_argnums=(0, 1, 2)),
            jax.jit(pad_into, donate_argnums=(0, 1, 2)))


# ---------------------------------------------------------------------------
# placement strategies — the ONLY thing the two streaming executors differ on
# ---------------------------------------------------------------------------


class _DevicePlacement:
    """Single-device strategy: buffers live on the default device, levels
    run through the jitted single-device ``itis_step``."""

    def __init__(self, plan: FitPlan, d: int):
        self.plan = plan
        self.d = d
        self.mult = 1
        self.donate = plan.donate_stream

    def reservoir(self, n: int):
        return (jnp.zeros((n, self.d), jnp.float32),
                jnp.zeros((n,), jnp.float32),
                jnp.zeros((n,), bool))

    def place_chunk(self, buf: np.ndarray, n_valid: int):
        xj = jnp.asarray(buf)
        vj = jnp.arange(buf.shape[0]) < n_valid
        return xj, vj.astype(jnp.float32), vj

    def place_slab(self, px, pm, pv):
        """Raw host slab → device (replication is a no-op here)."""
        return jnp.asarray(px), jnp.asarray(pm), jnp.asarray(pv)

    def level_step(self, x, mass, valid, key, n_out: int) -> ITISLevelOut:
        p = self.plan
        return itis_step(
            x, mass, valid, p.t, key=key, weighted=p.weighted, impl=p.impl,
            knn_block=p.knn_block, n_out=n_out, n_blocks=p.n_blocks)

    def fold(self, res, px, pm, pv, offset: int):
        return _FOLD[self.donate](*res, px, pm, pv, jnp.int32(offset),
                                  _dispatch=runtime.dispatch_key())

    def compact(self, res):
        new_x, new_m, new_v, assignment = _COMPACT[self.donate](*res)
        return (new_x, new_m, new_v), assignment

    def absorb(self, out: ITISLevelOut, total_n: int, old_res):
        """New reservoir from a cascade output: the reduced slab padded
        back to reservoir size — into the donated old buffers when
        donation is on, a fresh padded copy otherwise (bit-identical)."""
        if self.donate:
            return _PAD_INTO_DONATED(*old_res, out.protos, out.mass,
                                     out.valid)
        pad = total_n - out.protos.shape[0]
        return (jnp.pad(out.protos, ((0, pad), (0, 0))),
                jnp.pad(out.mass, (0, pad)),
                jnp.pad(out.valid, (0, pad)))

    def prefix(self, res, frontier: int, size0: int):
        res_x, res_m, res_v = res
        return res_x[:size0], res_m[:size0], res_v[:size0]

    def clone(self, bufs):
        """Force fresh device buffers (a full-reservoir ``prefix`` slice is
        the *same* array object in jax, and a later donated fold would
        invalidate it — snapshots must outlive the live reservoir)."""
        return tuple(jnp.array(b) for b in bufs)


class _MeshPlacement:
    """Mesh strategy (the composed ``streaming_sharded`` executor): the
    reservoir and every chunk buffer are row-sharded over the plan's mesh
    axis, levels run through the sharded level step, and the slab fold is a
    per-shard masked write (each shard overwrites exactly its rows of the
    ``[offset, offset + slab)`` window from the replicated slab — no
    cross-shard traffic beyond replicating the already-reduced slab)."""

    def __init__(self, plan: FitPlan, d: int):
        from jax.sharding import NamedSharding, PartitionSpec as P

        self.plan = plan
        self.d = d
        self.mult = plan.shard_multiple()
        self.mesh = plan.mesh
        self.axis_name = plan.axis_name
        self.donate = plan.donate_stream
        self._row = NamedSharding(self.mesh, P(self.axis_name, None))
        self._vec = NamedSharding(self.mesh, P(self.axis_name))
        self._rep = NamedSharding(self.mesh, P())

    def _place(self, x, m, v):
        return (jax.device_put(x, self._row), jax.device_put(m, self._vec),
                jax.device_put(v, self._vec))

    def reservoir(self, n: int):
        return self._place(jnp.zeros((n, self.d), jnp.float32),
                           jnp.zeros((n,), jnp.float32),
                           jnp.zeros((n,), bool))

    def place_chunk(self, buf: np.ndarray, n_valid: int):
        vj = np.arange(buf.shape[0]) < n_valid
        return self._place(buf, vj.astype(np.float32), vj)

    def place_slab(self, px, pm, pv):
        """Replicate a slab over the mesh. ``device_put`` reshards
        device-resident slabs (cascade outputs, already committed jax
        arrays) device-to-device and takes raw host slabs directly — no
        ``jnp.asarray`` round trip through the default device."""
        return (jax.device_put(px, self._rep),
                jax.device_put(pm, self._rep),
                jax.device_put(pv, self._rep))

    def level_step(self, x, mass, valid, key, n_out: int) -> ITISLevelOut:
        from repro.core.distributed import _itis_level_sharded

        p = self.plan
        protos, pmass, pvalid, assignment, ncs = _itis_level_sharded(
            x, mass, valid, key, t=p.t, n_out=n_out, weighted=p.weighted,
            impl=p.impl, n_blocks=self.mult, axis_name=self.axis_name,
            mesh=self.mesh, _dispatch=runtime.dispatch_key())
        return ITISLevelOut(protos, pmass, pvalid, assignment, ncs[0])

    def fold(self, res, px, pm, pv, offset: int):
        px, pm, pv = self.place_slab(px, pm, pv)
        return _FOLD_SHARDED[self.donate](
            *res, px, pm, pv, jnp.int32(offset),
            slab_n=px.shape[0], axis_name=self.axis_name, mesh=self.mesh,
            _dispatch=runtime.dispatch_key())

    def compact(self, res):
        # _compact is exact (integer ranks + unique-index scatters), so
        # running it resident stays deterministic; the plain path re-pins
        # the layout afterwards, the donating twin pins it in-program
        if self.donate:
            cfn, _ = _mesh_donating_jits(self.mesh, self.axis_name)
            new_x, new_m, new_v, assignment = cfn(*res)
            return (new_x, new_m, new_v), assignment
        new_x, new_m, new_v, assignment = _compact(*res)
        return self._place(new_x, new_m, new_v), assignment

    def absorb(self, out: ITISLevelOut, total_n: int, old_res):
        if self.donate:
            _, pfn = _mesh_donating_jits(self.mesh, self.axis_name)
            return pfn(*old_res, out.protos, out.mass, out.valid)
        pad = total_n - out.protos.shape[0]
        return self._place(jnp.pad(out.protos, ((0, pad), (0, 0))),
                           jnp.pad(out.mass, (0, pad)),
                           jnp.pad(out.valid, (0, pad)))

    def prefix(self, res, frontier: int, size0: int):
        res_x, res_m, res_v = res
        pad = size0 - frontier
        return self._place(
            jnp.pad(res_x[:frontier], ((0, pad), (0, 0))),
            jnp.pad(res_m[:frontier], (0, pad)),
            jnp.pad(res_v[:frontier], (0, pad)))

    def clone(self, bufs):
        """Fresh buffers re-pinned to the reservoir layout (see the
        single-device twin: a zero-pad prefix can alias the live
        reservoir, which a later donated fold would invalidate)."""
        x, m, v = (jnp.array(b) for b in bufs)
        return self._place(x, m, v)


def _fold_sharded_impl(res_x, res_m, res_v, px, pm, pv, offset, *,
                       slab_n: int, axis_name: str, mesh,
                       _dispatch: tuple = ()):
    """Per-shard twin of :func:`_fold_impl`: every shard overwrites the
    rows of the global ``[offset, offset + slab_n)`` window it owns,
    reading from the replicated slab. One compiled program per slab shape
    serves the whole stream (the offset stays traced)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import _shard_map

    def body(rx, rm, rv, px, pm, pv, offset):
        nl = rx.shape[0]
        me = jax.lax.axis_index(axis_name)
        rel = me * nl + jnp.arange(nl, dtype=jnp.int32) - offset
        take = (rel >= 0) & (rel < slab_n)
        safe = jnp.clip(rel, 0, slab_n - 1)
        rx = jnp.where(take[:, None], px[safe], rx)
        rm = jnp.where(take, pm[safe], rm)
        rv = jnp.where(take, pv[safe], rv)
        return rx, rm, rv

    a = axis_name
    return _shard_map(
        body, mesh,
        in_specs=(P(a, None), P(a), P(a), P(), P(), P(), P()),
        out_specs=(P(a, None), P(a), P(a)),
    )(res_x, res_m, res_v, px, pm, pv, offset)


_fold_sharded = jax.jit(
    _fold_sharded_impl,
    static_argnames=("slab_n", "axis_name", "mesh", "_dispatch"))
_FOLD_SHARDED = {
    False: _fold_sharded,
    True: jax.jit(
        _fold_sharded_impl,
        static_argnames=("slab_n", "axis_name", "mesh", "_dispatch"),
        donate_argnums=(0, 1, 2)),
}


# executor name → placement strategy (the lifecycle layer resolves the
# plan's executor through this instead of reaching into the registry)
_PLACEMENTS = {"streaming": _DevicePlacement,
               "streaming_sharded": _MeshPlacement}


# ---------------------------------------------------------------------------
# the stream loop (once, for both executors) — a long-lived machine
# ---------------------------------------------------------------------------


class _StreamMachine:
    """The §12/§18 stream loop as a long-lived object.

    ``_run_stream`` used to be one closure-heavy function: geometry fixed
    from the first chunk, a consume/process/fold/cascade loop, a
    destructive end-of-stream finalize. The online lifecycle (DESIGN.md
    §19) needs the identical machinery to *outlive* a single fit —
    :class:`repro.serve.lifecycle.OnlineFitter` keeps folding observed
    chunks into the same bounded reservoir for the life of a deployment
    and re-finalizes on demand — so the loop's state (reservoir, frontier,
    spill lists, the index-bound key schedule) and its transitions
    (``consume`` / ``process`` / ``fold`` / ``cascade`` / ``finalize``)
    live here as methods instead of closures.

    Epilogue contract: ``finalize(snapshot=False)`` is exactly the old
    end-of-stream epilogue (the batch executors call it once and drop the
    machine). ``finalize(snapshot=True)`` is **non-destructive**: it
    drains the deferred-spill backlog, composes the back-out state over
    *copies* of the spill lists, clones the occupied reservoir prefix
    (a full-reservoir prefix slice aliases the live buffers, which the
    next donated fold would invalidate), and runs levels 1..m-1 from the
    stored level-1 chain key. The level keys are re-derived from the same
    stored key on every finalize — the schedule is a pure function of
    (reservoir state, plan key) — so a snapshot after zero further chunks
    is bit-identical to the FitResult the batch executor returns, and
    ingestion continues afterwards as if the snapshot never happened.
    """

    def __init__(self, plan: FitPlan, placement_cls, first_arr: np.ndarray):
        driver = plan.driver
        self.plan = plan
        self.driver = driver
        self.t, self.m = plan.t, plan.m
        self.floor = plan.reduction_floor()
        self.depth = plan.prefetch_depth
        key_itis, _ = plan.split_keys()
        # the in-memory key schedule: one split per level, level 0 first.
        # key_chain seeds levels 1..m-1 and is NOT consumed in place —
        # finalize re-splits from it every time (snapshot purity).
        self.key_chain, self.key_level0 = jax.random.split(key_itis)
        self.key_cascade = jax.random.fold_in(self.key_level0,
                                              _CASCADE_KEY_TAG)

        chunk_n = plan.chunk_n
        if not chunk_n:
            chunk_n = first_arr.shape[0]
            if chunk_n == 0:
                raise ValueError(
                    f"{driver}: cannot infer chunk_n from an empty first "
                    f"chunk; pass chunk_n= or configure runtime chunk_n")
        d = first_arr.shape[1] if first_arr.ndim == 2 else None
        if d is None:
            raise ValueError(f"{driver}: chunks must be 2-D (rows, d)")
        validate_reduction_params(self.t, self.m, n=chunk_n, min_m=1,
                                  driver=driver)
        self.chunk_n = chunk_n
        self.d = d

        self.placement = placement_cls(plan, d)
        mult = self.placement.mult
        self.mult = mult
        self.chunk_buf_n = round_up(chunk_n, mult)
        self.chunk_out = round_up(max(self.chunk_buf_n // self.t, 1), mult)
        # raw-fold slab for chunks too small to reduce (the in-memory
        # early-stop rule, applied per chunk): their valid prefix is copied
        # verbatim. Raw slabs enter the fold replicated, so they need no
        # shard padding.
        self.raw_len = min(chunk_n, self.floor)
        reservoir_n = plan.reservoir_n
        if not reservoir_n:
            # large enough for the feasibility bound below by construction,
            # including the compaction degradation case
            reservoir_n = max(4 * self.chunk_out, 2 * self.raw_len,
                              self.floor - 1 + max(self.chunk_out,
                                                   self.raw_len))
        reservoir_n = round_up(reservoir_n, mult)
        self.reservoir_n = reservoir_n
        self.cascade_out = round_up(max(reservoir_n // self.t, 1), mult)
        # feasibility up front, before any of the stream is consumed: an
        # overflow frees down to cascade_out (reduction) or, degraded, to at
        # most floor - 1 valid rows (compaction — too few valid prototypes
        # to reduce); the next slab may be a full chunk reduce (chunk_out
        # rows) or a raw tail (raw_len)
        post_overflow = max(self.cascade_out, self.floor - 1)
        if reservoir_n - post_overflow < max(self.chunk_out, self.raw_len):
            raise ValueError(
                f"{driver}: reservoir_n={reservoir_n} cannot absorb a "
                f"{max(self.chunk_out, self.raw_len)}-row slab right after "
                f"an overflow (which frees down to at most {post_overflow} "
                f"occupied slots); need reservoir_n - "
                f"max(reservoir_n//t, {self.floor - 1}) "
                f">= max(chunk_n//t, {self.raw_len})")

        # staging pool: `depth` chunks queued ahead + one being staged by
        # the producer + one still owned by the consumer; the serial loop
        # double-buffers so a recycled buffer never waits on its own
        # transfer
        self.pool = _StagingPool(self.depth + 2 if self.depth else 2,
                                 self.chunk_buf_n, d)

        self.res = self.placement.reservoir(reservoir_n)
        self.frontier = 0     # host-tracked write position (no device sync)
        self.n_cascades = 0

        self.chunk_assign: List[np.ndarray] = []
        self.chunk_offset: List[int] = []
        self.chunk_epoch: List[int] = []
        self.chunk_counts: List[int] = []
        self.maps: List[np.ndarray] = []
        self.spill_pending: List[int] = []  # chunk_assign slots on device
        self.ingest_wait_s = 0.0  # consumer time blocked on ingest
        self.loop_t0 = time.perf_counter()

    @classmethod
    def open_stream(cls, plan: FitPlan, chunks, placement_cls):
        """Peek the first chunk (it fixes the geometry), build the machine.

        Returns ``(machine, first, rest)``: feed them to :meth:`ingest` to
        run the stream loop exactly as the batch executors do.
        """
        it = iter(chunks)
        first = None
        for item in it:
            first = _normalize_chunk(item, plan.driver)
            break
        if first is None:
            raise ValueError(f"{plan.driver}: the chunk stream is empty")
        return cls(plan, placement_cls, first[0]), first, it

    @property
    def n_chunks(self) -> int:
        """Chunks consumed so far == the next chunk's key-schedule index."""
        return len(self.chunk_counts)

    @property
    def n_points(self) -> int:
        """Valid rows folded so far (host bookkeeping, no device sync)."""
        return int(sum(self.chunk_counts))

    # ---- the stream loop --------------------------------------------------

    def drain_spills(self) -> None:
        # deferred spill drain (§18): the per-chunk assignment maps were
        # enqueued as device buffers; copy them to host in one batch off
        # the per-chunk critical path, restoring the §12 forced-copy
        # contract before anything reads them
        for i in self.spill_pending:
            # repro: allow[HS201]: §12 spill — forced host copy (np.array, never a view) of the chunk assignment, batch-drained off the critical path (§18)
            self.chunk_assign[i] = np.array(self.chunk_assign[i])
        self.spill_pending.clear()

    def cascade(self) -> None:
        self.drain_spills()  # the cascade syncs anyway; clear the backlog
        # repro: allow[HS202]: deliberate per-cascade sync — compaction-vs-reduction is a host decision, once per reservoir fill, not per chunk
        occ_valid = int(jnp.sum(self.res[2]))
        if occ_valid < self.floor:
            # the frontier is exhausted but the slots are mostly masked
            # holes (slabs whose chunks produced very few clusters): too
            # few valid prototypes for a reduction level, so squeeze the
            # holes out instead — an identity level that frees the space
            # without collapsing anything
            self.res, assignment = self.placement.compact(self.res)
            # repro: allow[HS201]: §12 spill — forced host copy (np.array, never a view) of the per-level map
            self.maps.append(np.array(assignment))  # true host copy
            self.frontier = occ_valid
            return
        ck = jax.random.fold_in(self.key_cascade, self.n_cascades)
        out = self.placement.level_step(*self.res, key=ck,
                                        n_out=self.cascade_out)
        # repro: allow[HS201]: §12 spill — forced host copy (np.array, never a view) of the per-level map
        self.maps.append(np.array(out.assignment))  # true host copy
        self.res = self.placement.absorb(out, self.reservoir_n, self.res)
        self.frontier = self.cascade_out
        self.n_cascades += 1

    def fold(self, px, pm, pv, slab: int) -> int:
        if self.frontier + slab > self.reservoir_n:
            self.cascade()
        if self.frontier + slab > self.reservoir_n:
            raise ValueError(
                f"{self.driver}: a {slab}-row slab does not fit the "
                f"reservoir even after a cascade (frontier={self.frontier}, "
                f"reservoir_n={self.reservoir_n}); increase reservoir_n")
        offset = self.frontier
        self.res = self.placement.fold(self.res, px, pm, pv, offset)
        self.frontier += slab
        return offset

    def process(self, chunk_idx: int, buf_i: Optional[int],
                n_valid: int) -> None:
        """Device half of one chunk: place the staged buffer, reduce, fold,
        record the spill — identical for the serial and pipelined loops."""
        if n_valid == 0:  # nothing to cluster; keep chunk indexing aligned
            self.chunk_assign.append(
                np.full((self.chunk_buf_n,), -1, np.int32))
            self.chunk_offset.append(0)
            self.chunk_epoch.append(len(self.maps))
            self.chunk_counts.append(0)
            return
        buf = self.pool.buffer(buf_i)
        if n_valid < self.floor:
            # too small to reduce (the itis early-stop rule): fold the
            # valid prefix raw, with an identity assignment map
            pv = np.arange(self.raw_len) < n_valid
            px, pm, pv = self.placement.place_slab(
                buf[:self.raw_len], pv.astype(np.float32), pv)
            off = self.fold(px, pm, pv, self.raw_len)
            # release AFTER the fold that consumed the slab: the recycle
            # dep must be the consumer's output (res), not the placed
            # array — placement may hold a zero-copy view of the host
            # buffer, so "transfer done" is not "done reading"
            self.pool.release(buf_i, self.res[0])
            # epoch AFTER the fold: a cascade the fold itself triggered
            # must not apply to the slots it just wrote
            epoch = len(self.maps)
            ident = np.arange(self.chunk_buf_n, dtype=np.int32)
            self.chunk_assign.append(
                np.where(ident < n_valid, ident, -1).astype(np.int32))
            self.chunk_offset.append(off)
            self.chunk_epoch.append(epoch)
            self.chunk_counts.append(n_valid)
            return
        xj, mj, vj = self.placement.place_chunk(buf, n_valid)
        sub = self.key_level0 if chunk_idx == 0 else jax.random.fold_in(
            self.key_level0, chunk_idx)
        out = self.placement.level_step(xj, mj, vj, key=sub,
                                        n_out=self.chunk_out)
        # release AFTER the level step that consumed xj: the recycle dep
        # must be the consumer's output — ``place_chunk`` may hold a
        # zero-copy view of the host buffer, so blocking on the placed
        # array alone proves the transfer landed, not that the reduction
        # finished reading it
        self.pool.release(buf_i, out.protos)
        off = self.fold(out.protos, out.mass, out.valid, self.chunk_out)
        epoch = len(self.maps)  # after the fold — see the raw path above
        if self.depth:
            # deferred spill (§18): keep the map on device, drain in
            # batches — the cascade and the stream end drain the rest
            self.chunk_assign.append(out.assignment)
            self.spill_pending.append(len(self.chunk_assign) - 1)
            if len(self.spill_pending) >= _SPILL_DRAIN_BATCH:
                self.drain_spills()
        else:
            # repro: allow[HS201]: §12 spill — forced host copy (np.array, never a view) of the chunk assignment
            self.chunk_assign.append(np.array(out.assignment))  # host copy
        self.chunk_offset.append(off)
        self.chunk_epoch.append(epoch)
        self.chunk_counts.append(n_valid)

    def consume(self, arr: np.ndarray, n_valid: int, chunk_idx: int) -> None:
        """Serial (depth 0) path: validate, stage inline, process."""
        _validate_chunk(arr, chunk_idx, self.chunk_n, self.d, self.driver)
        buf_i = None
        if n_valid > 0:
            t0 = time.perf_counter()
            buf_i = self.pool.stage(arr)
            self.ingest_wait_s += time.perf_counter() - t0
        self.process(chunk_idx, buf_i, n_valid)

    def feed(self, item) -> int:
        """Push-style ingest (the online fitter): normalize one chunk and
        consume it at the next key-schedule index. Returns the number of
        valid rows folded."""
        arr, n_valid = _normalize_chunk(item, self.driver)
        self.consume(arr, n_valid, self.n_chunks)
        return n_valid

    def ingest(self, it, *, first=None) -> None:
        """Drain an iterator through the loop: serial at depth 0, through
        the bounded background prefetcher otherwise (DESIGN.md §18). The
        already-normalized ``first`` chunk (from :meth:`open_stream`) is
        always consumed inline — it fixed the geometry."""
        if first is not None:
            self.consume(*first, self.n_chunks)
        start = self.n_chunks
        if self.depth == 0:
            for chunk_idx, item in enumerate(it, start=start):
                t0 = time.perf_counter()
                arr, n_valid = _normalize_chunk(item, self.driver)
                self.ingest_wait_s += time.perf_counter() - t0
                self.consume(arr, n_valid, chunk_idx)
            return
        pf = _Prefetcher(it, self.pool, driver=self.driver,
                         chunk_n=self.chunk_n, d=self.d, depth=self.depth,
                         start_idx=start)
        try:
            expected = start
            while True:
                t0 = time.perf_counter()
                tag, a, b, c = pf.get()
                self.ingest_wait_s += time.perf_counter() - t0
                if tag == "end":
                    break
                if tag == "err":
                    raise a
                if a != expected:
                    # the chunk key schedule is index-bound; folding out of
                    # order would silently change the estimator
                    raise RuntimeError(
                        f"{self.driver}: prefetch delivered chunk {a}, "
                        f"expected {expected} — stream order violated")
                expected += 1
                self.process(a, b, c)
        finally:
            pf.close()

    # ---- the epilogue -----------------------------------------------------

    def finalize(self, *, snapshot: bool = False) -> Reduction:
        """Levels 1..m-1 on the occupied reservoir prefix + the back-out
        spill. ``snapshot=True`` leaves the machine ready for more chunks
        (see the class docstring for the purity contract)."""
        if self.frontier == 0:
            raise ValueError(
                f"{self.driver}: the stream contained no valid rows (every "
                f"chunk was empty or fully masked) — nothing to cluster")
        self.drain_spills()  # every spilled map back on host
        # snapshot composes over copies: the live lists keep growing as
        # ingestion continues, but the returned Reduction must be frozen
        chunk_assign = (list(self.chunk_assign) if snapshot
                        else self.chunk_assign)
        chunk_offset = (list(self.chunk_offset) if snapshot
                        else self.chunk_offset)
        chunk_epoch = list(self.chunk_epoch) if snapshot else self.chunk_epoch
        chunk_counts = (list(self.chunk_counts) if snapshot
                        else self.chunk_counts)
        maps = list(self.maps) if snapshot else self.maps
        ingest_stats = {
            "prefetch_depth": self.depth,
            "donate": bool(self.plan.donate_stream),
            "n_chunks": len(chunk_counts),
            "wall_s": time.perf_counter() - self.loop_t0,
            "ingest_wait_s": self.ingest_wait_s,
        }

        size0 = round_up(self.frontier, self.mult)
        sizes = (level_sizes(size0, self.t, self.m - 1, multiple=self.mult)
                 if self.m > 1 else [size0])
        buf_x, buf_m, buf_v = self.placement.prefix(self.res, self.frontier,
                                                    size0)
        if snapshot:
            # a full-reservoir prefix is the live buffers themselves (jax
            # returns the same array for a whole-array slice); the next
            # donated fold would invalidate them under the snapshot
            buf_x, buf_m, buf_v = self.placement.clone((buf_x, buf_m, buf_v))
        key_chain = self.key_chain  # never consumed in place: snapshot purity
        for level in range(self.m - 1):
            # repro: allow[HS202]: deliberate per-level sync — the §6 early-exit floor is a host decision, m-1 times per fit, stream loop is already drained
            n_valid = int(jnp.sum(buf_v))
            if n_valid < self.floor:
                break
            key_chain, sub = jax.random.split(key_chain)
            out = self.placement.level_step(buf_x, buf_m, buf_v, key=sub,
                                            n_out=sizes[level + 1])
            # repro: allow[HS201]: §12 spill — forced host copy (np.array, never a view) of the per-level map
            maps.append(np.array(out.assignment))  # true host copy
            buf_x, buf_m, buf_v = out.protos, out.mass, out.valid

        spill = LabelSpill(
            chunk_n=self.chunk_n, chunk_assign=chunk_assign,
            chunk_offset=chunk_offset, chunk_epoch=chunk_epoch,
            chunk_counts=chunk_counts, maps=maps,
            n_cascades=self.n_cascades, ingest_stats=ingest_stats,
        )
        return Reduction(
            protos=buf_x, mass=buf_m, valid=buf_v,
            n_prototypes=jnp.sum(buf_v).astype(jnp.int32), assignments=[],
            n0=spill.n_total, spill=spill,
        )


def _run_stream(plan: FitPlan, chunks, placement_cls) -> Reduction:
    """One-shot stream fit: open, drain, finalize (the batch executors)."""
    machine, first, rest = _StreamMachine.open_stream(plan, chunks,
                                                      placement_cls)
    machine.ingest(rest, first=first)
    return machine.finalize()


@register_executor("streaming")
def _execute_streaming(plan: FitPlan, chunks) -> Reduction:
    return _run_stream(plan, chunks, _DevicePlacement)


@register_executor("streaming_sharded")
def _execute_streaming_sharded(plan: FitPlan, chunks) -> Reduction:
    return _run_stream(plan, chunks, _MeshPlacement)


def ihtc_streaming(
    chunks,
    t: int,
    m: int,
    backend: Union[str, BackendFn] = "kmeans",
    *,
    chunk_n: Optional[int] = None,
    reservoir_n: Optional[int] = None,
    prefetch_depth: Optional[int] = None,
    donate_stream: Optional[bool] = None,
    weighted: bool = False,
    use_mass_in_backend: bool = True,
    key: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    knn_block: Optional[int] = None,
    n_blocks: Optional[int] = None,
    min_points: int = 4,
    **backend_kwargs,
) -> FitResult:
    """Fit IHTC over a chunk stream in O(chunk + reservoir) device memory
    (deprecated alias of ``repro.fit(..., executor="streaming")`` — the
    planner entry point also unlocks the composed ``streaming_sharded``
    executor when a mesh is configured; this alias stays pinned to the
    single-device executor for backward compatibility).

    ``chunks`` is any iterator of host chunks — bare (c, d) arrays (e.g.
    :func:`repro.data.pipeline.point_chunks`) or ``(chunk, n_valid)`` pairs
    for pre-padded buffers. Chunks may be ragged up to ``chunk_n`` rows;
    each is padded to the static ``chunk_n`` shape so the whole stream runs
    through one compiled level-0 program.

    ``chunk_n`` / ``reservoir_n`` default to the active runtime config
    (``REPRO_CHUNK_N`` / ``REPRO_RESERVOIR_N``); 0 = auto (the first
    chunk's row count, resp. ``4 * (chunk_n // t)``). ``prefetch_depth`` /
    ``donate_stream`` (``REPRO_PREFETCH_DEPTH`` / ``REPRO_DONATE_STREAM``)
    pipeline the ingest loop — see DESIGN.md §18; results are bit-identical
    at every setting. ``m >= 1`` is required: with m = 0 no reduction ever
    happens and the backend would need all n points at once — exactly what
    streaming exists to avoid.

    Returns the canonical :class:`repro.core.plan.FitResult`;
    ``labels_for(i)`` / ``iter_labels()`` stream the final labels back out,
    ``to_index()`` freezes the servable artifact. See the module docstring
    for the parity contract with the in-memory executor.
    """
    return fit(
        chunks, t, m, backend, executor="streaming",
        chunk_n=chunk_n, reservoir_n=reservoir_n,
        prefetch_depth=prefetch_depth, donate_stream=donate_stream,
        weighted=weighted,
        use_mass_in_backend=use_mass_in_backend, key=key, impl=impl,
        knn_block=knn_block, n_blocks=n_blocks, min_points=min_points,
        driver="ihtc_streaming", **backend_kwargs,
    )
