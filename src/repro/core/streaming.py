"""Out-of-core streaming IHTC executors — clustering data that never fits
at once, on one device or on every device of a mesh.

The paper's whole premise is data too massive for k-means/HAC, yet the
resident-array executors require the full (n, d) array in device memory.
These executors close that gap with the reduce-then-cluster aggregation
strategy of the Data Nuggets / hierarchical-aggregation line of work: every
host chunk is collapsed to weighted prototypes by one jitted ITIS level,
the prototypes fold into a bounded device-side **reservoir**, and the
reservoir cascades through a further ITIS level whenever it fills. Peak
device memory is O(chunk + reservoir) — independent of n.

Since the planner/executor split (DESIGN.md §13) the stream loop lives here
ONCE, parameterized by a *placement strategy* — the only thing the two
executors disagree on:

  * ``streaming`` (:class:`_DevicePlacement`) — chunk buffers and the
    reservoir live on the default device; levels run through the jitted
    single-device :func:`repro.core.itis.itis_step`.
  * ``streaming_sharded`` (:class:`_MeshPlacement`) — the composed path
    neither PR's driver could reach: chunk buffers and the reservoir are
    **row-sharded over the mesh**, per-chunk reduces and cascades run
    through the sharded level step of :mod:`repro.core.distributed`, and
    the slab fold is a per-shard ``shard_map`` write at the frontier. Every
    device works on every chunk while per-device memory stays
    O((chunk + reservoir) / shards).

Execution plan (DESIGN.md §12–§13):

  * **level 0, per chunk** — every chunk is padded to the static
    ``chunk_n`` shape (rounded to the shard multiple under a mesh) and
    reduced by one ITIS level (one compiled program for the whole stream).
    The chunk→prototype assignment map spills to host for the back-out.
  * **reservoir fold** — each chunk's prototype slab lands at the
    reservoir's write frontier; the frontier advances by host arithmetic,
    so the chunk loop never synchronizes with the device.
  * **cascade** — when the next fold would overflow, one ITIS level over
    the whole reservoir compacts it to ``reservoir_n // t`` slots (or, with
    too few valid prototypes to reduce, an identity hole-compaction); the
    reservoir-wide assignment map spills to host.
  * **finalize** — after the stream, the occupied reservoir prefix runs the
    remaining ``m - 1`` ITIS levels (the in-memory key schedule and
    early-stop rule); the planner's epilogue labels the survivors.

Labels stream *back out* chunk-by-chunk through the spilled maps
(:class:`repro.core.plan.LabelSpill`), entirely in host numpy — the device
never holds an O(n) label array.

Parity contract (tested): when the stream presents the dataset as a single
level-0 buffer (one chunk with ``chunk_n == n``) and the reservoir never
overflows mid-level, the fold degenerates to an identity placement and
every subsequent level runs in the exact buffers, with the exact keys, of
the in-memory executor — labels, prototypes and masses are bit-identical to
``repro.fit(x)``. The same holds between ``streaming_sharded`` and the
plain ``streaming`` executor when every buffer size already divides the
shard multiple (the DESIGN.md §4.3 alignment condition), which is what the
executor-equivalence matrix in tests/test_distribution.py asserts.
Multi-chunk streams are a *different estimator of the same family* (level
0's TC graph cannot cross chunk boundaries), so they are held to the
pipeline's invariants (mass conservation, the (t*)^m size guarantee,
accuracy on the §4 mixture) rather than bitwise equality — DESIGN.md §12
spells out why.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.cluster.registry import BackendFn
from repro.core.itis import (
    ITISLevelOut,
    itis_step,
    level_sizes,
    round_up,
    validate_reduction_params,
)
from repro.core.plan import (
    FitPlan,
    FitResult,
    LabelSpill,
    Reduction,
    fit,
    register_executor,
)

# fold_in tag separating the cascade key stream from the per-chunk stream
_CASCADE_KEY_TAG = 0x7FFFFFFF

# deprecation alias: every executor returns the canonical FitResult now
StreamingIHTCResult = FitResult


def _normalize_chunk(item, driver: str) -> Tuple[np.ndarray, int]:
    """Accept bare (c, d) arrays or ``(chunk, n_valid)`` pairs."""
    if isinstance(item, (tuple, list)) and len(item) == 2:
        arr, n_valid = item
        # repro: allow[HS201]: chunk ingest — stream chunks are host data by contract (§12), coerced once before any device work
        arr = np.asarray(arr, np.float32)
        n_valid = int(n_valid)
        if not 0 <= n_valid <= arr.shape[0]:
            raise ValueError(
                f"{driver}: chunk n_valid={n_valid} outside "
                f"[0, {arr.shape[0]}]")
        return arr, n_valid
    # repro: allow[HS201]: chunk ingest — stream chunks are host data by contract (§12), coerced once before any device work
    arr = np.asarray(item, np.float32)
    return arr, arr.shape[0]


@jax.jit
def _compact(res_x, res_m, res_v):
    """Gather the valid reservoir rows to the front (an identity level: no
    reduction, just squeezing out the masked holes between slabs). Returns
    the compacted buffers plus the old-slot → new-slot assignment map, in
    the same format an ITIS level emits."""
    n = res_v.shape[0]
    rank = (jnp.cumsum(res_v) - 1).astype(jnp.int32)
    dst = jnp.where(res_v, rank, n)  # invalid rows: out of range, dropped
    new_x = jnp.zeros_like(res_x).at[dst].set(res_x, mode="drop")
    new_m = jnp.zeros_like(res_m).at[dst].set(res_m, mode="drop")
    new_v = jnp.zeros_like(res_v).at[dst].set(res_v, mode="drop")
    assignment = jnp.where(res_v, rank, -1)
    return new_x, new_m, new_v, assignment


@functools.partial(jax.jit, static_argnames=("_dispatch",))
def _fold(res_x, res_m, res_v, px, pm, pv, offset, _dispatch: tuple = ()):
    """Write one prototype slab at the reservoir frontier (traced offset:
    a single compiled program serves the whole stream)."""
    res_x = jax.lax.dynamic_update_slice(res_x, px, (offset, 0))
    res_m = jax.lax.dynamic_update_slice(res_m, pm, (offset,))
    res_v = jax.lax.dynamic_update_slice(res_v, pv, (offset,))
    return res_x, res_m, res_v


# ---------------------------------------------------------------------------
# placement strategies — the ONLY thing the two streaming executors differ on
# ---------------------------------------------------------------------------


class _DevicePlacement:
    """Single-device strategy: buffers live on the default device, levels
    run through the jitted single-device ``itis_step``."""

    def __init__(self, plan: FitPlan, d: int):
        self.plan = plan
        self.d = d
        self.mult = 1

    def reservoir(self, n: int):
        return (jnp.zeros((n, self.d), jnp.float32),
                jnp.zeros((n,), jnp.float32),
                jnp.zeros((n,), bool))

    def place_chunk(self, buf: np.ndarray, n_valid: int):
        xj = jnp.asarray(buf)
        vj = jnp.arange(buf.shape[0]) < n_valid
        return xj, vj.astype(jnp.float32), vj

    def place_slab(self, px, pm, pv):
        """Raw host slab → device (replication is a no-op here)."""
        return jnp.asarray(px), jnp.asarray(pm), jnp.asarray(pv)

    def level_step(self, x, mass, valid, key, n_out: int) -> ITISLevelOut:
        p = self.plan
        return itis_step(
            x, mass, valid, p.t, key=key, weighted=p.weighted, impl=p.impl,
            knn_block=p.knn_block, n_out=n_out, n_blocks=p.n_blocks)

    def fold(self, res, px, pm, pv, offset: int):
        return _fold(*res, px, pm, pv, jnp.int32(offset),
                     _dispatch=runtime.dispatch_key())

    def compact(self, res):
        new_x, new_m, new_v, assignment = _compact(*res)
        return (new_x, new_m, new_v), assignment

    def pad_protos(self, out: ITISLevelOut, total_n: int):
        pad = total_n - out.protos.shape[0]
        return (jnp.pad(out.protos, ((0, pad), (0, 0))),
                jnp.pad(out.mass, (0, pad)),
                jnp.pad(out.valid, (0, pad)))

    def prefix(self, res, frontier: int, size0: int):
        res_x, res_m, res_v = res
        return res_x[:size0], res_m[:size0], res_v[:size0]


class _MeshPlacement:
    """Mesh strategy (the composed ``streaming_sharded`` executor): the
    reservoir and every chunk buffer are row-sharded over the plan's mesh
    axis, levels run through the sharded level step, and the slab fold is a
    per-shard masked write (each shard overwrites exactly its rows of the
    ``[offset, offset + slab)`` window from the replicated slab — no
    cross-shard traffic beyond replicating the already-reduced slab)."""

    def __init__(self, plan: FitPlan, d: int):
        from jax.sharding import NamedSharding, PartitionSpec as P

        self.plan = plan
        self.d = d
        self.mult = plan.shard_multiple()
        self.mesh = plan.mesh
        self.axis_name = plan.axis_name
        self._row = NamedSharding(self.mesh, P(self.axis_name, None))
        self._vec = NamedSharding(self.mesh, P(self.axis_name))
        self._rep = NamedSharding(self.mesh, P())

    def _place(self, x, m, v):
        return (jax.device_put(x, self._row), jax.device_put(m, self._vec),
                jax.device_put(v, self._vec))

    def reservoir(self, n: int):
        return self._place(jnp.zeros((n, self.d), jnp.float32),
                           jnp.zeros((n,), jnp.float32),
                           jnp.zeros((n,), bool))

    def place_chunk(self, buf: np.ndarray, n_valid: int):
        vj = np.arange(buf.shape[0]) < n_valid
        return self._place(buf, vj.astype(np.float32), vj)

    def place_slab(self, px, pm, pv):
        return (jax.device_put(jnp.asarray(px), self._rep),
                jax.device_put(jnp.asarray(pm), self._rep),
                jax.device_put(jnp.asarray(pv), self._rep))

    def level_step(self, x, mass, valid, key, n_out: int) -> ITISLevelOut:
        from repro.core.distributed import _itis_level_sharded

        p = self.plan
        protos, pmass, pvalid, assignment, ncs = _itis_level_sharded(
            x, mass, valid, key, t=p.t, n_out=n_out, weighted=p.weighted,
            impl=p.impl, n_blocks=self.mult, axis_name=self.axis_name,
            mesh=self.mesh, _dispatch=runtime.dispatch_key())
        return ITISLevelOut(protos, pmass, pvalid, assignment, ncs[0])

    def fold(self, res, px, pm, pv, offset: int):
        px, pm, pv = self.place_slab(px, pm, pv)
        return _fold_sharded(
            *res, px, pm, pv, jnp.int32(offset),
            slab_n=px.shape[0], axis_name=self.axis_name, mesh=self.mesh,
            _dispatch=runtime.dispatch_key())

    def compact(self, res):
        # _compact is exact (integer ranks + unique-index scatters), so
        # running it resident and re-pinning the layout stays deterministic
        new_x, new_m, new_v, assignment = _compact(*res)
        return self._place(new_x, new_m, new_v), assignment

    def pad_protos(self, out: ITISLevelOut, total_n: int):
        pad = total_n - out.protos.shape[0]
        return self._place(jnp.pad(out.protos, ((0, pad), (0, 0))),
                           jnp.pad(out.mass, (0, pad)),
                           jnp.pad(out.valid, (0, pad)))

    def prefix(self, res, frontier: int, size0: int):
        res_x, res_m, res_v = res
        pad = size0 - frontier
        return self._place(
            jnp.pad(res_x[:frontier], ((0, pad), (0, 0))),
            jnp.pad(res_m[:frontier], (0, pad)),
            jnp.pad(res_v[:frontier], (0, pad)))


@functools.partial(
    jax.jit, static_argnames=("slab_n", "axis_name", "mesh", "_dispatch"))
def _fold_sharded(res_x, res_m, res_v, px, pm, pv, offset, *,
                  slab_n: int, axis_name: str, mesh, _dispatch: tuple = ()):
    """Per-shard twin of :func:`_fold`: every shard overwrites the rows of
    the global ``[offset, offset + slab_n)`` window it owns, reading from
    the replicated slab. One compiled program per slab shape serves the
    whole stream (the offset stays traced)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import _shard_map

    def body(rx, rm, rv, px, pm, pv, offset):
        nl = rx.shape[0]
        me = jax.lax.axis_index(axis_name)
        rel = me * nl + jnp.arange(nl, dtype=jnp.int32) - offset
        take = (rel >= 0) & (rel < slab_n)
        safe = jnp.clip(rel, 0, slab_n - 1)
        rx = jnp.where(take[:, None], px[safe], rx)
        rm = jnp.where(take, pm[safe], rm)
        rv = jnp.where(take, pv[safe], rv)
        return rx, rm, rv

    a = axis_name
    return _shard_map(
        body, mesh,
        in_specs=(P(a, None), P(a), P(a), P(), P(), P(), P()),
        out_specs=(P(a, None), P(a), P(a)),
    )(res_x, res_m, res_v, px, pm, pv, offset)


# ---------------------------------------------------------------------------
# the stream loop (once, for both executors)
# ---------------------------------------------------------------------------


def _run_stream(plan: FitPlan, chunks, placement_cls) -> Reduction:
    driver = plan.driver
    t, m = plan.t, plan.m
    floor = plan.reduction_floor()
    key_itis, _ = plan.split_keys()
    # the in-memory key schedule: one split per level, level 0 first
    key_chain, key_level0 = jax.random.split(key_itis)
    key_cascade = jax.random.fold_in(key_level0, _CASCADE_KEY_TAG)

    it = iter(chunks)
    first = None
    for item in it:
        first = _normalize_chunk(item, driver)
        break
    if first is None:
        raise ValueError(f"{driver}: the chunk stream is empty")
    chunk_n = plan.chunk_n
    if not chunk_n:
        chunk_n = first[0].shape[0]
        if chunk_n == 0:
            raise ValueError(
                f"{driver}: cannot infer chunk_n from an empty first "
                f"chunk; pass chunk_n= or configure runtime chunk_n")
    d = first[0].shape[1] if first[0].ndim == 2 else None
    if d is None:
        raise ValueError(f"{driver}: chunks must be 2-D (rows, d)")
    validate_reduction_params(t, m, n=chunk_n, min_m=1, driver=driver)

    placement = placement_cls(plan, d)
    mult = placement.mult
    chunk_buf_n = round_up(chunk_n, mult)
    chunk_out = round_up(max(chunk_buf_n // t, 1), mult)
    # raw-fold slab for chunks too small to reduce (the in-memory early-stop
    # rule, applied per chunk): their valid prefix is copied verbatim.
    # Raw slabs enter the fold replicated, so they need no shard padding.
    raw_len = min(chunk_n, floor)
    reservoir_n = plan.reservoir_n
    if not reservoir_n:
        # large enough for the feasibility bound below by construction,
        # including the compaction degradation case
        reservoir_n = max(4 * chunk_out, 2 * raw_len,
                          floor - 1 + max(chunk_out, raw_len))
    reservoir_n = round_up(reservoir_n, mult)
    cascade_out = round_up(max(reservoir_n // t, 1), mult)
    # feasibility up front, before any of the stream is consumed: an
    # overflow frees down to cascade_out (reduction) or, degraded, to at
    # most floor - 1 valid rows (compaction — too few valid prototypes to
    # reduce); the next slab may be a full chunk reduce (chunk_out rows) or
    # a raw tail (raw_len)
    post_overflow = max(cascade_out, floor - 1)
    if reservoir_n - post_overflow < max(chunk_out, raw_len):
        raise ValueError(
            f"{driver}: reservoir_n={reservoir_n} cannot absorb a "
            f"{max(chunk_out, raw_len)}-row slab right after an overflow "
            f"(which frees down to at most {post_overflow} occupied "
            f"slots); need reservoir_n - max(reservoir_n//t, {floor - 1}) "
            f">= max(chunk_n//t, {raw_len})")

    res = placement.reservoir(reservoir_n)
    frontier = 0          # host-tracked write position (no device sync)
    n_cascades = 0

    chunk_assign: List[np.ndarray] = []
    chunk_offset: List[int] = []
    chunk_epoch: List[int] = []
    chunk_counts: List[int] = []
    maps: List[np.ndarray] = []

    def cascade():
        nonlocal res, frontier, n_cascades
        # repro: allow[HS202]: deliberate per-cascade sync — compaction-vs-reduction is a host decision, once per reservoir fill, not per chunk
        occ_valid = int(jnp.sum(res[2]))
        if occ_valid < floor:
            # the frontier is exhausted but the slots are mostly masked
            # holes (slabs whose chunks produced very few clusters): too
            # few valid prototypes for a reduction level, so squeeze the
            # holes out instead — an identity level that frees the space
            # without collapsing anything
            res, assignment = placement.compact(res)
            # repro: allow[HS201]: §12 spill — forced host copy (np.array, never a view) of the per-level map
            maps.append(np.array(assignment))  # true host copy
            frontier = occ_valid
            return
        ck = jax.random.fold_in(key_cascade, n_cascades)
        out = placement.level_step(*res, key=ck, n_out=cascade_out)
        # repro: allow[HS201]: §12 spill — forced host copy (np.array, never a view) of the per-level map
        maps.append(np.array(out.assignment))  # true host copy, not a view
        res = placement.pad_protos(out, reservoir_n)
        frontier = cascade_out
        n_cascades += 1

    def fold(px, pm, pv, slab: int) -> int:
        nonlocal res, frontier
        if frontier + slab > reservoir_n:
            cascade()
        if frontier + slab > reservoir_n:
            raise ValueError(
                f"{driver}: a {slab}-row slab does not fit the "
                f"reservoir even after a cascade (frontier={frontier}, "
                f"reservoir_n={reservoir_n}); increase reservoir_n")
        offset = frontier
        res = placement.fold(res, px, pm, pv, offset)
        frontier += slab
        return offset

    def consume(arr: np.ndarray, n_valid: int, chunk_idx: int) -> None:
        if arr.shape[0] > chunk_n:
            raise ValueError(
                f"{driver}: chunk {chunk_idx} has {arr.shape[0]} rows "
                f"> chunk_n={chunk_n}; re-chunk the stream or raise chunk_n")
        if arr.ndim != 2 or arr.shape[1] != d:
            raise ValueError(
                f"{driver}: chunk {chunk_idx} has shape {arr.shape}, "
                f"expected (<= {chunk_n}, {d})")
        if n_valid == 0:  # nothing to cluster; keep chunk indexing aligned
            chunk_assign.append(np.full((chunk_buf_n,), -1, np.int32))
            chunk_offset.append(0)
            chunk_epoch.append(len(maps))
            chunk_counts.append(0)
            return
        buf = np.zeros((chunk_buf_n, d), np.float32)
        buf[: arr.shape[0]] = arr
        if n_valid < floor:
            # too small to reduce (the itis early-stop rule): fold the valid
            # prefix raw, with an identity assignment map
            pv = np.arange(raw_len) < n_valid
            px, pm, pv = placement.place_slab(
                buf[:raw_len], pv.astype(np.float32), pv)
            off = fold(px, pm, pv, raw_len)
            # epoch AFTER the fold: a cascade the fold itself triggered
            # must not apply to the slots it just wrote
            epoch = len(maps)
            ident = np.arange(chunk_buf_n, dtype=np.int32)
            chunk_assign.append(
                np.where(ident < n_valid, ident, -1).astype(np.int32))
            chunk_offset.append(off)
            chunk_epoch.append(epoch)
            chunk_counts.append(n_valid)
            return
        xj, mj, vj = placement.place_chunk(buf, n_valid)
        sub = key_level0 if chunk_idx == 0 else jax.random.fold_in(
            key_level0, chunk_idx)
        out = placement.level_step(xj, mj, vj, key=sub, n_out=chunk_out)
        off = fold(out.protos, out.mass, out.valid, chunk_out)
        epoch = len(maps)  # after the fold — see the raw path above
        # repro: allow[HS201]: §12 spill — forced host copy (np.array, never a view) of the chunk assignment
        chunk_assign.append(np.array(out.assignment))  # true host copy
        chunk_offset.append(off)
        chunk_epoch.append(epoch)
        chunk_counts.append(n_valid)

    consume(*first, 0)
    for chunk_idx, item in enumerate(it, start=1):
        consume(*_normalize_chunk(item, driver), chunk_idx)
    if frontier == 0:
        raise ValueError(
            f"{driver}: the stream contained no valid rows (every "
            f"chunk was empty or fully masked) — nothing to cluster")

    # ---- finalize: levels 1..m-1 on the occupied reservoir prefix --------
    size0 = round_up(frontier, mult)
    sizes = level_sizes(size0, t, m - 1, multiple=mult) if m > 1 else [size0]
    buf_x, buf_m, buf_v = placement.prefix(res, frontier, size0)
    for level in range(m - 1):
        # repro: allow[HS202]: deliberate per-level sync — the §6 early-exit floor is a host decision, m-1 times per fit, stream loop is already drained
        n_valid = int(jnp.sum(buf_v))
        if n_valid < floor:
            break
        key_chain, sub = jax.random.split(key_chain)
        out = placement.level_step(buf_x, buf_m, buf_v, key=sub,
                                   n_out=sizes[level + 1])
        # repro: allow[HS201]: §12 spill — forced host copy (np.array, never a view) of the per-level map
        maps.append(np.array(out.assignment))  # true host copy, not a view
        buf_x, buf_m, buf_v = out.protos, out.mass, out.valid

    spill = LabelSpill(
        chunk_n=chunk_n, chunk_assign=chunk_assign,
        chunk_offset=chunk_offset, chunk_epoch=chunk_epoch,
        chunk_counts=chunk_counts, maps=maps, n_cascades=n_cascades,
    )
    return Reduction(
        protos=buf_x, mass=buf_m, valid=buf_v,
        n_prototypes=jnp.sum(buf_v).astype(jnp.int32), assignments=[],
        n0=spill.n_total, spill=spill,
    )


@register_executor("streaming")
def _execute_streaming(plan: FitPlan, chunks) -> Reduction:
    return _run_stream(plan, chunks, _DevicePlacement)


@register_executor("streaming_sharded")
def _execute_streaming_sharded(plan: FitPlan, chunks) -> Reduction:
    return _run_stream(plan, chunks, _MeshPlacement)


def ihtc_streaming(
    chunks,
    t: int,
    m: int,
    backend: Union[str, BackendFn] = "kmeans",
    *,
    chunk_n: Optional[int] = None,
    reservoir_n: Optional[int] = None,
    weighted: bool = False,
    use_mass_in_backend: bool = True,
    key: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    knn_block: Optional[int] = None,
    n_blocks: Optional[int] = None,
    min_points: int = 4,
    **backend_kwargs,
) -> FitResult:
    """Fit IHTC over a chunk stream in O(chunk + reservoir) device memory
    (deprecated alias of ``repro.fit(..., executor="streaming")`` — the
    planner entry point also unlocks the composed ``streaming_sharded``
    executor when a mesh is configured; this alias stays pinned to the
    single-device executor for backward compatibility).

    ``chunks`` is any iterator of host chunks — bare (c, d) arrays (e.g.
    :func:`repro.data.pipeline.point_chunks`) or ``(chunk, n_valid)`` pairs
    for pre-padded buffers. Chunks may be ragged up to ``chunk_n`` rows;
    each is padded to the static ``chunk_n`` shape so the whole stream runs
    through one compiled level-0 program.

    ``chunk_n`` / ``reservoir_n`` default to the active runtime config
    (``REPRO_CHUNK_N`` / ``REPRO_RESERVOIR_N``); 0 = auto (the first
    chunk's row count, resp. ``4 * (chunk_n // t)``). ``m >= 1`` is
    required: with m = 0 no reduction ever happens and the backend would
    need all n points at once — exactly what streaming exists to avoid.

    Returns the canonical :class:`repro.core.plan.FitResult`;
    ``labels_for(i)`` / ``iter_labels()`` stream the final labels back out,
    ``to_index()`` freezes the servable artifact. See the module docstring
    for the parity contract with the in-memory executor.
    """
    return fit(
        chunks, t, m, backend, executor="streaming",
        chunk_n=chunk_n, reservoir_n=reservoir_n, weighted=weighted,
        use_mass_in_backend=use_mass_in_backend, key=key, impl=impl,
        knn_block=knn_block, n_blocks=n_blocks, min_points=min_points,
        driver="ihtc_streaming", **backend_kwargs,
    )
