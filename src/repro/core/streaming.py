"""Out-of-core streaming IHTC fit — clustering data that never fits at once.

The paper's whole premise is data too massive for k-means/HAC, yet the
in-memory drivers (:func:`repro.core.ihtc.ihtc`, the sharded twin, and
``ClusterIndex.fit``) all require the full (n, d) array resident in device
memory — ``data.stream_to_mesh`` streams *ingestion* only. This module
closes that gap with the reduce-then-cluster aggregation strategy of the
Data Nuggets / hierarchical-aggregation line of work: every host chunk is
collapsed to weighted prototypes by one jitted ITIS level, the prototypes
fold into a bounded device-side **reservoir**, and the reservoir cascades
through a further ITIS level whenever it fills. Peak device memory is
O(chunk + reservoir) — independent of n.

Execution plan (DESIGN.md §12):

  * **level 0, per chunk** — every chunk is padded to the static
    ``chunk_n`` shape and reduced by the *existing* jitted
    :func:`repro.core.itis.itis_step` (one compiled program for the whole
    stream). The (chunk_n,)-sized chunk→prototype assignment map spills to
    host memory for the final back-out.
  * **reservoir fold** — each chunk's prototype buffer (its ``chunk_n//t``
    slots, validity-masked) lands at the reservoir's write frontier via one
    jitted ``dynamic_update_slice``; the frontier advances by plain host
    arithmetic, so the chunk loop never synchronizes with the device.
  * **cascade** — when the next fold would overflow, one ``itis_step`` over
    the whole reservoir buffer (again a single compiled program for every
    cascade) compacts it to ``reservoir_n // t`` slots; the reservoir-wide
    assignment map spills to host.
  * **finalize** — after the stream, the occupied reservoir prefix runs the
    remaining ``m - 1`` ITIS levels (the same key-split schedule and
    early-stop rule as :func:`repro.core.itis.itis`), and the backend from
    :mod:`repro.cluster.registry` labels the surviving prototypes.

Labels stream *back out* chunk-by-chunk: ``labels_for(c)`` composes chunk
c's spilled map through every cascade/finalize map recorded at-or-after its
fold epoch, entirely in host numpy — the device never holds an O(n) label
array.

Parity contract (tested): when the stream presents the dataset as a single
level-0 buffer (one chunk with ``chunk_n == n``) and the reservoir never
overflows mid-level, the fold degenerates to an identity placement and
every subsequent level runs in the exact buffers, with the exact keys, of
the in-memory driver — labels, prototypes and masses are bit-identical to
``ihtc``. Multi-chunk streams are a *different estimator of the same
family* (level 0's TC graph cannot cross chunk boundaries), so they are
held to the pipeline's invariants (mass conservation, the (t*)^m size
guarantee, accuracy on the §4 mixture) rather than bitwise equality —
DESIGN.md §12 spells out why.
"""
from __future__ import annotations

import functools
from typing import Iterable, Iterator, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.cluster.registry import BackendFn, resolve_backend
from repro.core.itis import (
    itis_step,
    level_sizes,
    validate_reduction_params,
)

# fold_in tag separating the cascade key stream from the per-chunk stream
_CASCADE_KEY_TAG = 0x7FFFFFFF


class StreamingIHTCResult:
    """Fitted artifact of :func:`ihtc_streaming` plus the host-side spill
    needed to stream final labels back out.

    Device-resident (all O(reservoir), never O(n)):
      ``protos`` / ``proto_mass`` / ``proto_valid`` — the final prototype
      buffer; ``proto_labels`` — backend labels (-1 pad/noise);
      ``n_prototypes`` — valid count.

    Host-resident spill: one int32 assignment map per chunk plus one per
    cascade/finalize level (the format §12 documents). ``labels_for`` /
    ``iter_labels`` compose them lazily; nothing O(n) ever lands on device.
    """

    def __init__(
        self,
        *,
        protos: jax.Array,
        proto_mass: jax.Array,
        proto_valid: jax.Array,
        proto_labels: jax.Array,
        n_prototypes: jax.Array,
        chunk_n: int,
        chunk_assign: List[np.ndarray],
        chunk_offset: List[int],
        chunk_epoch: List[int],
        chunk_counts: List[int],
        maps: List[np.ndarray],
        n_cascades: int,
    ):
        self.protos = protos
        self.proto_mass = proto_mass
        self.proto_valid = proto_valid
        self.proto_labels = proto_labels
        self.n_prototypes = n_prototypes
        self.chunk_n = chunk_n
        self.n_cascades = n_cascades
        self._chunk_assign = chunk_assign
        self._chunk_offset = chunk_offset
        self._chunk_epoch = chunk_epoch
        self._chunk_counts = chunk_counts
        self._maps = maps
        self._proto_labels_host = np.asarray(proto_labels)

    @property
    def n_chunks(self) -> int:
        return len(self._chunk_assign)

    @property
    def n_total(self) -> int:
        return int(sum(self._chunk_counts))

    def labels_for(self, chunk_idx: int) -> np.ndarray:
        """Final cluster labels of chunk ``chunk_idx``'s valid rows.

        Pure host numpy over the spilled maps: chunk-local prototype id →
        reservoir slot at fold time → through every cascade/finalize map
        from the chunk's epoch onward → backend label.
        """
        count = self._chunk_counts[chunk_idx]
        lab = self._chunk_assign[chunk_idx][:count].astype(np.int64)
        slot = np.where(lab >= 0, lab + self._chunk_offset[chunk_idx], -1)
        for mp in self._maps[self._chunk_epoch[chunk_idx]:]:
            slot = np.where(slot >= 0, mp[np.maximum(slot, 0)], -1)
        out = np.where(
            slot >= 0, self._proto_labels_host[np.maximum(slot, 0)], -1)
        return out.astype(np.int32)

    def iter_labels(self) -> Iterator[np.ndarray]:
        """Final labels, one array per input chunk, in stream order."""
        for c in range(self.n_chunks):
            yield self.labels_for(c)

    def labels(self) -> np.ndarray:
        """All labels concatenated — convenience for datasets that fit on
        host; prefer :meth:`iter_labels` at scale."""
        if self.n_chunks == 0:
            return np.zeros((0,), np.int32)
        return np.concatenate(list(self.iter_labels()))

    def to_index(self):
        """Freeze into a servable :class:`repro.core.index.ClusterIndex`."""
        from repro.core.index import ClusterIndex  # lazy: no import cycle

        return ClusterIndex(
            protos=self.protos,
            proto_mass=self.proto_mass,
            proto_valid=self.proto_valid,
            proto_labels=self.proto_labels,
            n_prototypes=self.n_prototypes,
        )


def _normalize_chunk(item) -> Tuple[np.ndarray, int]:
    """Accept bare (c, d) arrays or ``(chunk, n_valid)`` pairs."""
    if isinstance(item, (tuple, list)) and len(item) == 2:
        arr, n_valid = item
        arr = np.asarray(arr, np.float32)
        n_valid = int(n_valid)
        if not 0 <= n_valid <= arr.shape[0]:
            raise ValueError(
                f"ihtc_streaming: chunk n_valid={n_valid} outside "
                f"[0, {arr.shape[0]}]")
        return arr, n_valid
    arr = np.asarray(item, np.float32)
    return arr, arr.shape[0]


@jax.jit
def _compact(res_x, res_m, res_v):
    """Gather the valid reservoir rows to the front (an identity level: no
    reduction, just squeezing out the masked holes between slabs). Returns
    the compacted buffers plus the old-slot → new-slot assignment map, in
    the same format an ITIS level emits."""
    n = res_v.shape[0]
    rank = (jnp.cumsum(res_v) - 1).astype(jnp.int32)
    dst = jnp.where(res_v, rank, n)  # invalid rows: out of range, dropped
    new_x = jnp.zeros_like(res_x).at[dst].set(res_x, mode="drop")
    new_m = jnp.zeros_like(res_m).at[dst].set(res_m, mode="drop")
    new_v = jnp.zeros_like(res_v).at[dst].set(res_v, mode="drop")
    assignment = jnp.where(res_v, rank, -1)
    return new_x, new_m, new_v, assignment


@functools.partial(jax.jit, static_argnames=("_dispatch",))
def _fold(res_x, res_m, res_v, px, pm, pv, offset, _dispatch: tuple = ()):
    """Write one prototype slab at the reservoir frontier (traced offset:
    a single compiled program serves the whole stream)."""
    res_x = jax.lax.dynamic_update_slice(res_x, px, (offset, 0))
    res_m = jax.lax.dynamic_update_slice(res_m, pm, (offset,))
    res_v = jax.lax.dynamic_update_slice(res_v, pv, (offset,))
    return res_x, res_m, res_v


def ihtc_streaming(
    chunks: Iterable,
    t: int,
    m: int,
    backend: Union[str, BackendFn] = "kmeans",
    *,
    chunk_n: Optional[int] = None,
    reservoir_n: Optional[int] = None,
    weighted: bool = False,
    use_mass_in_backend: bool = True,
    key: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    knn_block: Optional[int] = None,
    n_blocks: Optional[int] = None,
    min_points: int = 4,
    **backend_kwargs,
) -> StreamingIHTCResult:
    """Fit IHTC over a chunk stream in O(chunk + reservoir) device memory.

    ``chunks`` is any iterator of host chunks — bare (c, d) arrays (e.g.
    :func:`repro.data.pipeline.point_chunks`) or ``(chunk, n_valid)`` pairs
    for pre-padded buffers. Chunks may be ragged up to ``chunk_n`` rows;
    each is padded to the static ``chunk_n`` shape so the whole stream runs
    through one compiled level-0 program.

    ``chunk_n`` / ``reservoir_n`` default to the active runtime config
    (``REPRO_CHUNK_N`` / ``REPRO_RESERVOIR_N``); 0 = auto (the first
    chunk's row count, resp. ``4 * (chunk_n // t)``). ``m >= 1`` is
    required: with m = 0 no reduction ever happens and the backend would
    need all n points at once — exactly what streaming exists to avoid.

    Returns a :class:`StreamingIHTCResult`; ``labels_for(i)`` /
    ``iter_labels()`` stream the final labels back out, ``to_index()``
    (or :meth:`repro.core.index.ClusterIndex.fit_streaming`) freezes the
    servable artifact. See the module docstring for the parity contract
    with the in-memory driver.
    """
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    knn_block = cfg.knn_block if knn_block is None else knn_block
    n_blocks = cfg.n_blocks if n_blocks is None else n_blocks
    chunk_n = cfg.chunk_n if chunk_n is None else chunk_n
    reservoir_n = cfg.reservoir_n if reservoir_n is None else reservoir_n
    validate_reduction_params(t, m, min_m=1, driver="ihtc_streaming")
    if key is None:
        key = jax.random.PRNGKey(0)
    key_itis, key_backend = jax.random.split(key)
    # the in-memory driver's key schedule: one split per level, level 0 first
    key_chain, key_level0 = jax.random.split(key_itis)
    key_cascade = jax.random.fold_in(key_level0, _CASCADE_KEY_TAG)

    it = iter(chunks)
    first = None
    for item in it:
        first = _normalize_chunk(item)
        break
    if first is None:
        raise ValueError("ihtc_streaming: the chunk stream is empty")
    if not chunk_n:
        chunk_n = first[0].shape[0]
        if chunk_n == 0:
            raise ValueError(
                "ihtc_streaming: cannot infer chunk_n from an empty first "
                "chunk; pass chunk_n= or configure runtime chunk_n")
    d = first[0].shape[1] if first[0].ndim == 2 else None
    if d is None:
        raise ValueError("ihtc_streaming: chunks must be 2-D (rows, d)")
    validate_reduction_params(t, m, n=chunk_n, min_m=1,
                              driver="ihtc_streaming")

    chunk_out = max(chunk_n // t, 1)
    # raw-fold slab for chunks too small to reduce (the in-memory early-stop
    # rule, applied per chunk): their valid prefix is copied verbatim
    raw_len = min(chunk_n, max(min_points, 2 * t))
    if not reservoir_n:
        # large enough for the feasibility bound below by construction,
        # including the compaction degradation case
        reservoir_n = max(4 * chunk_out, 2 * raw_len,
                          max(min_points, 2 * t) - 1 + max(chunk_out, raw_len))
    cascade_out = max(reservoir_n // t, 1)
    # feasibility up front, before any of the stream is consumed: an
    # overflow frees down to cascade_out (reduction) or, degraded, to at
    # most max(min_points, 2t) - 1 valid rows (compaction — too few valid
    # prototypes to reduce); the next slab may be a full chunk reduce
    # (chunk_out rows) or a raw tail (raw_len)
    post_overflow = max(cascade_out, max(min_points, 2 * t) - 1)
    if reservoir_n - post_overflow < max(chunk_out, raw_len):
        raise ValueError(
            f"ihtc_streaming: reservoir_n={reservoir_n} cannot absorb a "
            f"{max(chunk_out, raw_len)}-row slab right after an overflow "
            f"(which frees down to at most {post_overflow} occupied "
            f"slots); need reservoir_n - max(reservoir_n//t, "
            f"{max(min_points, 2 * t) - 1}) >= max(chunk_n//t, {raw_len})")

    res_x = jnp.zeros((reservoir_n, d), jnp.float32)
    res_m = jnp.zeros((reservoir_n,), jnp.float32)
    res_v = jnp.zeros((reservoir_n,), bool)
    frontier = 0          # host-tracked write position (no device sync)
    n_cascades = 0

    chunk_assign: List[np.ndarray] = []
    chunk_offset: List[int] = []
    chunk_epoch: List[int] = []
    chunk_counts: List[int] = []
    maps: List[np.ndarray] = []

    def cascade():
        nonlocal res_x, res_m, res_v, frontier, n_cascades
        occ_valid = int(jnp.sum(res_v))
        if occ_valid < max(min_points, 2 * t):
            # the frontier is exhausted but the slots are mostly masked
            # holes (slabs whose chunks produced very few clusters): too
            # few valid prototypes for a reduction level, so squeeze the
            # holes out instead — an identity level that frees the space
            # without collapsing anything
            res_x, res_m, res_v, assignment = _compact(res_x, res_m, res_v)
            maps.append(np.array(assignment))  # true host copy
            frontier = occ_valid
            return
        ck = jax.random.fold_in(key_cascade, n_cascades)
        out = itis_step(
            res_x, res_m, res_v, t, key=ck, weighted=weighted, impl=impl,
            knn_block=knn_block, n_out=cascade_out, n_blocks=n_blocks)
        maps.append(np.array(out.assignment))  # true host copy, not a zero-copy view
        pad = reservoir_n - cascade_out
        res_x = jnp.pad(out.protos, ((0, pad), (0, 0)))
        res_m = jnp.pad(out.mass, (0, pad))
        res_v = jnp.pad(out.valid, (0, pad))
        frontier = cascade_out
        n_cascades += 1

    def fold(px, pm, pv, slab: int):
        nonlocal res_x, res_m, res_v, frontier
        if frontier + slab > reservoir_n:
            cascade()
        if frontier + slab > reservoir_n:
            raise ValueError(
                f"ihtc_streaming: a {slab}-row slab does not fit the "
                f"reservoir even after a cascade (frontier={frontier}, "
                f"reservoir_n={reservoir_n}); increase reservoir_n")
        offset = frontier
        res_x, res_m, res_v = _fold(
            res_x, res_m, res_v, px, pm, pv, jnp.int32(offset),
            _dispatch=cfg.dispatch_key())
        frontier += slab
        return offset

    def consume(arr: np.ndarray, n_valid: int, chunk_idx: int) -> None:
        if arr.shape[0] > chunk_n:
            raise ValueError(
                f"ihtc_streaming: chunk {chunk_idx} has {arr.shape[0]} rows "
                f"> chunk_n={chunk_n}; re-chunk the stream or raise chunk_n")
        if arr.ndim != 2 or arr.shape[1] != d:
            raise ValueError(
                f"ihtc_streaming: chunk {chunk_idx} has shape {arr.shape}, "
                f"expected (<= {chunk_n}, {d})")
        if n_valid == 0:  # nothing to cluster; keep chunk indexing aligned
            chunk_assign.append(np.full((chunk_n,), -1, np.int32))
            chunk_offset.append(0)
            chunk_epoch.append(len(maps))
            chunk_counts.append(0)
            return
        buf = np.zeros((chunk_n, d), np.float32)
        buf[: arr.shape[0]] = arr
        xj = jnp.asarray(buf)
        vj = jnp.arange(chunk_n) < n_valid
        mj = vj.astype(jnp.float32)
        if n_valid < max(min_points, 2 * t):
            # too small to reduce (the itis early-stop rule): fold the valid
            # prefix raw, with an identity assignment map
            off = fold(xj[:raw_len], mj[:raw_len], vj[:raw_len], raw_len)
            # epoch AFTER the fold: a cascade the fold itself triggered
            # must not apply to the slots it just wrote
            epoch = len(maps)
            ident = np.arange(chunk_n, dtype=np.int32)
            chunk_assign.append(
                np.where(ident < n_valid, ident, -1).astype(np.int32))
            chunk_offset.append(off)
            chunk_epoch.append(epoch)
            chunk_counts.append(n_valid)
            return
        sub = key_level0 if chunk_idx == 0 else jax.random.fold_in(
            key_level0, chunk_idx)
        out = itis_step(
            xj, mj, vj, t, key=sub, weighted=weighted, impl=impl,
            knn_block=knn_block, n_out=chunk_out, n_blocks=n_blocks)
        off = fold(out.protos, out.mass, out.valid, chunk_out)
        epoch = len(maps)  # after the fold — see the raw path above
        chunk_assign.append(np.array(out.assignment))  # true host copy
        chunk_offset.append(off)
        chunk_epoch.append(epoch)
        chunk_counts.append(n_valid)

    consume(*first, 0)
    for chunk_idx, item in enumerate(it, start=1):
        consume(*_normalize_chunk(item), chunk_idx)
    if frontier == 0:
        raise ValueError(
            "ihtc_streaming: the stream contained no valid rows (every "
            "chunk was empty or fully masked) — nothing to cluster")

    # ---- finalize: levels 1..m-1 on the occupied reservoir prefix --------
    buf_x = res_x[:frontier]
    buf_m = res_m[:frontier]
    buf_v = res_v[:frontier]
    sizes = level_sizes(frontier, t, m - 1) if m > 1 else [frontier]
    for level in range(m - 1):
        n_valid = int(jnp.sum(buf_v))
        if n_valid < max(min_points, 2 * t):
            break
        key_chain, sub = jax.random.split(key_chain)
        out = itis_step(
            buf_x, buf_m, buf_v, t, key=sub, weighted=weighted, impl=impl,
            knn_block=knn_block, n_out=sizes[level + 1], n_blocks=n_blocks)
        maps.append(np.array(out.assignment))  # true host copy, not a zero-copy view
        buf_x, buf_m, buf_v = out.protos, out.mass, out.valid

    fn = resolve_backend(backend)
    w = buf_m if use_mass_in_backend else None
    proto_labels = fn(buf_x, valid=buf_v, weights=w, key=key_backend,
                      impl=impl, **backend_kwargs)
    proto_labels = jnp.where(buf_v, proto_labels, -1).astype(jnp.int32)

    return StreamingIHTCResult(
        protos=buf_x,
        proto_mass=buf_m,
        proto_valid=buf_v,
        proto_labels=proto_labels,
        n_prototypes=jnp.sum(buf_v).astype(jnp.int32),
        chunk_n=chunk_n,
        chunk_assign=chunk_assign,
        chunk_offset=chunk_offset,
        chunk_epoch=chunk_epoch,
        chunk_counts=chunk_counts,
        maps=maps,
        n_cascades=n_cascades,
    )
