"""ITIS — Iterated Threshold Instance Selection (the paper's §3.1).

Repeat {TC at threshold t* → collapse clusters to prototypes} m times.
Each iteration shrinks the point set by ≥ t*, so ITIS level l lives in a
*static* padded buffer of size n₀ // (t*)^l — fully jit-compatible fixed
shapes with validity masks (one XLA program per level shape; the geometric
shrink means total compile+run cost is dominated by level 0). See
DESIGN.md §3 for the padding scheme and DESIGN.md §4 for the multi-device
twin of this driver (:func:`repro.core.distributed.itis_sharded`), which
shares :func:`level_sizes` so both drivers agree on every buffer shape.

The host-level driver (`itis`) orchestrates the per-level jitted step and
keeps the level assignment maps needed for IHTC back-out.

:func:`level_sizes` and :func:`validate_reduction_params` are the single
sources every fit executor shares — the planner (:mod:`repro.core.plan`,
DESIGN.md §13) wraps them as ``FitPlan.schedule`` and validates once at
plan time, so no executor re-implements level scheduling or t/m rules.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import runtime
from repro.core.prototypes import PrototypeSet, reduce_to_prototypes
from repro.core.tc import TCResult, threshold_clustering


def round_up(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is ≥ ``n``."""
    if multiple <= 1:
        return n
    return ((n + multiple - 1) // multiple) * multiple


def validate_reduction_params(
    t: int, m: int, *, n: Optional[int] = None, min_m: int = 0,
    driver: str = "itis",
) -> None:
    """Reject t/m values every ITIS-family driver would silently mishandle.

    ``t < 2`` never shrinks the point set, so ``t = 1`` would run ``m``
    full-size levels (the original silent-acceptance bug); ``m`` below
    ``min_m`` is meaningless for the driver; and with any reduction level to
    run, TC needs a ``k = t - 1``-NN graph, which requires ``t - 1 < n``.
    """
    if int(t) != t or t < 2:
        raise ValueError(
            f"{driver}: threshold t must be an integer >= 2 (t={t!r} would "
            f"never shrink the point set, so every level stays full-size)")
    if int(m) != m or m < min_m:
        raise ValueError(
            f"{driver}: iteration count m must be an integer >= {min_m}, "
            f"got {m!r}")
    if n is not None and m >= 1 and t - 1 >= n:
        raise ValueError(
            f"{driver}: TC builds a k = t-1 = {t - 1} nearest-neighbour "
            f"graph, which needs t - 1 < n points; got n={n}")


def level_sizes(n0: int, t: int, m: int, *, multiple: int = 1) -> List[int]:
    """Static buffer size of every ITIS level, levels 0..m inclusive.

    ``multiple`` pads each level to a multiple (1 = the paper-exact sizes;
    the sharded driver uses the reduction-block count so every level splits
    evenly across devices). Both the single-device and the distributed
    drivers derive their shapes from this one function: when the unpadded
    sizes already satisfy the multiple, the two compute in identical buffers
    and their results agree bit-for-bit (DESIGN.md §4.3).
    """
    validate_reduction_params(t, m, driver="level_sizes")
    sizes = [round_up(n0, multiple)]
    for _ in range(m):
        sizes.append(round_up(max(sizes[-1] // t, 1), multiple))
    return sizes


class ITISLevelOut(NamedTuple):
    protos: jax.Array      # (n_out_max, d)
    mass: jax.Array        # (n_out_max,)
    valid: jax.Array       # (n_out_max,) bool
    assignment: jax.Array  # (n_in,) int32 → [0, n_out_max), -1 for padding
    n_clusters: jax.Array  # () int32


class ITISResult(NamedTuple):
    protos: jax.Array               # final level prototypes (padded)
    mass: jax.Array
    valid: jax.Array
    assignments: Sequence[jax.Array]  # one per level, for back-out
    n_prototypes: jax.Array           # () int32 — valid count at final level


def itis_step(
    x: jax.Array,
    mass: jax.Array,
    valid: jax.Array,
    t: int,
    *,
    key: jax.Array,
    weighted: bool = False,
    impl: Optional[str] = None,
    knn_block: Optional[int] = None,
    n_out: Optional[int] = None,
    n_blocks: Optional[int] = None,
) -> ITISLevelOut:
    """One ITIS level: TC on the valid points, reduce to ≤ n//t prototypes.

    ``n_out`` overrides the output buffer size (default ``max(n // t, 1)``;
    the sharded driver passes a device-padded size from ``level_sizes``).
    ``impl``/``knn_block``/``n_blocks`` default to the active runtime config,
    resolved before the jit boundary (DESIGN.md §10).
    """
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    knn_block = cfg.knn_block if knn_block is None else knn_block
    n_blocks = cfg.n_blocks if n_blocks is None else n_blocks
    return _itis_step(x, mass, valid, t, key=key, weighted=weighted,
                      impl=impl, knn_block=knn_block, n_out=n_out,
                      n_blocks=n_blocks, _dispatch=cfg.dispatch_key())


@functools.partial(
    jax.jit,
    static_argnames=("t", "weighted", "impl", "knn_block", "n_out",
                     "n_blocks", "_dispatch"),
)
def _itis_step(
    x: jax.Array,
    mass: jax.Array,
    valid: jax.Array,
    t: int,
    *,
    key: jax.Array,
    weighted: bool,
    impl: str,
    knn_block: int,
    n_out: Optional[int],
    n_blocks: int,
    _dispatch: tuple = (),  # cache-key pin for trace-time config reads (§10)
) -> ITISLevelOut:
    n = x.shape[0]
    if n_out is None:
        n_out = max(n // t, 1)
    tc: TCResult = threshold_clustering(
        x, t, valid=valid, key=key, impl=impl, knn_block=knn_block
    )
    ps: PrototypeSet = reduce_to_prototypes(
        x, tc.labels, n_out, weights=mass, weighted=weighted, impl=impl,
        n_blocks=n_blocks,
    )
    return ITISLevelOut(ps.x, ps.mass, ps.valid, tc.labels, tc.n_clusters)


def itis(
    x: jax.Array,
    t: int,
    m: int,
    *,
    weights: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    weighted: bool = False,
    impl: Optional[str] = None,
    knn_block: Optional[int] = None,
    min_points: int = 4,
    pad_multiple: int = 1,
    n_blocks: Optional[int] = None,
) -> ITISResult:
    """Run m ITIS iterations (host driver).

    Stops early if fewer than ``max(min_points, 2*t)`` valid points remain
    (further reduction would collapse everything into one cluster).
    ``pad_multiple`` > 1 pads every level buffer to that multiple (used to
    shape-match the sharded driver; results are unchanged semantically but
    padding alters TC's random seed-priority draw, so only shape-identical
    runs are bit-comparable — see DESIGN.md §4.3). ``impl``/``knn_block``/
    ``n_blocks`` default to the active runtime config.
    """
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    knn_block = cfg.knn_block if knn_block is None else knn_block
    n_blocks = cfg.n_blocks if n_blocks is None else n_blocks
    validate_reduction_params(t, m, n=x.shape[0], driver="itis")
    if key is None:
        key = jax.random.PRNGKey(0)
    n = x.shape[0]
    mass = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    valid = jnp.ones((n,), bool)

    sizes = level_sizes(n, t, m, multiple=pad_multiple)
    if sizes[0] != n:
        pad = sizes[0] - n
        x = jnp.pad(x, ((0, pad), (0, 0)))
        mass = jnp.pad(mass, (0, pad))
        valid = jnp.pad(valid, (0, pad))

    assignments = []
    cur_x, cur_m, cur_v = x, mass, valid
    n_protos = jnp.sum(cur_v).astype(jnp.int32)
    for level in range(m):
        # repro: allow[HS202]: deliberate per-level sync — the early-exit floor is a host decision, m times per fit
        n_valid = int(jnp.sum(cur_v))
        if n_valid < max(min_points, 2 * t):
            break
        key, sub = jax.random.split(key)
        out = itis_step(
            cur_x, cur_m, cur_v, t,
            key=sub, weighted=weighted, impl=impl, knn_block=knn_block,
            n_out=sizes[level + 1], n_blocks=n_blocks,
        )
        assignments.append(out.assignment)
        cur_x, cur_m, cur_v = out.protos, out.mass, out.valid
        n_protos = out.n_clusters
    return ITISResult(cur_x, cur_m, cur_v, assignments, n_protos)
