"""ITIS — Iterated Threshold Instance Selection (the paper's §3.1).

Repeat {TC at threshold t* → collapse clusters to prototypes} m times.
Each iteration shrinks the point set by ≥ t*, so ITIS level l lives in a
*static* padded buffer of size n₀ // (t*)^l — fully jit-compatible fixed
shapes with validity masks (one XLA program per level shape; the geometric
shrink means total compile+run cost is dominated by level 0).

The host-level driver (`itis`) orchestrates the per-level jitted step and
keeps the level assignment maps needed for IHTC back-out.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.prototypes import PrototypeSet, reduce_to_prototypes
from repro.core.tc import TCResult, threshold_clustering


class ITISLevelOut(NamedTuple):
    protos: jax.Array      # (n_out_max, d)
    mass: jax.Array        # (n_out_max,)
    valid: jax.Array       # (n_out_max,) bool
    assignment: jax.Array  # (n_in,) int32 → [0, n_out_max), -1 for padding
    n_clusters: jax.Array  # () int32


class ITISResult(NamedTuple):
    protos: jax.Array               # final level prototypes (padded)
    mass: jax.Array
    valid: jax.Array
    assignments: Sequence[jax.Array]  # one per level, for back-out
    n_prototypes: jax.Array           # () int32 — valid count at final level


@functools.partial(jax.jit, static_argnames=("t", "weighted", "impl", "knn_block"))
def itis_step(
    x: jax.Array,
    mass: jax.Array,
    valid: jax.Array,
    t: int,
    *,
    key: jax.Array,
    weighted: bool = False,
    impl: str = "auto",
    knn_block: int = 0,
) -> ITISLevelOut:
    """One ITIS level: TC on the valid points, reduce to ≤ n//t prototypes."""
    n = x.shape[0]
    n_out = max(n // t, 1)
    tc: TCResult = threshold_clustering(
        x, t, valid=valid, key=key, impl=impl, knn_block=knn_block
    )
    ps: PrototypeSet = reduce_to_prototypes(
        x, tc.labels, n_out, weights=mass, weighted=weighted, impl=impl
    )
    return ITISLevelOut(ps.x, ps.mass, ps.valid, tc.labels, tc.n_clusters)


def itis(
    x: jax.Array,
    t: int,
    m: int,
    *,
    weights: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    weighted: bool = False,
    impl: str = "auto",
    knn_block: int = 0,
    min_points: int = 4,
) -> ITISResult:
    """Run m ITIS iterations (host driver).

    Stops early if fewer than ``max(min_points, 2*t)`` valid points remain
    (further reduction would collapse everything into one cluster).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    n = x.shape[0]
    mass = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    valid = jnp.ones((n,), bool)

    assignments = []
    cur_x, cur_m, cur_v = x, mass, valid
    n_protos = jnp.sum(cur_v).astype(jnp.int32)
    for level in range(m):
        n_valid = int(jnp.sum(cur_v))
        if n_valid < max(min_points, 2 * t):
            break
        key, sub = jax.random.split(key)
        out = itis_step(
            cur_x, cur_m, cur_v, t,
            key=sub, weighted=weighted, impl=impl, knn_block=knn_block,
        )
        assignments.append(out.assignment)
        cur_x, cur_m, cur_v = out.protos, out.mass, out.valid
        n_protos = out.n_clusters
    return ITISResult(cur_x, cur_m, cur_v, assignments, n_protos)
