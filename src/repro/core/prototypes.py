"""Prototype (cluster centre) construction and back-out label composition."""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import runtime
from repro.kernels import ops

# Canonical reduction-tree width for prototype/centroid accumulations.
# Pinning the block count (instead of letting it follow the device count or
# XLA's scatter order) makes reductions device-layout-invariant, which is
# what lets the sharded pipeline in repro.core.distributed match the
# single-device driver bit-for-bit (DESIGN.md §4.3). This is also the
# default of RuntimeConfig.n_blocks — the runtime config is the live knob;
# this constant documents the canonical parity value.
REDUCE_BLOCKS = 8


class PrototypeSet(NamedTuple):
    x: jax.Array        # (n_max, d) prototype coordinates (padded)
    mass: jax.Array     # (n_max,) total original-unit mass per prototype
    valid: jax.Array    # (n_max,) bool — real prototype vs padding


def reduce_to_prototypes(
    x: jax.Array,
    labels: jax.Array,
    n_max: int,
    *,
    weights: Optional[jax.Array] = None,
    weighted: bool = True,
    impl: Optional[str] = None,
    n_blocks: Optional[int] = None,
) -> PrototypeSet:
    """Collapse clusters to centroid prototypes.

    ``labels`` in [0, n_max) (use -1 / out-of-range for masked rows — they are
    dropped). ``weighted=False`` reproduces the paper exactly (plain centroid
    of the points at this level); ``weighted=True`` carries original-unit mass
    through ITIS levels (mass-correct centroids — the beyond-paper fix).
    ``mass`` always accumulates true unit counts for the size guarantee and
    for weighted clustering of the prototypes downstream. ``n_blocks`` pins
    the accumulation order (see ``ops.blocked_segment_sum``); it and ``impl``
    default to the active runtime config, resolved before the jit boundary.
    """
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    n_blocks = cfg.n_blocks if n_blocks is None else n_blocks
    return _reduce_to_prototypes(x, labels, n_max, weights=weights,
                                 weighted=weighted, impl=impl,
                                 n_blocks=n_blocks,
                                 _dispatch=cfg.dispatch_key())


@functools.partial(
    jax.jit,
    static_argnames=("n_max", "weighted", "impl", "n_blocks", "_dispatch"),
)
def _reduce_to_prototypes(
    x: jax.Array,
    labels: jax.Array,
    n_max: int,
    *,
    weights: Optional[jax.Array],
    weighted: bool,
    impl: str,
    n_blocks: int,
    _dispatch: tuple = (),  # cache-key pin for trace-time config reads (§10)
) -> PrototypeSet:
    n = x.shape[0]
    w = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    safe_labels = jnp.where(labels >= 0, labels, n_max).astype(jnp.int32)

    if weighted:
        sums, denom = ops.blocked_segment_sum(
            x, safe_labels, n_max, weights=w, n_blocks=n_blocks, impl=impl)
        mass = denom
    else:
        ones = jnp.where(labels >= 0, 1.0, 0.0).astype(jnp.float32)
        sums, denom = ops.blocked_segment_sum(
            x, safe_labels, n_max, weights=ones, n_blocks=n_blocks, impl=impl)
        _, mass = ops.blocked_segment_sum(
            jnp.zeros((n, 1), x.dtype), safe_labels, n_max, weights=w,
            n_blocks=n_blocks, impl=impl,
        )
    protos = sums / jnp.maximum(denom, 1e-12)[:, None]
    valid = denom > 0
    protos = jnp.where(valid[:, None], protos, 0.0).astype(x.dtype)
    return PrototypeSet(protos, mass, valid)


def compose_assignments(levels: Sequence[jax.Array], final: jax.Array) -> jax.Array:
    """Back out labels to the original units.

    ``levels[l]`` maps level-l points to level-(l+1) prototype ids; ``final``
    maps the last level's prototypes to backend cluster labels. -1 entries
    (padding) propagate as -1.
    """
    lab = levels[0]
    for nxt in list(levels[1:]) + [final]:
        ok = lab >= 0
        lab = jnp.where(ok, nxt[jnp.where(ok, lab, 0)], -1)
    return lab


def standardize(x: jax.Array, valid: Optional[jax.Array] = None) -> jax.Array:
    """Standardized-Euclidean preprocessing (the paper's recommended metric)."""
    if valid is None:
        mu = jnp.mean(x, axis=0)
        sd = jnp.std(x, axis=0)
    else:
        w = valid.astype(x.dtype)[:, None]
        denom = jnp.maximum(jnp.sum(w), 1.0)
        mu = jnp.sum(x * w, axis=0) / denom
        sd = jnp.sqrt(jnp.sum(jnp.square(x - mu) * w, axis=0) / denom)
    return (x - mu) / jnp.maximum(sd, 1e-12)
