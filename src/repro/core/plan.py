"""Unified fit planner/executor architecture — one ``repro.fit()`` over
every execution strategy.

The paper's pipeline is one algorithm — ITIS reduces n units to weighted
prototypes, a registered backend labels the prototypes, labels are backed
out — but the repo had grown three hand-rolled drivers (``ihtc``,
``ihtc_sharded``, ``ihtc_streaming``) that each re-implemented parameter
validation, level scheduling, backend finalize and label back-out, returned
three result types, and could not compose (no out-of-core *and*
multi-device fit). This module is the split that makes the aggregation
layer a pluggable front-end instead of three incidental copies:

  * :class:`FitPlan` — everything decided *before* any data moves: the
    reduction parameters (validated once), the key schedule, the backend
    spec, every dispatch knob resolved from the active
    :class:`repro.runtime.RuntimeConfig`, and the **executor** choice
    (``chunk stream → streaming``, ``mesh → sharded``, both → the composed
    ``streaming_sharded`` path).
  * the **executor registry** — ``@register_executor("memory")`` etc.; an
    executor owns exactly one thing, its data-movement strategy, and
    returns a :class:`Reduction` (final prototype buffers + the back-out
    maps it spilled along the way).
  * the **planner epilogue** — backend finalize (registry resolution,
    mass-weighting, ``-1`` masking of invalid rows) and label back-out
    (:func:`repro.core.prototypes.compose_assignments` on device maps, or
    host composition over a :class:`LabelSpill`) live here exactly once.
  * :class:`FitResult` — the one canonical fitted artifact every executor
    returns (a superset of the old ``IHTCResult`` / ``StreamingIHTCResult``,
    which survive as thin deprecation aliases).

``repro.fit(x_or_chunks, t, m, backend)`` is the public entry point;
``ClusterIndex.build`` and ``ClusterService.from_fit`` consume the result
uniformly. DESIGN.md §13 documents the executor contract and the
composed-reservoir invariants.
"""
from __future__ import annotations

import dataclasses
from typing import (
    Any, Callable, Dict, Iterator, List, Mapping, NamedTuple, Optional,
    Sequence, Tuple, Union,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.cluster.registry import BackendFn, resolve_backend
from repro.core.itis import level_sizes, validate_reduction_params
from repro.core.prototypes import compose_assignments

# ---------------------------------------------------------------------------
# executor registry (the twin of repro.cluster.registry, one level up:
# backends label prototypes, executors move data)
# ---------------------------------------------------------------------------

# uniform executor signature: reduction = fn(plan, data)
ExecutorFn = Callable[["FitPlan", Any], "Reduction"]

#: executors that place level buffers on a mesh (and therefore must not be
#: handed a single-device ``knn_block`` — see :func:`plan_fit`)
SHARDED_EXECUTORS = ("sharded", "streaming_sharded")

#: executors that consume a chunk iterator instead of a resident array
STREAMING_EXECUTORS = ("streaming", "streaming_sharded")

_REGISTRY: Dict[str, ExecutorFn] = {}


def register_executor(name: str) -> Callable[[ExecutorFn], ExecutorFn]:
    """Decorator: ``@register_executor("memory")`` on an ExecutorFn."""

    def deco(fn: ExecutorFn) -> ExecutorFn:
        if name in _REGISTRY and _REGISTRY[name] is not fn:
            raise ValueError(f"executor {name!r} is already registered "
                             f"({_REGISTRY[name]!r})")
        _REGISTRY[name] = fn
        return fn

    return deco


def _ensure_builtin_executors() -> None:
    # importing the modules runs their @register_executor decorators; local
    # import keeps plan importable from anywhere without a cycle
    from repro.core import distributed, ihtc, streaming  # noqa: F401


def resolve_executor(name: str) -> ExecutorFn:
    """Executor name → registered ExecutorFn (the one resolution point)."""
    _ensure_builtin_executors()
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown executor {name!r}; have {available_executors()}")
    return _REGISTRY[name]


def available_executors() -> list:
    """Sorted names of every registered executor."""
    _ensure_builtin_executors()
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# the executor output contract
# ---------------------------------------------------------------------------


class LabelSpill:
    """Host-side back-out state a streaming executor spilled while it ran.

    One int32 assignment map per chunk (chunk-local prototype id, ``-1`` for
    masked rows) plus one map per cascade / compaction / finalize level, in
    epoch order; ``chunk_offset`` places each chunk's prototype slab in the
    reservoir and ``chunk_epoch`` says how many maps existed at fold time,
    so a chunk is only composed through the maps recorded at-or-after its
    fold (DESIGN.md §12). Everything here is host numpy — nothing O(n) ever
    lands on device: the constructor enforces the forced-copy contract
    (every map a real ``np.ndarray``), so a deferred spill drain that
    forgot to materialize a device buffer fails here, not at back-out.

    ``ingest_stats`` (optional) carries the stream loop's pipeline
    telemetry (DESIGN.md §18): prefetch depth, donation flag, loop wall
    seconds and the time the consumer spent waiting on ingest —
    benchmarks/bench_ingest.py derives ``device_idle_frac`` from it.
    """

    def __init__(
        self,
        *,
        chunk_n: int,
        chunk_assign: List[np.ndarray],
        chunk_offset: List[int],
        chunk_epoch: List[int],
        chunk_counts: List[int],
        maps: List[np.ndarray],
        n_cascades: int,
        ingest_stats: Optional[dict] = None,
    ):
        for name, arrs in (("chunk_assign", chunk_assign), ("maps", maps)):
            for a in arrs:
                if not isinstance(a, np.ndarray):
                    raise TypeError(
                        f"LabelSpill.{name} must be host numpy (forced "
                        f"copies, §12); got {type(a).__name__} — a spill "
                        f"drain left a device buffer behind")
        self.chunk_n = chunk_n
        self.chunk_assign = chunk_assign
        self.chunk_offset = chunk_offset
        self.chunk_epoch = chunk_epoch
        self.chunk_counts = chunk_counts
        self.maps = maps
        self.n_cascades = n_cascades
        self.ingest_stats = ingest_stats

    @property
    def n_chunks(self) -> int:
        return len(self.chunk_assign)

    @property
    def n_total(self) -> int:
        return int(sum(self.chunk_counts))

    def labels_for(self, chunk_idx: int,
                   proto_labels_host: np.ndarray) -> np.ndarray:
        """Compose chunk ``chunk_idx``'s map through every level map from
        its epoch onward, then through the backend labels (pure numpy)."""
        count = self.chunk_counts[chunk_idx]
        lab = self.chunk_assign[chunk_idx][:count].astype(np.int64)
        slot = np.where(lab >= 0, lab + self.chunk_offset[chunk_idx], -1)
        for mp in self.maps[self.chunk_epoch[chunk_idx]:]:
            slot = np.where(slot >= 0, mp[np.maximum(slot, 0)], -1)
        out = np.where(slot >= 0, proto_labels_host[np.maximum(slot, 0)], -1)
        return out.astype(np.int32)


class Reduction(NamedTuple):
    """What an executor hands back to the planner: the final prototype
    buffers plus whatever back-out state its data-movement strategy
    produced (device-resident level maps, or a host :class:`LabelSpill`).
    The planner owns everything after this point — backend finalize and
    label back-out — so no executor ever touches the backend registry."""

    protos: jax.Array          # (n_max, d) final-level prototypes (padded)
    mass: jax.Array            # (n_max,)
    valid: jax.Array           # (n_max,) bool
    n_prototypes: jax.Array    # () int32 — valid count at the final level
    assignments: Sequence[jax.Array]  # device level maps ([] for streaming)
    n0: int                    # original unit count (back-out slice length)
    spill: Optional[LabelSpill] = None


# ---------------------------------------------------------------------------
# the canonical result
# ---------------------------------------------------------------------------


class _SpillLabels:
    """Lazy label view over a :class:`LabelSpill`.

    Kept callable so the historical streaming API ``result.labels()`` keeps
    working, and array-convertible (``np.asarray(result.labels)``) so the
    in-memory idiom works on streamed fits too. Prefer
    :meth:`FitResult.iter_labels` at scale — this view concatenates."""

    def __init__(self, result: "FitResult"):
        self._result = result

    def __call__(self) -> np.ndarray:
        r = self._result
        if r.n_chunks == 0:
            return np.zeros((0,), np.int32)
        return np.concatenate(list(r.iter_labels()))

    def __array__(self, dtype=None):
        out = self()
        return out if dtype is None else out.astype(dtype)

    def __repr__(self) -> str:
        return (f"<spilled labels of {self._result.n_total} units over "
                f"{self._result.n_chunks} chunks; call or np.asarray() to "
                f"materialize>")


class FitResult:
    """Canonical fitted artifact of every executor (DESIGN.md §13).

    Device-resident (all O(n/(t*)^m) or O(reservoir), never O(n) for the
    streaming family): ``protos`` / ``proto_mass`` / ``proto_valid`` — the
    final prototype buffer; ``proto_labels`` — backend labels (-1 for
    padding/noise); ``n_prototypes`` — valid count.

    ``labels``: for in-memory executors, the (n,) int32 device array backed
    out through the level maps; for streaming executors, a lazy host view
    (callable, the historical API, and ``np.asarray``-able) composed from
    the :class:`LabelSpill`. ``labels_for(i)`` / ``iter_labels()`` stream
    labels chunk-by-chunk for either family; ``to_index()`` freezes the
    servable :class:`repro.core.index.ClusterIndex`.

    The old ``IHTCResult`` / ``StreamingIHTCResult`` names are deprecation
    aliases of this class.
    """

    def __init__(
        self,
        *,
        executor: str,
        protos: jax.Array,
        proto_mass: jax.Array,
        proto_valid: jax.Array,
        proto_labels: jax.Array,
        n_prototypes: jax.Array,
        assignments: Sequence[jax.Array] = (),
        labels: Optional[jax.Array] = None,
        spill: Optional[LabelSpill] = None,
    ):
        if (labels is None) == (spill is None):
            raise ValueError("FitResult needs exactly one of labels= "
                             "(in-memory back-out) or spill= (streaming)")
        self.executor = executor
        self.protos = protos
        self.proto_mass = proto_mass
        self.proto_valid = proto_valid
        self.proto_labels = proto_labels
        self.n_prototypes = n_prototypes
        self.assignments = assignments
        self.spill = spill
        self._labels = labels
        self._proto_labels_host: Optional[np.ndarray] = None

    # ---- labels -----------------------------------------------------------

    @property
    def labels(self):
        """(n,) int32 device labels (in-memory executors) or the lazy host
        view over the spill (streaming executors; call it or np.asarray)."""
        if self._labels is not None:
            return self._labels
        return _SpillLabels(self)

    def _proto_labels_np(self) -> np.ndarray:
        if self._proto_labels_host is None:
            # repro: allow[HS201]: fit epilogue — final labels materialize to host once, cached; the fit loop is already complete
            self._proto_labels_host = np.asarray(self.proto_labels)
        return self._proto_labels_host

    def labels_for(self, chunk_idx: int) -> np.ndarray:
        """Final labels of chunk ``chunk_idx``'s valid rows (host numpy).
        In-memory fits are one chunk: only index 0 exists."""
        if self.spill is not None:
            return self.spill.labels_for(chunk_idx, self._proto_labels_np())
        if chunk_idx != 0:
            raise IndexError(
                f"in-memory fit has a single chunk; got index {chunk_idx}")
        # repro: allow[HS201]: fit epilogue — labels_for is the documented host hand-off point, after the fit completed
        return np.asarray(self._labels)

    def iter_labels(self) -> Iterator[np.ndarray]:
        """Final labels, one array per input chunk, in stream order."""
        for c in range(self.n_chunks):
            yield self.labels_for(c)

    # ---- stream bookkeeping (degenerate for in-memory fits) ---------------

    @property
    def n_chunks(self) -> int:
        return self.spill.n_chunks if self.spill is not None else 1

    @property
    def n_total(self) -> int:
        if self.spill is not None:
            return self.spill.n_total
        return int(self._labels.shape[0])

    @property
    def n_cascades(self) -> int:
        return self.spill.n_cascades if self.spill is not None else 0

    @property
    def chunk_n(self) -> Optional[int]:
        return self.spill.chunk_n if self.spill is not None else None

    # ---- conversion -------------------------------------------------------

    def to_index(self, *, pack: bool = True):
        """Freeze into a servable :class:`repro.core.index.ClusterIndex`
        (via :meth:`ClusterIndex.build` — ``pack=True`` also freezes the
        bf16/int8 prototype buffers the quantized assign variants serve
        from; bitwise-identical assigns either way, the packed form just
        skips the per-trace repack)."""
        from repro.core.index import ClusterIndex  # lazy: no import cycle

        return ClusterIndex.build(self, pack=pack)

    def __repr__(self) -> str:
        return (f"FitResult(executor={self.executor!r}, "
                f"n_prototypes={int(self.n_prototypes)}, "
                f"n_chunks={self.n_chunks})")


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class FitPlan:
    """Everything decided before any data moves.

    Reduction parameters are validated at construction; the key schedule,
    level schedule and shard-padding rules live here as methods so no
    executor re-implements them. Executors read the plan; only the planner
    (:func:`execute_plan`) runs the backend and backs labels out.
    """

    t: int
    m: int
    backend: Union[str, BackendFn]
    executor: str
    key: jax.Array
    weighted: bool = False
    use_mass_in_backend: bool = True
    impl: str = "auto"
    knn_block: int = 0
    block_q: int = 256
    block_k: int = 512
    n_blocks: int = 8
    chunk_n: int = 0
    reservoir_n: int = 0
    prefetch_depth: int = 0
    donate_stream: bool = False
    mesh: Any = None
    axis_name: str = "data"
    min_points: int = 4
    weights: Optional[jax.Array] = None
    valid: Optional[jax.Array] = None
    driver: str = "fit"
    backend_kwargs: Mapping[str, Any] = dataclasses.field(
        default_factory=dict)

    # ---- the logic the three drivers used to re-implement -----------------

    def schedule(self, n0: int, *, multiple: int = 1) -> List[int]:
        """Static buffer size of every level, 0..m inclusive (the single
        source both single- and multi-device executors derive shapes from).
        """
        return level_sizes(n0, self.t, self.m, multiple=multiple)

    def reduction_floor(self) -> int:
        """Fewer valid points than this and a level must not run (the
        shared early-stop rule: reduction would collapse everything)."""
        return max(self.min_points, 2 * self.t)

    def shard_count(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.axis_name]

    def shard_multiple(self) -> int:
        """Level-buffer padding multiple for mesh executors: the smallest
        multiple of the shard count covering the canonical reduction block
        width, so every level splits evenly and the fixed-tree segment sums
        stay bit-comparable to the single-device path (DESIGN.md §4.3)."""
        p = self.shard_count()
        return -(-max(self.n_blocks, p) // p) * p

    def split_keys(self) -> Tuple[jax.Array, jax.Array]:
        """(key_itis, key_backend) — the root split every executor shares,
        so aligned configs reproduce each other bit-for-bit."""
        key_itis, key_backend = jax.random.split(self.key)
        return key_itis, key_backend


def _is_chunk_stream(data: Any) -> bool:
    """Resident 2-D array → in-memory family; any other iterable → chunks."""
    return not (hasattr(data, "ndim") and hasattr(data, "shape"))


def plan_fit(
    data: Any,
    t: int,
    m: int,
    backend: Union[str, BackendFn] = "kmeans",
    *,
    executor: Optional[str] = None,
    weights: Optional[jax.Array] = None,
    valid: Optional[jax.Array] = None,
    weighted: bool = False,
    use_mass_in_backend: bool = True,
    key: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    knn_block: Optional[int] = None,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    n_blocks: Optional[int] = None,
    chunk_n: Optional[int] = None,
    reservoir_n: Optional[int] = None,
    prefetch_depth: Optional[int] = None,
    donate_stream: Optional[bool] = None,
    mesh=None,
    axis_name: Optional[str] = None,
    min_points: int = 4,
    driver: str = "fit",
    **backend_kwargs,
) -> FitPlan:
    """Resolve one :class:`FitPlan` from the call, the input shape and the
    active runtime config (explicit kwargs win — the §10 contract).

    Executor choice (when neither ``executor=`` nor the config names one):
    a chunk iterator streams, a resident array stays in memory, and a mesh
    (explicit or configured) upgrades either to its sharded flavour —
    ``streaming + mesh`` is the composed out-of-core multi-device path.

    Inputs the chosen executor cannot honour are rejected loudly rather
    than silently dropped: ``knn_block`` on sharded executors (the ring
    kNN has no blocked scan), ``weights`` on streaming executors (chunk
    streams carry unit mass), and ``valid`` anywhere but the ``sharded``
    executor (streams mask rows with ``(chunk, n_valid)`` pairs instead).
    """
    cfg = runtime.active()
    explicit_knn_block = knn_block is not None
    auto_block_q = block_q is None
    auto_block_k = block_k is None
    impl = cfg.impl if impl is None else impl
    knn_block = cfg.knn_block if knn_block is None else knn_block
    block_q = cfg.block_q if block_q is None else block_q
    block_k = cfg.block_k if block_k is None else block_k
    n_blocks = cfg.n_blocks if n_blocks is None else n_blocks
    chunk_n = cfg.chunk_n if chunk_n is None else chunk_n
    reservoir_n = cfg.reservoir_n if reservoir_n is None else reservoir_n
    explicit_prefetch = prefetch_depth is not None
    explicit_donate = donate_stream is not None
    prefetch_depth = (cfg.prefetch_depth if prefetch_depth is None
                      else prefetch_depth)
    donate_stream = (cfg.donate_stream if donate_stream is None
                     else donate_stream)
    mesh = cfg.mesh if mesh is None else mesh
    axis_name = cfg.axis_name if axis_name is None else axis_name
    if key is None:
        key = jax.random.PRNGKey(0)

    streaming_input = _is_chunk_stream(data)
    if executor is None and cfg.executor != "auto":
        executor = cfg.executor
    if executor is None:
        if streaming_input:
            executor = "streaming_sharded" if mesh is not None else "streaming"
        else:
            executor = "sharded" if mesh is not None else "memory"
    resolve_executor(executor)  # unknown names fail here, loudly

    if streaming_input and executor not in STREAMING_EXECUTORS:
        raise ValueError(
            f"{driver}: executor {executor!r} needs a resident (n, d) array "
            f"but got a chunk stream; use a streaming executor or pass the "
            f"materialized array")
    if not streaming_input and executor in STREAMING_EXECUTORS:
        raise ValueError(
            f"{driver}: executor {executor!r} consumes an iterable of host "
            f"chunks; wrap a resident array as iter([x]) to stream it")
    if executor in SHARDED_EXECUTORS and mesh is None:
        from repro.core.distributed import make_data_mesh  # lazy: no cycle

        mesh = make_data_mesh()

    # satellite fix: ihtc() used to silently DROP knn_block when a mesh
    # dispatched it to the sharded path (ring_knn shards keys instead of
    # blocking them, so the knob cannot be honoured there). Reject loudly.
    if executor in SHARDED_EXECUTORS and explicit_knn_block and knn_block:
        raise ValueError(
            f"{driver}: knn_block={knn_block} cannot apply to the "
            f"{executor!r} executor — the sharded kNN is a ring pass over "
            f"mesh shards (repro.core.knn.ring_knn), not a blocked scan; "
            f"drop the kwarg (a configured runtime knn_block is ignored on "
            f"sharded executors) or run a single-device executor")

    # same loud-reject treatment for inputs an executor cannot honour:
    # silently dropping a weight vector or a validity mask would corrupt
    # the fit in ways that only surface at scale
    if weights is not None and executor in STREAMING_EXECUTORS:
        raise ValueError(
            f"{driver}: weights= cannot apply to the {executor!r} executor "
            f"— per-unit weights need the resident array; chunk streams "
            f"carry unit mass (fold weighted data into the chunks, or use "
            f"an in-memory executor)")
    if valid is not None and executor != "sharded":
        raise ValueError(
            f"{driver}: valid= marks pre-padded rows of a resident mesh "
            f"array and only the 'sharded' executor honours it (got "
            f"{executor!r}); slice the array instead, or mask stream "
            f"chunks with (chunk, n_valid) pairs")
    if prefetch_depth < 0:
        raise ValueError(
            f"{driver}: prefetch_depth must be >= 0, got {prefetch_depth}")
    # the ingest-pipeline knobs only mean something to the stream loop
    # (DESIGN.md §18); an explicit value on an in-memory executor would be
    # silently dropped, so reject it like knn_block/weights above
    if executor not in STREAMING_EXECUTORS:
        if explicit_prefetch and prefetch_depth:
            raise ValueError(
                f"{driver}: prefetch_depth={prefetch_depth} cannot apply "
                f"to the {executor!r} executor — only the streaming "
                f"executors stage chunks (a configured runtime "
                f"prefetch_depth is ignored elsewhere)")
        if explicit_donate and donate_stream:
            raise ValueError(
                f"{driver}: donate_stream=True cannot apply to the "
                f"{executor!r} executor — only the streaming executors "
                f"hold a reservoir to donate (a configured runtime "
                f"donate_stream is ignored elsewhere)")

    # tuned-dispatch resolution (DESIGN.md §14): with the tuning policy
    # active, auto knobs resolve through the measured winners for this
    # hardware + shape bucket and the results are FROZEN into the plan, so
    # executor dispatch stays deterministic for the plan's lifetime even if
    # the cache mutates mid-fit. Explicit kwargs and non-auto configured
    # values still win; tune="off" leaves every constant bit-for-bit.
    if cfg.tune != "off":
        from repro import tune  # lazy: no cycle through core

        if streaming_input:
            if chunk_n == 0 or (prefetch_depth == 0
                                and not explicit_prefetch):
                ts = tune.tuned_params("stream")
                if chunk_n == 0:
                    if ts.get("chunk_n"):
                        chunk_n = int(ts["chunk_n"])
                    if reservoir_n == 0 and ts.get("reservoir_n"):
                        reservoir_n = int(ts["reservoir_n"])
                # depth 0 is the serial default, not a measured choice:
                # treat it as "auto" unless the caller pinned it (explicit
                # kwargs always win; donation stays manual — it changes
                # buffer lifetimes, not a measurable constant)
                if (prefetch_depth == 0 and not explicit_prefetch
                        and ts.get("prefetch_depth") is not None):
                    prefetch_depth = int(ts["prefetch_depth"])
        else:
            n0, d0 = int(data.shape[0]), int(data.shape[1])
            dt = str(data.dtype) if hasattr(data, "dtype") else "float32"
            tk = tune.tuned_params("knn", dtype=dt, n=n0, d=d0,
                                   k=max(t - 1, 1))
            if auto_block_q and tk.get("block_q"):
                block_q = int(tk["block_q"])
            if auto_block_k and tk.get("block_k"):
                block_k = int(tk["block_k"])
            if (knn_block == 0 and not explicit_knn_block
                    and executor not in SHARDED_EXECUTORS):
                tb = tune.tuned_params("knn_block", dtype=dt, n=n0, d=d0,
                                       k=max(t - 1, 1))
                if tb.get("knn_block"):
                    knn_block = int(tb["knn_block"])
            # the "assign" cell tunes the fused nearest/top-k family
            # (DESIGN.md §16): if its measured winner is a fused variant
            # and the impl policy is auto, freeze the fused streaming path
            # into the plan — the TC inner loop dispatches through the
            # same kernel, and ops without a fused path degrade it to
            # auto, so the frozen choice is safe plan-wide. Quantized
            # winners freeze as plain "fused": the fit has no frozen
            # low-precision buffers (those are a serve-time artifact).
            if impl == "auto" and executor not in SHARDED_EXECUTORS:
                ta = tune.tuned_params("assign", dtype=dt, nq=n0, p=n0,
                                       d=d0, k=max(t - 1, 1))
                if str(ta.get("impl", "")).startswith("fused"):
                    impl = "fused"

    if streaming_input:
        validate_reduction_params(t, m, min_m=1, driver=driver)
        if chunk_n:
            validate_reduction_params(t, m, n=chunk_n, min_m=1, driver=driver)
    else:
        validate_reduction_params(t, m, n=data.shape[0], driver=driver)

    return FitPlan(
        t=int(t), m=int(m), backend=backend, executor=executor, key=key,
        weighted=weighted, use_mass_in_backend=use_mass_in_backend,
        impl=impl, knn_block=knn_block, block_q=block_q, block_k=block_k,
        n_blocks=n_blocks, chunk_n=chunk_n,
        reservoir_n=reservoir_n, prefetch_depth=int(prefetch_depth),
        donate_stream=bool(donate_stream), mesh=mesh, axis_name=axis_name,
        min_points=min_points, weights=weights, valid=valid, driver=driver,
        backend_kwargs=dict(backend_kwargs),
    )


# ---------------------------------------------------------------------------
# the planner epilogue: backend finalize + label back-out (once, here)
# ---------------------------------------------------------------------------


def _finalize_backend(plan: FitPlan, red: Reduction) -> jax.Array:
    """Label the final prototype buffer: registry resolution, mass
    weighting, and ``-1`` masking of invalid rows — identical for every
    executor. Sharded executors keep ``backend="kmeans"`` on the mesh
    (:func:`repro.core.distributed.kmeans_sharded`); any other backend runs
    single-device on the already-reduced prototype set (O(n/(t*)^m) rows —
    the raw points are still never gathered)."""
    _, key_backend = plan.split_keys()
    w = red.mass if plan.use_mass_in_backend else None
    kwargs = dict(plan.backend_kwargs)
    if plan.executor in SHARDED_EXECUTORS and plan.backend == "kmeans":
        from repro.core.distributed import kmeans_sharded  # lazy: no cycle

        k = kwargs.pop("k", 3)
        iters = kwargs.pop("iters", 100)
        proto_labels = kmeans_sharded(
            red.protos, k, valid=red.valid,
            weights=jnp.ones_like(red.mass) if w is None else w,
            key=key_backend, mesh=plan.mesh, axis_name=plan.axis_name,
            iters=iters, impl=plan.impl, n_blocks=plan.shard_multiple(),
            **kwargs)
    else:
        fn = resolve_backend(plan.backend)
        protos, pvalid, pw = red.protos, red.valid, w
        if plan.executor in SHARDED_EXECUTORS:
            # the host backend cannot consume sharded arrays: gather the
            # (small) prototype set once, after the sharded reduction
            protos = jax.device_get(protos)  # repro: allow[HS201]: sharded epilogue gather
            pvalid = jax.device_get(pvalid)  # repro: allow[HS201]: sharded epilogue gather
            pw = None if pw is None else jax.device_get(pw)  # repro: allow[HS201]: sharded epilogue gather
        proto_labels = fn(protos, valid=pvalid, weights=pw, key=key_backend,
                          impl=plan.impl, **kwargs)
    return jnp.where(red.valid, proto_labels, -1).astype(jnp.int32)


def _plan_scope(plan: FitPlan):
    """The execution config scope (§14): the plan's resolved tile knobs
    pinned, the tune policy clamped to a non-measuring mode (``onthefly``
    → ``cached``; the planner may measure, execution never does). Opening
    the scope is idempotent — nesting it re-applies the same overrides —
    which is what lets the online lifecycle re-run the epilogue under a
    scope bit-identical to the one the executor originally ran in."""
    exec_tune = "off" if runtime.active().tune == "off" else "cached"
    return runtime.configure(block_q=plan.block_q, block_k=plan.block_k,
                             tune=exec_tune)


def finalize_reduction(plan: FitPlan, red: Reduction) -> FitResult:
    """The planner epilogue on an already-produced :class:`Reduction`:
    backend finalize + label back-out + the canonical result — exactly
    what :func:`execute_plan` runs after its executor returns. Split out
    so the online lifecycle (:class:`repro.serve.lifecycle.OnlineFitter`)
    can re-finalize a live reservoir snapshot into a fresh
    :class:`FitResult` through the identical code path."""
    with _plan_scope(plan):
        proto_labels = _finalize_backend(plan, red)
    if red.spill is not None:
        return FitResult(
            executor=plan.executor, protos=red.protos, proto_mass=red.mass,
            proto_valid=red.valid, proto_labels=proto_labels,
            n_prototypes=red.n_prototypes, spill=red.spill)
    if red.assignments:
        labels = compose_assignments(red.assignments, proto_labels)
    else:  # m == 0 or early-stop before level 0: backend ran on x itself
        labels = proto_labels
    labels = labels[: red.n0].astype(jnp.int32)
    return FitResult(
        executor=plan.executor, protos=red.protos, proto_mass=red.mass,
        proto_valid=red.valid, proto_labels=proto_labels,
        n_prototypes=red.n_prototypes, assignments=red.assignments,
        labels=labels)


def execute_plan(plan: FitPlan, data: Any) -> FitResult:
    """Run the plan's executor, then the shared epilogue.

    The executor (and the backend epilogue) run under a config scope
    pinning the plan's resolved ``block_q``/``block_k``, so trace-time
    kernel-tile reads default to what :func:`plan_fit` froze rather than
    whatever the ambient config says by the time data starts moving. The
    tune policy is also clamped to a non-measuring mode (``onthefly`` →
    ``cached``): the planner may measure, execution never does. Note the
    precise contract (§14): the plan's own knobs are frozen, while the
    per-shape ops-level lookups stay live against the cache — epoch-keyed,
    so deeper ITIS levels keep their finer-grained winners and any cache
    mutation retraces correctly. With tuning off both pins are no-ops.
    """
    with _plan_scope(plan):
        red = resolve_executor(plan.executor)(plan, data)
    return finalize_reduction(plan, red)


def fit(
    data: Any,
    t: int,
    m: int,
    backend: Union[str, BackendFn] = "kmeans",
    **kwargs,
) -> FitResult:
    """One ``fit()`` over in-memory, sharded, streaming, and composed
    execution — the public entry point (``repro.fit``).

    ``data`` is either a resident (n, d) array or any iterable of host
    chunks (bare (c, d) arrays or ``(chunk, n_valid)`` pairs). The plan
    resolves every dispatch default from the active runtime config and
    picks the executor from the input type and the mesh; pass
    ``executor="memory" | "sharded" | "streaming" | "streaming_sharded"``
    (or configure ``runtime.configure(executor=...)``) to pin one. All
    :func:`plan_fit` keywords are accepted; unknown keywords flow to the
    backend clusterer.

    Returns the canonical :class:`FitResult`.
    """
    plan = plan_fit(data, t, m, backend, **kwargs)
    return execute_plan(plan, data)
