"""IHTC — Iterative Hybridized Threshold Clustering (the paper's §3.2).

ITIS reduces n units to ≤ n/(t*)^m weighted prototypes, a "sophisticated"
clusterer (k-means / HAC / DBSCAN / any callable) runs on the prototypes,
and labels are backed out to all n units. Guarantee: every final cluster
contains ≥ (t*)^m original units.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro import runtime
from repro.cluster.registry import BackendFn, resolve_backend
from repro.core.itis import ITISResult, itis, validate_reduction_params
from repro.core.prototypes import compose_assignments

# backwards-compatible alias: backend resolution now lives in the registry
_resolve_backend = resolve_backend


class IHTCResult(NamedTuple):
    labels: jax.Array           # (n,) int32 final cluster label per original unit
    proto_labels: jax.Array     # (n_max,) labels of final-level prototypes (-1 pad)
    protos: jax.Array           # (n_max, d)
    proto_mass: jax.Array       # (n_max,)
    proto_valid: jax.Array      # (n_max,) bool
    n_prototypes: jax.Array     # () int32
    assignments: Sequence[jax.Array]


def ihtc(
    x: jax.Array,
    t: int,
    m: int,
    backend: Union[str, BackendFn] = "kmeans",
    *,
    weights: Optional[jax.Array] = None,
    weighted: bool = False,
    use_mass_in_backend: bool = True,
    key: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    knn_block: Optional[int] = None,
    mesh=None,
    axis_name: Optional[str] = None,
    **backend_kwargs,
) -> IHTCResult:
    """Full IHTC pipeline (host driver).

    ``weighted`` controls ITIS centroid weighting (paper-faithful default:
    False). ``use_mass_in_backend`` feeds prototype masses as sample weights
    to the backend clusterer (paper runs backends unweighted; mass-weighting
    is the statistically consistent variant — both supported).

    ``backend`` is a registered name (:mod:`repro.cluster.registry`) or any
    callable satisfying the BackendFn contract. ``impl``/``knn_block``/
    ``mesh``/``axis_name`` default to the active runtime config, so
    ``with runtime.configure(mesh=...)`` shards this call without touching
    the call site.

    Passing ``mesh`` (or configuring one) dispatches to the multi-device
    pipeline (:func:`repro.core.distributed.ihtc_sharded`): every level is
    sharded over the mesh's ``axis_name`` axis and the points are never
    gathered to one device. See DESIGN.md §4 for the determinism contract
    between the two paths.
    """
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    knn_block = cfg.knn_block if knn_block is None else knn_block
    mesh = cfg.mesh if mesh is None else mesh
    axis_name = cfg.axis_name if axis_name is None else axis_name
    validate_reduction_params(t, m, n=x.shape[0], driver="ihtc")
    if mesh is not None:
        from repro.core.distributed import ihtc_sharded  # lazy: no cycle

        return ihtc_sharded(
            x, t, m, backend, mesh=mesh, axis_name=axis_name,
            weights=weights, weighted=weighted,
            use_mass_in_backend=use_mass_in_backend, key=key, impl=impl,
            **backend_kwargs,
        )
    if key is None:
        key = jax.random.PRNGKey(0)
    key_itis, key_backend = jax.random.split(key)

    r: ITISResult = itis(
        x, t, m, weights=weights, key=key_itis, weighted=weighted,
        impl=impl, knn_block=knn_block,
    )
    fn = resolve_backend(backend)
    w = r.mass if use_mass_in_backend else None
    proto_labels = fn(
        r.protos, valid=r.valid, weights=w, key=key_backend, impl=impl,
        **backend_kwargs,
    )
    proto_labels = jnp.where(r.valid, proto_labels, -1).astype(jnp.int32)

    if r.assignments:
        labels = compose_assignments(r.assignments, proto_labels)
    else:  # m == 0 or early-stop before the first level: backend ran on x itself
        labels = proto_labels[: x.shape[0]]
    return IHTCResult(
        labels.astype(jnp.int32), proto_labels, r.protos, r.mass, r.valid,
        r.n_prototypes, r.assignments,
    )
