"""IHTC — Iterative Hybridized Threshold Clustering (the paper's §3.2).

ITIS reduces n units to ≤ n/(t*)^m weighted prototypes, a "sophisticated"
clusterer (k-means / HAC / DBSCAN / any callable) runs on the prototypes,
and labels are backed out to all n units. Guarantee: every final cluster
contains ≥ (t*)^m original units.

Since the planner/executor split (DESIGN.md §13) this module owns exactly
one thing: the **memory executor** — the data-movement strategy for a
dataset resident on one device (the per-level ``itis_step`` loop over
static buffers). Validation, level scheduling, backend finalize and label
back-out live once in :mod:`repro.core.plan`; :func:`ihtc` survives as a
thin deprecation alias over ``repro.fit``.
"""
from __future__ import annotations

from typing import Optional, Union

import jax

from repro.cluster.registry import BackendFn, resolve_backend
from repro.core.itis import itis
from repro.core.plan import FitPlan, FitResult, Reduction, fit, register_executor

# backwards-compatible aliases: backend resolution lives in the registry,
# the result type in the planner
_resolve_backend = resolve_backend
IHTCResult = FitResult


@register_executor("memory")
def _execute_memory(plan: FitPlan, x: jax.Array) -> Reduction:
    """Single-device resident-array strategy: every level is one jitted
    ``itis_step`` over a static padded buffer; assignment maps stay on
    device for the planner's back-out."""
    key_itis, _ = plan.split_keys()
    r = itis(
        x, plan.t, plan.m, weights=plan.weights, key=key_itis,
        weighted=plan.weighted, impl=plan.impl, knn_block=plan.knn_block,
        min_points=plan.min_points, n_blocks=plan.n_blocks,
    )
    return Reduction(
        protos=r.protos, mass=r.mass, valid=r.valid,
        n_prototypes=r.n_prototypes, assignments=r.assignments,
        n0=x.shape[0],
    )


def ihtc(
    x: jax.Array,
    t: int,
    m: int,
    backend: Union[str, BackendFn] = "kmeans",
    *,
    weights: Optional[jax.Array] = None,
    weighted: bool = False,
    use_mass_in_backend: bool = True,
    key: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    knn_block: Optional[int] = None,
    mesh=None,
    axis_name: Optional[str] = None,
    **backend_kwargs,
) -> FitResult:
    """Full IHTC pipeline on a resident array (deprecated alias of
    :func:`repro.fit` — prefer that entry point for new code).

    ``weighted`` controls ITIS centroid weighting (paper-faithful default:
    False). ``use_mass_in_backend`` feeds prototype masses as sample weights
    to the backend clusterer (paper runs backends unweighted; mass-weighting
    is the statistically consistent variant — both supported).

    ``backend`` is a registered name (:mod:`repro.cluster.registry`) or any
    callable satisfying the BackendFn contract. ``impl``/``knn_block``/
    ``mesh``/``axis_name`` default to the active runtime config, so
    ``with runtime.configure(mesh=...)`` shards this call without touching
    the call site.

    Passing ``mesh`` (or configuring one) plans the "sharded" executor:
    every level is sharded over the mesh's ``axis_name`` axis and the
    points are never gathered to one device (DESIGN.md §4 has the
    determinism contract between the two paths). An explicit ``knn_block``
    is rejected there — the ring kNN has no blocked scan to apply it to.
    """
    return fit(
        x, t, m, backend,
        weights=weights, weighted=weighted,
        use_mass_in_backend=use_mass_in_backend, key=key, impl=impl,
        knn_block=knn_block, mesh=mesh, axis_name=axis_name, driver="ihtc",
        **backend_kwargs,
    )
