"""Production training launcher.

    python -m repro.launch.train --arch qwen2.5-32b --shape train_4k \
        --steps 100 [--mesh pod1|pod2|debug|single] [--select-instances]

On real TPU pods this launches under `jax.distributed`; on the CPU container
use --mesh single (1 device) or debug (8 host devices) for a real sharded
run. XLA latency-hiding-scheduler flags are set for collective overlap.
"""
import os

_LHS_FLAGS = (
    " --xla_tpu_enable_latency_hiding_scheduler=true"
    " --xla_tpu_enable_async_collective_fusion=true"
)
if "--mesh debug" in " ".join(os.sys.argv):  # 8 host devices before jax init
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    )
elif os.environ.get("JAX_PLATFORMS", "") not in ("", "cpu"):
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + _LHS_FLAGS

import argparse  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES  # noqa: E402
from repro.configs.base import ParallelConfig  # noqa: E402
from repro.data import make_batch  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    data_axes,
    make_debug_mesh,
    make_plan,
    make_production_mesh,
)
from repro.models import build  # noqa: E402
from repro.train import (  # noqa: E402
    CheckpointManager,
    OptConfig,
    init_opt_state,
    make_train_step,
)
from repro.train.fault_tolerance import run_training  # noqa: E402
from repro.train.optimizer import zero_opt_specs  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=("single", "debug", "pod1", "pod2"))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=0, help="override batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="block", choices=("none", "block", "dots"))
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    shape = SHAPES[args.shape]
    bundle = build(cfg)
    parallel = ParallelConfig(remat=args.remat, microbatches=args.microbatches)

    if args.mesh == "single":
        from repro.models.transformer import ShardingPlan

        mesh = None
        plan = ShardingPlan()
    else:
        mesh = (make_debug_mesh(2, 4) if args.mesh == "debug"
                else make_production_mesh(multi_pod=(args.mesh == "pod2")))
        plan = make_plan(cfg, shape, mesh)

    params = bundle.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    if mesh is not None:
        tp_size = mesh.shape["model"]
        pspecs = bundle.param_specs(tp="model", tp_size=tp_size)
        ospecs = zero_opt_specs(pspecs, params, data_axes(mesh),
                                dict(mesh.shape))
        put = lambda tree, specs: jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs,
            is_leaf=lambda x: hasattr(x, "shape"))
        params = put(params, pspecs)
        opt = put(opt, ospecs)

    step = jax.jit(make_train_step(bundle, OptConfig(
        decay_steps=max(args.steps, 100)), parallel, plan))

    b = args.batch or min(shape.global_batch, 8)
    s = args.seq or min(shape.seq_len, 256)
    bfs = lambda st: make_batch(cfg, shape, st, batch_override=b,
                                seq_override=s)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt and args.resume and ckpt.latest_step():
        start = ckpt.latest_step()
        state = ckpt.restore(start, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    def on_metrics(st, m):
        if st % 10 == 0:
            print(f"step {st:>6} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.2f}")

    ctx = mesh if mesh is not None else _null_ctx()
    with ctx:
        params, opt, stats = run_training(
            train_step=step, init_state=(params, opt), batch_for_step=bfs,
            n_steps=args.steps, start_step=start,
            ckpt=ckpt, ckpt_every=args.ckpt_every, on_metrics=on_metrics)
    q = stats.quantiles()
    print(f"done: {args.steps - start} steps, p50 {q.get('p50', 0):.3f}s, "
          f"p99 {q.get('p99', 0):.3f}s, stragglers {stats.stragglers()}")


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
