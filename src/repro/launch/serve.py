"""Serving launcher: batched generation with optional IHTC KV compression.

    python -m repro.launch.serve --arch gemma2-2b --batch 4 --prompt-len 64 \
        --new-tokens 32 --compress
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.models import build
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--compress-t", type=int, default=2)
    ap.add_argument("--compress-m", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    args = ap.parse_args()

    cfg = smoke_config(ARCHS[args.arch]) if args.smoke else ARCHS[args.arch]
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        jnp.int32)

    eng = ServeEngine(bundle, params, ServeConfig(
        max_new_tokens=args.new_tokens, temperature=args.temperature,
        compress=args.compress, compress_t=args.compress_t,
        compress_m=args.compress_m))
    import time

    t0 = time.perf_counter()
    out = eng.generate({"tokens": prompts})
    sec = time.perf_counter() - t0
    toks = args.batch * out["n_steps"]
    print(f"generated {out['tokens'].shape} in {sec:.2f}s "
          f"({toks / sec:.1f} tok/s, {out['compressions']} recompressions)")


if __name__ == "__main__":
    main()
