"""Production mesh + per-(arch × shape) sharding plans.

Mesh axes: 'pod' (cross-pod DP, slow DCN links), 'data' (in-pod DP / ZeRO /
sequence), 'model' (TP/EP). Defined as functions so importing this module
never touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import ShardingPlan


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU distribution tests (device count set by the test)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_data_mesh(n_data: Optional[int] = None):
    """1-D ``("data",)`` mesh for the sharded clustering pipeline.

    The distributed ITIS/IHTC drivers (repro.core.distributed) shard points,
    kNN graphs and prototype buffers over this single axis; model-parallel
    axes are irrelevant to clustering, so the full device set goes to data.
    """
    from repro.core.distributed import make_data_mesh as _mk

    return _mk(n_data)


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def make_plan(cfg: ModelConfig, shape: ShapeConfig, mesh,
              *, heads_mode: str = "auto") -> ShardingPlan:
    """Activation-sharding plan for one (arch × shape × mesh) cell.

    heads_mode (for archs whose head counts don't divide TP):
      auto — leave attention sharding to SPMD propagation;
      seq  — context parallelism: q sequence-sharded over 'model', k/v
             replicated once per layer (one small all-gather)."""
    dp = data_axes(mesh)
    dp_size = axis_size(mesh, dp)
    tp_size = mesh.shape["model"]
    b = shape.global_batch

    batch_axes = dp if (b % dp_size == 0 and b >= dp_size) else None
    heads_ok = cfg.n_heads % tp_size == 0 if cfg.n_heads else False
    kv_ok = cfg.n_kv_heads % tp_size == 0 if cfg.n_kv_heads else False
    # heads not divisible by TP (qwen/llama4 40H, gemma2 8H at tp=16): leave
    # attention sharding to SPMD propagation — XLA partially tiles the kv
    # heads (e.g. 8-of-16 with replication), which beats both forced
    # replication (q all-gather) and forced q-seq sharding (per-chunk
    # resharding thrash). Measured in EXPERIMENTS.md §Perf.
    kv_spec = None
    if heads_ok:
        heads_spec = P(batch_axes, "model", None, None)
    elif heads_mode == "seq" and shape.kind in ("train", "prefill"):
        heads_spec = P(batch_axes, None, "model", None)
        kv_spec = P(batch_axes, None, None, None)  # replicate k/v once
    else:
        heads_spec = None

    if cfg.ssm_state:
        from repro.models.mamba2 import _dims

        _, h_m, _, _ = _dims(cfg)
        mamba_ok = h_m % tp_size == 0
    else:
        mamba_ok = False

    # decode KV cache: batch over dp when possible; kv-heads over model when
    # divisible, else sequence over model (flash-decoding style partial
    # softmax — XLA partitions the softmax reduction); batch=1 long-context
    # shards the sequence over everything available.
    if shape.kind == "decode":
        if b == 1:
            seq_axes = dp + ("model",) if not kv_ok else dp
            cache = P(None, "model" if kv_ok else None, seq_axes, None)
        elif kv_ok:
            cache = P(batch_axes, "model", None, None)
        else:
            cache = P(batch_axes, None, "model", None)
    else:
        cache = P(batch_axes, "model" if kv_ok else None, None, None)

    if cfg.n_experts and cfg.n_experts % tp_size == 0:
        ep = (P(batch_axes, "model", None, None) if cfg.moe_groups > 1
              else P("model", None, None))
    elif cfg.n_experts:
        ep = P(None, None, None)
    else:
        ep = None
    return ShardingPlan(
        resid=P(batch_axes, None, None),
        heads=heads_spec,
        kv=kv_spec,
        mamba_heads=P(batch_axes, None, "model" if mamba_ok else None, None),
        ep=ep,
        cache=cache,
        logits=P(batch_axes, None, "model"),
    )


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, *, kind: str):
    """PartitionSpec pytree for the input batch dict of this cell."""
    dp = data_axes(mesh)
    dp_size = axis_size(mesh, dp)
    b = shape.global_batch if kind != "decode" else shape.global_batch
    bx = dp if (b % dp_size == 0 and b >= dp_size) else None
    specs = {"tokens": P(bx, None)}
    if kind == "train":
        specs["labels"] = P(bx, None)
    if cfg.frontend == "vision" and kind != "decode":
        specs["patch_embeds"] = P(bx, None, None)
    if cfg.frontend == "audio" and kind != "decode":
        specs["frames"] = P(bx, None, None)
    return specs
