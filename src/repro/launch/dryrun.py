import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract roofline terms. The two lines above MUST run before any jax import —
jax locks the device count at first init. This is the ONLY entry point that
requests 512 host devices (tests/benches see 1).

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/results/dryrun
  python -m repro.launch.dryrun --arch granite-20b --shape long_500k \
      --variant ihtc-kv   # paper-technique-compressed long context
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config  # noqa: E402
from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    batch_specs,
    data_axes,
    make_plan,
    make_production_mesh,
)
from repro.models import build  # noqa: E402
from repro.models.frontends import VISION_PREFIX_TOKENS  # noqa: E402
from repro.train.optimizer import OptConfig, init_opt_state, zero_opt_specs  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402
from repro.utils import hlo as hlo_utils  # noqa: E402
from repro.utils.roofline import build_report, model_flops_for  # noqa: E402
from repro.utils.tree import tree_size  # noqa: E402

# long_500k baseline needs sub-quadratic sequence mixing: only ssm/hybrid
# qualify (DESIGN.md §6). Dense/MoE/enc-dec archs run it only under the
# --variant ihtc-kv paper-technique compression.
LONG_OK_FAMILIES = ("ssm", "hybrid")


def cell_is_baseline_runnable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k" and cfg.family not in LONG_OK_FAMILIES:
        return False
    return True


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _shard_tree(mesh, abstract, specs):
    return jax.tree_util.tree_map(
        lambda a, s: _sds(a.shape, a.dtype, NamedSharding(mesh, s)),
        abstract,
        specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _active_params(cfg: ModelConfig, abstract_params) -> int:
    total = tree_size(abstract_params)
    if not cfg.tie_embeddings:
        total -= cfg.vocab_size * cfg.d_model  # gather table is not matmul flops
    if cfg.n_experts:
        per_expert = 3 * cfg.d_model * cfg.d_ff
        n_moe = sum(cfg.layer_is_moe(l) for l in range(cfg.n_layers))
        total -= n_moe * (cfg.n_experts - cfg.n_experts_per_tok) * per_expert
    return int(total)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, *, kind: str,
                variant: str = "baseline"):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    bx = dp if (b % dp_size == 0 and b >= dp_size) else None
    sh = lambda spec: NamedSharding(mesh, spec)

    if kind == "train":
        batch = {
            "tokens": _sds((b, s), jnp.int32, sh(P(bx, None))),
            "labels": _sds((b, s), jnp.int32, sh(P(bx, None))),
        }
        if cfg.frontend == "vision":
            batch["patch_embeds"] = _sds(
                (b, VISION_PREFIX_TOKENS, cfg.d_model), jnp.bfloat16,
                sh(P(bx, None, None)))
        if cfg.frontend == "audio":
            batch["frames"] = _sds((b, s, cfg.d_model), jnp.bfloat16,
                                   sh(P(bx, None, None)))
        return batch
    if kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32, sh(P(bx, None)))}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = _sds(
                (b, VISION_PREFIX_TOKENS, cfg.d_model), jnp.bfloat16,
                sh(P(bx, None, None)))
        if cfg.frontend == "audio":
            batch["frames"] = _sds((b, s, cfg.d_model), jnp.bfloat16,
                                   sh(P(bx, None, None)))
        return batch
    # decode: one new token against a seq_len KV cache
    return {"tokens": _sds((b, 1), jnp.int32, sh(P(bx, None)))}


def _add_ihtc_bias(c, s):
    """Recursively add prototype bias/mass entries to attention caches
    (shape = k.shape minus head_dim), mirrored in the spec tree."""
    if isinstance(c, dict):
        if "k" in c and "pos" in c:
            kshape = c["k"].shape
            kspec = tuple(s["k"]) + (None,) * (len(kshape) - len(tuple(s["k"])))
            bias_spec = P(*kspec[:-1])
            c, s = dict(c), dict(s)
            c["bias"] = _sds(kshape[:-1], jnp.float32)
            c["mass"] = _sds(kshape[:-1], jnp.float32)
            s["bias"] = bias_spec
            s["mass"] = bias_spec
            return c, s
        cc, ss = {}, {}
        for k2 in c:
            cc[k2], ss[k2] = _add_ihtc_bias(c[k2], s[k2])
        return cc, ss
    if isinstance(c, (list, tuple)):
        pairs = [_add_ihtc_bias(a, b) for a, b in zip(c, s, strict=True)]
        return [p[0] for p in pairs], [p[1] for p in pairs]
    return c, s


def cache_abstract(cfg: ModelConfig, shape: ShapeConfig, mesh, bundle,
                   plan, variant: str):
    """(abstract caches, cache sharding tree) for prefill/decode cells."""
    b, s = shape.global_batch, shape.seq_len
    kw = {}
    if cfg.family == "encdec-audio":
        kw["enc_len"] = s
    if variant == "ihtc-kv":
        t, m, tail = 2, 2, 1024  # 4× compression + fresh tail
        s_c = s // (t**m) + tail
        caches = jax.eval_shape(lambda: bundle.init_caches(b, s_c, **kw))
    else:
        caches = jax.eval_shape(lambda: bundle.init_caches(b, s, **kw))

    tp_size = mesh.shape["model"]
    spec_tree = bundle.cache_specs(plan=plan, tp_size=tp_size)
    if variant == "ihtc-kv":
        caches, spec_tree = _add_ihtc_bias(caches, spec_tree)

    sharded = jax.tree_util.tree_map(
        lambda a, sp: _sds(a.shape, a.dtype, NamedSharding(mesh, sp)),
        caches, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    return sharded, spec_tree


def _lower_and_compile(cfg, shape, mesh, *, variant, parallel, kind,
                       heads_mode="auto", param_dtype="float32"):
    """Lower + compile one step for (possibly layer-reduced) cfg; return raw
    per-chip cost artifacts."""
    bundle = build(cfg)
    plan = make_plan(cfg, shape, mesh, heads_mode=heads_mode)
    tp_size = mesh.shape["model"]
    dp = data_axes(mesh)
    master = param_dtype == "bfloat16"

    abstract_params = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    if master:  # bf16 working params; fp32 master lives in the opt state
        abstract_params = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape,
                jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
            abstract_params,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
    pspecs = bundle.param_specs(tp="model", tp_size=tp_size)
    params_in = _shard_tree(mesh, abstract_params, pspecs)
    t0 = time.time()

    with mesh:
        if kind == "train":
            opt_abstract = jax.eval_shape(
                lambda p: init_opt_state(p, master=master), abstract_params)
            ospecs = zero_opt_specs(
                pspecs, abstract_params, dp, dict(mesh.shape),
                zero_stage=parallel.zero_stage, master=master,
            )
            opt_in = _shard_tree(mesh, opt_abstract, ospecs)
            batch_in = input_specs(cfg, shape, mesh, kind="train")
            step = make_train_step(bundle, OptConfig(), parallel, plan)
            jitted = jax.jit(
                step,
                out_shardings=(
                    jax.tree_util.tree_map(
                        lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P)),
                    jax.tree_util.tree_map(
                        lambda s: NamedSharding(mesh, s), ospecs,
                        is_leaf=lambda x: isinstance(x, P)),
                    None,
                ),
            )
            lowered = jitted.lower(params_in, opt_in, batch_in)
        elif kind == "prefill":
            caches_in, _ = cache_abstract(cfg, shape, mesh, bundle, plan, variant)
            batch_in = input_specs(cfg, shape, mesh, kind="prefill")

            def prefill_fn(params, caches, batch):
                return bundle.prefill(params, caches, batch, plan=plan)

            lowered = jax.jit(prefill_fn).lower(params_in, caches_in, batch_in)
        else:  # decode
            caches_in, _ = cache_abstract(cfg, shape, mesh, bundle, plan, variant)
            batch_in = input_specs(cfg, shape, mesh, kind="decode",
                                   variant=variant)

            def decode_fn(params, caches, batch):
                return bundle.decode_step(params, caches, batch, plan=plan)

            lowered = jax.jit(decode_fn).lower(params_in, caches_in, batch_in)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo_text = compiled.as_text()
    return {
        "abstract_params": abstract_params,
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": hlo_utils.collective_bytes(hlo_text),
        "coll_counts": hlo_utils.collective_op_counts(hlo_text),
        "mem": mem,
        "t_lower": t_lower,
        "t_compile": t_compile,
    }


def run_cell(
    arch: str,
    shape_name: str,
    mesh_name: str,
    *,
    variant: str = "baseline",
    parallel: Optional[ParallelConfig] = None,
    verbose: bool = True,
    cfg_override: Optional[ModelConfig] = None,
    heads_mode: str = "auto",
    param_dtype: str = "float32",
    force: bool = False,  # bypass the long_500k full-attention skip policy
) -> dict:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    chips = int(np.prod(tuple(mesh.shape.values())))
    if parallel is None:
        # train: grad-accumulation microbatches bound activation memory; the
        # per-step cost accounting is unchanged (same total tokens/step).
        micro = 8 if shape.kind == "train" else 1
        parallel = ParallelConfig(
            remat="block" if shape.kind == "train" else "none",
            microbatches=micro,
        )

    if variant == "baseline" and not force \
            and not cell_is_baseline_runnable(cfg, shape):
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "variant": variant, "status": "skip",
            "reason": "full-attention arch at 500k context (DESIGN.md §6); "
                      "runnable under --variant ihtc-kv",
        }

    from repro.models.transformer import stack_plan

    # full-config compile: THE deliverable (proves lower+compile succeeds and
    # yields the real memory analysis)
    full = _lower_and_compile(cfg, shape, mesh, variant=variant,
                              parallel=parallel, kind=shape.kind,
                              heads_mode=heads_mode, param_dtype=param_dtype)

    # HloCostAnalysis counts a while(scan) body ONCE, so the scanned layer
    # stack under-counts by ~n_repeats. Everything *inside* a layer is fully
    # visible (the flash-attention chunk loop is deliberately unrolled — see
    # attention.py), so cost(L) = a + L·b is exact; solve it from two
    # UNROLLED shallow probes at L=1, 2 and extrapolate to the full depth.
    n_prefix, period, rep = stack_plan(cfg)
    enc_stacked = cfg.n_enc_layers >= 2  # enc-dec stacks scale with n_layers too
    if rep >= 3 or enc_stacked:
        def mk(r):
            kw = dict(scan_layers=False)
            if enc_stacked:
                kw.update(n_layers=r, n_enc_layers=r)
            else:
                kw.update(n_layers=n_prefix + period * r)
            return dataclasses.replace(cfg, **kw)

        L_full = cfg.n_layers if enc_stacked else rep
        # probes run without microbatching (a grad-accumulation scan body is
        # also invisible to HloCostAnalysis); per-step totals are identical
        probe_par = dataclasses.replace(parallel, microbatches=1)
        f1 = _lower_and_compile(mk(1), shape, mesh, variant=variant,
                                parallel=probe_par, kind=shape.kind,
                                heads_mode=heads_mode, param_dtype=param_dtype)
        f2 = _lower_and_compile(mk(2), shape, mesh, variant=variant,
                                parallel=probe_par, kind=shape.kind,
                                heads_mode=heads_mode, param_dtype=param_dtype)

        def extrap(get):
            b = get(f2) - get(f1)
            a = get(f1) - b
            return max(a + L_full * b, 0.0)

        flops_per_chip = extrap(lambda r: r["flops"])
        bytes_per_chip_accessed = extrap(lambda r: r["bytes"])
        keys = set(f1["coll"]) | set(f2["coll"])
        coll = {k: extrap(lambda r: r["coll"].get(k, 0.0)) for k in keys}
        cost_method = (
            f"two-point extrapolation over unrolled layer probes (L=1,2 → "
            f"{L_full}); attention chunk loop is unrolled so per-layer costs "
            f"are exact"
        )
    else:
        flops_per_chip = full["flops"]
        bytes_per_chip_accessed = full["bytes"]
        coll = full["coll"]
        cost_method = "direct (stack unrolled or shallow)"

    abstract_params = full["abstract_params"]
    mem = full["mem"]
    coll_counts = full["coll_counts"]
    t_lower, t_compile = full["t_lower"], full["t_compile"]

    peak_bytes = float(
        getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        + getattr(mem, "temp_size_in_bytes", 0)
    )
    n_active = _active_params(cfg, abstract_params)
    mf = model_flops_for(cfg, shape, n_active=n_active)
    report = build_report(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        flops=flops_per_chip * chips, hbm_bytes=bytes_per_chip_accessed * chips,
        collective_per_chip_bytes=float(coll.get("total", 0.0)),
        model_flops=mf, bytes_per_chip=peak_bytes,
    )
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "variant": variant,
        "status": "ok",
        "chips": chips,
        "n_params": tree_size(abstract_params),
        "n_active_params": n_active,
        "cost_method": cost_method,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
            "output_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
            "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
            "peak_gb": peak_bytes / 1e9,
        },
        "cost": {"flops_per_chip": flops_per_chip,
                 "bytes_per_chip": bytes_per_chip_accessed},
        "collectives": {"bytes_per_chip": coll, "op_counts": coll_counts},
        "roofline": dataclasses.asdict(report),
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name} × {variant}] "
              f"compile={t_compile:.0f}s chips={chips}")
        print(f"  memory_analysis: peak {peak_bytes/1e9:.2f} GB/chip "
              f"(args {out['memory']['argument_gb']:.2f} + temp "
              f"{out['memory']['temp_gb']:.2f})")
        print(f"  cost_analysis: {flops_per_chip/1e9:.1f} GFLOP/chip, "
              f"{bytes_per_chip_accessed/1e9:.2f} GB/chip accessed")
        print(f"  collectives/chip: { {k: f'{v/1e6:.1f}MB' for k, v in coll.items()} }")
        r = report
        print(f"  roofline: compute {r.compute_term_s:.2e}s | memory "
              f"{r.memory_term_s:.2e}s | collective {r.collective_term_s:.2e}s "
              f"→ {r.dominant}-bound; useful-FLOP ratio {r.useful_ratio:.2f}; "
              f"MFU bound {r.mfu_bound*100:.1f}%")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="pod1", choices=("pod1", "pod2", "both"))
    ap.add_argument("--variant", default="baseline",
                    choices=("baseline", "ihtc-kv"))
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default=None, help="directory for JSON results")
    args = ap.parse_args()

    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                for m in meshes:
                    cells.append((a, s, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for m in meshes:
            cells.append((args.arch, args.shape, m))

    failures = 0
    for a, s, m in cells:
        try:
            res = run_cell(a, s, m, variant=args.variant)
        except Exception as e:  # noqa: BLE001 — report and continue
            traceback.print_exc()
            res = {"arch": a, "shape": s, "mesh": m, "variant": args.variant,
                   "status": "error", "error": str(e)}
            failures += 1
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            fn = f"{a}__{s}__{m}__{args.variant}.json"
            with open(os.path.join(args.out, fn), "w") as f:
                json.dump(res, f, indent=1)
        if res["status"] == "skip":
            print(f"[{a} × {s} × {m}] SKIP: {res['reason']}")
    print(f"\ndry-run finished: {len(cells)} cells, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
