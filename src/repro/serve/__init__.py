"""Serving: the consolidated online surface (DESIGN.md §11/§15/§19).

One import site for everything between a fit and live traffic::

    from repro.serve import (
        ClusterService,        # micro-batched sync front-end
        AsyncClusterService,   # continuous-batching async front-end
        OnlineFitter,          # long-lived incremental refit
        RefreshDriver,         # drift-triggered zero-downtime refresh
        IndexStore,            # versioned, checksummed index artifacts
    )

Names resolve lazily (PEP 562, same pattern as the top-level package):
``import repro.serve`` stays cheap, and the artifact/lifecycle modules
only load when used.
"""

# public name -> defining module, resolved on first attribute access
_LAZY = {
    "AsyncClusterService": "repro.serve.async_service",
    "AsyncioServeLoop": "repro.serve.async_service",
    "BatchRecord": "repro.serve.async_service",
    "InlineExecutor": "repro.serve.async_service",
    "QueueFullError": "repro.serve.async_service",
    "ServeError": "repro.serve.async_service",
    "ServiceClosedError": "repro.serve.async_service",
    "UnknownTenantError": "repro.serve.async_service",
    "ClusterService": "repro.serve.cluster_service",
    "DEFAULT_BUCKETS": "repro.serve.cluster_service",
    "OnlineFitter": "repro.serve.lifecycle",
    "RefreshPolicy": "repro.serve.lifecycle",
    "RefreshDriver": "repro.serve.lifecycle",
    "IndexStore": "repro.serve.artifacts",
    "ArtifactError": "repro.serve.artifacts",
    "ServeConfig": "repro.serve.engine",
    "ServeEngine": "repro.serve.engine",
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
