"""Serving: batched engine + IHTC KV-cache prototype compression."""
from repro.serve.engine import ServeConfig, ServeEngine  # noqa: F401
