"""Serving: batched LM engine, IHTC KV-cache prototype compression, the
micro-batched online cluster-assignment service, and the async
continuous-batching front-end (DESIGN.md §11/§15)."""
from repro.serve.async_service import (  # noqa: F401
    AsyncClusterService,
    AsyncioServeLoop,
    BatchRecord,
    InlineExecutor,
    QueueFullError,
    ServeError,
    ServiceClosedError,
    UnknownTenantError,
)
from repro.serve.cluster_service import ClusterService  # noqa: F401
from repro.serve.engine import ServeConfig, ServeEngine  # noqa: F401
