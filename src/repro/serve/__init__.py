"""Serving: batched LM engine, IHTC KV-cache prototype compression, and the
micro-batched online cluster-assignment service."""
from repro.serve.cluster_service import ClusterService  # noqa: F401
from repro.serve.engine import ServeConfig, ServeEngine  # noqa: F401
