"""Online cluster-assignment service: micro-batched ClusterIndex serving.

The fitted :class:`repro.core.index.ClusterIndex` gives a jitted
``assign(queries)``, but live traffic arrives in arbitrary batch sizes and
XLA compiles one program per input shape. The service front-end quantizes
every request onto a small ladder of padded bucket shapes (pad-to-bucket,
slice-on-return), so steady-state traffic runs entirely on warm compiled
programs no matter how request sizes fluctuate; requests larger than the
top bucket are chunked through it. ``warmup()`` pre-compiles the whole
ladder so no user request ever pays a compile.

Dispatch (impl / mesh / precision) follows the runtime config at call time,
so the same service object serves a laptop and a pod.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import runtime
from repro.core.index import ClusterIndex

DEFAULT_BUCKETS: Tuple[int, ...] = (32, 128, 512, 2048)


class ClusterService:
    """Micro-batching front-end over a fitted index.

    ``buckets`` are the padded batch shapes served (ascending); each is one
    compiled program. ``block`` streams the prototype set inside assign
    (see :func:`repro.core.index.nearest_valid_prototype`).
    """

    def __init__(
        self,
        index: ClusterIndex,
        *,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        block: int = 0,
        impl: Optional[str] = None,
    ):
        if not buckets or any(b < 1 for b in buckets):
            raise ValueError(f"buckets must be positive, got {buckets!r}")
        self.index = index
        self.buckets: Tuple[int, ...] = tuple(sorted(set(int(b) for b in buckets)))
        self.block = block
        self.impl = impl
        self._stats: Dict[str, int] = {
            "requests": 0, "points": 0, "chunks": 0,
            **{f"bucket_{b}": 0 for b in self.buckets},
        }

    @classmethod
    def from_fit(cls, result, **service_kwargs) -> "ClusterService":
        """Stand a service up straight from any fitted
        :class:`repro.core.plan.FitResult` — every executor (in-memory,
        sharded, streaming, composed) returns the same canonical artifact,
        so the serving path is one line from any fit."""
        return cls(result.to_index(), **service_kwargs)

    def bucket_for(self, n: int) -> int:
        """The bucket shape an ``n``-row batch pads to (top bucket if it
        exceeds the ladder — such batches chunk through it)."""
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def assign_bucket(self, queries: jax.Array) -> jax.Array:
        """Pad one ≤-top-bucket batch to its bucket shape and label it.

        This is the single compiled-program hop both front-ends share:
        :meth:`assign` chunks oversized requests through it, and the async
        continuous-batching scheduler (:mod:`repro.serve.async_service`)
        dispatches its coalesced batches here, so every served shape comes
        from one warm ladder.
        """
        n = queries.shape[0]
        b = self.bucket_for(n)
        padded = jnp.pad(queries, ((0, b - n), (0, 0)))
        labels = self.index.assign(padded, impl=self.impl, block=self.block)
        self._stats[f"bucket_{b}"] += 1
        self._stats["chunks"] += 1
        return labels[:n]

    def assign(self, queries: jax.Array) -> jax.Array:
        """Label an (n, d) request; any n ≥ 0 (chunked above the top bucket)."""
        n = queries.shape[0]
        self._stats["requests"] += 1
        self._stats["points"] += int(n)
        if n == 0:
            return jnp.zeros((0,), jnp.int32)
        top = self.buckets[-1]
        if n <= top:
            return self.assign_bucket(queries)
        parts = [
            self.assign_bucket(queries[lo:lo + top])
            for lo in range(0, n, top)
        ]
        return jnp.concatenate(parts)

    def warmup(self) -> None:
        """Compile every bucket shape ahead of traffic. With a mesh in the
        runtime config, also replicates the index onto it once, so per-
        request assigns skip the host→device index transfer.

        Warmup is not traffic: it calls ``index.assign`` directly (never
        :meth:`assign_bucket`) and ends by zeroing the counters, so
        neither the warmup sweeps themselves nor any pre-warmup probe
        requests (deployment health checks routinely fire a few) pollute
        the steady-state throughput the stats report.
        """
        cfg = runtime.active()
        if cfg.mesh is not None and not self.index._is_replicated_on(cfg.mesh):
            self.index = self.index.replicate(cfg.mesh)
        d = self.index.dim
        for b in self.buckets:
            # repro: allow[HS201]: warmup — blocking here is the point: compile every bucket before traffic arrives
            jax.block_until_ready(
                self.index.assign(jnp.zeros((b, d), self.index.protos.dtype),
                                  impl=self.impl, block=self.block))
        self.reset_stats()

    def reset_stats(self) -> None:
        """Zero every traffic counter (requests/points/chunks/buckets)."""
        for k in self._stats:
            self._stats[k] = 0

    def stats_snapshot(self, *, reset: bool = False) -> Dict[str, int]:
        """The counters as one consistent snapshot; ``reset=True`` zeroes
        them in the same step (phase-delta reporting loses no counts) —
        the same contract as :meth:`AsyncClusterService.stats_snapshot`."""
        snap = dict(self._stats)
        if reset:
            self.reset_stats()
        return snap

    @property
    def stats(self) -> Dict[str, int]:
        """Counters: requests, points, chunks, per-bucket dispatches
        (since construction, the last :meth:`warmup`, or the last
        :meth:`reset_stats`, whichever is most recent)."""
        return dict(self._stats)
