"""Async continuous-batching front-end over fitted ``ClusterIndex`` versions.

:class:`repro.serve.ClusterService` quantizes one request at a time onto
the bucket ladder — a request that arrives alone rides a mostly-padding
bucket, and a request that arrives during another's device dispatch waits
behind it. Under the "millions of users" traffic shape (ROADMAP.md) both
are throughput killers. This module adds the production front-end:

* **continuous batching** — admitted requests are split into ≤-top-bucket
  segments and coalesced FIFO into shared batches; a batch dispatches the
  moment it fills the top bucket (or can no longer grow), and a flush
  deadline guarantees no admitted request waits longer than ``max_wait``
  for stragglers to fill its batch;
* **admission control** — a bounded queue (``queue_depth`` points across
  all tenants) rejects overload with :class:`QueueFullError` instead of
  queueing unboundedly, and ``max_inflight`` caps concurrently dispatched
  batches;
* **multi-tenant routing** — each tenant serves its own hosted
  :class:`~repro.core.index.ClusterIndex` *version*; versions hot-swap
  atomically (validated + warmed **before** the swap, so a half-installed
  artifact is never visible) while requests pin the version current at
  their admission;
* **graceful shutdown** — :meth:`AsyncClusterService.drain` stops
  admission and completes every admitted request.

**Determinism contract (DESIGN.md §15).** The scheduler core is a plain
callback-driven state machine: it never imports a wall clock or sleeps —
every notion of time, deferral and completion goes through three injected
seams (``loop.now`` / ``loop.call_later`` / ``loop.create_future``, plus
an ``executor.submit`` for batch execution). Under real traffic those
bind to asyncio (:class:`AsyncioServeLoop`, :class:`InlineExecutor`);
under test they bind to the virtual-time harness in ``tests/serve_sim.py``
— the *exact same scheduler code* runs in both, so tier-1 proves the
batching invariants in simulated milliseconds with zero real sleeps.
"""
from __future__ import annotations

import asyncio
import dataclasses
import functools
import itertools
from collections import deque
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro import runtime
from repro.core.index import ClusterIndex
from repro.serve.cluster_service import DEFAULT_BUCKETS, ClusterService


class ServeError(RuntimeError):
    """Base class for serve-front-end scheduling errors."""


class QueueFullError(ServeError):
    """Admission rejected: the bounded request queue is at capacity."""


class ServiceClosedError(ServeError):
    """Submitted to a service that is draining or drained."""


class UnknownTenantError(ServeError):
    """Routed to a tenant the service does not host."""


class AsyncioServeLoop:
    """Default loop seam: binds to the *running* asyncio event loop.

    Resolution happens per call (not at construction), so the service can
    be built synchronously — warming indexes, installing tenants — before
    any event loop exists, and start scheduling the first time it is used
    inside ``asyncio.run(...)``.
    """

    def now(self) -> float:
        return asyncio.get_running_loop().time()

    def call_later(self, delay: float, callback: Callable[[], None]):
        return asyncio.get_running_loop().call_later(delay, callback)

    def create_future(self):
        return asyncio.get_running_loop().create_future()


class InlineExecutor:
    """Default execution seam: run the batch on the scheduler thread,
    deliver the completion on the next loop turn.

    Executing inline is honest for a single-host JAX deployment (the
    dispatch is asynchronous on device; only the result materialization
    blocks), and delivering via ``call_later(0)`` keeps the scheduler
    non-reentrant — a dispatch can never complete inside the ``_pump``
    that issued it. An offloading executor (thread pool, RPC fan-out)
    only needs to implement ``submit(fn, on_done)`` with the same
    "``on_done(result, exc)`` runs as a loop callback" contract; the
    simulated-time twin lives in ``tests/serve_sim.py``.
    """

    def __init__(self, loop):
        self._loop = loop

    def submit(self, fn: Callable[[], Any],
               on_done: Callable[[Any, Optional[BaseException]], None]):
        try:
            result, exc = fn(), None
        except Exception as e:  # delivered, not raised: the loop must live
            result, exc = None, e
        self._loop.call_later(0.0, functools.partial(on_done, result, exc))


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """One dispatched batch, as seen by an ``observer`` hook: who was
    coalesced (``segments`` = (request id, rows, admit time) per segment,
    in dispatch order), onto which bucket, for which tenant/version."""

    tenant: str
    version: int
    bucket: int
    total: int
    t_dispatch: float
    segments: Tuple[Tuple[int, int, float], ...]


class _Request:
    __slots__ = ("rid", "tenant", "n", "future", "t_admit", "entry",
                 "parts", "n_segments", "done_segments", "cancel_counted")

    def __init__(self, rid, tenant, n, future, t_admit, entry):
        self.rid = rid
        self.tenant = tenant
        self.n = n
        self.future = future
        self.t_admit = t_admit
        self.entry = entry
        self.parts: list = []
        self.n_segments = 0
        self.done_segments = 0
        self.cancel_counted = False


class _Segment:
    __slots__ = ("request", "idx", "queries", "n", "deadline")

    def __init__(self, request, idx, queries, deadline):
        self.request = request
        self.idx = idx
        self.queries = queries
        self.n = queries.shape[0]
        self.deadline = deadline


class _IndexEntry:
    """One installed (tenant, version): an immutable routing target.

    Requests pin their entry at admission, and entries own their compiled
    bucket ladder via a private :class:`ClusterService`, so a hot-swap
    can never retarget work already admitted — the old entry keeps
    serving its pinned requests until they complete, then simply becomes
    unreferenced.
    """

    __slots__ = ("tenant", "version", "service")

    def __init__(self, tenant: str, version: int, service: ClusterService):
        self.tenant = tenant
        self.version = version
        self.service = service

    @property
    def index(self) -> ClusterIndex:
        return self.service.index


class _TenantState:
    __slots__ = ("tenant", "entry", "queue", "timer", "timer_deadline")

    def __init__(self, tenant: str, entry: _IndexEntry):
        self.tenant = tenant
        self.entry = entry
        self.queue: deque = deque()
        self.timer = None
        self.timer_deadline = 0.0


class AsyncClusterService:
    """Admission-controlled continuous-batching scheduler over hosted
    ``ClusterIndex`` versions.

    ``indexes`` is one :class:`ClusterIndex` (hosted under the runtime
    config's default tenant) or a ``{tenant: index}`` mapping. The
    scheduling knobs default from :class:`repro.runtime.RuntimeConfig`
    (``serve_queue_depth`` / ``serve_max_inflight`` /
    ``serve_max_wait_ms``, env-overridable as ``REPRO_SERVE_*``);
    ``max_wait`` is in **loop time units** — seconds under the default
    asyncio loop (the config's ms knob is converted), virtual units under
    an injected simulation loop.

    Client API: :meth:`submit` returns the loop's future (an
    ``asyncio.Future`` under the default loop — ``await`` it; the async
    sugar :meth:`assign` does exactly that). :meth:`install_index`
    hot-swaps a tenant's version; :meth:`drain` shuts down gracefully.
    """

    def __init__(
        self,
        indexes: Union[ClusterIndex, Mapping[str, ClusterIndex]],
        *,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        block: int = 0,
        impl: Optional[str] = None,
        queue_depth: Optional[int] = None,
        max_inflight: Optional[int] = None,
        max_wait: Optional[float] = None,
        loop=None,
        executor=None,
        observer: Optional[Callable[[BatchRecord], None]] = None,
        warmup: bool = True,
    ):
        cfg = runtime.active()
        if not buckets or any(b < 1 for b in buckets):
            raise ValueError(f"buckets must be positive, got {buckets!r}")
        self.buckets: Tuple[int, ...] = tuple(sorted(set(int(b)
                                                         for b in buckets)))
        self.capacity = self.buckets[-1]
        self.block = block
        self.impl = impl
        self.queue_depth = (cfg.serve_queue_depth if queue_depth is None
                            else int(queue_depth))
        self.max_inflight = (cfg.serve_max_inflight if max_inflight is None
                             else int(max_inflight))
        self.max_wait = (cfg.serve_max_wait_ms / 1e3 if max_wait is None
                         else float(max_wait))
        if self.queue_depth < 1 or self.max_inflight < 1 or self.max_wait < 0:
            raise ValueError(
                f"need queue_depth >= 1, max_inflight >= 1, max_wait >= 0; "
                f"got {self.queue_depth}, {self.max_inflight}, "
                f"{self.max_wait}")
        self._loop = loop if loop is not None else AsyncioServeLoop()
        self._executor = (executor if executor is not None
                          else InlineExecutor(self._loop))
        self._observer = observer
        self._default_tenant = cfg.serve_default_tenant
        self._tenants: Dict[str, _TenantState] = {}
        self._rid = itertools.count()
        self._queued_points = 0
        self._inflight = 0
        self._closed = False
        self._drain_future = None
        self._stats: Dict[str, int] = {
            "requests": 0, "points": 0, "batches": 0, "completed": 0,
            "rejected": 0, "cancelled": 0, "failed": 0, "swaps": 0,
        }
        if isinstance(indexes, ClusterIndex):
            indexes = {self._default_tenant: indexes}
        if not indexes:
            raise ValueError("need at least one hosted index")
        for tenant, index in indexes.items():
            self.install_index(tenant, index, warmup=warmup)

    # ------------------------------------------------------------------
    # tenant lifecycle

    @property
    def tenants(self) -> Tuple[str, ...]:
        return tuple(self._tenants)

    def version(self, tenant: Optional[str] = None) -> int:
        """The installed index version a new request to ``tenant`` serves."""
        return self._state(tenant).entry.version

    def install_index(self, tenant: str, index: ClusterIndex, *,
                      warmup: bool = True) -> int:
        """Install ``index`` as ``tenant``'s next version; returns it.

        Install is atomic with respect to serving: the artifact is
        structurally validated (:meth:`ClusterIndex.check_servable`,
        including that a hot-swap keeps the tenant's feature dim) and its
        bucket ladder compiled *before* the routing pointer moves, so a
        failure anywhere leaves the previous version serving untouched
        and a concurrent request can never observe a half-installed
        artifact. Requests admitted before the swap complete on the
        version they pinned at admission.
        """
        if self._closed:
            raise ServiceClosedError(
                f"cannot install {tenant!r}: service is draining")
        state = self._tenants.get(tenant)
        expect_dim = state.entry.index.dim if state is not None else None
        index.check_servable(expect_dim)
        service = ClusterService(index, buckets=self.buckets,
                                 block=self.block, impl=self.impl)
        if warmup:
            service.warmup()
        version = state.entry.version + 1 if state is not None else 1
        entry = _IndexEntry(tenant, version, service)
        if state is None:
            self._tenants[tenant] = _TenantState(tenant, entry)
        else:
            state.entry = entry  # the atomic swap
            self._stats["swaps"] += 1
            self._pump(state)  # a superseded entry's batch can't grow: flush
        return version

    def _state(self, tenant: Optional[str]) -> _TenantState:
        tenant = self._default_tenant if tenant is None else tenant
        state = self._tenants.get(tenant)
        if state is None:
            raise UnknownTenantError(
                f"unknown tenant {tenant!r}; hosted: {sorted(self._tenants)}")
        return state

    # ------------------------------------------------------------------
    # client API

    def submit(self, queries, *, tenant: Optional[str] = None):
        """Admit an (n, d) request for ``tenant``; returns the loop's
        future resolving to (n,) int32 labels.

        Raises :class:`ServiceClosedError` after :meth:`drain`,
        :class:`UnknownTenantError` for an unhosted tenant, and
        :class:`QueueFullError` when admission would push the queued-point
        total past ``queue_depth`` (the request is not partially admitted).
        Cancelling the returned future drops its undispatched segments;
        already-dispatched work completes on device and is discarded.
        """
        if self._closed:
            raise ServiceClosedError("service is draining; no new admissions")
        state = self._state(tenant)
        n = int(queries.shape[0])
        if n == 0:
            fut = self._loop.create_future()
            fut.set_result(np.zeros((0,), np.int32))
            self._stats["requests"] += 1
            self._stats["completed"] += 1
            return fut
        if self._queued_points + n > self.queue_depth:
            self._stats["rejected"] += 1
            raise QueueFullError(
                f"admission queue full: {self._queued_points}/"
                f"{self.queue_depth} points queued, request of {n} rejected"
                + (f" (request exceeds queue_depth={self.queue_depth} and "
                   f"can never be admitted)" if n > self.queue_depth else ""))
        fut = self._loop.create_future()
        t_admit = self._loop.now()
        req = _Request(next(self._rid), state.tenant, n, fut, t_admit,
                       state.entry)
        # repro: allow[HS201]: admission-time ingest — client queries are host data; sliced into segments before any device work
        q = np.asarray(queries)
        deadline = t_admit + self.max_wait
        segments = [
            _Segment(req, idx, q[lo:lo + self.capacity], deadline)
            for idx, lo in enumerate(range(0, n, self.capacity))
        ]
        req.n_segments = len(segments)
        req.parts = [None] * len(segments)
        state.queue.extend(segments)
        self._queued_points += n
        self._stats["requests"] += 1
        self._stats["points"] += n
        add_cb = getattr(fut, "add_done_callback", None)
        if add_cb is not None:  # eager cleanup when the client cancels
            add_cb(lambda f: self._on_request_done(state, f))
        self._pump(state)
        return fut

    async def assign(self, queries, *, tenant: Optional[str] = None):
        """Asyncio sugar: ``await service.assign(x)`` — submit + await."""
        return await self.submit(queries, tenant=tenant)

    def drain(self):
        """Stop admission and complete all admitted work; returns a future
        resolving to the final stats snapshot once the last batch lands.
        Pending partial batches flush immediately (the ``max_wait``
        deadline no longer applies); further :meth:`submit` /
        :meth:`install_index` calls raise :class:`ServiceClosedError`."""
        self._closed = True
        if self._drain_future is None:
            self._drain_future = self._loop.create_future()
            self._pump_all()
            self._maybe_finish_drain()
        return self._drain_future

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def stats(self) -> Dict[str, int]:
        """Scheduler counters: requests/points admitted, batches
        dispatched, completed/rejected/cancelled/failed requests, hot
        swaps. Per-tenant bucket telemetry lives in
        :meth:`tenant_stats`."""
        return dict(self._stats)

    def tenant_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-tenant: installed version + the entry's bucket-ladder
        counters (chunks == dispatched batches for that version)."""
        return {
            t: {"version": s.entry.version, **s.entry.service.stats}
            for t, s in self._tenants.items()
        }

    def stats_snapshot(self, *, reset: bool = False) -> Dict[str, Any]:
        """One consistent view of scheduler **and** per-tenant counters:
        ``{"scheduler": {...}, "tenants": {tenant: {...}}}``.

        ``reset=True`` zeroes every counter in the same step the snapshot
        is taken, so phase-delta reporting (bench_lifecycle's per-phase
        rows) never loses a count between a read and a reset — the
        scheduler is single-threaded per the loop seam, so read-then-zero
        with no interleaved callback *is* atomic here; this method exists
        so callers don't have to know that. ``ClusterService`` exposes the
        same method, fixing the historical asymmetry where the sync
        service could ``reset_stats`` per phase but the async front-end's
        tenant counters could only be read and zeroed separately."""
        snap = {"scheduler": self.stats, "tenants": self.tenant_stats()}
        if reset:
            for k in self._stats:
                self._stats[k] = 0
            for state in self._tenants.values():
                state.entry.service.reset_stats()
        return snap

    def reset_stats(self) -> None:
        """Zero the scheduler counters and every tenant's bucket counters
        (e.g. after a warmup/probe phase, so steady-state reporting starts
        clean — the same contract as :meth:`ClusterService.warmup`)."""
        self.stats_snapshot(reset=True)

    def current_index(self, tenant: Optional[str] = None) -> ClusterIndex:
        """The index new admissions to ``tenant`` would serve right now
        (the drift proxy of :class:`repro.serve.lifecycle.RefreshDriver`
        scores observed traffic against exactly this artifact). In-flight
        requests may still be pinned to an older version."""
        return self._state(tenant).entry.index

    # ------------------------------------------------------------------
    # scheduler core (every callback below runs as a loop callback)

    def _on_request_done(self, state: _TenantState, fut) -> None:
        cancelled = getattr(fut, "cancelled", None)
        if cancelled is not None and cancelled():
            self._pump(state)  # purges the cancelled segments eagerly

    def _purge_cancelled(self, state: _TenantState) -> None:
        if not any(s.request.future.done() for s in state.queue):
            return
        kept: deque = deque()
        for seg in state.queue:
            if seg.request.future.done():
                # done while still queued == cancelled (or failed by a
                # sibling segment's batch error): never dispatch it
                self._queued_points -= seg.n
                self._count_cancel(seg.request)
            else:
                kept.append(seg)
        state.queue = kept

    def _count_cancel(self, req: _Request) -> None:
        if not req.cancel_counted and req.future.cancelled():
            req.cancel_counted = True
            self._stats["cancelled"] += 1

    def _pump(self, state: _TenantState) -> None:
        """Form and dispatch batches for one tenant until the queue can't
        yield another (empty, inflight-saturated, or waiting to fill)."""
        self._purge_cancelled(state)
        while state.queue and self._inflight < self.max_inflight:
            head_entry = state.queue[0].request.entry
            batch, total = [], 0
            for seg in state.queue:
                if seg.request.entry is not head_entry:
                    break  # one batch == one index version
                if total + seg.n > self.capacity:
                    break  # FIFO: never reorder a later segment past this
                batch.append(seg)
                total += seg.n
            packed_all = len(batch) == len(state.queue)
            # a batch can only grow if every queued segment joined it,
            # there is spare capacity, and future arrivals would still be
            # batchable with it (the head entry is the live version)
            can_grow = (packed_all and total < self.capacity
                        and head_entry is state.entry)
            deadline = state.queue[0].deadline
            if can_grow and not self._closed and self._loop.now() < deadline:
                self._arm_timer(state, deadline)
                return
            self._dispatch(state, batch, total)
        if not state.queue:
            self._disarm_timer(state)

    def _arm_timer(self, state: _TenantState, deadline: float) -> None:
        if state.timer is not None and state.timer_deadline <= deadline:
            return  # an earlier-or-equal flush is already scheduled
        self._disarm_timer(state)
        state.timer_deadline = deadline
        state.timer = self._loop.call_later(
            max(0.0, deadline - self._loop.now()),
            functools.partial(self._on_timer, state))

    def _disarm_timer(self, state: _TenantState) -> None:
        if state.timer is not None:
            state.timer.cancel()
            state.timer = None

    def _on_timer(self, state: _TenantState) -> None:
        state.timer = None
        self._pump(state)

    def _dispatch(self, state: _TenantState, batch, total: int) -> None:
        for _ in batch:  # the batch is exactly the queue's head prefix
            state.queue.popleft()
        self._queued_points -= total
        entry = batch[0].request.entry
        if len(batch) == 1:
            queries = batch[0].queries
        else:
            queries = np.concatenate([s.queries for s in batch], axis=0)
        self._inflight += 1
        self._stats["batches"] += 1
        if self._observer is not None:
            self._observer(BatchRecord(
                tenant=state.tenant, version=entry.version,
                bucket=entry.service.bucket_for(total), total=total,
                t_dispatch=self._loop.now(),
                segments=tuple((s.request.rid, s.n, s.request.t_admit)
                               for s in batch)))
        self._executor.submit(
            functools.partial(self._run_batch, entry, queries),
            functools.partial(self._on_batch_done, batch))

    @staticmethod
    def _run_batch(entry: _IndexEntry, queries: np.ndarray) -> np.ndarray:
        # np.asarray materializes (device sync) so completion == labels
        # actually available to the client, not a lazy device handle
        # repro: allow[HS201]: deliberate materialization — completion must mean results-on-host, runs on the worker thread, never the event loop
        return np.asarray(entry.service.assign_bucket(queries))

    def _on_batch_done(self, batch, result, exc) -> None:
        self._inflight -= 1
        offset = 0
        for seg in batch:
            req = seg.request
            if exc is not None:
                if not req.future.done():
                    req.future.set_exception(exc)
                    self._stats["failed"] += 1
                continue
            part = result[offset:offset + seg.n]
            offset += seg.n
            if req.future.done():  # cancelled while in flight: discard
                self._count_cancel(req)
                continue
            req.parts[seg.idx] = part
            req.done_segments += 1
            if req.done_segments == req.n_segments:
                labels = (req.parts[0] if req.n_segments == 1
                          else np.concatenate(req.parts))
                req.future.set_result(labels)
                self._stats["completed"] += 1
        self._pump_all()
        self._maybe_finish_drain()

    def _pump_all(self) -> None:
        for state in self._tenants.values():
            if state.queue and self._inflight < self.max_inflight:
                self._pump(state)

    def _maybe_finish_drain(self) -> None:
        if (self._drain_future is not None and not self._drain_future.done()
                and self._inflight == 0
                and all(not s.queue for s in self._tenants.values())):
            self._drain_future.set_result(self.stats)
