"""Online index lifecycle: incremental refit + zero-downtime refresh (§19).

A batch fit is a single pass: plan, stream, finalize, serve. Production
traffic does not stop arriving when the pass ends — the data drifts, and
the index that was optimal at fit time slowly is not. This module turns
the streaming executor's bounded reservoir (DESIGN.md §12/§18) into a
*long-lived* object and closes the loop back into serving:

:class:`OnlineFitter`
    wraps the live :class:`repro.core.streaming._StreamMachine`.
    ``observe(points)`` folds new data in as weighted prototypes through
    the *same* jitted fold/cascade path the batch executor uses (staging
    pool, donated folds, index-bound key schedule all included);
    ``snapshot()`` re-finalizes the reservoir — levels 1..m-1 plus the
    backend — into a fresh :class:`repro.core.plan.FitResult` without
    stopping ingestion. Snapshots are *pure*: the key chain is re-split
    from the stored root each time and the reservoir prefix is cloned,
    so a snapshot after zero observes is bit-identical to the one-shot
    batch fit of the same stream, and a later donated fold can never
    invalidate an earlier snapshot.

:class:`RefreshPolicy`
    the decision rule for *when* a refreshed index is worth installing:
    points folded since the last install, cascades survived, and a drift
    proxy (served-traffic mean assign distance vs the post-install
    baseline). Defaults come from the runtime config
    (``REPRO_REFRESH_MAX_POINTS`` / ``_MAX_CASCADES`` /
    ``_DRIFT_RATIO``); zero disables a trigger.

:class:`RefreshDriver`
    glues the two to a serving front-end: feed observed traffic through
    :meth:`RefreshDriver.observe`, and when the policy fires it
    snapshots, freezes (:meth:`repro.core.index.ClusterIndex.build`,
    packed), optionally persists through an
    :class:`repro.serve.artifacts.IndexStore`, and atomically hot-swaps
    via :meth:`repro.serve.async_service.AsyncClusterService.install_index`
    — warmed up before the routing pointer moves, while in-flight
    requests finish on the version they pinned at admission.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.core.index import ClusterIndex, nearest_valid_prototype
from repro.core.plan import (FitResult, _is_chunk_stream, _plan_scope,
                             finalize_reduction, plan_fit)
from repro.core.streaming import _PLACEMENTS, _StreamMachine

__all__ = ["OnlineFitter", "RefreshPolicy", "RefreshDriver"]


class OnlineFitter:
    """A streaming fit held open: fold forever, snapshot any time.

    ``source`` seeds the fitter and fixes the geometry — a resident
    (n, d) array (folded as one chunk) or any chunk iterable, exactly as
    :func:`repro.fit` accepts. The fitter resolves the same
    :class:`FitPlan` a batch call would (``t``/``m``/``backend`` plus any
    :func:`repro.core.plan.plan_fit` keyword), forces the streaming
    executor family, and drains the seed through the §18 ingestion loop.
    From then on:

    * :meth:`observe` pushes new points through the identical
      fold/cascade path (oversized batches are sliced to ``chunk_n``);
    * :meth:`snapshot` returns a fresh :class:`FitResult` over
      everything folded so far — ingestion continues unaffected.

    Every device interaction runs under the plan's pinned config scope
    (:func:`repro.core.plan._plan_scope`), so a snapshot is bit-identical
    to what :func:`repro.core.plan.execute_plan` would have produced on
    the same chunk sequence.
    """

    def __init__(
        self,
        source: Any,
        t: int,
        m: int,
        backend: str = "kmeans",
        **fit_kwargs: Any,
    ):
        resident = not _is_chunk_stream(source)
        if resident:
            # repro: allow[HS201]: seed ingest — a resident seed is host data by the §12 chunk contract, coerced once
            arr = np.asarray(source, np.float32)
            source = iter([arr])  # a one-chunk stream, re-sliced below
        plan = plan_fit(source, t, m, backend, driver="online_fitter",
                        **fit_kwargs)
        if resident and plan.chunk_n and arr.shape[0] > plan.chunk_n:
            cn = plan.chunk_n  # honour the configured chunk geometry
            source = iter([arr[lo:lo + cn]
                           for lo in range(0, arr.shape[0], cn)])
        if plan.executor not in _PLACEMENTS:
            raise ValueError(
                f"OnlineFitter needs a streaming executor, but the plan "
                f"resolved {plan.executor!r}; drop the executor= override "
                f"(the fitter picks streaming/streaming_sharded itself)")
        self.plan = plan
        self._n_snapshots = 0
        with _plan_scope(plan):
            machine, first, rest = _StreamMachine.open_stream(
                plan, source, _PLACEMENTS[plan.executor])
            machine.ingest(rest, first=first)
        self._machine = machine

    # ---- ingestion --------------------------------------------------------

    def observe(self, points: Any) -> int:
        """Fold a batch of new points into the live reservoir; returns the
        number of valid rows folded.

        ``points`` is an (n, d) host array or a ``(chunk, n_valid)`` pair
        (the §12 chunk contract). Batches larger than the stream's
        ``chunk_n`` are sliced and folded as consecutive chunks — each at
        the next index of the key schedule, so an observe-split stream
        folds exactly like the same data pre-chunked.
        """
        if (isinstance(points, (tuple, list)) and len(points) == 2):
            arr, n_valid = points
            # repro: allow[HS201]: chunk ingest — observe() takes host data by the §12 chunk contract, coerced once
            arr = np.asarray(arr, np.float32)
            n_valid = int(n_valid)
        else:
            # repro: allow[HS201]: chunk ingest — observe() takes host data by the §12 chunk contract, coerced once
            arr = np.asarray(points, np.float32)
            n_valid = arr.shape[0]
        cn = self._machine.chunk_n
        folded = 0
        with _plan_scope(self.plan):
            for lo in range(0, max(arr.shape[0], 1), cn):
                sub = arr[lo:lo + cn]
                sub_valid = min(max(n_valid - lo, 0), sub.shape[0])
                folded += self._machine.feed((sub, sub_valid))
        return folded

    # ---- snapshot ---------------------------------------------------------

    def snapshot(self) -> FitResult:
        """Re-finalize the live reservoir into a fresh
        :class:`FitResult` — levels 1..m-1, backend, label back-out —
        without stopping ingestion.

        The machine's state is untouched: the reservoir prefix is cloned
        before any level step (a later donated fold cannot invalidate the
        snapshot), the key chain is re-split from the stored root (not
        consumed), and the spill maps are composed over frozen copies.
        Calling this with zero intervening observes repeatedly returns
        bitwise-identical results.
        """
        with _plan_scope(self.plan):
            red = self._machine.finalize(snapshot=True)
            result = finalize_reduction(self.plan, red)
        self._n_snapshots += 1
        return result

    def build_index(self, *, pack: bool = True) -> ClusterIndex:
        """Snapshot and freeze in one hop (the refresh path's artifact)."""
        return ClusterIndex.build(self.snapshot(), pack=pack)

    # ---- introspection ----------------------------------------------------

    @property
    def n_points(self) -> int:
        """Valid rows folded so far (seed + every observe)."""
        return self._machine.n_points

    @property
    def n_chunks(self) -> int:
        return self._machine.n_chunks

    @property
    def n_cascades(self) -> int:
        return self._machine.n_cascades

    @property
    def stats(self) -> Dict[str, Any]:
        m = self._machine
        return {
            "executor": self.plan.executor,
            "n_points": m.n_points,
            "n_chunks": m.n_chunks,
            "n_cascades": m.n_cascades,
            "frontier": m.frontier,
            "reservoir_n": m.reservoir_n,
            "chunk_n": m.chunk_n,
            "n_snapshots": self._n_snapshots,
        }

    def __repr__(self) -> str:
        s = self.stats
        return (f"OnlineFitter(executor={s['executor']!r}, "
                f"n_points={s['n_points']}, n_chunks={s['n_chunks']}, "
                f"n_cascades={s['n_cascades']}, "
                f"frontier={s['frontier']}/{s['reservoir_n']})")


@dataclass(frozen=True)
class RefreshPolicy:
    """When is a refreshed index worth installing? Three independent
    triggers, each disabled at zero:

    ``max_points``
        refresh once this many valid rows have been folded since the
        last install (volume: enough new evidence to matter);
    ``max_cascades``
        refresh once the reservoir has cascaded this many times since
        the last install (churn: the §12 cascade compresses level-0
        detail, so the *served* index lags the reservoir's summary);
    ``drift_ratio``
        refresh once the drift proxy — an EMA of observed traffic's mean
        assign distance against the *served* index, normalized by the
        post-install baseline — exceeds ``1 + drift_ratio`` (quality:
        traffic has moved away from the prototypes serving it).
    """

    max_points: int = 0
    max_cascades: int = 0
    drift_ratio: float = 0.0

    @classmethod
    def from_config(cls, cfg=None) -> "RefreshPolicy":
        """The policy the runtime config describes
        (``refresh_max_points`` / ``refresh_max_cascades`` /
        ``refresh_drift_ratio``, env-overridable as ``REPRO_REFRESH_*``)."""
        cfg = runtime.active() if cfg is None else cfg
        return cls(max_points=cfg.refresh_max_points,
                   max_cascades=cfg.refresh_max_cascades,
                   drift_ratio=cfg.refresh_drift_ratio)

    @property
    def enabled(self) -> bool:
        return (self.max_points > 0 or self.max_cascades > 0
                or self.drift_ratio > 0)

    def should_refresh(self, *, points_since: int, cascades_since: int,
                       drift: Optional[float]) -> Optional[str]:
        """The first trigger that fires, or None. ``drift`` is the
        baseline-normalized proxy (None until a baseline exists)."""
        if self.max_points > 0 and points_since >= self.max_points:
            return "max_points"
        if self.max_cascades > 0 and cascades_since >= self.max_cascades:
            return "max_cascades"
        if (self.drift_ratio > 0 and drift is not None
                and drift >= 1.0 + self.drift_ratio):
            return "drift_ratio"
        return None


class RefreshDriver:
    """Close the loop: observed traffic → fitter → policy → hot-swap.

    The driver sits beside a serving
    :class:`repro.serve.async_service.AsyncClusterService` (the traffic
    path never goes through it). Feed each observed batch to
    :meth:`observe`: the driver scores it against the tenant's *served*
    index (the drift proxy), folds it into the :class:`OnlineFitter`,
    and asks the :class:`RefreshPolicy` whether to refresh. A firing
    trigger — or an explicit :meth:`refresh` — snapshots the fitter,
    freezes a packed index, optionally persists it to an
    :class:`repro.serve.artifacts.IndexStore`, and installs it with
    warmup; the swap is atomic and in-flight requests finish on their
    admitted version (the §15 pin). The drift baseline resets at each
    install, so the proxy always measures drift *since the serving index
    last caught up*.
    """

    def __init__(
        self,
        service,
        fitter: OnlineFitter,
        *,
        tenant: Optional[str] = None,
        policy: Optional[RefreshPolicy] = None,
        store=None,
        warmup: bool = True,
        drift_alpha: float = 0.2,
    ):
        if not 0 < drift_alpha <= 1:
            raise ValueError(f"drift_alpha must be in (0, 1], "
                             f"got {drift_alpha}")
        self.service = service
        self.fitter = fitter
        self.tenant = tenant
        self.policy = policy if policy is not None else RefreshPolicy.from_config()
        self.store = store
        self.warmup = warmup
        self.drift_alpha = drift_alpha
        self._points_mark = fitter.n_points
        self._cascades_mark = fitter.n_cascades
        self._ema: Optional[float] = None
        self._baseline: Optional[float] = None
        self.history: List[Tuple[int, str]] = []  # (version, trigger)

    # ---- drift proxy ------------------------------------------------------

    @property
    def drift(self) -> Optional[float]:
        """EMA mean assign distance / post-install baseline (None until
        both exist). 1.0 ≈ traffic looks like it did right after the
        last install; rising values mean the served index is going stale."""
        if self._ema is None or not self._baseline:
            return None
        return self._ema / self._baseline

    def _update_drift(self, arr: np.ndarray) -> None:
        if arr.shape[0] == 0:
            return
        index = self.service.current_index(self.tenant)
        dist, _ = nearest_valid_prototype(
            jnp.asarray(arr), index.protos, index.proto_valid)
        # repro: allow[HS202]: drift proxy — one deliberate scalar readback per observed batch, off the request path
        mean = float(jnp.mean(jnp.sqrt(jnp.maximum(dist, 0.0))))
        a = self.drift_alpha
        self._ema = mean if self._ema is None else a * mean + (1 - a) * self._ema
        if self._baseline is None:
            self._baseline = mean  # first traffic after an install

    # ---- the loop ---------------------------------------------------------

    def observe(self, points: Any) -> Optional[int]:
        """Score ``points`` against the served index, fold them into the
        fitter, refresh if the policy fires. Returns the new version when
        a refresh happened, else None."""
        # repro: allow[HS201]: chunk ingest — observed traffic is host data by the §12 chunk contract, coerced once
        arr = np.asarray(points, np.float32)
        self._update_drift(arr)
        self.fitter.observe(arr)
        trigger = self.policy.should_refresh(
            points_since=self.fitter.n_points - self._points_mark,
            cascades_since=self.fitter.n_cascades - self._cascades_mark,
            drift=self.drift)
        if trigger is None:
            return None
        return self.refresh(trigger=trigger)

    def refresh(self, *, trigger: str = "manual") -> int:
        """Snapshot → freeze (packed) → persist (if a store is attached)
        → atomic warm hot-swap. Returns the installed version."""
        index = self.fitter.build_index(pack=True)
        if self.store is not None:
            self.store.save(index, metadata={
                "trigger": trigger,
                "n_points": self.fitter.n_points,
                "n_cascades": self.fitter.n_cascades,
            })
        tenant = (self.tenant if self.tenant is not None
                  else self.service._default_tenant)
        version = self.service.install_index(tenant, index,
                                             warmup=self.warmup)
        self._points_mark = self.fitter.n_points
        self._cascades_mark = self.fitter.n_cascades
        self._ema = None       # the proxy restarts against the new index
        self._baseline = None  # first post-install batch re-baselines
        self.history.append((version, trigger))
        return version

    @property
    def stats(self) -> Dict[str, Any]:
        return {
            "points_since_install": self.fitter.n_points - self._points_mark,
            "cascades_since_install": (self.fitter.n_cascades
                                       - self._cascades_mark),
            "drift": self.drift,
            "refreshes": len(self.history),
            "history": list(self.history),
        }
