"""Versioned on-disk artifacts for servable indexes (DESIGN.md §19).

The online lifecycle turns one fit into a *sequence* of index versions —
refreshed, rolled back, shipped between the fitting host and serving
hosts. This module is the artifact layer underneath that loop: an
:class:`IndexStore` serializes a :class:`repro.core.index.ClusterIndex`
(or any :class:`repro.core.plan.FitResult`, frozen on the way in) to a
directory of monotonically numbered versions, each a self-describing
manifest plus one ``.npy`` file per array with a sha256 checksum.

Integrity contract — a *torn* artifact (crashed writer, truncated copy,
bit-rotted file, manifest from a different index) must never reach a
serving hot-swap:

* **atomic publication** — a version is written into a hidden temp
  directory and ``os.rename``d into place (same-filesystem directory
  rename: readers see either nothing or the complete version, never a
  half-written one);
* **checksums + shape/dtype echo** — every array file's sha256, shape
  and logical dtype are recorded in the manifest and re-verified on
  load; any mismatch raises :class:`ArtifactError`;
* **structural validation** — ``check_servable()`` runs both before save
  and after load, so the same invariants the serve front-ends enforce at
  install time (DESIGN.md §15) hold at the storage boundary too.

bf16 buffers are stored as their uint16 bit pattern (numpy cannot
round-trip ``ml_dtypes.bfloat16`` portably) and re-viewed on load —
the round trip is bit-exact for every buffer, which is what makes
save → load → ``assign`` bitwise-identical to the in-memory index
(asserted in tier-1).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core.index import ClusterIndex
from repro.core.plan import FitResult

_FORMAT = 1
_MANIFEST = "manifest.json"
_VERSION_RE = re.compile(r"^v(\d{4,})$")

# (field, required): the ClusterIndex arrays in manifest order; optional
# packed buffers are omitted from the manifest when the index has none
_FIELDS = (
    ("protos", True),
    ("proto_mass", True),
    ("proto_valid", True),
    ("proto_labels", True),
    ("n_prototypes", True),
    ("protos_bf16", False),
    ("protos_q8", False),
    ("q8_scale", False),
    ("q8_zero", False),
)


class ArtifactError(RuntimeError):
    """A stored index version is missing, torn, or fails validation."""


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _to_storable(arr) -> tuple:
    """Device array → (host array to write, logical dtype, stored dtype)."""
    # repro: allow[HS201]: artifact save — serialization is the one place the index must materialize on host; runs off the serving path
    host = np.asarray(arr)
    logical = str(host.dtype)
    if host.dtype == jnp.bfloat16:  # numpy can't save ml_dtypes portably
        return host.view(np.uint16), "bfloat16", "uint16"
    return host, logical, logical


def _from_stored(raw: np.ndarray, logical: str):
    if logical == "bfloat16":
        return jnp.asarray(raw.view(jnp.bfloat16))
    return jnp.asarray(raw)


class IndexStore:
    """Directory of versioned, checksummed index artifacts.

    ``IndexStore(root)`` manages ``root/v0001``, ``root/v0002``, ... —
    one directory per version, atomically published. ``save`` assigns
    the next version number; ``load`` defaults to the latest. The store
    is append-only by design (refreshes only ever add versions); pruning
    old versions is the deployment's retention policy, not the store's.
    """

    def __init__(self, root: Union[str, os.PathLike]):
        self.root = os.fspath(root)
        os.makedirs(self.root, exist_ok=True)

    # ---- enumeration ------------------------------------------------------

    def list_versions(self) -> List[int]:
        """Published version numbers, ascending (temp dirs excluded)."""
        out = []
        for name in sorted(os.listdir(self.root)):
            mt = _VERSION_RE.match(name)
            if mt and os.path.isdir(os.path.join(self.root, name)):
                out.append(int(mt.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        """The newest published version, or None for an empty store."""
        versions = self.list_versions()
        return versions[-1] if versions else None

    def path(self, version: int) -> str:
        return os.path.join(self.root, f"v{version:04d}")

    # ---- save -------------------------------------------------------------

    def save(
        self,
        source: Union[ClusterIndex, FitResult],
        *,
        metadata: Optional[Dict[str, Any]] = None,
    ) -> int:
        """Publish ``source`` as the next version; returns its number.

        ``source`` is a servable :class:`ClusterIndex` or any
        :class:`FitResult` (frozen via ``ClusterIndex.build`` on the way
        in, packed buffers included). The artifact is validated
        (``check_servable``) before a byte is written, written into a
        hidden temp directory, then renamed into place — a concurrent
        reader can never observe a partial version, and a crashed save
        leaves only a temp directory the next save sweeps away.
        """
        if isinstance(source, FitResult):
            index = ClusterIndex.build(source)
        elif isinstance(source, ClusterIndex):
            index = source
        else:
            raise TypeError(
                f"IndexStore.save takes a ClusterIndex or FitResult, got "
                f"{type(source).__name__}")
        index.check_servable()

        version = (self.latest() or 0) + 1
        final = self.path(version)
        tmp = os.path.join(self.root, f"_tmp.v{version:04d}")
        if os.path.isdir(tmp):  # a crashed previous save; sweep it
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        try:
            arrays: Dict[str, Dict[str, Any]] = {}
            for name, required in _FIELDS:
                arr = getattr(index, name)
                if arr is None:
                    if required:
                        raise ArtifactError(
                            f"index is missing required array {name!r}")
                    continue
                host, logical, stored = _to_storable(arr)
                fname = f"{name}.npy"
                np.save(os.path.join(tmp, fname), host, allow_pickle=False)
                arrays[name] = {
                    "file": fname,
                    "dtype": logical,
                    "stored_dtype": stored,
                    "shape": [int(s) for s in host.shape],
                    "sha256": _sha256(os.path.join(tmp, fname)),
                }
            manifest = {
                "format": _FORMAT,
                "version": version,
                "kind": "cluster_index",
                "dim": int(index.dim),
                "arrays": arrays,
                "metadata": dict(metadata or {}),
            }
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(manifest, f, indent=2, sort_keys=True)
            if os.path.exists(final):
                raise ArtifactError(
                    f"version {version} already exists at {final} "
                    f"(concurrent saver?)")
            os.rename(tmp, final)  # atomic publication
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        return version

    # ---- load -------------------------------------------------------------

    def load(self, version: Optional[int] = None, *,
             expect_dim: Optional[int] = None) -> ClusterIndex:
        """Reconstruct a stored version (latest when ``version`` is None),
        rejecting torn artifacts.

        Every failure mode — missing/unreadable/truncated manifest, a
        listed array file missing, checksum or shape/dtype mismatch, or
        an index that fails ``check_servable(expect_dim)`` — raises
        :class:`ArtifactError`, so an installer can treat "loadable" as
        "servable" and hot-swap the result directly.
        """
        if version is None:
            version = self.latest()
            if version is None:
                raise ArtifactError(f"index store {self.root!r} is empty")
        vdir = self.path(version)
        mpath = os.path.join(vdir, _MANIFEST)
        if not os.path.isfile(mpath):
            raise ArtifactError(
                f"version {version} has no manifest at {mpath}")
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as exc:
            raise ArtifactError(
                f"version {version}: torn manifest ({exc})") from exc
        if manifest.get("format") != _FORMAT:
            raise ArtifactError(
                f"version {version}: unknown artifact format "
                f"{manifest.get('format')!r} (this reader speaks {_FORMAT})")
        arrays = manifest.get("arrays")
        if not isinstance(arrays, dict):
            raise ArtifactError(
                f"version {version}: manifest has no arrays table")

        fields: Dict[str, Any] = {}
        for name, required in _FIELDS:
            meta = arrays.get(name)
            if meta is None:
                if required:
                    raise ArtifactError(
                        f"version {version}: manifest is missing required "
                        f"array {name!r}")
                fields[name] = None
                continue
            apath = os.path.join(vdir, meta["file"])
            if not os.path.isfile(apath):
                raise ArtifactError(
                    f"version {version}: listed array file {meta['file']!r} "
                    f"is missing")
            digest = _sha256(apath)
            if digest != meta["sha256"]:
                raise ArtifactError(
                    f"version {version}: checksum mismatch on "
                    f"{meta['file']!r} (stored {meta['sha256'][:12]}…, "
                    f"recomputed {digest[:12]}…) — torn or corrupted")
            try:
                raw = np.load(apath, allow_pickle=False)
            except Exception as exc:  # truncated past the checksummed copy
                raise ArtifactError(
                    f"version {version}: unreadable array {meta['file']!r} "
                    f"({exc})") from exc
            if (list(raw.shape) != list(meta["shape"])
                    or str(raw.dtype) != meta["stored_dtype"]):
                raise ArtifactError(
                    f"version {version}: {meta['file']!r} is "
                    f"{raw.dtype}{list(raw.shape)}, manifest says "
                    f"{meta['stored_dtype']}{meta['shape']}")
            fields[name] = _from_stored(raw, meta["dtype"])

        index = ClusterIndex(**fields)
        try:
            index.check_servable(expect_dim)
        except ValueError as exc:
            raise ArtifactError(
                f"version {version}: not servable ({exc})") from exc
        return index
