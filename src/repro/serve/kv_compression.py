"""IHTC KV-cache prototype compression — the paper's instance selection
applied to long-context attention (beyond-paper; DESIGN.md §3.2).

A KV cache of S entries per (batch, kv-head) is a point set. Threshold
clustering at t* collapses it to ≤ S/t* prototypes: K̄ = cluster-mean key,
V̄ = cluster-mean value, mass = cluster size. Attention over prototypes with
an additive ``log(mass)`` logit bias is *exactly* softmax attention over the
original keys when cluster members are identical, and the error is otherwise
controlled by the cluster radius — the very bottleneck objective TC
4-approximates. m iterations give (t*)^m memory & FLOPs reduction per token.

The compressed cache is a *regular* cache dict plus a "bias" entry, so the
whole serving stack (attention_apply → lm_apply → engine) runs unmodified.
A fresh-token tail stays uncompressed; recompress when the tail fills.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import runtime
from repro.core.itis import itis_step
from repro.core.prototypes import reduce_to_prototypes

_MASKED = -1e30


@functools.partial(jax.jit, static_argnames=("t", "m", "impl", "_dispatch"))
def compress_kv_head(
    k: jax.Array,      # (S, hd)
    v: jax.Array,      # (S, hd)
    mass: jax.Array,   # (S,) f32 — 1 for raw entries, >1 if re-compressing
    valid: jax.Array,  # (S,) bool
    t: int,
    m: int = 1,
    *,
    key: Optional[jax.Array] = None,
    impl: str = "auto",
    _dispatch: tuple = (),
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Compress one head's KV set by (t)^m. Returns (k̄ (P,hd), v̄, mass, valid)
    with P = S // t^m. V prototypes use the same clustering as K (attention
    output = Σ p_i v_i needs E[v | cluster], mass-weighted).

    ``_dispatch`` is the §10 cache-key pin: ``itis_step`` /
    ``reduce_to_prototypes`` resolve the active config while this trace is
    live, so the caller passes ``runtime.dispatch_key()`` to make config
    changes retrace."""
    if key is None:
        key = jax.random.PRNGKey(0)
    kv = jnp.concatenate([k.astype(jnp.float32), v.astype(jnp.float32)], axis=-1)
    x, w, val = k.astype(jnp.float32), mass, valid
    kvx, hd = kv, k.shape[-1]
    for level in range(m):
        sub = jax.random.fold_in(key, level)
        out = itis_step(x, w, val, t, key=sub, weighted=True, impl=impl)
        # apply the same assignment to the stacked [k|v] payload
        ps = reduce_to_prototypes(
            kvx, out.assignment, out.protos.shape[0], weights=w, weighted=True,
            impl=impl,
        )
        x, w, val, kvx = out.protos, out.mass, out.valid, ps.x
    kbar, vbar = kvx[:, :hd], kvx[:, hd:]
    return kbar, vbar, w, val


def compress_cache(
    cache: Dict[str, jax.Array],
    t: int = 2,
    m: int = 1,
    *,
    tail: int = 128,
    key: Optional[jax.Array] = None,
    impl: str = "auto",
) -> Dict[str, jax.Array]:
    """Compress a layer's attention cache {"k","v","pos"[, "bias","mass"]}.

    Output cache has static length P + tail: prototypes in the first P slots
    (with log-mass bias), `tail` empty slots for new tokens, pos = P.
    vmapped over (batch × kv-heads).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    k, v = cache["k"], cache["v"]           # (b, h, S, hd)
    b, h, S, hd = k.shape
    pos = cache["pos"]
    prev_mass = cache.get("mass")
    mass = (
        prev_mass
        if prev_mass is not None
        else jnp.ones((b, h, S), jnp.float32)
    )
    valid = jnp.broadcast_to(jnp.arange(S)[None, None, :] < pos, (b, h, S))

    flat = lambda x: x.reshape((b * h,) + x.shape[2:])
    # resolve the dispatch fingerprint here, outside the jit boundary, and
    # close over it — the static pin that keys the compiled program (§10)
    dk = runtime.dispatch_key()
    fn = jax.vmap(
        lambda kk, vv, mm, vl: compress_kv_head(
            kk, vv, mm, vl, t, m, key=key, impl=impl, _dispatch=dk
        )
    )
    kbar, vbar, pmass, pvalid = fn(flat(k), flat(v), flat(mass), flat(valid))
    P = kbar.shape[1]

    unflat = lambda x: x.reshape((b, h) + x.shape[1:])
    kbar, vbar = unflat(kbar).astype(k.dtype), unflat(vbar).astype(v.dtype)
    pmass, pvalid = unflat(pmass), unflat(pvalid)

    total = P + tail
    nk = jnp.zeros((b, h, total, hd), k.dtype).at[:, :, :P].set(kbar)
    nv = jnp.zeros((b, h, total, hd), v.dtype).at[:, :, :P].set(vbar)
    bias = jnp.where(
        pvalid, jnp.log(jnp.maximum(pmass, 1e-9)), _MASKED
    )  # (b, h, P): mass-correct softmax; padding masked out
    nbias = jnp.zeros((b, h, total), jnp.float32).at[:, :, :P].set(bias)
    nmass = jnp.ones((b, h, total), jnp.float32).at[:, :, :P].set(
        jnp.where(pvalid, pmass, 1.0)
    )
    return {
        "k": nk, "v": nv,
        "pos": jnp.asarray(P, jnp.int32),
        "bias": nbias.astype(jnp.float32),
        "mass": nmass,
    }


def _compress_stacked(c: Dict[str, jax.Array], t, m, tail, key, impl):
    """Compress an attention cache whose leaves carry a leading (rep,) layer
    axis (the scanned-stack layout): fold rep into batch, compress, unfold."""
    rep, b = c["k"].shape[0], c["k"].shape[1]
    flat = {
        "k": c["k"].reshape((rep * b,) + c["k"].shape[2:]),
        "v": c["v"].reshape((rep * b,) + c["v"].shape[2:]),
        "pos": c["pos"][0],
    }
    if "bias" in c:
        flat["bias"] = c["bias"].reshape((rep * b,) + c["bias"].shape[2:])
        flat["mass"] = c["mass"].reshape((rep * b,) + c["mass"].shape[2:])
    out = compress_cache(flat, t, m, tail=tail, key=key, impl=impl)
    unfold = lambda x: x.reshape((rep, b) + x.shape[1:])
    return {
        "k": unfold(out["k"]), "v": unfold(out["v"]),
        "pos": jnp.broadcast_to(out["pos"], (rep,)),
        "bias": unfold(out["bias"]), "mass": unfold(out["mass"]),
    }


def compress_model_caches(caches, t: int = 2, m: int = 1, *, tail: int = 128,
                          key: Optional[jax.Array] = None, impl: str = "auto"):
    """Compress every attention layer's cache (mamba/None caches untouched).

    Handles both the stacked LM layout ({"prefix": [...], "stack": [...]})
    and plain per-layer lists (enc-dec)."""
    if key is None:
        key = jax.random.PRNGKey(0)

    def is_attn(c):
        return isinstance(c, dict) and "k" in c and "pos" in c

    if isinstance(caches, dict) and "prefix" in caches:
        new_prefix = [
            compress_cache(c, t, m, tail=tail, key=jax.random.fold_in(key, i),
                           impl=impl) if is_attn(c) else c
            for i, c in enumerate(caches["prefix"])
        ]
        stack = caches["stack"]
        new_stack = None
        if stack is not None:
            new_stack = [
                _compress_stacked(c, t, m, tail,
                                  jax.random.fold_in(key, 100 + j), impl)
                if is_attn(c) else c
                for j, c in enumerate(stack)
            ]
        return {"prefix": new_prefix, "stack": new_stack}
    out = []
    for i, c in enumerate(caches):
        if is_attn(c):
            out.append(compress_cache(c, t, m, tail=tail,
                                      key=jax.random.fold_in(key, i), impl=impl))
        else:
            out.append(c)
    return out


def find_attention_caches(caches):
    """Yield attention-cache dicts from either cache layout."""
    if isinstance(caches, dict) and "prefix" in caches:
        for c in caches["prefix"]:
            if isinstance(c, dict) and "k" in c:
                yield c
        if caches["stack"] is not None:
            for c in caches["stack"]:
                if isinstance(c, dict) and "k" in c:
                    yield c
    else:
        for c in caches:
            if isinstance(c, dict) and "k" in c:
                yield c
