"""Batched serving engine: prefill → decode with greedy/temperature sampling,
EOS tracking, and optional IHTC KV-cache compression at a fill threshold.

The engine is deliberately simple-but-real: static batch (continuous batching
slots), jitted prefill/decode, per-sequence stop state. With
``compress_every``, caches are re-compressed whenever the uncompressed tail
fills — steady-state memory is O(S / t^m + tail) per sequence.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import ModelBundle
from repro.models.transformer import ShardingPlan
from repro.serve.kv_compression import (
    compress_model_caches,
    find_attention_caches,
)


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 ⇒ greedy
    eos_id: int = -1                # -1 ⇒ never stop early
    # IHTC cache compression
    compress: bool = False
    compress_t: int = 2
    compress_m: int = 1
    compress_tail: int = 128
    impl: str = "xla"


class ServeEngine:
    def __init__(self, bundle: ModelBundle, params,
                 scfg: Optional[ServeConfig] = None,
                 plan: Optional[ShardingPlan] = None):
        self.bundle = bundle
        self.params = params
        self.scfg = scfg if scfg is not None else ServeConfig()
        self.plan = plan if plan is not None else ShardingPlan()
        scfg, plan = self.scfg, self.plan
        self._prefill = jax.jit(
            lambda p, c, b: bundle.prefill(p, c, b, plan=plan, impl=scfg.impl)
        )
        self._decode = jax.jit(
            lambda p, c, b: bundle.decode_step(p, c, b, plan=plan, impl=scfg.impl)
        )

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1] / self.scfg.temperature
        ).astype(jnp.int32)

    def generate(
        self,
        batch: Dict[str, jax.Array],
        *,
        max_len: Optional[int] = None,
        key=None,
        **cache_kw,
    ) -> Dict[str, jax.Array]:
        """batch: prompt inputs per the arch family. Returns
        {"tokens": (b, max_new), "n_steps", "compressions"}."""
        scfg = self.scfg
        if key is None:
            key = jax.random.PRNGKey(0)
        prompt = batch["tokens"]
        b, s = prompt.shape
        total = max_len or (s + scfg.max_new_tokens)

        caches = self.bundle.init_caches(b, total, **cache_kw)
        logits, caches = self._prefill(self.params, caches, batch)

        # Host-side mirror of the cache write position (§12: the decode
        # loop must not read the device to know where it is). After a
        # compress, pos == P == cache_size - tail — all shape arithmetic;
        # each decode step then advances it by one.
        pos_host = -1
        if scfg.compress:
            caches = compress_model_caches(
                caches, scfg.compress_t, scfg.compress_m,
                tail=scfg.compress_tail, impl="ref" if scfg.impl == "xla" else scfg.impl,
            )
            pos_host = self._cache_size(caches) - scfg.compress_tail

        out: List[jax.Array] = []
        done = jnp.zeros((b,), bool)
        n_compress = 0
        tok = self._sample(logits, key)
        for i in range(scfg.max_new_tokens):
            out.append(tok)
            if scfg.eos_id >= 0:
                done = done | (tok == scfg.eos_id)
                # Deliberate one-scalar-per-step sync: EOS early-exit is a
                # host control decision, there is nothing to derive it from
                # but the device. device_get makes the transfer explicit
                # rather than hiding it in a bool() coercion.
                # repro: allow[HS201]: deliberate EOS early-exit sync — one scalar per step, the only device read in the decode loop
                if jax.device_get(jnp.all(done)):
                    break
            key = jax.random.fold_in(key, i)
            logits, caches = self._decode(
                self.params, caches, {"tokens": tok[:, None]}
            )
            tok = self._sample(logits, key)
            if scfg.compress:
                pos_host += 1  # _decode appended one token per sequence
                if pos_host >= self._cache_size(caches):  # tail full
                    caches = compress_model_caches(
                        caches, scfg.compress_t, scfg.compress_m,
                        tail=scfg.compress_tail,
                        impl="ref" if scfg.impl == "xla" else scfg.impl,
                    )
                    pos_host = self._cache_size(caches) - scfg.compress_tail
                    n_compress += 1
        return {
            "tokens": jnp.stack(out, axis=1),
            "n_steps": len(out),
            "compressions": n_compress,
        }

    @staticmethod
    def _cache_size(caches) -> int:
        """Sequence capacity of the first attention cache — static shape
        metadata, no device read."""
        c0 = next(find_attention_caches(caches))
        stacked = c0["k"].ndim == 5  # (rep, b, h, S, hd)
        return c0["k"].shape[3 if stacked else 2]
