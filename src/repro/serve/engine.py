"""Batched serving engine: prefill → decode with greedy/temperature sampling,
EOS tracking, and optional IHTC KV-cache compression at a fill threshold.

The engine is deliberately simple-but-real: static batch (continuous batching
slots), jitted prefill/decode, per-sequence stop state. With
``compress_every``, caches are re-compressed whenever the uncompressed tail
fills — steady-state memory is O(S / t^m + tail) per sequence.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import ModelBundle
from repro.models.transformer import ShardingPlan
from repro.serve.kv_compression import compress_model_caches


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 ⇒ greedy
    eos_id: int = -1                # -1 ⇒ never stop early
    # IHTC cache compression
    compress: bool = False
    compress_t: int = 2
    compress_m: int = 1
    compress_tail: int = 128
    impl: str = "xla"


class ServeEngine:
    def __init__(self, bundle: ModelBundle, params, scfg: ServeConfig = ServeConfig(),
                 plan: ShardingPlan = ShardingPlan()):
        self.bundle = bundle
        self.params = params
        self.scfg = scfg
        self.plan = plan
        self._prefill = jax.jit(
            lambda p, c, b: bundle.prefill(p, c, b, plan=plan, impl=scfg.impl)
        )
        self._decode = jax.jit(
            lambda p, c, b: bundle.decode_step(p, c, b, plan=plan, impl=scfg.impl)
        )

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1] / self.scfg.temperature
        ).astype(jnp.int32)

    def generate(
        self,
        batch: Dict[str, jax.Array],
        *,
        max_len: Optional[int] = None,
        key=None,
        **cache_kw,
    ) -> Dict[str, jax.Array]:
        """batch: prompt inputs per the arch family. Returns
        {"tokens": (b, max_new), "n_steps", "compressions"}."""
        scfg = self.scfg
        if key is None:
            key = jax.random.PRNGKey(0)
        prompt = batch["tokens"]
        b, s = prompt.shape
        total = max_len or (s + scfg.max_new_tokens)

        caches = self.bundle.init_caches(b, total, **cache_kw)
        logits, caches = self._prefill(self.params, caches, batch)

        if scfg.compress:
            caches = compress_model_caches(
                caches, scfg.compress_t, scfg.compress_m,
                tail=scfg.compress_tail, impl="ref" if scfg.impl == "xla" else scfg.impl,
            )

        out: List[jax.Array] = []
        done = jnp.zeros((b,), bool)
        n_compress = 0
        tok = self._sample(logits, key)
        for i in range(scfg.max_new_tokens):
            out.append(tok)
            if scfg.eos_id >= 0:
                done = done | (tok == scfg.eos_id)
                if bool(jnp.all(done)):
                    break
            key = jax.random.fold_in(key, i)
            logits, caches = self._decode(
                self.params, caches, {"tokens": tok[:, None]}
            )
            tok = self._sample(logits, key)
            if scfg.compress:
                from repro.serve.kv_compression import find_attention_caches

                c0 = next(find_attention_caches(caches))
                pos = c0["pos"]
                stacked = c0["k"].ndim == 5  # (rep, b, h, S, hd)
                size = c0["k"].shape[3 if stacked else 2]
                pos_val = int(pos[0]) if stacked else int(pos)
                if pos_val >= size:  # tail full → recompress
                    caches = compress_model_caches(
                        caches, scfg.compress_t, scfg.compress_m,
                        tail=scfg.compress_tail,
                        impl="ref" if scfg.impl == "xla" else scfg.impl,
                    )
                    n_compress += 1
        return {
            "tokens": jnp.stack(out, axis=1),
            "n_steps": len(out),
            "compressions": n_compress,
        }
