"""repro — Hybridized Threshold Clustering at production scale.

``repro.fit(x_or_chunks, t, m, backend)`` is the single public entry
point: the planner (:mod:`repro.core.plan`) resolves every dispatch knob
from the active :mod:`repro.runtime` config, picks the executor from the
input type and the mesh (in-memory, sharded, streaming, or the composed
streaming+sharded path), and returns the canonical
:class:`repro.core.plan.FitResult`.

Heavy submodules load lazily (PEP 562), so ``import repro`` stays cheap
and the ``from repro import runtime`` idiom used throughout the package
never cycles through the clustering stack.
"""
from repro import runtime  # noqa: F401  (light: no jax import)

# public name -> defining module, resolved on first attribute access
_LAZY = {
    "fit": "repro.core.plan",
    "plan_fit": "repro.core.plan",
    "execute_plan": "repro.core.plan",
    "FitPlan": "repro.core.plan",
    "FitResult": "repro.core.plan",
    "register_executor": "repro.core.plan",
    "available_executors": "repro.core.plan",
    "ClusterIndex": "repro.core.index",
    "ClusterService": "repro.serve.cluster_service",
    "AsyncClusterService": "repro.serve.async_service",
    "OnlineFitter": "repro.serve.lifecycle",
    "RefreshDriver": "repro.serve.lifecycle",
    "RefreshPolicy": "repro.serve.lifecycle",
    "IndexStore": "repro.serve.artifacts",
    "ihtc": "repro.core.ihtc",
    "ihtc_sharded": "repro.core.distributed",
    "ihtc_streaming": "repro.core.streaming",
    "make_data_mesh": "repro.core.distributed",
}

__all__ = ["runtime", *sorted(_LAZY)]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
