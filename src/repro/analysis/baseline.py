"""The committed findings baseline.

The baseline is the adoption mechanism: pre-existing, *justified* findings
live in a committed JSON file so ``check`` can gate on "no NEW findings"
from day one. Entries are matched by fingerprint — ``(rule, path, stripped
line text)`` — never line numbers, so edits elsewhere in a file do not
expire them (the identity-over-position choice ``benchmarks/gate.py`` made
for perf rows). When the flagged line itself changes or disappears, the
entry goes stale and ``check`` reports it for pruning: a baseline only
shrinks.

Every entry carries a mandatory reason, same policy as pragmas. Pragmas
are for sites whose justification is local and permanent (§12 spill
points); the baseline is for debt being tracked toward zero.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Tuple

from repro.analysis.findings import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE_PATH = "analysis-baseline.json"

Fingerprint = Tuple[str, str, str]  # (rule, path, line_text)


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    line_text: str
    reason: str

    def fingerprint(self) -> Fingerprint:
        return (self.rule, self.path, self.line_text)


class Baseline:
    """In-memory view of the committed baseline file."""

    def __init__(self, entries: Iterable[BaselineEntry] = ()) -> None:
        self.entries: Dict[Fingerprint, BaselineEntry] = {}
        for e in entries:
            self.entries[e.fingerprint()] = e

    def __len__(self) -> int:
        return len(self.entries)

    def match(self, finding: Finding) -> bool:
        return finding.fingerprint() in self.entries

    def stale(self, findings: Iterable[Finding]) -> List[BaselineEntry]:
        """Entries no current finding matches — fixed or drifted; prune."""
        seen = {f.fingerprint() for f in findings}
        return [e for fp, e in sorted(self.entries.items())
                if fp not in seen]


def load_baseline(path: str) -> Baseline:
    """Read the baseline file; a missing file is an empty baseline."""
    try:
        with open(path, "r", encoding="utf-8") as fh:
            raw = json.load(fh)
    except FileNotFoundError:
        return Baseline()
    if not isinstance(raw, dict) or raw.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"{path}: not a v{BASELINE_VERSION} analysis baseline")
    entries = []
    for row in raw.get("entries", []):
        entry = BaselineEntry(
            rule=row["rule"], path=row["path"],
            line_text=row["line_text"], reason=row["reason"])
        if not entry.reason.strip():
            raise ValueError(
                f"{path}: baseline entry for {entry.rule} at {entry.path} "
                f"has no reason — every accepted finding must say why")
        entries.append(entry)
    return Baseline(entries)


def save_baseline(path: str, baseline: Baseline) -> None:
    rows = [dataclasses.asdict(e)
            for _, e in sorted(baseline.entries.items())]
    payload = {"version": BASELINE_VERSION, "entries": rows}
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2, ensure_ascii=False)
        fh.write("\n")


def extend_baseline(baseline: Baseline, findings: Iterable[Finding],
                    reason: str) -> int:
    """Add every finding (by fingerprint) with ``reason``; returns #added."""
    if not reason.strip():
        raise ValueError("baseline entries require a --reason")
    added = 0
    for f in findings:
        fp = f.fingerprint()
        if fp not in baseline.entries:
            baseline.entries[fp] = BaselineEntry(
                rule=f.rule, path=f.path, line_text=f.line_text,
                reason=reason.strip())
            added += 1
    return added


def prune_baseline(baseline: Baseline,
                   findings: Iterable[Finding]) -> int:
    """Drop entries nothing matches anymore; returns #removed."""
    stale = baseline.stale(findings)
    for e in stale:
        del baseline.entries[e.fingerprint()]
    return len(stale)
