"""CLI: ``python -m repro.analysis <check|explain|baseline> [--self-test]``.

Run from the repo root — rule scopes and baseline paths are repo-relative.
Pure stdlib: this entry point must work in a CI job that never installs
jax (see the ``static-analysis`` workflow job).

Exit codes: 0 clean / self-test passed; 1 findings, pragma errors or
self-test failures; 2 usage errors.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List

from repro.analysis.baseline import (
    DEFAULT_BASELINE_PATH,
    extend_baseline,
    load_baseline,
    prune_baseline,
    save_baseline,
)
from repro.analysis.registry import FAMILIES, available_rules, resolve_rule
from repro.analysis.runner import Report, gather_sources, run_check
from repro.analysis.selftest import run_selftest

DEFAULT_PATHS = ("src", "benchmarks", "examples")


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analyzer for the repo's runtime contracts "
                    "(DESIGN.md §17).")
    p.add_argument("--self-test", action="store_true",
                   help="verify every rule flags its canonical violation "
                        "and spares the repaired idiom, then exit")
    sub = p.add_subparsers(dest="command")

    chk = sub.add_parser("check", help="analyze files; fail on new findings")
    chk.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                     help="files/directories to analyze "
                          f"(default: {' '.join(DEFAULT_PATHS)})")
    chk.add_argument("--rules", help="comma-separated rule ids to run "
                                     "(default: all)")
    chk.add_argument("--baseline", default=DEFAULT_BASELINE_PATH,
                     help="baseline file (default: %(default)s)")
    chk.add_argument("--no-baseline", action="store_true",
                     help="ignore the baseline; report every finding")
    chk.add_argument("--json", action="store_true",
                     help="machine-readable report on stdout")
    chk.add_argument("--verbose", action="store_true",
                     help="also list pragma- and baseline-suppressed "
                          "findings")

    exp = sub.add_parser("explain",
                         help="explain rule ids (no args: list all rules)")
    exp.add_argument("rules", nargs="*", help="rule ids, e.g. RC101 HS202")

    bl = sub.add_parser(
        "baseline",
        help="manage the committed findings baseline")
    bl.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS))
    bl.add_argument("--baseline", default=DEFAULT_BASELINE_PATH)
    bl.add_argument("--write", action="store_true",
                    help="add every currently-new finding to the baseline "
                         "(requires --reason)")
    bl.add_argument("--reason",
                    help="why these findings are accepted (mandatory with "
                         "--write)")
    bl.add_argument("--prune", action="store_true",
                    help="drop baseline entries nothing matches anymore")
    return p


def main(argv: List[str] = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    parser = _build_parser()
    args = parser.parse_args(argv)

    if args.self_test:
        ok, lines = run_selftest()
        for line in lines:
            print(line)
        print("self-test:", "PASS" if ok else "FAIL")
        return 0 if ok else 1

    if args.command == "explain":
        return _cmd_explain(args)
    if args.command == "baseline":
        return _cmd_baseline(args)
    if args.command == "check" or args.command is None:
        if args.command is None:  # bare invocation = check with defaults
            args = parser.parse_args(["check"] + argv)
        return _cmd_check(args)
    parser.error(f"unknown command {args.command!r}")
    return 2


def _cmd_explain(args: argparse.Namespace) -> int:
    if not args.rules:
        print("rule families:")
        for fam in sorted(FAMILIES):
            print(f"  {fam}  {FAMILIES[fam]}")
        print("\nrules:")
        for rid in available_rules():
            print(f"  {rid}  {resolve_rule(rid).title}")
        print("\nsuppress with `# repro: allow[RULE,...]: reason` "
              "(same line or the line above);")
        print("accept tracked debt with "
              "`python -m repro.analysis baseline --write --reason ...`.")
        return 0
    try:
        rules = [resolve_rule(r) for r in args.rules]
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    for i, rule in enumerate(rules):
        if i:
            print()
        print(f"{rule.rule_id}: {rule.title}")
        print(f"  family: {rule.family} — {FAMILIES[rule.family]}")
        if rule.scope:
            print(f"  scope:  {', '.join(rule.scope)}")
        print()
        for line in rule.explain.splitlines():
            print(f"  {line}")
    return 0


def _run(paths: List[str], baseline_path: str, use_baseline: bool,
         only: List[str] = None) -> Report:
    sources = gather_sources(paths)
    baseline = load_baseline(baseline_path) if use_baseline else None
    return run_check(sources, baseline=baseline, only=only)


def _cmd_check(args: argparse.Namespace) -> int:
    only = args.rules.split(",") if args.rules else None
    try:
        report = _run(args.paths, args.baseline,
                      not args.no_baseline, only)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2

    if args.json:
        print(json.dumps(_report_json(report), indent=2))
        return 0 if report.ok else 1

    for err in report.pragma_errors:
        print(err.format())
    for f in report.new:
        print(f.format())
    if args.verbose:
        for f, supp in report.suppressed_pragma:
            print(f.format(suffix=f"pragma: {supp.reason}"))
        for f in report.suppressed_baseline:
            print(f.format(suffix="baseline"))
    for supp in report.unused_pragmas:
        print(f"{supp.path}:{supp.comment_line}: note: unused pragma "
              f"allow[{','.join(supp.rules)}]")
    for entry in report.stale_baseline:
        print(f"{entry.path}: note: stale baseline entry {entry.rule} "
              f"(`{entry.line_text}`) — run "
              f"`python -m repro.analysis baseline --prune`")

    n_supp = len(report.suppressed_pragma) + len(report.suppressed_baseline)
    print(f"{report.files_checked} files, {len(report.new)} new finding(s), "
          f"{n_supp} suppressed, {len(report.pragma_errors)} pragma "
          f"error(s)")
    return 0 if report.ok else 1


def _cmd_baseline(args: argparse.Namespace) -> int:
    if args.write and not (args.reason and args.reason.strip()):
        print("error: --write requires --reason (baseline entries must "
              "say why they are accepted)", file=sys.stderr)
        return 2
    if not args.write and not args.prune:
        print("error: nothing to do — pass --write and/or --prune",
              file=sys.stderr)
        return 2
    try:
        baseline = load_baseline(args.baseline)
        sources = gather_sources(args.paths)
        report = run_check(sources, baseline=baseline)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    if args.prune:
        removed = prune_baseline(baseline, report.all_findings())
        print(f"pruned {removed} stale entr{'y' if removed == 1 else 'ies'}")
    if args.write:
        added = extend_baseline(baseline, report.new, args.reason)
        print(f"baselined {added} finding(s)")
    save_baseline(args.baseline, baseline)
    print(f"wrote {args.baseline} ({len(baseline)} entries)")
    return 0


def _report_json(report: Report) -> dict:
    return {
        "ok": report.ok,
        "files_checked": report.files_checked,
        "new": [dataclasses.asdict(f) for f in report.new],
        "suppressed_pragma": [
            {"finding": dataclasses.asdict(f), "reason": s.reason}
            for f, s in report.suppressed_pragma],
        "suppressed_baseline": [
            dataclasses.asdict(f) for f in report.suppressed_baseline],
        "pragma_errors": [
            dataclasses.asdict(e) for e in report.pragma_errors],
        "unused_pragmas": [
            {"path": s.path, "line": s.comment_line,
             "rules": list(s.rules)} for s in report.unused_pragmas],
        "stale_baseline": [
            dataclasses.asdict(e) for e in report.stale_baseline],
    }


if __name__ == "__main__":
    sys.exit(main())
