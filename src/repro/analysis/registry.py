"""Rule registry — the analyzer's twin of the backend/executor registries.

Rules register with ``@register_rule(...)`` exactly the way clustering
backends register with ``@register_backend`` and fit executors with
``@register_executor``: a decorator validates the contract at import time
and a resolver is the single lookup point. A rule is a checker function

    fn(ctx: FileContext) -> Iterable[RawFinding]

where a ``RawFinding`` is ``(node_or_line, message)`` — the runner turns it
into a located :class:`repro.analysis.findings.Finding`. Rules that need
whole-repo context (the RC call-graph rule) read ``ctx.project``.

Rule ids are ``<FAMILY><number>`` (``RC101``); the family prefix groups
rules that police one documented contract (DESIGN.md §17 lists them all).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

# rule family prefix -> what contract it polices (DESIGN.md §17)
FAMILIES = {
    "RC": "runtime-config dispatch contract (DESIGN.md §10)",
    "HS": "host-sync discipline on hot paths (DESIGN.md §12)",
    "RT": "retrace hazards (DESIGN.md §10/§14)",
    "PK": "Pallas kernel geometry (DESIGN.md §16)",
    "DT": "determinism (DESIGN.md §4.3)",
    "WN": "warning hygiene",
}

# (ast node | int line, message)
RawFinding = Tuple[Union[object, int], str]
CheckFn = Callable[..., Iterable[RawFinding]]


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered rule: id, one-line title, long explanation, checker."""

    rule_id: str
    title: str
    explain: str
    check: CheckFn
    # path prefixes (repo-relative, posix) the rule is restricted to;
    # empty = every analyzed file
    scope: Tuple[str, ...] = ()

    @property
    def family(self) -> str:
        return "".join(c for c in self.rule_id if c.isalpha())

    def applies_to(self, path: str) -> bool:
        if not self.scope:
            return True
        return any(path.startswith(prefix) for prefix in self.scope)


_REGISTRY: Dict[str, Rule] = {}


def register_rule(rule_id: str, *, title: str, explain: str,
                  scope: Tuple[str, ...] = ()) -> Callable[[CheckFn], CheckFn]:
    """Decorator: ``@register_rule("RC101", title=..., explain=...)``.

    Validates the id (known family prefix, unique) and the checker
    signature (must accept exactly one positional ``ctx`` argument) at
    import time, mirroring ``register_backend``'s fail-at-import policy —
    a malformed rule must never surface as a silent no-op in CI.
    """
    family = "".join(c for c in rule_id if c.isalpha())
    if family not in FAMILIES:
        raise ValueError(
            f"rule id {rule_id!r} has unknown family {family!r}; "
            f"known families: {sorted(FAMILIES)}")
    if not title or not explain:
        raise ValueError(f"rule {rule_id!r} needs a title and an explain text")

    def deco(fn: CheckFn) -> CheckFn:
        if rule_id in _REGISTRY and _REGISTRY[rule_id].check is not fn:
            raise ValueError(f"rule {rule_id!r} is already registered")
        sig = inspect.signature(fn)
        positional = [
            p for p in sig.parameters.values()
            if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                          inspect.Parameter.POSITIONAL_OR_KEYWORD)
        ]
        if len(positional) != 1:
            raise TypeError(
                f"rule {rule_id!r} checker must take exactly one positional "
                f"argument (the FileContext); signature is {sig}")
        _REGISTRY[rule_id] = Rule(rule_id=rule_id, title=title,
                                  explain=inspect.cleandoc(explain),
                                  check=fn, scope=scope)
        return fn

    return deco


def _ensure_builtin_rules() -> None:
    # importing the package runs every @register_rule decorator; local
    # import keeps the registry importable without a cycle
    from repro.analysis import rules  # noqa: F401


def resolve_rule(rule_id: str) -> Rule:
    """Rule id -> Rule (the one lookup point; raises on unknown ids)."""
    _ensure_builtin_rules()
    if rule_id not in _REGISTRY:
        raise ValueError(
            f"unknown rule {rule_id!r}; have {available_rules()}")
    return _REGISTRY[rule_id]


def known_rule(rule_id: str) -> bool:
    _ensure_builtin_rules()
    return rule_id in _REGISTRY


def available_rules() -> List[str]:
    """Sorted ids of every registered rule."""
    _ensure_builtin_rules()
    return sorted(_REGISTRY)


def iter_rules(only: Optional[Iterable[str]] = None) -> List[Rule]:
    """Registered rules, optionally restricted to the given ids."""
    _ensure_builtin_rules()
    if only is None:
        return [_REGISTRY[k] for k in sorted(_REGISTRY)]
    return [resolve_rule(r) for r in only]
