"""The two-pass analysis driver.

Pass 1 parses every file into a :class:`FileContext` and feeds the
:class:`ProjectIndex`, which closes ``reads_config`` over the dotted-name
call graph — this is why the runner cannot be a per-file loop: RC102
needs the whole file set indexed before any rule runs. Pass 2 runs each
registered rule over each in-scope file, then settles every raw finding
against the file's pragmas and the committed baseline.

Sources arrive as a ``{repo-relative path: source}`` mapping, so tests and
the self-test analyze virtual files without touching disk; the CLI builds
the mapping by walking real directories.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis.baseline import Baseline, BaselineEntry
from repro.analysis.context import FileContext, ProjectIndex
from repro.analysis.findings import Finding, PragmaError, Suppression
from repro.analysis.pragmas import parse_pragmas
from repro.analysis.registry import iter_rules


@dataclasses.dataclass
class Report:
    """Everything one ``check`` run learned, settled into buckets."""

    new: List[Finding] = dataclasses.field(default_factory=list)
    suppressed_pragma: List[Tuple[Finding, Suppression]] = \
        dataclasses.field(default_factory=list)
    suppressed_baseline: List[Finding] = dataclasses.field(
        default_factory=list)
    stale_baseline: List[BaselineEntry] = dataclasses.field(
        default_factory=list)
    pragma_errors: List[PragmaError] = dataclasses.field(
        default_factory=list)
    unused_pragmas: List[Suppression] = dataclasses.field(
        default_factory=list)
    files_checked: int = 0

    @property
    def ok(self) -> bool:
        """check passes iff there are no new findings and no bad pragmas.

        Stale baseline entries and unused pragmas are reported but do not
        fail the run — they are cleanup debt, not contract violations.
        """
        return not self.new and not self.pragma_errors

    def all_findings(self) -> List[Finding]:
        return (self.new
                + [f for f, _ in self.suppressed_pragma]
                + self.suppressed_baseline)


def _locate(ctx: FileContext, where: object) -> Tuple[int, int]:
    if isinstance(where, int):
        return where, 0
    line = getattr(where, "lineno", 0) or 0
    col = getattr(where, "col_offset", 0) or 0
    return line, col


def collect_findings(sources: Dict[str, str],
                     only: Optional[Iterable[str]] = None,
                     ) -> Tuple[List[Finding], List[PragmaError],
                                Dict[str, List[Suppression]]]:
    """Run the rules; return raw findings + pragma parse results.

    Findings here are *unsettled* — suppression/baseline matching is
    :func:`run_check`'s job.
    """
    project = ProjectIndex()
    contexts: List[FileContext] = []
    errors: List[PragmaError] = []
    for path in sorted(sources):
        try:
            ctx = FileContext(path, sources[path], project=project)
        except SyntaxError as exc:
            errors.append(PragmaError(
                path=path, line=exc.lineno or 0,
                message=f"file does not parse: {exc.msg}"))
            continue
        project.add_file(ctx)
        contexts.append(ctx)
    project.finalize()

    rules = iter_rules(only)
    findings: List[Finding] = []
    suppressions: Dict[str, List[Suppression]] = {}
    for ctx in contexts:
        supp, perrs = parse_pragmas(ctx.path, ctx.source)
        suppressions[ctx.path] = supp
        errors.extend(perrs)
        for rule in rules:
            if not rule.applies_to(ctx.path):
                continue
            for where, message in rule.check(ctx):
                line, col = _locate(ctx, where)
                findings.append(Finding(
                    rule=rule.rule_id, path=ctx.path, line=line, col=col,
                    message=message, line_text=ctx.line_text(line)))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, errors, suppressions


def run_check(sources: Dict[str, str],
              baseline: Optional[Baseline] = None,
              only: Optional[Iterable[str]] = None) -> Report:
    """Analyze ``sources`` and settle findings against pragmas+baseline."""
    baseline = baseline or Baseline()
    findings, errors, suppressions = collect_findings(sources, only)

    report = Report(pragma_errors=errors, files_checked=len(sources))
    used: set = set()
    for f in findings:
        supp = _matching_pragma(f, suppressions.get(f.path, ()))
        if supp is not None:
            used.add(id(supp))
            report.suppressed_pragma.append((f, supp))
        elif baseline.match(f):
            report.suppressed_baseline.append(f)
        else:
            report.new.append(f)
    for path in sorted(suppressions):
        for supp in suppressions[path]:
            if id(supp) not in used:
                report.unused_pragmas.append(supp)
    report.stale_baseline = baseline.stale(findings)
    return report


def _matching_pragma(finding: Finding,
                     supps: Iterable[Suppression],
                     ) -> Optional[Suppression]:
    for supp in supps:
        if supp.line == finding.line and finding.rule in supp.rules:
            return supp
    return None


# --------------------------------------------------------------- sources
_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", ".ruff_cache"}


def gather_sources(paths: Iterable[str],
                   root: str = ".") -> Dict[str, str]:
    """Walk ``paths`` (files or directories, relative to ``root``) into a
    ``{repo-relative posix path: source}`` mapping of ``.py`` files."""
    out: Dict[str, str] = {}
    for spec in paths:
        full = os.path.join(root, spec)
        if os.path.isfile(full):
            out[_rel(full, root)] = _read(full)
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = sorted(
                d for d in dirnames if d not in _SKIP_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    fp = os.path.join(dirpath, fn)
                    out[_rel(fp, root)] = _read(fp)
    return out


def _rel(path: str, root: str) -> str:
    return os.path.relpath(path, root).replace(os.sep, "/")


def _read(path: str) -> str:
    with open(path, "r", encoding="utf-8") as fh:
        return fh.read()
