"""Per-file analysis context and the whole-repo call-graph index.

``FileContext`` owns everything a rule needs about one module: the parsed
tree, a parent map, import-alias resolution (``np.asarray`` →
``numpy.asarray``), which functions are jit-traced and how (decorator,
``jax.jit(fn)`` wrapping, ``pallas_call`` kernel bodies), and best-effort
constant resolution for tile-geometry checks.

``ProjectIndex`` is the cross-module layer: it records, for every function
in the analyzed set, whether its body (transitively, through a dotted-name
call graph) reads the runtime config at trace time. That is what lets the
RC rules flag ``serve/kv_compression.py`` -- a jitted function whose
*callee* (``itis_step``) resolves ``runtime.active()`` during tracing --
and not just bodies that mention ``active()`` lexically.

Everything here is stdlib-only (ast + tokenize): the analyzer must run in
CI without installing jax.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple

# dotted names whose *call* reads the active runtime config (§10). The
# attribute form through any alias of repro.runtime / repro.runtime.config
# resolves onto one of these.
CONFIG_READ_CALLS = frozenset({
    "repro.runtime.active",
    "repro.runtime.dispatch_key",
    "repro.runtime.default_config",
    "repro.runtime.config_from_env",
    "repro.runtime.config.active",
    "repro.runtime.config.dispatch_key",
    "repro.runtime.config.default_config",
    "repro.runtime.config.config_from_env",
})

JIT_CALLS = frozenset({"jax.jit", "jax.api.jit"})
PALLAS_CALL = "pallas_call"  # matched by suffix: pl.pallas_call aliases vary

#: the §10 cache-key pin: a jitted function carrying this parameter declares
#: its trace-time config reads covered by the static dispatch fingerprint.
DISPATCH_PARAM = "_dispatch"


def module_name_for_path(path: str) -> str:
    """Repo-relative path -> dotted module name (``src/`` layout aware)."""
    p = path.replace("\\", "/")
    for prefix in ("src/",):
        if p.startswith(prefix):
            p = p[len(prefix):]
    if p.endswith(".py"):
        p = p[:-3]
    if p.endswith("/__init__"):
        p = p[: -len("/__init__")]
    return p.replace("/", ".")


@dataclasses.dataclass
class FuncInfo:
    """One function (or lambda) and what the analyzer knows about it."""

    node: ast.AST                 # FunctionDef / AsyncFunctionDef / Lambda
    qualname: str                 # module.Class.name / module.name / <lambda>
    path: str
    jitted: bool = False
    jit_reason: str = ""          # "decorator" | "jax.jit(...)" | "pallas_call"
    static_names: Tuple[str, ...] = ()
    has_dispatch: bool = False
    calls: Set[str] = dataclasses.field(default_factory=set)
    reads_config: bool = False    # lexical read in this body
    config_read_lines: List[int] = dataclasses.field(default_factory=list)


def _arg_names(node: ast.AST) -> List[str]:
    a = node.args
    names = [x.arg for x in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


class FileContext:
    """Parsed module + resolution helpers, shared by every rule."""

    def __init__(self, path: str, source: str,
                 project: Optional["ProjectIndex"] = None):
        self.path = path.replace("\\", "/")
        self.source = source
        self.lines = source.splitlines()
        self.module = module_name_for_path(self.path)
        self.tree = ast.parse(source)
        self.project = project

        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node

        self.aliases = self._collect_aliases()
        self.module_consts = self._collect_module_consts()
        self.functions: Dict[ast.AST, FuncInfo] = {}
        self._collect_functions()
        self._detect_jit()

    # ------------------------------------------------------------ imports
    def _collect_aliases(self) -> Dict[str, str]:
        aliases: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                if node.level:  # relative: resolve against this package
                    base_parts = self.module.split(".")
                    # level 1 = current package (drop the module segment)
                    base_parts = base_parts[: len(base_parts) - node.level]
                    base = ".".join(base_parts)
                else:
                    base = ""
                mod = ".".join(x for x in (base, node.module or "") if x)
                for a in node.names:
                    if a.name == "*":
                        continue
                    aliases[a.asname or a.name] = (
                        f"{mod}.{a.name}" if mod else a.name)
        return aliases

    def _collect_module_consts(self) -> Dict[str, int]:
        consts: Dict[str, int] = {}
        for node in self.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Constant) \
                    and type(node.value.value) is int:
                consts[node.targets[0].id] = node.value.value
        return consts

    # ---------------------------------------------------------- functions
    def _collect_functions(self) -> None:
        def visit(node: ast.AST, scope: List[str]) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = ".".join([self.module] + scope + [child.name])
                    self._add_function(child, qual)
                    visit(child, scope + [child.name])
                elif isinstance(child, ast.ClassDef):
                    visit(child, scope + [child.name])
                elif isinstance(child, ast.Lambda):
                    qual = ".".join([self.module] + scope + ["<lambda>"])
                    self._add_function(child, qual)
                    visit(child, scope)
                else:
                    visit(child, scope)

        visit(self.tree, [])

    def _add_function(self, node: ast.AST, qual: str) -> None:
        info = FuncInfo(node=node, qualname=qual, path=self.path,
                        has_dispatch=DISPATCH_PARAM in _arg_names(node))
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                name = self.dotted(sub.func)
                if name:
                    info.calls.add(name)
                    if name in CONFIG_READ_CALLS:
                        info.reads_config = True
                        info.config_read_lines.append(sub.lineno)
        self.functions[node] = info

    # ------------------------------------------------------------ jit map
    def _detect_jit(self) -> None:
        # 1. decorators: @jax.jit / @functools.partial(jax.jit, ...)
        for node, info in self.functions.items():
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in node.decorator_list:
                target, statics = self._jit_spec(dec)
                if target:
                    info.jitted = True
                    info.jit_reason = "decorator"
                    info.static_names = statics
        # 2. call sites: jax.jit(<lambda>| <local name>), pallas_call(kernel)
        by_name = {
            info.qualname.rsplit(".", 1)[-1]: info
            for node, info in self.functions.items()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            name = self.dotted(node.func) or ""
            is_jit = name in JIT_CALLS
            is_pallas = name.endswith(PALLAS_CALL)
            if not (is_jit or is_pallas):
                continue
            statics = self._static_names_from_call(node)
            for arg in node.args[:1]:  # the traced callable is arg 0
                target: Optional[ast.AST] = None
                if isinstance(arg, ast.Lambda):
                    target = arg
                elif isinstance(arg, ast.Name) and arg.id in by_name:
                    target = by_name[arg.id].node
                if target is not None and target in self.functions:
                    info = self.functions[target]
                    info.jitted = True
                    info.jit_reason = ("pallas_call" if is_pallas
                                       else "jax.jit(...)")
                    if statics:
                        info.static_names = statics

    def _jit_spec(self, dec: ast.AST) -> Tuple[bool, Tuple[str, ...]]:
        """Decorator node -> (is a jit decorator, static_argnames)."""
        if self.dotted(dec) in JIT_CALLS:
            return True, ()
        if isinstance(dec, ast.Call):
            fname = self.dotted(dec.func) or ""
            if fname in JIT_CALLS:
                return True, self._static_names_from_call(dec)
            if fname in ("functools.partial", "partial") and dec.args:
                if self.dotted(dec.args[0]) in JIT_CALLS:
                    return True, self._static_names_from_call(dec)
        return False, ()

    @staticmethod
    def _static_names_from_call(call: ast.Call) -> Tuple[str, ...]:
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                try:
                    v = ast.literal_eval(kw.value)
                except (ValueError, SyntaxError):
                    return ()
                if isinstance(v, str):
                    return (v,)
                if isinstance(v, (tuple, list)):
                    return tuple(x for x in v if isinstance(x, str))
        return ()

    # --------------------------------------------------------- resolution
    def dotted(self, node: ast.AST) -> Optional[str]:
        """Best-effort dotted name for a Name/Attribute chain.

        ``np.asarray`` -> ``numpy.asarray``; ``runtime.active`` ->
        ``repro.runtime.active``; a bare name naming a module-level def ->
        its qualified name; ``self.f`` -> ``module.Class.f`` when the
        chain starts at ``self`` inside a class. Returns None for
        anything dynamic.
        """
        parts: List[str] = []
        cur = node
        while isinstance(cur, ast.Attribute):
            parts.append(cur.attr)
            cur = cur.value
        if not isinstance(cur, ast.Name):
            return None
        parts.append(cur.id)
        parts.reverse()
        head, rest = parts[0], parts[1:]
        if head == "self" and rest:
            cls = self._enclosing_class(node)
            if cls is not None:
                return ".".join([self.module, cls.name] + rest)
            return None
        base = self.aliases.get(head)
        if base is None:
            # a bare local name: qualify module-level defs so the call
            # graph can link them
            if not rest and any(
                info.qualname == f"{self.module}.{head}"
                for info in self.functions.values()
            ):
                return f"{self.module}.{head}"
            base = head if rest else None
            if base is None:
                return None
        return ".".join([base] + rest)

    def _enclosing_class(self, node: ast.AST) -> Optional[ast.ClassDef]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return cur
            cur = self.parents.get(cur)
        return None

    def enclosing_functions(self, node: ast.AST) -> Iterator[FuncInfo]:
        """Innermost-out FuncInfo chain containing ``node``."""
        cur = self.parents.get(node)
        while cur is not None:
            if cur in self.functions:
                yield self.functions[cur]
            cur = self.parents.get(cur)

    def enclosing_jit(self, node: ast.AST) -> Optional[FuncInfo]:
        """Nearest enclosing function that jax traces (jit / pallas body).

        Anything lexically inside a jitted function — including nested
        helper defs, which execute when the trace calls them — counts as
        trace-time context. This over-approximates (a nested def that is
        only ever returned, not called, still counts) and rules accept
        that: the pragma mechanism exists for the rare justified case.
        """
        for info in self.enclosing_functions(node):
            if info.jitted:
                return info
        return None

    def in_loop(self, node: ast.AST) -> bool:
        """Whether ``node`` sits lexically inside a for/while body of the
        same function (crossing a def boundary resets — a closure defined
        in a loop is the closure's problem, not its body's)."""
        cur = self.parents.get(node)
        child = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return False
            if isinstance(cur, (ast.For, ast.AsyncFor, ast.While)):
                # the For iterable evaluates once, everything else in the
                # loop node (body / orelse / While test) runs per iteration
                if not (isinstance(cur, (ast.For, ast.AsyncFor))
                        and child is cur.iter):
                    return True
            child = cur
            cur = self.parents.get(cur)
        return False

    def resolve_int(self, node: ast.AST,
                    fn: Optional[ast.AST] = None) -> Optional[int]:
        """Literal int value of an expression, chasing simple names.

        Resolves: int constants; names bound to an int default of the
        enclosing function; names bound to a module-level int constant.
        Anything else (min()/arithmetic/attributes) -> None, and the
        geometry rules skip it rather than guess.
        """
        if isinstance(node, ast.Constant) and type(node.value) is int:
            return node.value
        if isinstance(node, ast.Name):
            if fn is not None:
                v = self._default_int(fn, node.id)
                if v is not None:
                    return v
            for info in self.enclosing_functions(node):
                v = self._default_int(info.node, node.id)
                if v is not None:
                    return v
            return self.module_consts.get(node.id)
        return None

    @staticmethod
    def _default_int(fn: ast.AST, name: str) -> Optional[int]:
        a = fn.args
        pos = a.posonlyargs + a.args
        defaults = a.defaults
        for arg, d in zip(pos[len(pos) - len(defaults):], defaults,
                          strict=True):
            if arg.arg == name and isinstance(d, ast.Constant) \
                    and type(d.value) is int:
                return d.value
        for arg, d in zip(a.kwonlyargs, a.kw_defaults, strict=True):
            if d is not None and arg.arg == name \
                    and isinstance(d, ast.Constant) and type(d.value) is int:
                return d.value
        return None

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""


class ProjectIndex:
    """Cross-module view: which functions read the config, transitively.

    Built from every analyzed file's ``FuncInfo`` records, then closed
    over the dotted-name call graph to a fixed point. Resolution is
    best-effort by construction — a call the graph cannot link (dynamic
    dispatch, registries) simply does not propagate, which keeps the
    analysis quiet rather than noisy; the self-test pins the idioms it
    must catch.
    """

    def __init__(self) -> None:
        self.functions: Dict[str, FuncInfo] = {}

    def add_file(self, ctx: FileContext) -> None:
        for info in ctx.functions.values():
            if info.qualname.endswith("<lambda>"):
                continue  # lambdas are analyzed via their enclosing function
            # first definition wins; duplicate qualnames (overloads in
            # branches) are rare enough to ignore
            self.functions.setdefault(info.qualname, info)

    def finalize(self) -> None:
        """Fixed-point propagation of ``reads_config`` up the call graph."""
        changed = True
        while changed:
            changed = False
            for info in self.functions.values():
                if info.reads_config:
                    continue
                for callee in info.calls:
                    target = self.functions.get(callee)
                    if target is not None and target.reads_config:
                        info.reads_config = True
                        changed = True
                        break

    def reads_config(self, qualname: str) -> bool:
        info = self.functions.get(qualname)
        return bool(info and info.reads_config)

    def reading_callees(self, info: FuncInfo) -> List[str]:
        """Which of ``info``'s direct callees (transitively) read config."""
        out = []
        for callee in sorted(info.calls):
            target = self.functions.get(callee)
            if target is not None and target.reads_config:
                out.append(callee)
        return out
