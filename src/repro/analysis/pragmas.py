"""Suppression pragmas: ``# repro: allow[RULE,...]: reason``.

Grammar (one comment, same physical line as the violation or a standalone
comment on the line directly above it)::

    # repro: allow[HS201]: §12 spill — forced host copy at the boundary
    # repro: allow[RC101,RC102]: wrapper resolves config pre-jit

The *reason* is mandatory: a suppression with no stated justification is
exactly the silent contract erosion the analyzer exists to prevent, so a
reasonless or unknown-rule pragma is a check failure (:class:`PragmaError`),
not a warning.

Parsing uses :mod:`tokenize`, not string search, so pragma examples inside
docstrings and string literals (this repo documents the grammar in several
places, including this module) never act as live suppressions.
"""
from __future__ import annotations

import io
import re
import tokenize
from typing import List, Tuple

from repro.analysis.findings import PragmaError, Suppression
from repro.analysis.registry import known_rule

PRAGMA_RE = re.compile(
    r"#\s*repro:\s*allow\[(?P<rules>[^\]]*)\]\s*(?::\s*(?P<reason>.*))?$")

#: loose detector for things that *look like* a pragma but do not parse —
#: a typo'd pragma must fail loudly, not silently suppress nothing
PRAGMA_HINT_RE = re.compile(r"#\s*repro:")


def parse_pragmas(path: str, source: str,
                  ) -> Tuple[List[Suppression], List[PragmaError]]:
    """Extract suppressions (and malformed-pragma errors) from a module.

    A trailing comment suppresses its own line; a standalone comment
    (nothing but whitespace before the ``#``) suppresses the next line.
    """
    suppressions: List[Suppression] = []
    errors: List[PragmaError] = []
    try:
        tokens = list(tokenize.generate_tokens(
            io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError):
        return suppressions, errors  # the runner reports the syntax error

    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        text = tok.string.strip()
        if not PRAGMA_HINT_RE.match(text):
            continue
        lineno, col = tok.start
        m = PRAGMA_RE.match(text)
        if not m:
            errors.append(PragmaError(
                path=path, line=lineno,
                message=(f"malformed pragma {text!r} — expected "
                         f"`# repro: allow[RULE,...]: reason`")))
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",")
                      if r.strip())
        reason = (m.group("reason") or "").strip()
        if not rules:
            errors.append(PragmaError(
                path=path, line=lineno,
                message="pragma suppresses no rules — allow[] is empty"))
            continue
        unknown = [r for r in rules if not known_rule(r)]
        if unknown:
            errors.append(PragmaError(
                path=path, line=lineno,
                message=(f"pragma names unknown rule(s) "
                         f"{', '.join(unknown)} — see "
                         f"`python -m repro.analysis explain`")))
            continue
        if not reason:
            errors.append(PragmaError(
                path=path, line=lineno,
                message=(f"pragma allow[{','.join(rules)}] has no reason — "
                         f"a suppression must say *why* the contract does "
                         f"not apply here")))
            continue
        # standalone comment (only whitespace before it) covers the next
        # line; a trailing comment covers its own
        line_src = source.splitlines()[lineno - 1]
        standalone = line_src[:col].strip() == ""
        suppressions.append(Suppression(
            path=path,
            line=lineno + 1 if standalone else lineno,
            rules=rules,
            reason=reason,
            comment_line=lineno,
        ))
    return suppressions, errors
