"""Finding records and their stable fingerprints.

A finding is one rule violation at one source location. Findings are
matched against the committed baseline by *fingerprint* — ``(rule, path,
stripped source line)`` — never by line number, so unrelated edits above a
baselined site do not expire its entry (the same identity-over-position
choice as ``benchmarks/gate.py``'s row matching).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str            # rule id, e.g. "RC101"
    path: str            # repo-relative posix path
    line: int            # 1-based
    col: int             # 0-based
    message: str         # one-line description of this occurrence
    line_text: str = ""  # stripped source of the flagged line

    def fingerprint(self) -> Tuple[str, str, str]:
        """Identity used for baseline matching (line numbers drift)."""
        return (self.rule, self.path, self.line_text)

    def format(self, *, suffix: str = "") -> str:
        tail = f"  [{suffix}]" if suffix else ""
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.rule} {self.message}{tail}")


@dataclasses.dataclass(frozen=True)
class PragmaError:
    """A malformed suppression pragma (missing reason / unknown rule id).

    Pragma errors fail ``check`` like findings do: an unreasoned
    suppression is exactly the silent contract erosion the analyzer
    exists to stop.
    """

    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: PRAGMA {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    """A parsed ``# repro: allow[RULE,...]: reason`` pragma."""

    path: str
    line: int            # line the pragma suppresses (its own physical line,
                         # or the next line for a standalone comment)
    rules: Tuple[str, ...]
    reason: str
    comment_line: Optional[int] = None  # where the comment physically sits
