"""Analyzer self-test: every rule must catch its canonical violation.

For each registered rule there is a *bad* snippet (the exact idiom the
rule exists to flag, at a virtual path inside the rule's scope) and a
*clean* snippet (the repaired idiom at the same path). The self-test runs
the full pipeline — FileContext, ProjectIndex, pragma parsing — over the
virtual files and asserts: bad flags the rule, clean stays quiet, and a
pragma'd copy of the bad snippet is suppressed. CI runs this as its own
leg so a refactor of the analyzer cannot silently lobotomize a rule: the
gate would go green for the wrong reason, which is the one failure mode a
static gate must not have.
"""
from __future__ import annotations

import dataclasses
import textwrap
from typing import Dict, List, Tuple

from repro.analysis.registry import available_rules
from repro.analysis.runner import run_check


@dataclasses.dataclass(frozen=True)
class Case:
    rule: str
    path: str        # virtual repo-relative path (chooses the rule's scope)
    bad: str         # must yield >=1 finding of `rule`
    clean: str       # must yield none
    pragma_ok: bool = True  # also verify a pragma'd bad copy is suppressed


CASES: Tuple[Case, ...] = (
    Case(
        rule="RC101",
        path="src/repro/models/x.py",
        bad="""
            import jax
            from repro import runtime

            @jax.jit
            def step(x):
                cfg = runtime.active()
                return x * cfg.block_q
            """,
        clean="""
            import functools
            import jax
            from repro import runtime

            @functools.partial(jax.jit, static_argnames=("_dispatch",))
            def step(x, _dispatch=()):
                cfg = runtime.active()
                return x * cfg.block_q
            """,
    ),
    Case(
        rule="RC102",
        path="src/repro/models/x.py",
        bad="""
            import jax
            from repro import runtime

            def resolve_impl(x):
                return runtime.active().impl

            @jax.jit
            def step(x):
                return resolve_impl(x)
            """,
        clean="""
            import functools
            import jax
            from repro import runtime

            def resolve_impl(x):
                return runtime.active().impl

            @functools.partial(jax.jit, static_argnames=("_dispatch",))
            def step(x, _dispatch=()):
                return resolve_impl(x)
            """,
    ),
    Case(
        rule="RC103",
        path="src/repro/models/x.py",
        bad="""
            import os

            INTERPRET = os.getenv("REPRO_INTERPRET", "0") == "1"
            """,
        clean="""
            from repro import runtime

            def interpret_enabled():
                return runtime.active().interpret
            """,
    ),
    Case(
        rule="HS201",
        path="src/repro/core/x.py",
        bad="""
            import numpy as np

            def frontier(chunk):
                return np.asarray(chunk)
            """,
        clean="""
            def frontier(chunk):
                return chunk
            """,
    ),
    Case(
        rule="HS202",
        path="src/repro/serve/x.py",
        bad="""
            import jax.numpy as jnp

            def decode_done(tokens, eos):
                done = jnp.all(tokens == eos)
                return bool(done)
            """,
        clean="""
            def decode_done(pos_host, max_len):
                return bool(pos_host >= max_len)
            """,
    ),
    Case(
        rule="RT301",
        path="src/repro/models/x.py",
        bad="""
            from repro import runtime

            def run(cfg):
                with runtime.configure(interpret=True):
                    runtime.update_default(impl="ref")
            """,
        clean="""
            from repro import runtime

            def run(cfg):
                runtime.update_default(impl="ref")
                with runtime.configure(interpret=True):
                    pass
            """,
    ),
    Case(
        rule="RT302",
        path="src/repro/models/x.py",
        bad="""
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("opts",))
            def step(x, opts=[]):
                return x
            """,
        clean="""
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("opts",))
            def step(x, opts=()):
                return x
            """,
    ),
    Case(
        rule="RT303",
        path="src/repro/models/x.py",
        bad="""
            import jax

            def sweep(fns, x):
                for fn in fns:
                    x = jax.jit(fn)(x)
                return x
            """,
        clean="""
            import jax

            def sweep(fns, x):
                jitted = [jax.jit(fn) for fn in fns]
                for fn in jitted:
                    x = fn(x)
                return x
            """,
    ),
    Case(
        rule="PK401",
        path="src/repro/kernels/x.py",
        bad="""
            from jax.experimental import pallas as pl

            def spec():
                return pl.BlockSpec((8, 96), lambda i: (i, 0))
            """,
        clean="""
            from jax.experimental import pallas as pl

            def spec():
                return pl.BlockSpec((8, 128), lambda i: (i, 0))
            """,
    ),
    Case(
        rule="PK402",
        path="src/repro/kernels/x.py",
        bad="""
            from jax.experimental import pallas as pl

            def call(kernel, shape):
                return pl.pallas_call(
                    kernel,
                    out_shape=shape,
                    in_specs=[pl.BlockSpec((4096, 4096), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((4096, 4096), lambda i: (i, 0)),
                )
            """,
        clean="""
            from jax.experimental import pallas as pl

            def call(kernel, shape):
                return pl.pallas_call(
                    kernel,
                    out_shape=shape,
                    in_specs=[pl.BlockSpec((256, 128), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((256, 128), lambda i: (i, 0)),
                )
            """,
    ),
    Case(
        rule="DT501",
        path="src/repro/models/x.py",
        bad="""
            import numpy as np

            def init(n):
                rng = np.random.default_rng()
                return rng.normal(size=n)
            """,
        clean="""
            import numpy as np

            def init(n, seed):
                rng = np.random.default_rng(seed)
                return rng.normal(size=n)
            """,
    ),
    Case(
        rule="DT502",
        path="src/repro/models/x.py",
        bad="""
            def emit(handlers):
                out = []
                for name in {"b", "a", "c"}:
                    out.append(handlers[name])
                return out
            """,
        clean="""
            def emit(handlers):
                out = []
                for name in sorted({"b", "a", "c"}):
                    out.append(handlers[name])
                return out
            """,
    ),
    Case(
        rule="DT503",
        path="src/repro/models/x.py",
        bad="""
            import os

            def shards(d):
                return [f for f in os.listdir(d) if f.endswith(".npz")]
            """,
        clean="""
            import os

            def shards(d):
                return [f for f in sorted(os.listdir(d))
                        if f.endswith(".npz")]
            """,
    ),
    Case(
        rule="WN601",
        path="src/repro/models/x.py",
        bad="""
            import warnings

            def prune(cache):
                warnings.warn("stale entry", RuntimeWarning)
            """,
        clean="""
            import warnings

            def prune(cache):
                warnings.warn("stale entry", RuntimeWarning, stacklevel=2)
            """,
    ),
)


def _pragma_variant(case: Case) -> str:
    """The bad snippet with a standalone pragma above every line the rule
    flags — built by running the rule and inserting comments."""
    src = textwrap.dedent(case.bad).strip("\n") + "\n"
    report = run_check({case.path: src}, only=[case.rule])
    lines = src.splitlines()
    flagged = sorted({f.line for f in report.new}, reverse=True)
    for line in flagged:
        indent = lines[line - 1][: len(lines[line - 1])
                                 - len(lines[line - 1].lstrip())]
        lines.insert(
            line - 1,
            f"{indent}# repro: allow[{case.rule}]: self-test suppression")
    return "\n".join(lines) + "\n"


def run_selftest() -> Tuple[bool, List[str]]:
    """Run every case; returns (all passed, human-readable lines)."""
    lines: List[str] = []
    ok = True
    covered = {c.rule for c in CASES}
    missing = [r for r in available_rules() if r not in covered]
    if missing:
        ok = False
        lines.append(
            f"FAIL registry: rules without a self-test case: "
            f"{', '.join(missing)}")

    for case in CASES:
        bad_src = textwrap.dedent(case.bad).strip("\n") + "\n"
        clean_src = textwrap.dedent(case.clean).strip("\n") + "\n"
        failures: List[str] = []

        bad = run_check({case.path: bad_src}, only=[case.rule])
        if not any(f.rule == case.rule for f in bad.new):
            failures.append("bad snippet not flagged")

        clean = run_check({case.path: clean_src}, only=[case.rule])
        if clean.new:
            failures.append(
                "clean snippet flagged: "
                + "; ".join(f.format() for f in clean.new))

        if case.pragma_ok and not failures:
            sup = run_check({case.path: _pragma_variant(case)},
                            only=[case.rule])
            if sup.new:
                failures.append("pragma did not suppress the bad snippet")
            elif not sup.suppressed_pragma:
                failures.append("pragma variant produced no suppression")

        if failures:
            ok = False
            lines.append(f"FAIL {case.rule}: " + "; ".join(failures))
        else:
            lines.append(f"ok   {case.rule}")
    return ok, lines
