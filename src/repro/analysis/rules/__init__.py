"""Built-in rule families. Importing this package runs every
``@register_rule`` decorator, populating the registry — the same
import-time registration the clustering backends use."""
from repro.analysis.rules import dt, hs, pk, rc, rt, wn  # noqa: F401

__all__ = ["dt", "hs", "pk", "rc", "rt", "wn"]
