"""RC — the §10 runtime-config dispatch contract.

The contract: config resolution happens *before* the jit boundary. Public
drivers are unjitted wrappers that resolve ``None`` kwargs from
``runtime.active()`` and call an inner jitted function whose statics are
the concrete values; any jitted function that still reads the config at
trace time must carry ``RuntimeConfig.dispatch_key()`` as a static
``_dispatch`` argument, so the compiled-cache key covers everything the
trace read. A config read inside a jit without that pin is served from a
stale compiled program after the config changes — silently.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.context import (
    CONFIG_READ_CALLS,
    DISPATCH_PARAM,
    FileContext,
)
from repro.analysis.registry import RawFinding, register_rule


@register_rule(
    "RC101",
    title="trace-time config read inside jit without a _dispatch pin",
    explain="""
    A function that jax traces (a ``@jax.jit``/``functools.partial(jax.jit,
    ...)`` decorated def, a callable wrapped by ``jax.jit(...)``, or a
    ``pallas_call`` kernel body) calls ``runtime.active()`` /
    ``runtime.dispatch_key()`` / ``runtime.default_config()`` directly,
    and takes no ``_dispatch`` parameter.

    Why it matters (DESIGN.md §10): values read from the config during
    tracing are baked into the compiled program, but without a
    ``_dispatch`` static the jit cache key does not cover them — change
    the config, hit the stale program. Fix by resolving the config in the
    unjitted wrapper and passing concrete statics down, or by adding a
    static ``_dispatch: tuple = ()`` parameter fed
    ``RuntimeConfig.dispatch_key()`` by the wrapper (the idiom of
    ``core/knn.py`` / ``cluster/kmeans.py``).
    """,
)
def rc101(ctx: FileContext) -> Iterator[RawFinding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = ctx.dotted(node.func)
        if name not in CONFIG_READ_CALLS:
            continue
        jit_fn = ctx.enclosing_jit(node)
        if jit_fn is None or jit_fn.has_dispatch:
            continue
        yield node, (
            f"`{ctx.line_text(node.lineno)[:60]}` reads the runtime config "
            f"at trace time inside jitted `{jit_fn.qualname}`, which has no "
            f"static `{DISPATCH_PARAM}` parameter — a config change will "
            f"not retrace this program (DESIGN.md §10)")


@register_rule(
    "RC102",
    title="jitted function traces a config-reading callee without a "
          "_dispatch pin",
    explain="""
    A jitted function without a ``_dispatch`` parameter calls — possibly
    through several layers — a function that reads the runtime config
    (``itis_step``, the ``kernels.ops`` entry points, any public wrapper
    that resolves ``None`` kwargs from ``runtime.active()``). The read
    happens while *this* function's trace is live, so it is exactly the
    RC101 hazard, one call deeper: the cache key of the outer program does
    not cover the configuration the trace consulted.

    The call graph is resolved over dotted names across the analyzed file
    set (best-effort: dynamic dispatch and registry indirection do not
    propagate). Fix like RC101 — resolve in the wrapper, or add the
    ``_dispatch`` static and thread ``runtime.dispatch_key()`` from the
    call sites.
    """,
)
def rc102(ctx: FileContext) -> Iterator[RawFinding]:
    if ctx.project is None:
        return
    for info in ctx.functions.values():
        # config_read_lines, not reads_config: finalize() propagates the
        # latter transitively, and a *lexical* read is RC101's finding
        if not info.jitted or info.has_dispatch or info.config_read_lines:
            continue
        node = info.node
        readers = ctx.project.reading_callees(info)
        if not readers:
            continue
        pretty = ", ".join(r.rsplit(".", 1)[-1] for r in readers[:3])
        yield node, (
            f"jitted `{info.qualname}` has no static `{DISPATCH_PARAM}` "
            f"parameter but traces config-reading callee(s) {pretty} — "
            f"the compiled cache key does not cover the config they "
            f"resolve (DESIGN.md §10)")


@register_rule(
    "RC103",
    title="REPRO_* environment read outside the runtime config",
    explain="""
    ``os.environ`` / ``os.getenv`` is consulted for a ``REPRO_*`` variable
    somewhere other than ``repro/runtime/config.py``. The runtime config
    reads every ``REPRO_*`` override exactly once at import into the
    process-global default (DESIGN.md §10); a second ad-hoc read sees a
    different value after ``update_default``/``configure`` scopes, or
    changes behaviour mid-process when the environment mutates —
    configuration must flow through :class:`RuntimeConfig` so scoping,
    ``dispatch_key()`` and the documented precedence apply. Fix by adding
    a config field (plus ``_ENV_FIELDS`` entry) and reading the active
    config instead.
    """,
)
def rc103(ctx: FileContext) -> Iterator[RawFinding]:
    if ctx.path.endswith("runtime/config.py"):
        return
    for node in ast.walk(ctx.tree):
        var = None
        if isinstance(node, ast.Call):
            name = ctx.dotted(node.func)
            if name in ("os.getenv", "os.environ.get") and node.args:
                a0 = node.args[0]
                if isinstance(a0, ast.Constant) and isinstance(a0.value, str):
                    var = a0.value
        elif isinstance(node, ast.Subscript):
            if ctx.dotted(node.value) == "os.environ" \
                    and isinstance(node.slice, ast.Constant) \
                    and isinstance(node.slice.value, str) \
                    and isinstance(node.ctx, ast.Load):
                var = node.slice.value
        if var is not None and var.startswith("REPRO_"):
            yield node, (
                f"{var} read outside repro/runtime/config.py — REPRO_* "
                f"overrides must flow through RuntimeConfig so scoped "
                f"configure() and dispatch_key() see them (DESIGN.md §10)")
