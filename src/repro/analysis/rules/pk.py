"""PK — Pallas kernel geometry (DESIGN.md §16).

TPU vector memory is tiled: (8, 128) for f32 — 8 sublanes x 128 lanes —
with the minor-most dimension on lanes. Pallas block shapes that are not
powers of two, or whose trailing dims break sublane/lane alignment, force
the compiler into padded/strided layouts (silent 2-8x slowdowns), and
blocks that do not fit VMEM fail at lowering time on real hardware only —
CI on CPU interpret mode never sees it. These rules check the *static*
geometry: literal tile constants, defaults of ``block_*`` parameters, and
a conservative VMEM working-set estimate per ``pallas_call``.

Scope: only modules that import ``jax.experimental.pallas``.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

from repro.analysis.context import FileContext
from repro.analysis.registry import RawFinding, register_rule

#: f32 register tiling on TPU: 8 sublanes (second-minor) x 128 lanes (minor)
SUBLANE, LANE = 8, 128

#: VMEM budget per core in bytes. Real parts have ~16 MiB; the estimate
#: must leave room for double buffering (the x2 below) and spill slack.
VMEM_BUDGET = 16 * 1024 * 1024

_TILE_PARAM_NAMES = ("block_q", "block_k", "block_s", "block_n", "block_d",
                     "bq", "bk", "bs", "bn")


def _imports_pallas(ctx: FileContext) -> bool:
    return any(v.startswith("jax.experimental.pallas")
               for v in ctx.aliases.values())


def _pow2(v: int) -> bool:
    return v >= 1 and (v & (v - 1)) == 0


def _blockspec_dims(ctx: FileContext, call: ast.Call) -> List[ast.AST]:
    """The shape-tuple element nodes of a ``pl.BlockSpec((a, b), ...)``."""
    shape = None
    if call.args and isinstance(call.args[0], ast.Tuple):
        shape = call.args[0]
    for kw in call.keywords:
        if kw.arg == "block_shape" and isinstance(kw.value, ast.Tuple):
            shape = kw.value
    return list(shape.elts) if shape is not None else []


def _check_dim(value: int, position: int, ndims: int) -> Optional[str]:
    """Alignment verdict for one resolved literal dim (None = fine)."""
    if not _pow2(value):
        return (f"{value} is not a power of two — it cannot tile the "
                f"pow2 shape buckets the autotuner measures at "
                f"(DESIGN.md §14/§16)")
    if position == ndims - 1 and value >= LANE and value % LANE != 0:
        return f"minor dim {value} is not lane-aligned (multiple of {LANE})"
    if ndims >= 2 and position == ndims - 2 and value >= SUBLANE \
            and value % SUBLANE != 0:
        return (f"second-minor dim {value} is not sublane-aligned "
                f"(multiple of {SUBLANE})")
    return None


@register_rule(
    "PK401",
    title="Pallas tile constant breaks pow2 / sublane / lane alignment",
    explain="""
    A literal block dimension in a ``pl.BlockSpec`` shape (or the default
    of a ``block_*`` tile parameter in a Pallas module) is not a power of
    two, or a trailing dimension breaks the (8, 128) f32 register tiling.
    Misaligned blocks compile — to padded, strided layouts that quietly
    cost the 2-4x the fused kernels exist to win (DESIGN.md §16); non-pow2
    tiles additionally can never be produced or validated by the tuning
    cache, whose shape buckets are pow2 by construction (§14, the exact
    staleness check ``tuned_params`` enforces at runtime).

    Only dims the analyzer can resolve to int literals (constants,
    parameter defaults, module constants) are checked; computed sizes
    (``min(block_q, n)``) are skipped, not guessed.
    """,
    scope=("src/repro/kernels/",),
)
def pk401(ctx: FileContext) -> Iterator[RawFinding]:
    if not _imports_pallas(ctx):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            name = ctx.dotted(node.func) or ""
            if name.endswith("BlockSpec"):
                dims = _blockspec_dims(ctx, node)
                for i, dim in enumerate(dims):
                    v = ctx.resolve_int(dim)
                    if v is None:
                        continue
                    verdict = _check_dim(v, i, len(dims))
                    if verdict:
                        yield dim, f"BlockSpec dim {i}: {verdict}"
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = node.args
            pos = a.posonlyargs + a.args
            pairs = list(zip(pos[len(pos) - len(a.defaults):], a.defaults,
                             strict=True))
            pairs += [(arg, d)
                      for arg, d in zip(a.kwonlyargs, a.kw_defaults,
                                        strict=True)
                      if d is not None]
            for arg, default in pairs:
                if arg.arg in _TILE_PARAM_NAMES \
                        and isinstance(default, ast.Constant) \
                        and type(default.value) is int \
                        and not _pow2(default.value):
                    yield default, (
                        f"default {arg.arg}={default.value} of "
                        f"`{node.name}` is not a power of two — it cannot "
                        f"tile the pow2 tuning buckets (DESIGN.md §14/§16)")


@register_rule(
    "PK402",
    title="Pallas block working set exceeds the VMEM budget",
    explain="""
    The sum of a ``pallas_call``'s resolvable block buffers — every
    ``BlockSpec`` shape in ``in_specs``/``out_specs``, assumed f32 (4
    bytes) and doubled for the pipeline's double buffering — exceeds the
    16 MiB per-core VMEM budget. Oversized blocks fail at Mosaic lowering
    time on real TPUs only; CPU interpret mode (what CI runs) happily
    simulates them, so the first signal would otherwise be a production
    deploy. Dims that cannot be resolved to literals contribute their
    resolvable factors only — the estimate is a lower bound, so exceeding
    it is definitive.
    """,
    scope=("src/repro/kernels/",),
)
def pk402(ctx: FileContext) -> Iterator[RawFinding]:
    if not _imports_pallas(ctx):
        return
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and (ctx.dotted(node.func) or "").endswith("pallas_call")):
            continue
        total = 0
        resolved_any = False
        specs: List[ast.AST] = []
        for kw in node.keywords:
            if kw.arg in ("in_specs", "out_specs"):
                if isinstance(kw.value, (ast.List, ast.Tuple)):
                    specs.extend(kw.value.elts)
                else:
                    specs.append(kw.value)
            elif kw.arg == "out_shape":
                pass  # shapes there are full-array, not per-block
        for spec in specs:
            if not (isinstance(spec, ast.Call)
                    and (ctx.dotted(spec.func) or "").endswith("BlockSpec")):
                continue
            dims = _blockspec_dims(ctx, spec)
            size = 1
            ok = bool(dims)
            for dim in dims:
                v = ctx.resolve_int(dim)
                if v is None:
                    ok = False
                    continue
                size *= v
            if ok:
                resolved_any = True
                total += size * 4  # f32 bytes; conservative lower bound
        est = total * 2  # double buffering
        if resolved_any and est > VMEM_BUDGET:
            yield node, (
                f"pallas_call block working set ≥ {est // (1024 * 1024)} MiB "
                f"(f32, double-buffered) exceeds the "
                f"{VMEM_BUDGET // (1024 * 1024)} MiB VMEM budget — this "
                f"lowers on interpret-mode CI but fails on real TPUs "
                f"(DESIGN.md §16)")


def _tuple_dims(t: Tuple[int, ...]) -> str:
    return "x".join(str(x) for x in t)
