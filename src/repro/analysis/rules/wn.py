"""WN — warning hygiene.

A ``warnings.warn`` without ``stacklevel=`` reports the *library* line
that raised it, not the caller that triggered it. For the warnings this
repo emits on behalf of user code (stale tune-cache winners, deprecated
kwargs), that renders the warning useless: the user sees
``repro/tune/__init__.py:118`` instead of their own call site, and
``-W error::RuntimeWarning`` CI jobs can't attribute the failure.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.context import FileContext
from repro.analysis.registry import RawFinding, register_rule


@register_rule(
    "WN601",
    title="warnings.warn without stacklevel",
    explain="""
    ``warnings.warn(...)`` called without a ``stacklevel=`` keyword.
    The default (``stacklevel=1``) attributes the warning to the line
    inside this library that raised it — the one place the user did not
    write. Warnings that fire on behalf of a caller must pass
    ``stacklevel=2`` (or deeper, matching the wrapper depth) so the
    reported filename/lineno is the user's call site; the tune-cache
    prune warning is the in-repo reference. If the warning genuinely
    concerns this module itself (an import-time environment notice), say
    so with a pragma.
    """,
)
def wn601(ctx: FileContext) -> Iterator[RawFinding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        if ctx.dotted(node.func) != "warnings.warn":
            continue
        if any(kw.arg == "stacklevel" for kw in node.keywords):
            continue
        yield node, (
            "warnings.warn(...) without stacklevel= reports the library "
            "line, not the caller's — pass stacklevel=2 (or deeper) so "
            "the warning points at the triggering call site")
