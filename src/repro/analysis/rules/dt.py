"""DT — determinism (DESIGN.md §4.3).

The repo's reproducibility contract: same config + same seed → identical
assignments, identical tuned parameters, identical checkpoints. These
rules catch the entropy leaks that break it silently — RNGs seeded from
wall-clock/OS entropy (or not at all), and iteration orders that the
runtime does not define (sets, directory listings) feeding anything that
accumulates, so two runs of the same job diverge with no error anywhere.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.context import FileContext
from repro.analysis.registry import RawFinding, register_rule

#: draws against the process-global numpy RNG — order-dependent across
#: every call site in the process, untouched by the repo's seed plumbing
_GLOBAL_NP_DRAWS = frozenset(
    f"numpy.random.{fn}" for fn in (
        "rand", "randn", "randint", "random", "random_sample", "normal",
        "uniform", "choice", "permutation", "shuffle", "standard_normal",
    ))
_GLOBAL_STDLIB_DRAWS = frozenset(
    f"random.{fn}" for fn in (
        "random", "randint", "randrange", "uniform", "choice", "choices",
        "sample", "shuffle", "gauss",
    ))
_RNG_FACTORIES = ("numpy.random.default_rng", "random.Random")
_SEEDERS = ("numpy.random.seed", "random.seed")

#: calls whose value is wall-clock / OS entropy — a seed derived from one
#: makes the "seed" different every run by construction
_ENTROPY_PREFIXES = ("time.", "secrets.", "uuid.")
_ENTROPY_CALLS = ("os.urandom",)


def _entropy_call(ctx: FileContext, node: ast.AST) -> Optional[str]:
    if not isinstance(node, ast.Call):
        return None
    name = ctx.dotted(node.func)
    if name is None:
        return None
    if name in _ENTROPY_CALLS or name.startswith(_ENTROPY_PREFIXES):
        return name
    return None


@register_rule(
    "DT501",
    title="unseeded or entropy-seeded randomness",
    explain="""
    An RNG constructed from OS entropy — ``np.random.default_rng()`` /
    ``random.Random()`` with no seed, a seed derived from ``time.*`` /
    ``os.urandom`` / ``secrets`` / ``uuid``, or any draw against the
    process-global ``np.random`` / ``random`` singletons (whose state
    depends on every other call site in the process).

    The §4.3 contract is bit-exact reruns: k-means++ seeding, ITIS
    sampling and the data pipeline all thread explicit
    ``default_rng(seed)`` / ``jax.random`` keys precisely so the same job
    replays identically. One entropy-seeded draw upstream of a key
    schedule makes results irreproducible with no error anywhere. Fix by
    threading a seed from the caller (ultimately from config / CLI), and
    deriving child seeds with ``spawn()`` / ``fold_in`` rather than fresh
    entropy.
    """,
)
def dt501(ctx: FileContext) -> Iterator[RawFinding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = ctx.dotted(node.func)
        if name is None:
            continue
        if name in _GLOBAL_NP_DRAWS or name in _GLOBAL_STDLIB_DRAWS:
            yield node, (
                f"{name}(...) draws from the process-global RNG — state "
                f"depends on unrelated call sites; thread an explicit "
                f"seeded generator instead (DESIGN.md §4.3)")
            continue
        if name in _RNG_FACTORIES or name in _SEEDERS:
            if not node.args and not node.keywords:
                yield node, (
                    f"{name}() with no seed draws OS entropy — two runs of "
                    f"the same job diverge; thread an explicit seed "
                    f"(DESIGN.md §4.3)")
                continue
            seed = node.args[0] if node.args else None
            if seed is None:
                for kw in node.keywords:
                    if kw.arg in ("seed", None):
                        seed = kw.value
            ent = _entropy_call(ctx, seed) if seed is not None else None
            if ent:
                yield node, (
                    f"{name}(...) seeded from {ent}() — a wall-clock/OS "
                    f"entropy seed is different every run; derive seeds "
                    f"from the job seed (DESIGN.md §4.3)")


def _sorted_wrapped(ctx: FileContext, node: ast.AST) -> bool:
    """Whether ``node`` is a direct argument of ``sorted(...)``."""
    parent = ctx.parents.get(node)
    return (isinstance(parent, ast.Call)
            and isinstance(parent.func, ast.Name)
            and parent.func.id == "sorted")


def _iteration_targets(tree: ast.AST) -> Iterator[ast.AST]:
    """Every expression something iterates over: for-loops and
    comprehension generators."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.AsyncFor)):
            yield node.iter
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                yield gen.iter


@register_rule(
    "DT502",
    title="iteration over a set with undefined order",
    explain="""
    A ``for`` loop or comprehension iterates a set literal or
    ``set(...)`` / ``frozenset(...)`` call directly. Set iteration order
    is a function of insertion history and hash seeding — stable enough to
    pass tests, unstable enough to reorder work across processes. When
    the loop feeds anything order-sensitive (accumulation into a float
    sum, key derivation, file emission order), two runs differ. Wrap in
    ``sorted(...)`` — the repo pays the O(n log n) everywhere order can
    escape (cache keys in ``tune``, manifest writes in ``train``).
    """,
)
def dt502(ctx: FileContext) -> Iterator[RawFinding]:
    for it in _iteration_targets(ctx.tree):
        bad = None
        if isinstance(it, ast.Set):
            bad = "a set literal"
        elif isinstance(it, ast.Call) and isinstance(it.func, ast.Name) \
                and it.func.id in ("set", "frozenset"):
            bad = f"{it.func.id}(...)"
        if bad and not _sorted_wrapped(ctx, it):
            yield it, (
                f"iterating {bad} — set order is undefined across "
                f"processes; wrap in sorted(...) so downstream order is "
                f"reproducible (DESIGN.md §4.3)")


_FS_LISTING_CALLS = {
    "os.listdir": "os.listdir",
    "os.scandir": "os.scandir",
    "glob.glob": "glob.glob",
    "glob.iglob": "glob.iglob",
}
_FS_LISTING_METHODS = ("iterdir", "glob", "rglob")


@register_rule(
    "DT503",
    title="unsorted filesystem listing order",
    explain="""
    A loop or comprehension iterates ``os.listdir`` / ``glob.glob`` /
    ``Path.iterdir`` output directly. Listing order is filesystem-
    dependent (POSIX guarantees nothing; it differs between ext4, tmpfs
    and object-store FUSE mounts) — so checkpoint discovery, shard
    ingestion and cache scans ordered by it do different things on
    different machines. ``sorted(...)`` makes the order part of the
    program. The checkpoint manager's retention scan is the canonical
    in-repo example: it must delete the *oldest* steps, not the first
    ones the kernel happens to return.
    """,
)
def dt503(ctx: FileContext) -> Iterator[RawFinding]:
    for it in _iteration_targets(ctx.tree):
        if not isinstance(it, ast.Call) or _sorted_wrapped(ctx, it):
            continue
        name = ctx.dotted(it.func)
        label = None
        if name in _FS_LISTING_CALLS:
            label = _FS_LISTING_CALLS[name]
        elif isinstance(it.func, ast.Attribute) \
                and it.func.attr in _FS_LISTING_METHODS \
                and name is None:
            # method on a non-module object: Path(...).iterdir() and such
            label = f".{it.func.attr}()"
        if label:
            yield it, (
                f"iterating {label} output directly — filesystem listing "
                f"order is platform-dependent; wrap in sorted(...) "
                f"(DESIGN.md §4.3)")
