"""HS — host-sync discipline on the hot paths.

The fit/serve hot paths (``repro/kernels``, ``repro/core``,
``repro/serve``) are built so the host never waits on the device: the
streaming fold advances by host arithmetic alone, the decode loop feeds
tokens without reading them back, spills to the host are *deliberate*
forced copies (DESIGN.md §12). An accidental ``np.asarray`` / ``.item()``
/ ``bool(jnp...)`` in that code inserts a device→host synchronization —
latency the profiler attributes to nothing — or, on CPU backends, a
zero-copy view that pins a device buffer. Deliberate sync points carry a
``# repro: allow[HS...]: reason`` pragma; everything else is a bug.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional, Set

from repro.analysis.context import FileContext
from repro.analysis.registry import RawFinding, register_rule

HOT_PATHS = ("src/repro/kernels/", "src/repro/core/", "src/repro/serve/")

# calls that force (or can force) a device->host transfer / sync
_SYNC_CALLS = {
    "numpy.asarray": "np.asarray",
    "numpy.array": "np.array",
    "numpy.ascontiguousarray": "np.ascontiguousarray",
    "jax.device_get": "jax.device_get",
    "jax.block_until_ready": "jax.block_until_ready",
}
_SYNC_METHODS = ("item", "block_until_ready", "copy_to_host_async")

#: names whose call produces a jax value (for the scalar-coercion rule):
#: any dotted path rooted at jax/jnp, e.g. jnp.all, jax.numpy.sum, lax.*
_JAX_ROOTS = ("jax", "jax.numpy", "jax.lax")


@register_rule(
    "HS201",
    title="device->host sync/copy call on a hot path",
    explain="""
    ``np.asarray`` / ``np.array`` / ``np.ascontiguousarray`` /
    ``jax.device_get`` / ``jax.block_until_ready`` / ``.item()`` /
    ``.block_until_ready()`` called inside ``repro/kernels``,
    ``repro/core`` or ``repro/serve``. Applied to a device value these
    block the host on the device stream (and on CPU backends
    ``np.asarray`` is a zero-copy view that *pins* the buffer — the exact
    failure DESIGN.md §12 forces copies to avoid).

    The analyzer cannot see types, so every occurrence on a hot path is
    flagged; the documented spill points answer with a pragma stating the
    reason, e.g.::

        maps.append(np.array(out.assignment))  # repro: allow[HS201]: §12 spill — forced host copy

    Anything without a pragma is either an accidental sync (fix: keep the
    value on device, or batch the transfer at a documented boundary) or an
    undocumented one (fix: add the reasoned pragma).
    """,
    scope=HOT_PATHS,
)
def hs201(ctx: FileContext) -> Iterator[RawFinding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = ctx.dotted(node.func)
        if name in _SYNC_CALLS:
            yield node, (
                f"{_SYNC_CALLS[name]}(...) on a hot path forces a "
                f"device->host sync (or a pinning zero-copy view) — spill "
                f"points must be deliberate and pragma'd (DESIGN.md §12)")
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in _SYNC_METHODS and not node.args \
                and not node.keywords:
            yield node, (
                f".{node.func.attr}() on a hot path blocks the host on "
                f"the device stream — spill points must be deliberate "
                f"and pragma'd (DESIGN.md §12)")


def _local_jax_names(fn: ast.AST) -> Set[str]:
    """Names assigned from a jax/jnp-rooted call within ``fn`` (one level
    of single-assignment tracking — enough for ``x = jnp.all(...); int(x)``)."""
    names: Set[str] = set()
    for sub in ast.walk(fn):
        if isinstance(sub, ast.Assign) and isinstance(sub.value, ast.Call):
            if _is_jax_call(sub.value):
                for t in sub.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
    return names


def _is_jax_call(call: ast.Call, ctx: Optional[FileContext] = None) -> bool:
    # cheap structural test: dotted chain rooted at a jax-ish alias
    cur = call.func
    while isinstance(cur, ast.Attribute):
        cur = cur.value
    return isinstance(cur, ast.Name) and cur.id in ("jax", "jnp", "lax")


@register_rule(
    "HS202",
    title="python scalar coercion of a jax value on a hot path",
    explain="""
    ``int(...)`` / ``float(...)`` / ``bool(...)`` applied to a jax
    expression (a call rooted at ``jnp``/``jax``/``lax``, or a local name
    assigned from one) inside the hot-path packages. Coercing a traced or
    device value to a python scalar synchronizes the host with the device
    — per loop iteration, that is the difference between a pipelined
    decode/stream loop and one that stalls every step (the §12 streaming
    executor exists to avoid exactly this).

    Fix by keeping the decision on the device, deriving the quantity from
    host-side arithmetic (shapes, counters), or — where a host decision
    point is genuinely required, e.g. an early-exit check — making the
    sync explicit and pragma'd.
    """,
    scope=HOT_PATHS,
)
def hs202(ctx: FileContext) -> Iterator[RawFinding]:
    locals_cache: dict = {}

    def scope_jax_locals(node: ast.AST) -> Set[str]:
        encl = next(
            (i.node for i in ctx.enclosing_functions(node)), ctx.tree)
        if encl not in locals_cache:
            locals_cache[encl] = _local_jax_names(encl)
        return locals_cache[encl]

    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in ("int", "float", "bool")
                and len(node.args) == 1 and not node.keywords):
            continue
        arg = node.args[0]
        coerced = None
        if isinstance(arg, ast.Call) and _is_jax_call(arg):
            coerced = "a jax call result"
        elif isinstance(arg, ast.Name) and arg.id in scope_jax_locals(node):
            coerced = f"`{arg.id}` (assigned from a jax call)"
        elif isinstance(arg, ast.Subscript) \
                and isinstance(arg.value, ast.Name) \
                and arg.value.id in scope_jax_locals(node):
            coerced = f"`{arg.value.id}[...]` (assigned from a jax call)"
        if coerced:
            yield node, (
                f"{node.func.id}() of {coerced} synchronizes host "
                f"and device on a hot path — derive it host-side "
                f"or pragma the deliberate sync (DESIGN.md §12)")
