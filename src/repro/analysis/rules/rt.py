"""RT — retrace hazards.

The §10/§14 machinery guarantees that *config changes retrace*; these
rules catch the patterns that defeat or abuse that guarantee from the
other side: mutating the process default inside a scoped override (the
mutation is shadowed, so nothing retraces), statics that cannot be hashed
into a cache key, and jit wrappers constructed per loop iteration (every
iteration gets a fresh cache, i.e. a guaranteed retrace).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.context import FileContext
from repro.analysis.registry import RawFinding, register_rule

_MUTATORS = ("repro.runtime.update_default", "repro.runtime.set_default",
             "repro.runtime.config.update_default",
             "repro.runtime.config.set_default")
_CONFIGURE = ("repro.runtime.configure", "repro.runtime.config.configure")


@register_rule(
    "RT301",
    title="process-default config mutation inside a configure() scope",
    explain="""
    ``runtime.update_default(...)`` / ``runtime.set_default(...)`` called
    lexically inside a ``with runtime.configure(...):`` block. The scoped
    override sits on top of the default on the thread-local stack
    (DESIGN.md §10), so the mutated default is shadowed until the scope
    exits: dispatch keeps resolving the scope's values, nothing retraces,
    and the "change" silently applies only after an unwind the author may
    be three frames away from. Mutate the default outside the scope, or
    use a nested ``configure(...)`` override instead.
    """,
)
def rt301(ctx: FileContext) -> Iterator[RawFinding]:
    # collect configure() with-blocks, then flag mutators inside them
    scopes = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                expr = item.context_expr
                if isinstance(expr, ast.Call) \
                        and ctx.dotted(expr.func) in _CONFIGURE:
                    scopes.append(node)
                    break
    for scope in scopes:
        for node in ast.walk(scope):
            if isinstance(node, ast.Call) \
                    and ctx.dotted(node.func) in _MUTATORS:
                fn = ctx.dotted(node.func).rsplit(".", 1)[-1]
                yield node, (
                    f"runtime.{fn}(...) inside a `with runtime.configure"
                    f"(...)` scope — the scoped override shadows the "
                    f"mutated default, so the change is invisible (and "
                    f"nothing retraces) until the scope exits")


@register_rule(
    "RT302",
    title="jit static argument with an unhashable default",
    explain="""
    A parameter named in ``static_argnames`` defaults to a list / dict /
    set literal. Static arguments become part of the jit cache key, which
    requires hashing: the default value raises ``TypeError: unhashable
    type`` the first time the caller omits the argument — at call time,
    far from the definition. Use a tuple / frozenset / None default (the
    repo's inner jits use ``_dispatch: tuple = ()``).
    """,
)
def rt302(ctx: FileContext) -> Iterator[RawFinding]:
    for node, info in ctx.functions.items():
        if not info.jitted or not info.static_names:
            continue
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        a = node.args
        pos = a.posonlyargs + a.args
        pairs = list(zip(pos[len(pos) - len(a.defaults):], a.defaults,
                         strict=True))
        pairs += [(arg, d)
                  for arg, d in zip(a.kwonlyargs, a.kw_defaults,
                                    strict=True)
                  if d is not None]
        for arg, default in pairs:
            if arg.arg in info.static_names \
                    and isinstance(default, (ast.List, ast.Dict, ast.Set,
                                             ast.DictComp, ast.ListComp,
                                             ast.SetComp)):
                kind = type(default).__name__.lower().replace("comp", " comp")
                yield default, (
                    f"static argument `{arg.arg}` of jitted "
                    f"`{info.qualname}` defaults to a {kind} — statics are "
                    f"hashed into the jit cache key, so the default raises "
                    f"TypeError at call time; use tuple/frozenset/None")


@register_rule(
    "RT303",
    title="jax.jit wrapper constructed inside a loop",
    explain="""
    ``jax.jit(...)`` called in a for/while body. jit caches compiled
    programs on the *wrapper object*: a wrapper constructed per iteration
    starts with an empty cache, so every iteration re-traces and
    re-compiles even when shapes and statics repeat — the retrace cost
    §10 is engineered to avoid, paid n times. Hoist the ``jax.jit`` call
    out of the loop (or cache the wrapper, as ``ServeEngine`` does at
    construction). Sweeps that *intend* one compile per iteration (each
    cell a different shape) carry a pragma saying so.
    """,
)
def rt303(ctx: FileContext) -> Iterator[RawFinding]:
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) \
                and ctx.dotted(node.func) in ("jax.jit", "jax.api.jit") \
                and ctx.in_loop(node):
            yield node, (
                "jax.jit(...) inside a loop body builds a fresh wrapper "
                "(empty compile cache) every iteration — every pass "
                "retraces; hoist the wrapper out of the loop")
