"""Static analysis for the repo's runtime contracts (DESIGN.md §17).

The contracts that make this codebase fast and reproducible — §10 config
dispatch, §12 host-sync discipline, §14 retrace hygiene, §16 kernel
geometry, §4.3 determinism — are invariants the test suite can only spot-
check: a missing ``_dispatch`` pin or a stray ``int(jnp_value)`` in the
decode loop produces *correct numbers, slowly or unreproducibly*. This
package checks them structurally, over the AST, with zero runtime
dependencies (no jax import), so CI gates on them before anything runs.

Layout mirrors the rest of the repo's registry idiom:

- ``registry``   ``@register_rule`` + resolution (cf. ``cluster.registry``)
- ``context``    per-file AST context + whole-repo call-graph index
- ``rules/``     the rule families: RC, HS, RT, PK, DT, WN
- ``pragmas``    ``# repro: allow[RULE]: reason`` suppressions
- ``baseline``   committed, reasoned debt ledger (``analysis-baseline.json``)
- ``runner``     two-pass driver producing a settled :class:`Report`
- ``selftest``   per-rule bad/clean/pragma'd golden snippets
- ``__main__``   ``python -m repro.analysis check|explain|baseline``

Import note: this package is intentionally importable without jax — keep
it that way (the ``static-analysis`` CI job runs on a bare python).
"""
from repro.analysis.baseline import (
    Baseline,
    BaselineEntry,
    load_baseline,
    save_baseline,
)
from repro.analysis.findings import Finding, PragmaError, Suppression
from repro.analysis.registry import (
    FAMILIES,
    Rule,
    available_rules,
    iter_rules,
    register_rule,
    resolve_rule,
)
from repro.analysis.runner import Report, gather_sources, run_check
from repro.analysis.selftest import run_selftest

__all__ = [
    "Baseline",
    "BaselineEntry",
    "FAMILIES",
    "Finding",
    "PragmaError",
    "Report",
    "Rule",
    "Suppression",
    "available_rules",
    "gather_sources",
    "iter_rules",
    "load_baseline",
    "register_rule",
    "resolve_rule",
    "run_check",
    "run_selftest",
    "save_baseline",
]
