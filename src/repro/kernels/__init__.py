"""Pallas TPU kernels for the compute hot-spots of IHTC + the LM stack.

Each kernel module ships ``pl.pallas_call`` + explicit BlockSpec VMEM tiling;
``ops.py`` holds the jit'd dispatch wrappers and ``ref.py`` the pure-jnp
oracles the kernels are validated against (interpret mode on CPU).
"""
from . import ops, ref  # noqa: F401
