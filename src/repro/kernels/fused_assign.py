"""Fused nearest-prototype / streaming top-k — the assign/TC hot path.

The serving assign path and the TC inner loop both reduce to: distances of
a query block against a big key set, keep the k best. Composing
``pairwise_sq_l2 -> top-k merge`` through XLA materializes the full
(query x key) distance block in HBM; this kernel streams key blocks
flash-attention-style instead — each program computes one (Bq, Bk)
distance tile on the MXU and folds it into a running (Bq, k) best list
carried in VMEM, so the distance tile is never written to HBM and traffic
is O(nq·d + p·d + nq·k).

Three entry points, one merge semantics (bit-compatible with the composed
``ref.pairwise_sq_l2 + ref.merge_topk`` path — DESIGN.md §16):

  * :func:`fused_topk`      — the Pallas kernel (TPU; interpret mode on CPU
    for the parity tests). Generalizes ``knn_topk`` to query != key sets,
    takes self-exclusion as a *traced* global-query-index array (so blocked
    drivers can call it under ``lax.map`` with a dynamic block offset), and
    dequantizes int8 key tiles in-register.
  * :func:`fused_topk_xla`  — the same streaming fold expressed as a jnp
    ``fori_loop`` over key blocks: the production fused path on CPU/GPU
    (XLA compiles it well; Pallas-interpret would be orders slower). Peak
    memory O(nq·block_k), never (nq, p).
  * :func:`quantize_keys` / :func:`rescore_top1` — freeze-time per-feature
    int8 scale/zero-point quantization and the exact-f32 shortlist rescore
    the quantized ``impl`` variants use (``fused_bf16`` / ``fused_int8``
    shortlist with cheap distances, then rescore the shortlist against the
    full-precision buffer so labels match the exact path on separated
    data).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import runtime
from repro.kernels import ref

#: shortlist length the quantized assign variants rescore in exact f32
RESCORE_K = 8


def _sublane(dtype) -> int:
    """Minimum second-to-minor tile multiple for ``dtype`` on TPU
    (f32: 8, bf16: 16, int8: 32 — see the Pallas guide)."""
    itemsize = jnp.dtype(dtype).itemsize
    return {1: 32, 2: 16}.get(itemsize, 8)


def _lane_pad(d: int) -> int:
    """Zero-pad width taking a feature dim to a 128-lane multiple. Padding
    features with 0.0 is bitwise-safe for sq-L2: each per-feature term of
    the norm/cross reductions is independent and x + 0.0 == x in f32."""
    return (-d) % 128 if d > 128 else (128 - d)


def _fused_kernel(*refs, k, bq, bk, has_qg, quantized):
    it = iter(refs)
    q_ref = next(it)
    y_ref = next(it)
    yv_ref = next(it)
    qg_ref = next(it) if has_qg else None
    if quantized:
        scale_ref = next(it)
        zero_ref = next(it)
    bd_ref = next(it)
    bi_ref = next(it)

    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bd_ref[...] = jnp.full((bq, k), jnp.inf, jnp.float32)
        bi_ref[...] = jnp.full((bq, k), -1, jnp.int32)

    x = q_ref[...].astype(jnp.float32)  # (bq, d)
    if quantized:
        # dequantize the int8 key tile in-register: padded features carry
        # scale == zero == 0 so they contribute exact 0.0 to the distance
        y = (y_ref[...].astype(jnp.float32) * scale_ref[...][None, :]
             + zero_ref[...][None, :])
    else:
        y = y_ref[...].astype(jnp.float32)  # (bk, d)
    xn = jnp.sum(x * x, axis=-1)[:, None]
    yn = jnp.sum(y * y, axis=-1)[None, :]
    cross = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d = jnp.maximum(xn + yn - 2.0 * cross, 0.0)  # (bq, bk)

    kcols = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + j * bk
    d = jnp.where(yv_ref[...][None, :] > 0.0, d, jnp.inf)
    if has_qg:
        # self-exclusion against *global* key indices; qg is a traced array
        # so blocked drivers can pass `block_offset + iota` under lax.map
        d = jnp.where(qg_ref[...][:, None] == kcols, jnp.inf, d)

    # Merge running best (bq, k) with this tile: k rounds of
    # (row-min, record, mask) — same tie semantics as ref.merge_topk
    # (earliest index in concat order wins), static unroll, no sorts.
    cat_d = jnp.concatenate([bd_ref[...], d], axis=1)  # (bq, k+bk)
    cat_i = jnp.concatenate([bi_ref[...], kcols], axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, cat_d.shape, 1)
    new_d, new_i = [], []
    for _ in range(k):
        md = jnp.min(cat_d, axis=1)
        am = jnp.argmin(cat_d, axis=1)
        onehot = cols == am[:, None]
        mi = jnp.sum(jnp.where(onehot, cat_i, 0), axis=1)
        mi = jnp.where(jnp.isfinite(md), mi, -1)
        new_d.append(md)
        new_i.append(mi)
        cat_d = jnp.where(onehot, jnp.inf, cat_d)
    bd_ref[...] = jnp.stack(new_d, axis=1)
    bi_ref[...] = jnp.stack(new_i, axis=1)


def fused_topk(
    q: jax.Array,
    keys: jax.Array,
    k: int,
    key_valid: Optional[jax.Array] = None,
    *,
    q_gidx: Optional[jax.Array] = None,
    keys_scale: Optional[jax.Array] = None,
    keys_zero: Optional[jax.Array] = None,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """k nearest valid keys of each query row — fused Pallas kernel.

    Args:
      q: (nq, d) queries (any float dtype; distances fold in f32).
      keys: (p, d) keys — float, or int8 with ``keys_scale``/``keys_zero``
        per-feature dequantization parameters (see :func:`quantize_keys`).
      k: best-list length (static; small — 1 for assign, t*-1 for TC).
      key_valid: optional (p,) mask; invalid keys get distance ``+inf``.
      q_gidx: optional (nq,) int32 *global* index of each query row among
        the keys — matching key columns are excluded (the blocked-kNN
        self-match mask). May be traced (dynamic block offsets).

    Returns:
      (dists (nq, k) ascending sq-L2 f32, idx (nq, k) int32; unfilled
      slots inf/-1). Bit-identical to
      ``ref.pairwise_sq_l2 + ref.merge_topk`` (DESIGN.md §16).
    """
    cfg = runtime.active()
    block_q = cfg.block_q if block_q is None else block_q
    block_k = cfg.block_k if block_k is None else block_k
    return _fused_topk(q, keys, k, key_valid, q_gidx, keys_scale, keys_zero,
                       block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_k", "interpret")
)
def _fused_topk(
    q: jax.Array,
    keys: jax.Array,
    k: int,
    key_valid: Optional[jax.Array] = None,
    q_gidx: Optional[jax.Array] = None,
    keys_scale: Optional[jax.Array] = None,
    keys_zero: Optional[jax.Array] = None,
    *,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
):
    nq, d = q.shape
    p = keys.shape[0]
    quantized = keys_scale is not None
    if key_valid is None:
        key_valid = jnp.ones((p,), jnp.float32)
    else:
        key_valid = key_valid.astype(jnp.float32)

    # Tiling (same contract as knn_topk, but query/key axes pad
    # independently since the sets differ): rows round up to the dtype's
    # sublane multiple, each axis then pads to its own block multiple so
    # both grid axes tile with zero remainder.
    qa = _sublane(q.dtype)
    qrows = -(-max(nq, qa) // qa) * qa
    bq = min(block_q, qrows)
    nqp = -(-qrows // bq) * bq

    ka = _sublane(keys.dtype)
    krows = -(-max(p, ka) // ka) * ka
    bk = min(block_k, krows)
    pp = -(-krows // bk) * bk

    d_pad = _lane_pad(d)
    qp = jnp.pad(q, ((0, nqp - nq), (0, d_pad)))
    yp = jnp.pad(keys, ((0, pp - p), (0, d_pad)))
    vp = jnp.pad(key_valid, (0, pp - p))

    grid = (nqp // bq, pp // bk)
    dd = qp.shape[1]
    inputs = [qp, yp, vp]
    in_specs = [
        pl.BlockSpec((bq, dd), lambda i, j: (i, 0)),
        pl.BlockSpec((bk, dd), lambda i, j: (j, 0)),
        pl.BlockSpec((bk,), lambda i, j: (j,)),
    ]
    if q_gidx is not None:
        # padded query rows get -2: never matches a real key column
        inputs.append(jnp.pad(q_gidx.astype(jnp.int32), (0, nqp - nq),
                              constant_values=-2))
        in_specs.append(pl.BlockSpec((bq,), lambda i, j: (i,)))
    if quantized:
        inputs.append(jnp.pad(keys_scale.astype(jnp.float32), (0, d_pad)))
        inputs.append(jnp.pad(keys_zero.astype(jnp.float32), (0, d_pad)))
        in_specs.append(pl.BlockSpec((dd,), lambda i, j: (0,)))
        in_specs.append(pl.BlockSpec((dd,), lambda i, j: (0,)))

    kernel = functools.partial(
        _fused_kernel, k=k, bq=bq, bk=bk,
        has_qg=q_gidx is not None, quantized=quantized,
    )
    bd, bi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nqp, k), jnp.float32),
            jax.ShapeDtypeStruct((nqp, k), jnp.int32),
        ],
        interpret=interpret,
    )(*inputs)
    return bd[:nq], bi[:nq]


def fused_topk_xla(
    q: jax.Array,
    keys: jax.Array,
    k: int,
    key_valid: Optional[jax.Array] = None,
    *,
    q_gidx: Optional[jax.Array] = None,
    keys_scale: Optional[jax.Array] = None,
    keys_zero: Optional[jax.Array] = None,
    block_k: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """The fused streaming fold as plain jnp — the production fused path on
    non-TPU backends. Identical signature/semantics to :func:`fused_topk`
    (minus ``block_q``: XLA fuses the query axis itself); peak live
    distance memory is O(nq·block_k) instead of O(nq·p)."""
    cfg = runtime.active()
    block_k = cfg.block_k if block_k is None else block_k
    return _fused_topk_xla(q, keys, k, key_valid, q_gidx, keys_scale,
                           keys_zero, block_k=block_k)


@functools.partial(jax.jit, static_argnames=("k", "block_k"))
def _fused_topk_xla(
    q: jax.Array,
    keys: jax.Array,
    k: int,
    key_valid: Optional[jax.Array] = None,
    q_gidx: Optional[jax.Array] = None,
    keys_scale: Optional[jax.Array] = None,
    keys_zero: Optional[jax.Array] = None,
    *,
    block_k: int = 512,
):
    nq = q.shape[0]
    p = keys.shape[0]
    if key_valid is None:
        key_valid = jnp.ones((p,), bool)
    bk = min(block_k, max(p, 1))
    pad = (-p) % bk
    yp = jnp.pad(keys, ((0, pad), (0, 0)))
    vp = jnp.pad(key_valid.astype(bool), (0, pad))
    nb = (p + pad) // bk

    def body(b, carry):
        bd, bi = carry
        y = jax.lax.dynamic_slice_in_dim(yp, b * bk, bk, axis=0)
        if keys_scale is not None:
            y = (y.astype(jnp.float32) * keys_scale[None, :]
                 + keys_zero[None, :])
        v = jax.lax.dynamic_slice_in_dim(vp, b * bk, bk, axis=0)
        d = ref.pairwise_sq_l2(q, y, y_valid=v)
        gidx = b * bk + jnp.arange(bk, dtype=jnp.int32)
        if q_gidx is not None:
            d = jnp.where(q_gidx[:, None] == gidx[None, :], jnp.inf, d)
        return ref.merge_topk(bd, bi, d, jnp.broadcast_to(gidx, d.shape), k)

    init = (
        jnp.full((nq, k), jnp.inf, jnp.float32),
        jnp.full((nq, k), -1, jnp.int32),
    )
    return jax.lax.fori_loop(0, nb, body, init)


# ---------------------------------------------------------------------------
# quantization (freeze time) + exact-f32 shortlist rescore (serve time)
# ---------------------------------------------------------------------------


def quantize_keys(
    keys: jax.Array, valid: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-feature symmetric-range int8 quantization of a key/prototype set.

    Scale/zero-point are computed over the *valid* rows only (padding rows
    carry arbitrary values and must not widen the range). Constant features
    (hi == lo) get a floor scale so dequantization reproduces them exactly
    via the zero-point.

    Returns ``(q8 (p, d) int8, scale (d,) f32, zero (d,) f32)`` with
    dequantization ``q8 * scale + zero``.
    """
    k32 = keys.astype(jnp.float32)
    if valid is None:
        v = jnp.ones((keys.shape[0],), bool)
    else:
        v = valid.astype(bool)
    any_valid = jnp.any(v)
    lo = jnp.min(jnp.where(v[:, None], k32, jnp.inf), axis=0)
    hi = jnp.max(jnp.where(v[:, None], k32, -jnp.inf), axis=0)
    lo = jnp.where(any_valid, lo, 0.0)
    hi = jnp.where(any_valid, hi, 0.0)
    zero = 0.5 * (hi + lo)
    scale = jnp.maximum((hi - lo) / 254.0, 1e-12)
    q8 = jnp.clip(jnp.round((k32 - zero) / scale), -127.0, 127.0)
    return q8.astype(jnp.int8), scale, zero


def rescore_top1(
    queries: jax.Array,
    keys: jax.Array,
    valid: jax.Array,
    cand_idx: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Exact-f32 rescore of a quantized shortlist: gather the candidate
    rows of the *full-precision* key buffer and return the true nearest.

    Args:
      queries: (nq, d); keys: (p, d) full-precision buffer.
      valid: (p,) mask; cand_idx: (nq, r) shortlist (int32, -1 = empty).

    Returns:
      (dist (nq,), idx (nq,)) — exact sq-L2 to the winner, -1 if the
      shortlist holds no valid candidate.
    """
    q32 = queries.astype(jnp.float32)
    safe = jnp.where(cand_idx >= 0, cand_idx, 0)
    cp = keys.astype(jnp.float32)[safe]  # (nq, r, d)
    d = jnp.sum(jnp.square(q32[:, None, :] - cp), axis=-1)
    ok = (cand_idx >= 0) & valid.astype(bool)[safe]
    d = jnp.where(ok, d, jnp.inf)
    j = jnp.argmin(d, axis=1)
    dist = jnp.take_along_axis(d, j[:, None], axis=1)[:, 0]
    idx = jnp.take_along_axis(cand_idx, j[:, None], axis=1)[:, 0]
    return dist, jnp.where(jnp.isfinite(dist), idx, -1)
