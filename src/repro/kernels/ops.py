"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy (``impl=``):
  * ``None``     — pull the policy from the active runtime config
    (:func:`repro.runtime.active`); this is the default everywhere, so one
    ``runtime.configure(impl=...)`` switches the whole pipeline.
  * ``"auto"``   — Pallas on TPU, jnp reference elsewhere (XLA:CPU/GPU compile
    the references well; Pallas-interpret would be orders slower).
  * ``"pallas"`` — force the kernel; on non-TPU backends runs ``interpret=True``
    (that is exactly what the correctness tests do) unless the runtime config
    pins ``interpret`` explicitly.
  * ``"ref"``    — force the pure-jnp oracle.

The dry-run/roofline path always uses ``"ref"`` so that
``compiled.cost_analysis()`` sees real FLOPs (a Pallas custom-call is opaque
to HLO cost analysis — see DESIGN.md §7).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import runtime

from . import flash_attention as _fa
from . import knn_topk as _knn
from . import pairwise_l2 as _pw
from . import ref
from . import segment_sum as _ss


from repro.runtime.config import _IMPLS as IMPLS  # single impl registry


def _resolve(impl: Optional[str] = None, *, tuned: Optional[str] = None) -> str:
    """Dispatch policy → concrete impl name, rejecting unknown strings.

    ``tuned`` is the measured winner from the tuning cache (if any): it
    only decides the ``"auto"`` case — an explicit ``impl=`` kwarg or a
    configured non-auto policy always wins over the autotuner.
    """
    if impl is None:
        impl = runtime.active().impl
    if impl == "auto":
        impl = tuned or ("pallas" if jax.default_backend() == "tpu"
                         else "ref")
    if impl not in ("pallas", "ref"):
        # an unknown string used to fall through silently to the XLA path —
        # a typo'd impl="palas" would quietly benchmark the wrong kernel
        raise ValueError(
            f"unknown impl {impl!r}; registered impls: {list(IMPLS)}")
    return impl


def _interpret() -> bool:
    pinned = runtime.active().interpret
    if pinned is not None:
        return bool(pinned)
    return jax.default_backend() != "tpu"


def _tuned(kernel: str, dtype, **dims: int) -> dict:
    """Measured winners for this call's shape bucket (``{}`` unless the
    tuning policy is active and has/measures an entry — DESIGN.md §14).

    Called at trace time from inside jitted drivers; sound because every
    inner jit takes ``dispatch_key()`` as a static argument and the key
    carries the cache epoch whenever tuning is on, so changed winners
    always retrace.
    """
    if runtime.active().tune == "off":
        return {}
    from repro import tune  # lazy: keeps kernels importable without tune

    return tune.tuned_params(kernel, dtype=str(dtype), **dims)


def pairwise_sq_l2(
    x: jax.Array,
    y: jax.Array,
    *,
    y_valid: Optional[jax.Array] = None,
    impl: Optional[str] = None,
) -> jax.Array:
    tp = _tuned("pairwise_sq_l2", x.dtype,
                n=x.shape[0], m=y.shape[0], d=x.shape[1])
    if _resolve(impl, tuned=tp.get("impl")) == "pallas":
        kw = {a: tp[a] for a in ("block_q", "block_k") if a in tp}
        return _pw.pairwise_sq_l2(x, y, y_valid, interpret=_interpret(), **kw)
    return ref.pairwise_sq_l2(x, y, y_valid=y_valid)


def knn(
    x: jax.Array,
    k: int,
    *,
    valid: Optional[jax.Array] = None,
    exclude_self: bool = True,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    tp = _tuned("knn", x.dtype, n=x.shape[0], d=x.shape[1], k=k)
    if _resolve(impl, tuned=tp.get("impl")) == "pallas":
        return _knn.knn_topk(
            x, k, valid, exclude_self=exclude_self, interpret=_interpret(),
            block_q=tp.get("block_q"), block_k=tp.get("block_k"),
        )
    return ref.knn(x, k, valid=valid, exclude_self=exclude_self)


def segment_sum(
    x: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    weights: Optional[jax.Array] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    tp = _tuned("segment_sum", x.dtype,
                n=x.shape[0], d=x.shape[1], s=num_segments)
    if _resolve(impl, tuned=tp.get("impl")) == "pallas":
        kw = {a: tp[a] for a in ("block_s", "block_n") if a in tp}
        return _ss.segment_sum(
            x, segment_ids, num_segments, weights, interpret=_interpret(),
            **kw
        )
    return ref.segment_sum(x, segment_ids, num_segments, weights=weights)


def blocked_segment_sum(
    x: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    weights: Optional[jax.Array] = None,
    n_blocks: Optional[int] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Segment sum with a *fixed* reduction tree (DESIGN.md §4.3).

    Rows are split into ``n_blocks`` equal blocks (right-padded with dropped
    ids), per-block partials are computed independently, and the partials are
    accumulated left-to-right in block order. Because the summation order is
    pinned by ``n_blocks`` — not by how rows happen to be laid out across
    devices — a sharded execution whose P shards each compute their
    ``n_blocks/P`` local partials and fold the all-gathered stack in the same
    block order reproduces this result bit-for-bit. This is what makes the
    distributed ITIS/IHTC pipeline label-identical to the single-device one.

    ``n_blocks`` defaults to the active runtime config's reduction width;
    ``n_blocks <= 1`` falls back to the plain one-shot ``segment_sum``.
    """
    if n_blocks is None:
        n_blocks = runtime.active().n_blocks
    n = x.shape[0]
    if n_blocks <= 1:
        return segment_sum(x, segment_ids, num_segments, weights=weights,
                           impl=impl)
    pad = (-n) % n_blocks
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    # padded rows get id == num_segments, which segment_sum drops
    ip = jnp.pad(segment_ids, (0, pad), constant_values=num_segments)
    wp = None if weights is None else jnp.pad(weights, (0, pad))
    nb = (n + pad) // n_blocks
    sums = masses = None
    for b in range(n_blocks):  # static unroll: left fold in block order
        sl = slice(b * nb, (b + 1) * nb)
        s_b, m_b = segment_sum(
            xp[sl], ip[sl], num_segments,
            weights=None if wp is None else wp[sl], impl=impl,
        )
        sums = s_b if sums is None else sums + s_b
        masses = m_b if masses is None else masses + m_b
    return sums, masses


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_bias: Optional[jax.Array] = None,
    logit_softcap: float = 0.0,
    impl: Optional[str] = None,
) -> jax.Array:
    """GQA-aware attention entry point: q (b, hq, lq, dh); k/v (b, hkv, lk, dh)."""
    hq, hkv = q.shape[1], k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
        if kv_bias is not None and kv_bias.shape[1] != hq:
            kv_bias = jnp.repeat(kv_bias, rep, axis=1)
    if _resolve(impl) == "pallas":
        return _fa.flash_attention(
            q, k, v, kv_bias, causal=causal, scale=scale,
            logit_softcap=float(logit_softcap), interpret=_interpret(),
        )
    return ref.flash_attention(
        q, k, v, causal=causal, scale=scale, kv_bias=kv_bias,
        logit_softcap=float(logit_softcap),
    )
