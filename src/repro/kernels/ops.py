"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy (``impl=``):
  * ``None``     — pull the policy from the active runtime config
    (:func:`repro.runtime.active`); this is the default everywhere, so one
    ``runtime.configure(impl=...)`` switches the whole pipeline.
  * ``"auto"``   — Pallas on TPU, jnp reference elsewhere (XLA:CPU/GPU compile
    the references well; Pallas-interpret would be orders slower).
  * ``"pallas"`` — force the kernel; on non-TPU backends runs ``interpret=True``
    (that is exactly what the correctness tests do) unless the runtime config
    pins ``interpret`` explicitly.
  * ``"ref"``    — force the pure-jnp oracle.

The dry-run/roofline path always uses ``"ref"`` so that
``compiled.cost_analysis()`` sees real FLOPs (a Pallas custom-call is opaque
to HLO cost analysis — see DESIGN.md §7).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import runtime

from . import flash_attention as _fa
from . import fused_assign as _fused
from . import knn_topk as _knn
from . import pairwise_l2 as _pw
from . import ref
from . import segment_sum as _ss


from repro.runtime.config import _IMPLS as IMPLS  # single impl registry

#: the fused nearest/top-k dispatch family (DESIGN.md §16). Ops without a
#: fused path degrade these to "auto" so a process-wide impl="fused" only
#: changes the assign/kNN hot path.
_FUSED_IMPLS = ("fused", "fused_bf16", "fused_int8")


def _resolve(impl: Optional[str] = None, *, tuned: Optional[str] = None,
             fused: bool = False) -> str:
    """Dispatch policy → concrete impl name, rejecting unknown strings.

    ``tuned`` is the measured winner from the tuning cache (if any): it
    only decides the ``"auto"`` case — an explicit ``impl=`` kwarg or a
    configured non-auto policy always wins over the autotuner.

    ``fused=True`` marks entry points with a fused streaming path
    (nearest_topk / knn): they may resolve to the fused family, and their
    ``"auto"`` prefers it on TPU. Everywhere else the fused family (from a
    global config or a tuned winner) degrades to the ``"auto"`` resolution
    instead of raising.
    """
    if impl is None:
        impl = runtime.active().impl
    if impl in _FUSED_IMPLS and not fused:
        impl = "auto"
    if impl == "auto":
        tpu = jax.default_backend() == "tpu"
        impl = tuned or (("fused" if fused else "pallas") if tpu else "ref")
        if impl in _FUSED_IMPLS:
            # a tuned fused winner leaking into a non-fused op degrades the
            # same way a configured one does
            impl = impl if fused else ("pallas" if tpu else "ref")
    allowed = ("pallas", "ref") + (_FUSED_IMPLS if fused else ())
    if impl not in allowed:
        # an unknown string used to fall through silently to the XLA path —
        # a typo'd impl="palas" would quietly benchmark the wrong kernel
        raise ValueError(
            f"unknown impl {impl!r}; registered impls: {list(IMPLS)}")
    return impl


def _interpret() -> bool:
    pinned = runtime.active().interpret
    if pinned is not None:
        return bool(pinned)
    return jax.default_backend() != "tpu"


def _tuned(kernel: str, dtype, **dims: int) -> dict:
    """Measured winners for this call's shape bucket (``{}`` unless the
    tuning policy is active and has/measures an entry — DESIGN.md §14).

    Called at trace time from inside jitted drivers; sound because every
    inner jit takes ``dispatch_key()`` as a static argument and the key
    carries the cache epoch whenever tuning is on, so changed winners
    always retrace.
    """
    if runtime.active().tune == "off":
        return {}
    from repro import tune  # lazy: keeps kernels importable without tune

    return tune.tuned_params(kernel, dtype=str(dtype), **dims)


def pairwise_sq_l2(
    x: jax.Array,
    y: jax.Array,
    *,
    y_valid: Optional[jax.Array] = None,
    impl: Optional[str] = None,
) -> jax.Array:
    tp = _tuned("pairwise_sq_l2", x.dtype,
                n=x.shape[0], m=y.shape[0], d=x.shape[1])
    if _resolve(impl, tuned=tp.get("impl")) == "pallas":
        kw = {a: tp[a] for a in ("block_q", "block_k") if a in tp}
        return _pw.pairwise_sq_l2(x, y, y_valid, interpret=_interpret(), **kw)
    return ref.pairwise_sq_l2(x, y, y_valid=y_valid)


def knn(
    x: jax.Array,
    k: int,
    *,
    valid: Optional[jax.Array] = None,
    exclude_self: bool = True,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    tp = _tuned("knn", x.dtype, n=x.shape[0], d=x.shape[1], k=k)
    r = _resolve(impl, tuned=tp.get("impl"), fused=True)
    if r in _FUSED_IMPLS:
        # the self-kNN Pallas kernel (knn_topk) IS the fused kernel for the
        # x-vs-x case; off-TPU the fused path is the XLA streaming fold
        # (never materializes (n, n)) — quantized variants have no frozen
        # buffer here, so they degrade to the f32 fused path
        if _use_pallas_fused():
            r = "pallas"
        else:
            gidx = (jnp.arange(x.shape[0], dtype=jnp.int32)
                    if exclude_self else None)
            return _fused.fused_topk_xla(x, x, k, valid, q_gidx=gidx,
                                         block_k=tp.get("block_k"))
    if r == "pallas":
        return _knn.knn_topk(
            x, k, valid, exclude_self=exclude_self, interpret=_interpret(),
            block_q=tp.get("block_q"), block_k=tp.get("block_k"),
        )
    return ref.knn(x, k, valid=valid, exclude_self=exclude_self)


def _use_pallas_fused() -> bool:
    """Whether the fused family dispatches to the Pallas kernel (real TPU,
    or interpret explicitly pinned on — what the parity tests do) rather
    than the XLA streaming fold (the off-TPU production fused path)."""
    return (jax.default_backend() == "tpu"
            or runtime.active().interpret is True)


def resolve_nearest(impl: Optional[str], *, dtype, nq: int, p: int, d: int,
                    k: int = 1) -> Tuple[str, dict]:
    """Resolve the nearest/top-k dispatch family through the ``"assign"``
    tuning cell. Returns ``(resolved impl, tuned params)`` — the tuned tile
    sizes apply only when the caller passed none explicitly."""
    tp = _tuned("assign", dtype, nq=nq, p=p, d=d, k=k)
    return _resolve(impl, tuned=tp.get("impl"), fused=True), tp


def nearest_topk(
    q: jax.Array,
    keys: jax.Array,
    k: int,
    *,
    key_valid: Optional[jax.Array] = None,
    q_gidx: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """k nearest valid keys of each query row (dists ascending, idx; -1 for
    unfillable slots) — the assign/TC hot-path entry point (DESIGN.md §16).

    The fused family streams key blocks against a running best list
    (Pallas kernel on TPU, XLA fold elsewhere; the distance block is never
    materialized); ``"pallas"``/``"ref"`` compose a dense distance matrix
    with the same merge, bit-identical by the shared tie semantics of
    :func:`repro.kernels.ref.merge_topk`. The quantized variants degrade
    to ``"fused"`` here — frozen low-precision buffers live on
    :class:`repro.core.index.ClusterIndex`, not at this stateless layer.
    """
    r, tp = resolve_nearest(impl, dtype=q.dtype, nq=q.shape[0],
                            p=keys.shape[0], d=q.shape[1], k=k)
    bq = block_q if block_q is not None else tp.get("block_q")
    bk = block_k if block_k is not None else tp.get("block_k")
    if r in _FUSED_IMPLS:
        if _use_pallas_fused():
            return _fused.fused_topk(
                q, keys, k, key_valid, q_gidx=q_gidx,
                block_q=bq, block_k=bk, interpret=_interpret())
        return _fused.fused_topk_xla(q, keys, k, key_valid, q_gidx=q_gidx,
                                     block_k=bk)
    if r == "pallas":
        d = _pw.pairwise_sq_l2(q, keys, key_valid, interpret=_interpret())
    else:
        d = ref.pairwise_sq_l2(q, keys, y_valid=key_valid)
    if q_gidx is not None:
        kcols = jnp.arange(keys.shape[0], dtype=jnp.int32)
        d = jnp.where(q_gidx[:, None] == kcols[None, :], jnp.inf, d)
    nq = q.shape[0]
    init_d = jnp.full((nq, k), jnp.inf, jnp.float32)
    init_i = jnp.full((nq, k), -1, jnp.int32)
    gidx = jnp.arange(keys.shape[0], dtype=jnp.int32)
    return ref.merge_topk(init_d, init_i, d, jnp.broadcast_to(gidx, d.shape),
                          k)


def segment_sum(
    x: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    weights: Optional[jax.Array] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    tp = _tuned("segment_sum", x.dtype,
                n=x.shape[0], d=x.shape[1], s=num_segments)
    if _resolve(impl, tuned=tp.get("impl")) == "pallas":
        kw = {a: tp[a] for a in ("block_s", "block_n") if a in tp}
        return _ss.segment_sum(
            x, segment_ids, num_segments, weights, interpret=_interpret(),
            **kw
        )
    return ref.segment_sum(x, segment_ids, num_segments, weights=weights)


def blocked_segment_sum(
    x: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    weights: Optional[jax.Array] = None,
    n_blocks: Optional[int] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Segment sum with a *fixed* reduction tree (DESIGN.md §4.3).

    Rows are split into ``n_blocks`` equal blocks (right-padded with dropped
    ids), per-block partials are computed independently, and the partials are
    accumulated left-to-right in block order. Because the summation order is
    pinned by ``n_blocks`` — not by how rows happen to be laid out across
    devices — a sharded execution whose P shards each compute their
    ``n_blocks/P`` local partials and fold the all-gathered stack in the same
    block order reproduces this result bit-for-bit. This is what makes the
    distributed ITIS/IHTC pipeline label-identical to the single-device one.

    ``n_blocks`` defaults to the active runtime config's reduction width;
    ``n_blocks <= 1`` falls back to the plain one-shot ``segment_sum``.
    """
    if n_blocks is None:
        n_blocks = runtime.active().n_blocks
    n = x.shape[0]
    if n_blocks <= 1:
        return segment_sum(x, segment_ids, num_segments, weights=weights,
                           impl=impl)
    pad = (-n) % n_blocks
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    # padded rows get id == num_segments, which segment_sum drops
    ip = jnp.pad(segment_ids, (0, pad), constant_values=num_segments)
    wp = None if weights is None else jnp.pad(weights, (0, pad))
    nb = (n + pad) // n_blocks
    sums = masses = None
    for b in range(n_blocks):  # static unroll: left fold in block order
        sl = slice(b * nb, (b + 1) * nb)
        s_b, m_b = segment_sum(
            xp[sl], ip[sl], num_segments,
            weights=None if wp is None else wp[sl], impl=impl,
        )
        sums = s_b if sums is None else sums + s_b
        masses = m_b if masses is None else masses + m_b
    return sums, masses


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_bias: Optional[jax.Array] = None,
    logit_softcap: float = 0.0,
    impl: Optional[str] = None,
) -> jax.Array:
    """GQA-aware attention entry point: q (b, hq, lq, dh); k/v (b, hkv, lk, dh)."""
    hq, hkv = q.shape[1], k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
        if kv_bias is not None and kv_bias.shape[1] != hq:
            kv_bias = jnp.repeat(kv_bias, rep, axis=1)
    if _resolve(impl) == "pallas":
        return _fa.flash_attention(
            q, k, v, kv_bias, causal=causal, scale=scale,
            logit_softcap=float(logit_softcap), interpret=_interpret(),
        )
    return ref.flash_attention(
        q, k, v, causal=causal, scale=scale, kv_bias=kv_bias,
        logit_softcap=float(logit_softcap),
    )
