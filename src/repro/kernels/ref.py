"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *reference semantics*. Each TPU kernel in this directory is
validated (in interpret mode on CPU, and on real TPUs via the same tests)
against these functions with ``assert_allclose`` across shape/dtype sweeps.

They are also the production execution path on non-TPU backends (XLA:CPU
compiles these well), selected by :mod:`repro.kernels.ops`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")


def pairwise_sq_l2(
    x: jax.Array,
    y: jax.Array,
    *,
    y_valid: Optional[jax.Array] = None,
    precision=jax.lax.Precision.HIGHEST,
) -> jax.Array:
    """Squared Euclidean distance matrix  ``D[i, j] = ||x_i - y_j||^2``.

    Args:
      x: (n, d) queries.
      y: (m, d) keys.
      y_valid: optional (m,) bool; invalid keys get distance ``+inf``.

    Returns:
      (n, m) float32 distances (clamped at >= 0 to absorb round-off).
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xn = jnp.sum(x * x, axis=-1)  # (n,)
    yn = jnp.sum(y * y, axis=-1)  # (m,)
    cross = jnp.dot(x, y.T, precision=precision)  # (n, m) -- MXU shaped
    d = xn[:, None] + yn[None, :] - 2.0 * cross
    d = jnp.maximum(d, 0.0)
    if y_valid is not None:
        d = jnp.where(y_valid[None, :], d, jnp.inf)
    return d


def knn(
    x: jax.Array,
    k: int,
    *,
    valid: Optional[jax.Array] = None,
    exclude_self: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Exact k-nearest-neighbours of each row of ``x`` within ``x``.

    Args:
      x: (n, d) points.
      k: neighbours per point (static).
      valid: optional (n,) bool mask; invalid points are neither queries whose
        output matters nor eligible neighbours.
      exclude_self: drop the trivial self-match.

    Returns:
      (dists, idx): both (n, k); ``dists`` are squared L2, ascending. Slots
      that could not be filled (fewer than k valid candidates) have ``inf``
      distance and index ``-1``.
    """
    n = x.shape[0]
    d = pairwise_sq_l2(x, x, y_valid=valid)
    if exclude_self:
        d = d.at[jnp.arange(n), jnp.arange(n)].set(jnp.inf)
    neg_d, idx = jax.lax.top_k(-d, k)
    dists = -neg_d
    idx = jnp.where(jnp.isfinite(dists), idx, -1)
    return dists, idx


def merge_topk(
    best_d: jax.Array, best_i: jax.Array, d: jax.Array, idx: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array]:
    """Fold candidate (d, idx) columns into a running (n, k) best list.

    This is the canonical merge semantics every streaming top-k path
    shares (blocked/ring kNN drivers, the fused assign kernel's in-tile
    unrolled selection): ``lax.top_k`` over the concatenation breaks
    distance ties toward the *earlier* concat position, so the running
    list (already ascending, earliest-first) wins over the new tile and,
    within a tile, the lowest global index wins — which is what makes
    block-streamed folds bit-identical to one dense top-k.
    """
    cat_d = jnp.concatenate([best_d, d], axis=1)
    cat_i = jnp.concatenate([best_i, idx], axis=1)
    neg, pos = jax.lax.top_k(-cat_d, k)
    new_i = jnp.take_along_axis(cat_i, pos, axis=1)
    new_d = -neg
    return new_d, jnp.where(jnp.isfinite(new_d), new_i, -1)


def segment_sum(
    x: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    weights: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Weighted segment sum: out[s] = sum_{i: seg[i]==s} w_i * x_i.

    ``segment_ids`` outside [0, num_segments) are dropped (use that for
    masking invalid rows).

    Returns:
      (sums (num_segments, d), masses (num_segments,)).
    """
    w = jnp.ones(x.shape[0], x.dtype) if weights is None else weights.astype(x.dtype)
    xw = x * w[:, None]
    sums = jax.ops.segment_sum(xw, segment_ids, num_segments=num_segments)
    masses = jax.ops.segment_sum(w, segment_ids, num_segments=num_segments)
    return sums, masses


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_bias: Optional[jax.Array] = None,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """Reference multi-head attention.

    Args:
      q: (b, h, lq, dh)
      k, v: (b, h, lk, dh)   (GQA repeat is done by the caller)
      causal: causal mask aligned to the *end* of the kv sequence (so a
        decode step with lq=1 attends to everything).
      kv_bias: optional (b, h, lk) additive logit bias — this is where the
        IHTC prototype ``log(count)`` mass-correction enters.
      logit_softcap: if > 0, gemma2-style ``cap * tanh(logits / cap)``.

    Returns:
      (b, h, lq, dh), same dtype as q.
    """
    orig_dtype = q.dtype
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    dh = q.shape[-1]
    s = (1.0 / jnp.sqrt(dh)) if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * s
    if logit_softcap and logit_softcap > 0.0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    if kv_bias is not None:
        logits = logits + kv_bias[:, :, None, :]
    if causal:
        lq, lk = logits.shape[-2], logits.shape[-1]
        # query i (global position lk - lq + i) sees key j iff j <= lk - lq + i
        qpos = jnp.arange(lq)[:, None] + (lk - lq)
        kpos = jnp.arange(lk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    return out.astype(orig_dtype)
