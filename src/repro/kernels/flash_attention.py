"""Blocked (flash) attention forward kernel with prototype-mass bias.

Standard online-softmax tiling: the kv axis is the innermost grid dimension,
running max / denominator / accumulator live in the revisited output blocks,
and the final kv step normalizes. Logit soft-capping (gemma2) and an additive
per-key bias are fused; the bias is how IHTC KV-cache prototype compression
enters attention (``+log(count)`` mass correction, see
``repro/serve/kv_compression.py``).

Grid: (batch*heads, Lq/Bq, Lk/Bk). Blocks are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_MASKED = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref, *, scale, causal, softcap,
    lq, lk, bq, bk,
):
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, _MASKED)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)  # (bk, dh)
    b = bias_ref[0].astype(jnp.float32)  # (bk,)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = logits + b[None, :]
    if causal:
        iq = pl.program_id(1)
        qpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + iq * bq + (lk - lq)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + jk * bk
        logits = jnp.where(kpos <= qpos, logits, _MASKED)

    m_prev = m_ref[...]  # (1, bq)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1)[None, :])
    alpha = jnp.exp(m_prev - m_new)  # (1, bq)
    p = jnp.exp(logits - m_new[0][:, None])  # (bq, bk)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)[None, :]
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, dh)
    o_ref[...] = o_ref[...] * alpha[0][None, :, None] + pv[None]
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(jk == pl.num_programs(2) - 1)
    def _final():
        o_ref[...] = o_ref[...] / jnp.maximum(l_ref[...][0][None, :, None], 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "logit_softcap", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_bias: jax.Array | None = None,
    *,
    causal: bool = True,
    scale: float | None = None,
    logit_softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention fwd. q: (b, h, lq, dh); k, v: (b, h, lk, dh) (heads
    already matched — GQA repeat happens in ops.py). kv_bias: (b, h, lk)."""
    bsz, h, lq, dh = q.shape
    lk = k.shape[2]
    s = (1.0 / (dh**0.5)) if scale is None else scale

    bq = min(block_q, max(lq, 8))
    bk = min(block_k, max(lk, 8))
    pq = (-lq) % bq
    pk = (-lk) % bk
    dpad = (-dh) % 128 if dh > 128 else (128 - dh)

    if kv_bias is None:
        kv_bias = jnp.zeros((bsz, h, lk), jnp.float32)
    # fold kv padding into the bias mask
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, dpad)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, dpad)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, dpad)))
    bp = jnp.pad(kv_bias.astype(jnp.float32), ((0, 0), (0, 0), (0, pk)),
                 constant_values=_MASKED)

    bh = bsz * h
    qp = qp.reshape(bh, lq + pq, dh + dpad)
    kp = kp.reshape(bh, lk + pk, dh + dpad)
    vp = vp.reshape(bh, lk + pk, dh + dpad)
    bp = bp.reshape(bh, lk + pk)

    grid = (bh, (lq + pq) // bq, (lk + pk) // bk)
    kernel = functools.partial(
        _flash_kernel, scale=s, causal=causal, softcap=float(logit_softcap),
        lq=lq, lk=lk, bq=bq, bk=bk,
    )
    o, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh + dpad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh + dpad), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh + dpad), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dh + dpad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, lq + pq, dh + dpad), jnp.float32),
            jax.ShapeDtypeStruct((bh, lq + pq), jnp.float32),
            jax.ShapeDtypeStruct((bh, lq + pq), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp, bp)
    out = o[:, :lq, :dh].reshape(bsz, h, lq, dh)
    return out.astype(q.dtype)
