"""Prototype aggregation (weighted segment-sum) as a one-hot MXU matmul.

Cluster-centroid computation is a scatter-add, which is slow on TPU (serialized
DMA). Instead each program builds the (Bn, Bs) one-hot membership tile for its
segment range on the VPU and contracts it against the (Bn, d) data tile on the
MXU: ``sums[s] += onehot.T @ (w * x)``. Mass (cluster size) falls out of the
same contraction against a column of ones.

Grid: (S/Bs, n/Bn), point axis innermost (accumulation pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segsum_kernel(ids_ref, w_ref, x_ref, sums_ref, mass_ref, *, bs, bn):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        mass_ref[...] = jnp.zeros_like(mass_ref)

    ids = ids_ref[...]  # (bn,) global segment ids; out-of-range = dropped
    w = w_ref[...].astype(jnp.float32)  # (bn,)
    x = x_ref[...].astype(jnp.float32)  # (bn, d)

    s0 = pl.program_id(0) * bs
    local = ids - s0  # in [0, bs) iff this block owns the segment
    seg_cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bs), 1)
    onehot = (seg_cols == local[:, None]).astype(jnp.float32) * w[:, None]  # (bn, bs)

    sums_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bs, d) — MXU
    mass_ref[...] += jnp.sum(onehot, axis=0)  # (bs,)


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_s", "block_n", "interpret")
)
def segment_sum(
    x: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    weights: jax.Array | None = None,
    *,
    block_s: int = 512,
    block_n: int = 1024,
    interpret: bool = False,
):
    """Weighted segment sum; ids outside [0, num_segments) are dropped.

    Returns (sums (num_segments, d) f32, masses (num_segments,) f32).
    """
    n, d = x.shape
    w = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)

    bs = min(block_s, max(num_segments, 8))
    bn = min(block_n, max(n, 8))
    s_pad = (-num_segments) % bs
    n_pad = (-n) % bn
    xp = jnp.pad(x, ((0, n_pad), (0, 0)))
    wp = jnp.pad(w, (0, n_pad))  # zero weight -> no contribution
    idp = jnp.pad(segment_ids.astype(jnp.int32), (0, n_pad), constant_values=-1)
    S = num_segments + s_pad

    grid = (S // bs, xp.shape[0] // bn)
    sums, mass = pl.pallas_call(
        functools.partial(_segsum_kernel, bs=bs, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda s, j: (j,)),
            pl.BlockSpec((bn,), lambda s, j: (j,)),
            pl.BlockSpec((bn, d), lambda s, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bs, d), lambda s, j: (s, 0)),
            pl.BlockSpec((bs,), lambda s, j: (s,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, d), jnp.float32),
            jax.ShapeDtypeStruct((S,), jnp.float32),
        ],
        interpret=interpret,
    )(idp, wp, xp)
    return sums[:num_segments], mass[:num_segments]
