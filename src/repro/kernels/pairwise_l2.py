"""Tiled squared-L2 pairwise distance — the inner primitive of kNN-graph
construction (the computational bottleneck of Threshold Clustering).

TPU mapping: the (Bq × Bk) distance tile is dominated by a (Bq, d) × (d, Bk)
matmul that runs on the MXU; the rank-1 norm corrections ride the VPU. With
128-aligned tiles the kernel is compute-bound at arithmetic intensity ≈ d.

Grid: (n/Bq, m/Bk). Each program owns one output tile in VMEM:
  x block  (Bq, d)  — revisited across the j axis (stays resident),
  y block  (Bk, d),
  out tile (Bq, Bk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_kernel(x_ref, y_ref, yv_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (bq, d)
    y = y_ref[...].astype(jnp.float32)  # (bk, d)
    xn = jnp.sum(x * x, axis=-1)[:, None]  # (bq, 1)
    yn = jnp.sum(y * y, axis=-1)[None, :]  # (1, bk)
    cross = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk) — MXU
    d = jnp.maximum(xn + yn - 2.0 * cross, 0.0)
    valid = yv_ref[...][None, :] > 0.0  # (1, bk)
    o_ref[...] = jnp.where(valid, d, jnp.inf)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def pairwise_sq_l2(
    x: jax.Array,
    y: jax.Array,
    y_valid: jax.Array | None = None,
    *,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Pallas pairwise squared-L2: (n, d) × (m, d) → (n, m) float32."""
    n, d = x.shape
    m = y.shape[0]
    if y_valid is None:
        y_valid = jnp.ones((m,), jnp.float32)
    else:
        y_valid = y_valid.astype(jnp.float32)

    bq = min(block_q, max(n, 8))
    bk = min(block_k, max(m, 8))
    n_pad = (-n) % bq
    m_pad = (-m) % bk
    d_pad = (-d) % 128 if d > 128 else (128 - d)  # lane-align the contraction
    xp = jnp.pad(x, ((0, n_pad), (0, d_pad)))
    yp = jnp.pad(y, ((0, m_pad), (0, d_pad)))
    vp = jnp.pad(y_valid, (0, m_pad))  # padded keys invalid -> +inf

    grid = (xp.shape[0] // bq, yp.shape[0] // bk)
    out = pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, xp.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, yp.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]), jnp.float32),
        interpret=interpret,
    )(xp, yp, vp)
    return out[:n, :m]
