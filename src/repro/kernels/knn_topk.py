"""Fused distance + streaming top-k: the TPU-native kNN-graph builder.

This is the kernel that replaces the paper's kd-tree. Instead of
materializing the (n, m) distance matrix in HBM (the memory wall of
brute-force kNN), each program computes one (Bq, Bk) distance tile on the
MXU and folds it into a running (Bq, k) best-list kept in VMEM, so HBM
traffic is O(n·d + n·k) instead of O(n·m).

Grid: (n/Bq, m/Bk) with the key axis innermost (sequentially revisits the
same output block — the Pallas TPU accumulation pattern). The merge step is
a static-k unrolled selection (min + one-hot mask), which avoids dynamic
gathers and sorts that do not lower to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import runtime


def _knn_kernel(x_ref, y_ref, yv_ref, bd_ref, bi_ref, *, k, bq, bk, exclude_self):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bd_ref[...] = jnp.full((bq, k), jnp.inf, jnp.float32)
        bi_ref[...] = jnp.full((bq, k), -1, jnp.int32)

    x = x_ref[...].astype(jnp.float32)  # (bq, d)
    y = y_ref[...].astype(jnp.float32)  # (bk, d)
    xn = jnp.sum(x * x, axis=-1)[:, None]
    yn = jnp.sum(y * y, axis=-1)[None, :]
    cross = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d = jnp.maximum(xn + yn - 2.0 * cross, 0.0)  # (bq, bk)

    kcols = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + j * bk
    d = jnp.where(yv_ref[...][None, :] > 0.0, d, jnp.inf)
    if exclude_self:
        i = pl.program_id(0)
        qrows = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + i * bq
        d = jnp.where(qrows == kcols, jnp.inf, d)

    # Merge running best (bq, k) with this tile (bq, bk): k rounds of
    # (row-min, record, mask). Static unroll; k is small (t*-1).
    cat_d = jnp.concatenate([bd_ref[...], d], axis=1)  # (bq, k+bk)
    cat_i = jnp.concatenate([bi_ref[...], kcols], axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, cat_d.shape, 1)
    new_d, new_i = [], []
    for _ in range(k):
        md = jnp.min(cat_d, axis=1)  # (bq,)
        am = jnp.argmin(cat_d, axis=1)  # (bq,)
        onehot = cols == am[:, None]
        mi = jnp.sum(jnp.where(onehot, cat_i, 0), axis=1)
        mi = jnp.where(jnp.isfinite(md), mi, -1)
        new_d.append(md)
        new_i.append(mi)
        cat_d = jnp.where(onehot, jnp.inf, cat_d)
    bd_ref[...] = jnp.stack(new_d, axis=1)
    bi_ref[...] = jnp.stack(new_i, axis=1)


def knn_topk(
    x: jax.Array,
    k: int,
    valid: jax.Array | None = None,
    *,
    exclude_self: bool = True,
    block_q: int | None = None,
    block_k: int | None = None,
    interpret: bool = False,
):
    """k nearest neighbours of each row of x within x.

    Returns (dists (n,k) ascending sq-L2, idx (n,k); unfilled slots inf/-1).
    ``block_q``/``block_k`` default to the active runtime config's tile
    sizes (resolved here, before the jit boundary).
    """
    cfg = runtime.active()
    block_q = cfg.block_q if block_q is None else block_q
    block_k = cfg.block_k if block_k is None else block_k
    return _knn_topk(x, k, valid, exclude_self=exclude_self,
                     block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_k", "exclude_self", "interpret")
)
def _knn_topk(
    x: jax.Array,
    k: int,
    valid: jax.Array | None = None,
    *,
    exclude_self: bool = True,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
):
    n, d = x.shape
    if valid is None:
        valid = jnp.ones((n,), jnp.float32)
    else:
        valid = valid.astype(jnp.float32)

    # Tiling: the grid must cover the padded row count *exactly* in both
    # axes — Mosaic block shapes that do not divide the array mis-tile the
    # BlockSpec grid (e.g. n=300, block_q=256, block_k=512 used to pad to
    # 512 rows with a 300-wide key block: 512 % 300 != 0). The query block
    # is clamped to an 8-aligned padded row count (so the key-block divisor
    # search below can never collapse to degenerate sub-sublane widths on a
    # prime row count), rows are padded to a bq multiple, and the key block
    # is the largest size <= block_k that divides the padded count: both
    # grid axes tile with zero remainder.
    rows8 = -(-max(n, 8) // 8) * 8
    bq = min(block_q, rows8)
    np_ = -(-rows8 // bq) * bq  # round padded rows up to a bq multiple
    limit = min(block_k, np_)
    bk = next(b for b in range(limit, 0, -1) if np_ % b == 0)
    pad = np_ - n
    d_pad = (-d) % 128 if d > 128 else (128 - d)
    xp = jnp.pad(x, ((0, pad), (0, d_pad)))
    vp = jnp.pad(valid, (0, pad))

    grid = (np_ // bq, np_ // bk)
    kernel = functools.partial(
        _knn_kernel, k=k, bq=bq, bk=bk, exclude_self=exclude_self
    )
    bd, bi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, xp.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, xp.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, k), jnp.float32),
            jax.ShapeDtypeStruct((np_, k), jnp.int32),
        ],
        interpret=interpret,
    )(xp, xp, vp)
    return bd[:n], bi[:n]
