"""Backend registry for IHTC's "sophisticated" clusterers.

The paper's pipeline is deliberately backend-agnostic: ITIS reduces n units
to prototypes and *any* clusterer labels the prototypes. This module is the
one place that agnosticism lives — the fit planner's epilogue
(:mod:`repro.core.plan`, the single backend call site for every executor),
the serving path and the benchmarks all resolve backends here instead of
each keeping a private name→function dict. The planner's *executor*
registry (``@register_executor``, DESIGN.md §13) is this module's twin one
level up: backends label prototypes, executors move data.

Every backend must satisfy the uniform ``BackendFn`` contract::

    fn(x, *, valid=None, weights=None, key=None, impl=None, **kwargs)
      -> (n,) int32 labels  (-1 for invalid/noise rows)

``register_backend`` validates the contract at registration time by
signature inspection (a backend that silently ignored ``valid`` or
``weights`` would corrupt masked/mass-weighted prototype clustering in ways
that only surface at scale), so a bad adapter fails at import, not mid-run.
"""
from __future__ import annotations

import inspect
from typing import Callable, Dict, Union

import jax

# uniform adapter signature: labels = fn(x, *, valid, weights, key, impl, **kw)
BackendFn = Callable[..., jax.Array]

REQUIRED_KWARGS = ("valid", "weights", "key", "impl")

_REGISTRY: Dict[str, BackendFn] = {}


def validate_backend_fn(fn: BackendFn, name: str = "") -> None:
    """Raise TypeError unless ``fn`` matches the BackendFn contract."""
    label = name or getattr(fn, "__name__", repr(fn))
    if not callable(fn):
        raise TypeError(f"backend {label!r} is not callable")
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return  # builtins/partials without introspectable signatures: trust
    params = list(sig.parameters.values())
    positional = [
        p for p in params
        if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                      inspect.Parameter.POSITIONAL_OR_KEYWORD)
    ]
    if not positional and not any(
        p.kind is inspect.Parameter.VAR_POSITIONAL for p in params
    ):
        raise TypeError(
            f"backend {label!r} must take the prototype array as its first "
            f"positional argument; signature is {sig}"
        )
    accepts_var_kw = any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params
    )
    missing = [
        kw for kw in REQUIRED_KWARGS
        if kw not in sig.parameters and not accepts_var_kw
    ]
    if missing:
        raise TypeError(
            f"backend {label!r} must accept keyword argument(s) "
            f"{missing} (or **kwargs); signature is {sig}"
        )


def register_backend(name: str) -> Callable[[BackendFn], BackendFn]:
    """Decorator: ``@register_backend("kmeans")`` on a BackendFn adapter."""

    def deco(fn: BackendFn) -> BackendFn:
        if name in _REGISTRY and _REGISTRY[name] is not fn:
            raise ValueError(f"backend {name!r} is already registered "
                             f"({_REGISTRY[name]!r})")
        validate_backend_fn(fn, name)
        _REGISTRY[name] = fn
        return fn

    return deco


def _ensure_builtin_backends() -> None:
    # importing the modules runs their @register_backend decorators; local
    # import keeps registry importable from anywhere without a cycle
    from repro.cluster import dbscan, hac, kmeans  # noqa: F401


def resolve_backend(backend: Union[str, BackendFn]) -> BackendFn:
    """Name or callable → validated BackendFn (the one resolution point)."""
    if callable(backend):
        validate_backend_fn(backend)
        return backend
    _ensure_builtin_backends()
    if backend not in _REGISTRY:
        raise ValueError(
            f"unknown backend {backend!r}; have {available_backends()}"
        )
    return _REGISTRY[backend]


def available_backends() -> list:
    """Sorted names of every registered backend."""
    _ensure_builtin_backends()
    return sorted(_REGISTRY)
