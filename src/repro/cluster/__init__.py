"""Sophisticated clustering backends hybridized by IHTC (paper baselines).

Backends self-register with :mod:`repro.cluster.registry` at import; resolve
names (or validate callables) through :func:`resolve_backend`.
"""
from . import dbscan, hac, kmeans, metrics  # noqa: F401
from .registry import (  # noqa: F401
    BackendFn,
    available_backends,
    register_backend,
    resolve_backend,
    validate_backend_fn,
)
