"""Sophisticated clustering backends hybridized by IHTC (paper baselines)."""
from . import dbscan, hac, kmeans, metrics  # noqa: F401
