"""Weighted k-means (Lloyd + k-means++) in pure JAX, mask-aware.

This is the paper's primary "sophisticated" backend. Supports sample weights
(prototype masses from ITIS) so that k-means on prototypes optimizes the same
objective as k-means on the original units would (the mass-correct variant);
with unit weights it reproduces the paper's plain k-means-on-prototypes.

Lloyd statistics are accumulated with ``ops.blocked_segment_sum`` — a fixed
``n_blocks``-wide reduction tree — so the mesh-aware twin in
:mod:`repro.core.distributed` (replicated centroids, sharded rows, ordered
fold of all-gathered per-shard partials) produces bit-identical centers and
labels (DESIGN.md §4.3).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import runtime
from repro.cluster.registry import register_backend
from repro.kernels import ops

STAT_BLOCKS = 8  # canonical reduction width; must match the distributed twin


class KMeansResult(NamedTuple):
    centers: jax.Array   # (k, d)
    labels: jax.Array    # (n,) int32, -1 for invalid rows
    inertia: jax.Array   # () weighted within-cluster sum of squares
    iters: jax.Array     # () iterations until convergence


def _plus_plus_init(x, w, valid, k, key, impl):
    """k-means++ seeding with weighted D² sampling."""
    n = x.shape[0]
    wv = jnp.where(valid, w, 0.0)
    key0, key_loop = jax.random.split(key)
    first = jax.random.categorical(key0, jnp.log(jnp.maximum(wv, 1e-30)))
    centers0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(i, carry):
        centers, key = carry
        key, sub = jax.random.split(key)
        d = ops.pairwise_sq_l2(x, centers, impl=impl)  # (n, k)
        # distance to nearest chosen center (ignore not-yet-filled slots)
        slot_ok = jnp.arange(k)[None, :] < i
        dmin = jnp.min(jnp.where(slot_ok, d, jnp.inf), axis=1)
        logits = jnp.log(jnp.maximum(wv * dmin, 1e-30))
        nxt = jax.random.categorical(sub, logits)
        return centers.at[i].set(x[nxt]), key

    centers, _ = jax.lax.fori_loop(1, k, body, (centers0, key_loop))
    return centers


def kmeans(
    x: jax.Array,
    k: int,
    *,
    valid: Optional[jax.Array] = None,
    weights: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    iters: int = 100,
    tol: float = 1e-6,
    impl: Optional[str] = None,
    n_blocks: Optional[int] = None,
) -> KMeansResult:
    """Weighted k-means; ``impl``/``n_blocks`` default to the runtime config
    (resolved before the jit boundary — DESIGN.md §10)."""
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    n_blocks = cfg.n_blocks if n_blocks is None else n_blocks
    return _kmeans(x, k, valid=valid, weights=weights, key=key, iters=iters,
                   tol=tol, impl=impl, n_blocks=n_blocks,
                   _dispatch=cfg.dispatch_key())


@functools.partial(
    jax.jit,
    static_argnames=("k", "iters", "impl", "n_blocks", "_dispatch"),
)
def _kmeans(
    x: jax.Array,
    k: int,
    *,
    valid: Optional[jax.Array],
    weights: Optional[jax.Array],
    key: Optional[jax.Array],
    iters: int,
    tol: float,
    impl: str,
    n_blocks: int,
    _dispatch: tuple = (),  # cache-key pin for trace-time config reads (§10)
) -> KMeansResult:
    n, d = x.shape
    if valid is None:
        valid = jnp.ones((n,), bool)
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    if key is None:
        key = jax.random.PRNGKey(0)
    w = jnp.where(valid, weights.astype(jnp.float32), 0.0)

    centers = _plus_plus_init(x, w, valid, k, key, impl)

    def assign(centers):
        dist = ops.pairwise_sq_l2(x, centers, impl=impl)  # (n, k)
        lab = jnp.argmin(dist, axis=1).astype(jnp.int32)
        dmin = jnp.min(dist, axis=1)
        return lab, dmin

    def cond(state):
        _, _, delta, it = state
        return (delta > tol) & (it < iters)

    def body(state):
        centers, _, _, it = state
        lab, _ = assign(centers)
        lab_safe = jnp.where(valid, lab, k)  # dropped by segment_sum
        sums, mass = ops.blocked_segment_sum(
            x, lab_safe, k, weights=w, n_blocks=n_blocks, impl=impl)
        new = jnp.where(
            (mass > 0)[:, None], sums / jnp.maximum(mass, 1e-30)[:, None], centers
        ).astype(x.dtype)
        delta = jnp.max(jnp.sum(jnp.square(new - centers), axis=1))
        return new, lab, delta, it + 1

    lab0, _ = assign(centers)
    state = (centers, lab0, jnp.asarray(jnp.inf, jnp.float32), jnp.asarray(0))
    centers, labels, _, it = jax.lax.while_loop(cond, body, state)
    labels, dmin = assign(centers)
    inertia = jnp.sum(jnp.where(valid, w * dmin, 0.0))
    labels = jnp.where(valid, labels, -1)
    return KMeansResult(centers, labels.astype(jnp.int32), inertia, it)


@register_backend("kmeans")
def kmeans_masked(
    x: jax.Array,
    *,
    k: int = 3,
    valid: Optional[jax.Array] = None,
    weights: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    iters: int = 100,
    **_: object,
) -> jax.Array:
    """IHTC backend adapter: returns labels only."""
    return kmeans(
        x, k, valid=valid, weights=weights, key=key, iters=iters, impl=impl
    ).labels
