"""Clustering quality metrics used by the paper's tables.

* prediction accuracy (GMM simulation, Tables 1–2) — best label matching;
* BSS/TSS (real-data tables 4–6, 9);
* bottleneck objective (max within-cluster dissimilarity) — the quantity TC
  4-approximates; used by the property tests.
"""
from __future__ import annotations

import itertools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def bss_tss(
    x: jax.Array,
    labels: jax.Array,
    k: int,
    *,
    weights: Optional[jax.Array] = None,
) -> jax.Array:
    """Between-cluster SS / total SS (higher = tighter clusters)."""
    n = x.shape[0]
    w = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    ok = labels >= 0
    w = jnp.where(ok, w, 0.0)
    tot_w = jnp.maximum(jnp.sum(w), 1e-30)
    mu = jnp.sum(x * w[:, None], axis=0) / tot_w
    tss = jnp.sum(w * jnp.sum(jnp.square(x - mu), axis=1))

    lab_safe = jnp.where(ok, labels, k)
    sums = jax.ops.segment_sum(x * w[:, None], lab_safe, num_segments=k + 1)[:k]
    mass = jax.ops.segment_sum(w, lab_safe, num_segments=k + 1)[:k]
    cent = sums / jnp.maximum(mass, 1e-30)[:, None]
    wss = jnp.sum(w * jnp.sum(jnp.square(x - cent[jnp.where(ok, labels, 0)]), axis=1)
                  * ok.astype(jnp.float32))
    # constant / single-point data has tss == 0; clamp like every other
    # division here so degenerate inputs report 0.0 instead of NaN
    return (tss - wss) / jnp.maximum(tss, 1e-30)


def confusion(true: np.ndarray, pred: np.ndarray, k_true: int, k_pred: int) -> np.ndarray:
    m = np.zeros((k_true, k_pred), dtype=np.int64)
    ok = (true >= 0) & (pred >= 0)
    np.add.at(m, (true[ok], pred[ok]), 1)
    return m


def clustering_accuracy(true, pred, k: int) -> float:
    """Paper's 'prediction accuracy': fraction correct under the best
    assignment of predicted clusters to true classes. Exact permutation
    search for k ≤ 8, greedy otherwise. Unmatched points (label -1) count
    as errors."""
    true = np.asarray(true)
    pred = np.asarray(pred)
    n = true.shape[0]
    k_pred = max(int(pred.max()) + 1, k) if pred.size and pred.max() >= 0 else k
    m = confusion(true, pred, k, k_pred)
    if k_pred <= 8:
        best = 0
        for perm in itertools.permutations(range(k_pred), min(k, k_pred)):
            best = max(best, sum(m[i, p] for i, p in enumerate(perm) if i < k))
        return best / n
    # greedy: repeatedly take the largest cell
    m = m.astype(np.float64).copy()
    total = 0.0
    for _ in range(min(k, k_pred)):
        i, j = np.unravel_index(np.argmax(m), m.shape)
        total += m[i, j]
        m[i, :] = -1
        m[:, j] = -1
    return total / n


def bottleneck_objective(x, labels) -> float:
    """Max within-cluster pairwise distance (brute force — small n only)."""
    x = np.asarray(x, dtype=np.float64)
    labels = np.asarray(labels)
    worst = 0.0
    for c in np.unique(labels[labels >= 0]):
        pts = x[labels == c]
        if len(pts) < 2:
            continue
        d = np.sqrt(((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1))
        worst = max(worst, float(d.max()))
    return worst


def optimal_bottleneck(x, t: int) -> float:
    """Exact optimum λ of BTPP by brute force over set partitions (tiny n).

    Used by the property test asserting TC ≤ 4λ."""
    x = np.asarray(x, dtype=np.float64)
    n = len(x)
    assert n <= 10, "brute force only"
    d = np.sqrt(((x[:, None, :] - x[None, :, :]) ** 2).sum(-1))

    best = [np.inf]

    def rec(i, parts):
        if i == n:
            if all(len(p) >= t for p in parts):
                worst = 0.0
                for p in parts:
                    for a in range(len(p)):
                        for b in range(a + 1, len(p)):
                            worst = max(worst, d[p[a], p[b]])
                best[0] = min(best[0], worst)
            return
        for p in parts:
            p.append(i)
            rec(i + 1, parts)
            p.pop()
        parts.append([i])
        rec(i + 1, parts)
        parts.pop()

    rec(0, [])
    return best[0]
