"""DBSCAN in pure JAX (the paper's Appendix-B backend), mask- and mass-aware.

Density counts use sample weights, so running DBSCAN on ITIS prototypes with
masses approximates density on the *original* units (each prototype stands
for ``mass`` points) — this is why IHTC+DBSCAN preserves cluster structure.

Core-point connected components are found by iterative min-label propagation
over the ε-graph (a matmul-shaped masked min, O(log diameter) rounds in a
``lax.while_loop``) — no union-find pointer chasing, TPU-friendly.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import runtime
from repro.cluster.registry import register_backend
from repro.kernels import ops


class DBSCANResult(NamedTuple):
    labels: jax.Array    # (n,) int32; -1 = noise or invalid
    is_core: jax.Array   # (n,) bool


def dbscan(
    x: jax.Array,
    eps: float,
    min_pts: float,
    *,
    valid: Optional[jax.Array] = None,
    weights: Optional[jax.Array] = None,
    impl: Optional[str] = None,
) -> DBSCANResult:
    """Weighted DBSCAN; ``impl`` defaults to the runtime config."""
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    return _dbscan(x, eps, min_pts, valid=valid, weights=weights, impl=impl,
                   _dispatch=cfg.dispatch_key())


@functools.partial(jax.jit, static_argnames=("impl", "_dispatch"))
def _dbscan(
    x: jax.Array,
    eps: float,
    min_pts: float,
    *,
    valid: Optional[jax.Array],
    weights: Optional[jax.Array],
    impl: str,
    _dispatch: tuple = (),  # cache-key pin for trace-time config reads (§10)
) -> DBSCANResult:
    n = x.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    w = jnp.where(valid, weights.astype(jnp.float32), 0.0)

    d = ops.pairwise_sq_l2(x, x, impl=impl)
    adj = (d <= eps * eps) & valid[:, None] & valid[None, :]  # includes self
    density = jnp.sum(adj * w[None, :], axis=1)               # weighted ε-count
    is_core = valid & (density >= min_pts)

    core_adj = adj & is_core[:, None] & is_core[None, :]
    idx = jnp.arange(n, dtype=jnp.int32)
    lab0 = jnp.where(is_core, idx, jnp.int32(n))  # n == +inf sentinel

    def cond(state):
        lab, changed = state
        return changed

    def body(state):
        lab, _ = state
        # min label over core neighbours (matmul-shaped masked min) ∪ self
        nbr_min = jnp.min(
            jnp.where(core_adj, lab[None, :], jnp.int32(n)), axis=1
        )
        new = jnp.minimum(lab, nbr_min)
        new = jnp.where(is_core, new, jnp.int32(n))
        return new, jnp.any(new != lab)

    lab, _ = jax.lax.while_loop(cond, body, (lab0, jnp.asarray(True)))

    # border points: adopt the min component label among neighbouring cores
    border_lab = jnp.min(
        jnp.where(adj & is_core[None, :], lab[None, :], jnp.int32(n)), axis=1
    )
    full = jnp.where(is_core, lab, jnp.where(valid, border_lab, jnp.int32(n)))

    # compact component representatives to [0, n_clusters)
    is_rep = (full == idx) & is_core
    rank = jnp.cumsum(is_rep.astype(jnp.int32)) - 1
    labels = jnp.where(full < n, rank[jnp.where(full < n, full, 0)], -1)
    return DBSCANResult(labels.astype(jnp.int32), is_core)


@register_backend("dbscan")
def dbscan_masked(
    x: jax.Array,
    *,
    eps: float = 0.5,
    min_pts: float = 5.0,
    valid: Optional[jax.Array] = None,
    weights: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,  # unused; uniform backend signature
    impl: Optional[str] = None,
    **_: object,
) -> jax.Array:
    """IHTC backend adapter: returns labels only (-1 = noise)."""
    del key
    return dbscan(x, eps, min_pts, valid=valid, weights=weights, impl=impl).labels
