"""Hierarchical agglomerative clustering (Lance–Williams) in pure JAX.

HAC is the paper's headline "intractable at scale" backend (R's hclust dies
at 2¹⁶ points); IHTC makes it usable by feeding it ≤ n/(t*)^m prototypes.
Implementation: masked (n, n) dissimilarity matrix, ``n_valid − k`` merge
steps inside a ``lax.while_loop``; each merge updates one row/column via the
Lance–Williams recurrence, so the whole run is O(n² · merges) dense vector
work — fine for the prototype regime (n ≲ 4k), by design of IHTC.

Linkages: single / complete / average / ward (weighted by cluster mass, so
prototype masses give the same dendrogram HAC would build on raw units for
ward/average).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import runtime
from repro.cluster.registry import register_backend
from repro.kernels import ops

_LINKAGES = ("single", "complete", "average", "ward")


class HACResult(NamedTuple):
    labels: jax.Array      # (n,) int32 flat clustering at k clusters, -1 invalid
    n_merges: jax.Array    # () int32


def hac(
    x: jax.Array,
    k: int,
    *,
    valid: Optional[jax.Array] = None,
    weights: Optional[jax.Array] = None,
    linkage: str = "complete",
    impl: Optional[str] = None,
) -> HACResult:
    """Lance–Williams HAC; ``impl`` defaults to the runtime config."""
    if linkage not in _LINKAGES:
        raise ValueError(f"linkage {linkage!r} not in {_LINKAGES}")
    cfg = runtime.active()
    impl = cfg.impl if impl is None else impl
    return _hac(x, k, valid=valid, weights=weights, linkage=linkage,
                impl=impl, _dispatch=cfg.dispatch_key())


@functools.partial(
    jax.jit, static_argnames=("k", "linkage", "impl", "_dispatch")
)
def _hac(
    x: jax.Array,
    k: int,
    *,
    valid: Optional[jax.Array],
    weights: Optional[jax.Array],
    linkage: str,
    impl: str,
    _dispatch: tuple = (),  # cache-key pin for trace-time config reads (§10)
) -> HACResult:
    n = x.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)

    big = jnp.inf
    d0 = ops.pairwise_sq_l2(x, x, impl=impl)
    if linkage != "ward":
        d0 = jnp.sqrt(d0)
    ok = valid[:, None] & valid[None, :]
    d0 = jnp.where(ok, d0, big)
    d0 = d0.at[jnp.arange(n), jnp.arange(n)].set(big)
    if linkage == "ward":
        # ward init: d(i,j) = (w_i w_j)/(w_i + w_j) ||x_i - x_j||²
        wi = weights[:, None]
        wj = weights[None, :]
        d0 = jnp.where(ok, d0 * wi * wj / jnp.maximum(wi + wj, 1e-30), big)
        d0 = d0.at[jnp.arange(n), jnp.arange(n)].set(big)

    n_valid = jnp.sum(valid).astype(jnp.int32)
    target = jnp.maximum(jnp.minimum(jnp.int32(k), n_valid), 1)
    merges_needed = n_valid - target

    def cond(state):
        _, _, _, _, done = state
        return done < merges_needed

    def body(state):
        dmat, assign, size, alive, done = state
        flat = jnp.argmin(dmat)
        i, j = jnp.unravel_index(flat, dmat.shape)
        i, j = jnp.minimum(i, j), jnp.maximum(i, j)
        dij = dmat[i, j]
        di = dmat[i, :]
        dj = dmat[j, :]
        ni, nj, nl = size[i], size[j], size
        if linkage == "single":
            new = jnp.minimum(di, dj)
        elif linkage == "complete":
            new = jnp.maximum(di, dj)
        elif linkage == "average":
            new = (ni * di + nj * dj) / jnp.maximum(ni + nj, 1e-30)
        else:  # ward (Lance–Williams with β term)
            tot = jnp.maximum(ni + nj + nl, 1e-30)
            new = ((ni + nl) * di + (nj + nl) * dj - nl * dij) / tot
        new = jnp.where(alive, new, big)
        new = new.at[i].set(big).at[j].set(big)
        dmat = dmat.at[i, :].set(new).at[:, i].set(new)
        dmat = dmat.at[j, :].set(big).at[:, j].set(big)
        assign = jnp.where(assign == j, i, assign)
        size = size.at[i].set(ni + nj).at[j].set(0.0)
        alive = alive.at[j].set(False)
        return dmat, assign, size, alive, done + 1

    assign0 = jnp.where(valid, jnp.arange(n, dtype=jnp.int32), -1)
    size0 = jnp.where(valid, weights.astype(jnp.float32), 0.0)
    state = (d0, assign0, size0, valid, jnp.int32(0))
    _, assign, _, alive, n_merges = jax.lax.while_loop(cond, body, state)

    # compact representatives to [0, k)
    rank = jnp.cumsum(alive.astype(jnp.int32)) - 1
    labels = jnp.where(assign >= 0, rank[jnp.where(assign >= 0, assign, 0)], -1)
    return HACResult(labels.astype(jnp.int32), n_merges)


@register_backend("hac")
def hac_masked(
    x: jax.Array,
    *,
    k: int = 3,
    valid: Optional[jax.Array] = None,
    weights: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,  # unused; uniform backend signature
    linkage: str = "complete",
    impl: Optional[str] = None,
    **_: object,
) -> jax.Array:
    """IHTC backend adapter: returns labels only."""
    del key
    return hac(x, k, valid=valid, weights=weights, linkage=linkage, impl=impl).labels
