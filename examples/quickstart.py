"""Quickstart: the paper's headline experiment in ~30 lines.

One entry point — ``repro.fit(x_or_chunks, t, m, backend)`` — runs IHTC
(ITIS + k-means) on the paper's Gaussian-mixture benchmark and prints the
time / reduction / accuracy trade-off as the ITIS iteration count m grows,
then freezes the last fit into a ClusterIndex and labels a fresh query
batch online. The same call on a chunk iterator runs the out-of-core
streaming executor (bit-identical here, where the stream is one aligned
buffer). All dispatch knobs flow through the runtime config:
`python examples/quickstart.py --n 100000 --impl ref`
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def main():
    import repro
    from repro import runtime
    from repro.cluster.metrics import clustering_accuracy

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--t", type=int, default=2, help="TC size threshold t*")
    ap.add_argument("--impl", default="auto", choices=("auto", "pallas", "ref"),
                    help="kernel dispatch policy (runtime.configure)")
    args = ap.parse_args()

    # the paper's §4 mixture: 3 bivariate Gaussians, weights .5/.3/.2
    rng = np.random.default_rng(0)
    mus = np.array([[1, 2], [7, 8], [3, 5]], float)
    sds = np.array([[1, 0.5], [2, 1], [3, 4]], float) ** 0.5
    comp = rng.choice(3, size=args.n, p=[0.5, 0.3, 0.2])
    x_np = (mus[comp] + rng.normal(size=(args.n, 2)) * sds[comp]).astype(
        np.float32)
    x = jnp.asarray(x_np)

    print(f"n={args.n}, t*={args.t}, impl={args.impl}  (m=0 is plain k-means)")
    print(f"{'m':>3} {'seconds':>9} {'prototypes':>11} {'accuracy':>9}")
    with runtime.configure(impl=args.impl):  # one knob, whole pipeline
        for m in range(0, 5):
            t0 = time.perf_counter()
            res = repro.fit(x, args.t, m, "kmeans", k=3,
                            key=jax.random.PRNGKey(0))
            jax.block_until_ready(res.labels)
            sec = time.perf_counter() - t0
            acc = clustering_accuracy(comp, np.asarray(res.labels), 3)
            print(f"{m:>3} {sec:>9.3f} {int(res.n_prototypes):>11} {acc:>9.4f}")

        # the same fit() over a chunk stream plans the out-of-core executor;
        # on this aligned single-buffer stream it is bit-identical
        streamed = repro.fit(iter([x_np]), args.t, 4, "kmeans", k=3,
                             key=jax.random.PRNGKey(0), chunk_n=args.n)
        same = np.array_equal(streamed.labels_for(0), np.asarray(res.labels))
        print(f"streaming executor ({streamed.executor}): "
              f"bit-identical labels = {same}")

        # freeze the last fit into a servable index and label new points
        index = res.to_index()
        comp_q = rng.choice(3, size=1000, p=[0.5, 0.3, 0.2])
        q = jnp.asarray(mus[comp_q] + rng.normal(size=(1000, 2)) * sds[comp_q],
                        jnp.float32)
        t0 = time.perf_counter()
        labels = jax.block_until_ready(index.assign(q))
        sec = time.perf_counter() - t0
        acc = clustering_accuracy(comp_q, np.asarray(labels), 3)
        print(f"online assign of 1000 fresh queries: {sec:.4f}s "
              f"(accuracy {acc:.4f}, {int(index.n_prototypes)} prototypes)")


if __name__ == "__main__":
    main()
