"""Quickstart: the paper's headline experiment in ~30 lines.

Runs IHTC (ITIS + k-means) on the paper's Gaussian-mixture benchmark and
prints the time / reduction / accuracy trade-off as the ITIS iteration
count m grows. `python examples/quickstart.py --n 100000`
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def main():
    from repro.cluster.metrics import clustering_accuracy
    from repro.core import ihtc

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--t", type=int, default=2, help="TC size threshold t*")
    args = ap.parse_args()

    # the paper's §4 mixture: 3 bivariate Gaussians, weights .5/.3/.2
    rng = np.random.default_rng(0)
    mus = np.array([[1, 2], [7, 8], [3, 5]], float)
    sds = np.array([[1, 0.5], [2, 1], [3, 4]], float) ** 0.5
    comp = rng.choice(3, size=args.n, p=[0.5, 0.3, 0.2])
    x = jnp.asarray(mus[comp] + rng.normal(size=(args.n, 2)) * sds[comp],
                    jnp.float32)

    print(f"n={args.n}, t*={args.t}  (m=0 is plain k-means)")
    print(f"{'m':>3} {'seconds':>9} {'prototypes':>11} {'accuracy':>9}")
    for m in range(0, 5):
        t0 = time.perf_counter()
        res = ihtc(x, args.t, m, "kmeans", k=3, key=jax.random.PRNGKey(0))
        jax.block_until_ready(res.labels)
        sec = time.perf_counter() - t0
        acc = clustering_accuracy(comp, np.asarray(res.labels), 3)
        print(f"{m:>3} {sec:>9.3f} {int(res.n_prototypes):>11} {acc:>9.4f}")


if __name__ == "__main__":
    main()
