"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production substrate — deterministic data pipeline, optional IHTC
instance selection, AdamW+ZeRO, fault-tolerant loop, async checkpoints.

    python examples/train_lm.py --arch mamba2-370m --steps 200 --width 256

(`--width` scales d_model down so a few hundred steps fit a CPU session;
drop it on real hardware to train the full config.)
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np


def main():
    from repro.configs import ARCHS, SHAPES, smoke_config
    from repro.data import make_batch
    from repro.models import build
    from repro.train import (CheckpointManager, OptConfig, init_opt_state,
                             make_train_step)
    from repro.train.fault_tolerance import run_training

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--width", type=int, default=0,
                    help="override d_model (0 = full config)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.width:
        cfg = smoke_config(cfg)
        kw = dict(d_model=args.width)
        if cfg.n_heads:
            kw["head_dim"] = max(args.width // max(cfg.n_heads, 1), 8)
        if args.layers:
            kw["n_layers"] = args.layers
        cfg = dataclasses.replace(cfg, **kw)
    bundle = build(cfg)

    params = bundle.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")

    opt = init_opt_state(params)
    step = jax.jit(make_train_step(bundle, OptConfig(
        peak_lr=args.lr, warmup_steps=20, decay_steps=args.steps)))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    losses = []

    def on_metrics(s, m):
        losses.append(float(m["loss"]))
        if s % 20 == 0 or s == args.steps - 1:
            print(f"step {s:>5}  loss {losses[-1]:.4f}  "
                  f"lr {float(m['lr']):.2e}  gnorm {float(m['grad_norm']):.2f}")

    params, opt, stats = run_training(
        train_step=step,
        init_state=(params, opt),
        batch_for_step=lambda s: make_batch(
            cfg, SHAPES["train_4k"], s, batch_override=args.batch,
            seq_override=args.seq),
        n_steps=args.steps,
        ckpt=ckpt, ckpt_every=50,
        on_metrics=on_metrics,
    )
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"step-time p50 {stats.quantiles().get('p50', 0):.3f}s; "
          f"checkpoints at {args.ckpt_dir}: {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
