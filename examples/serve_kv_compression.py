"""Serve a small model with batched requests and IHTC KV-cache compression —
the paper's instance selection applied to long-context inference.

Shows: batched prefill → greedy decode, cache compressed by (t*)^m with
log-mass bias correction, periodic recompression as the fresh tail fills,
and the logit agreement between compressed and exact decoding.

    python examples/serve_kv_compression.py --prompt-len 96 --new-tokens 32
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def main():
    from repro.configs import ARCHS, smoke_config
    from repro.models import build
    from repro.serve import ServeConfig, ServeEngine
    from repro.serve.kv_compression import compress_model_caches

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--m", type=int, default=1)
    args = ap.parse_args()

    cfg = smoke_config(ARCHS[args.arch])
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # repetitive prompts -> clusterable KV sets (the regime IHTC exploits)
    prompts = jnp.asarray(
        rng.integers(0, 12, size=(args.batch, args.prompt_len)), jnp.int32)

    # --- exact vs compressed single-step logit agreement ---
    caches = bundle.init_caches(args.batch, args.prompt_len + args.new_tokens)
    lg, caches = bundle.prefill(params, caches, {"tokens": prompts})
    comp = compress_model_caches(caches, args.t, args.m, tail=16, impl="ref")
    nxt = jnp.argmax(lg[:, -1], -1)[:, None]
    l_exact, _ = bundle.decode_step(params, caches, {"tokens": nxt})
    l_comp, _ = bundle.decode_step(params, comp, {"tokens": nxt})
    p1 = jax.nn.softmax(l_exact[:, -1].astype(jnp.float32), -1)
    p2 = jax.nn.softmax(l_comp[:, -1].astype(jnp.float32), -1)
    tv = 0.5 * float(jnp.mean(jnp.sum(jnp.abs(p1 - p2), -1)))
    agree = float(jnp.mean(jnp.argmax(p1, -1) == jnp.argmax(p2, -1)))
    full_slots = caches["prefix"][0]["k"].shape[2] if caches["prefix"] else \
        caches["stack"][0]["k"].shape[3]
    comp_slots = comp["prefix"][0]["k"].shape[2] if comp["prefix"] else \
        comp["stack"][0]["k"].shape[3]
    print(f"cache slots {full_slots} -> {comp_slots} "
          f"({args.t}^{args.m} compression + tail)")
    print(f"decode agreement: TV={tv:.3f}, top-1 match={agree:.2f}")

    # --- full generation with periodic recompression ---
    eng = ServeEngine(bundle, params, ServeConfig(
        max_new_tokens=args.new_tokens, compress=True,
        compress_t=args.t, compress_m=args.m, compress_tail=16))
    out = eng.generate({"tokens": prompts})
    print(f"generated {out['tokens'].shape} tokens with "
          f"{out['compressions']} in-flight recompressions")
    print("sample:", np.asarray(out["tokens"][0][:16]))


if __name__ == "__main__":
    main()
