"""Distributed IHTC: hierarchical (sharded) ITIS over a device mesh.

Demonstrates the 1000-node pattern at laptop scale: each shard runs TC
locally (ring-kNN available for exact cross-shard graphs), reduces to
weighted prototypes, prototypes all-gather, the host driver iterates, and
the final small prototype set is clustered with weighted k-means. The
composition is exact ITIS semantics — ITIS is already hierarchical.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/massive_clustering.py --n 65536
"""
import argparse
import os
import sys

if "--xla-devices" in sys.argv or os.environ.get("XLA_FLAGS") is None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def main():
    from repro.cluster.kmeans import kmeans
    from repro.cluster.metrics import clustering_accuracy
    from repro.core import itis_step

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65_536)
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--m", type=int, default=4)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    print(f"devices: {n_dev}; n = {args.n}; t* = {args.t}; m = {args.m}")

    rng = np.random.default_rng(0)
    mus = np.array([[1, 2], [7, 8], [3, 5]], float)
    sds = np.array([[1, 0.5], [2, 1], [3, 4]], float) ** 0.5
    comp = rng.choice(3, size=args.n, p=[0.5, 0.3, 0.2])
    x = jnp.asarray(mus[comp] + rng.normal(size=(args.n, 2)) * sds[comp],
                    jnp.float32)

    # --- sharded ITIS level: per-shard TC + prototype reduction ---
    def level(x_loc, mass_loc, valid_loc, t):
        out = itis_step(x_loc, mass_loc, valid_loc, t,
                        key=jax.random.PRNGKey(0), weighted=True, impl="ref")
        return out.protos, out.mass, out.valid

    t0 = time.perf_counter()
    cur_x, cur_m, cur_v = x, jnp.ones((args.n,)), jnp.ones((args.n,), bool)
    for lvl in range(args.m):
        fn = shard_map(
            functools.partial(level, t=args.t), mesh=mesh,
            in_specs=(P("data", None), P("data"), P("data")),
            out_specs=(P("data", None), P("data"), P("data")),
        )
        cur_x, cur_m, cur_v = fn(cur_x, cur_m, cur_v)
        n_valid = int(jnp.sum(cur_v))
        print(f"  level {lvl + 1}: {n_valid} prototypes "
              f"(mass check: {float(jnp.sum(jnp.where(cur_v, cur_m, 0))):.0f})")

    # --- final: weighted k-means on the gathered prototypes ---
    r = kmeans(cur_x, 3, valid=cur_v, weights=cur_m,
               key=jax.random.PRNGKey(1))
    sec = time.perf_counter() - t0
    # back out through nearest-prototype assignment for scoring
    from repro.kernels import ops

    d = ops.pairwise_sq_l2(x, r.centers, impl="ref")
    labels = np.asarray(jnp.argmin(d, axis=1))
    acc = clustering_accuracy(comp, labels, 3)
    print(f"hierarchical IHTC: {sec:.2f}s total, accuracy {acc:.4f}")


if __name__ == "__main__":
    main()
