"""Distributed IHTC: the end-to-end sharded ITIS pipeline over a data mesh.

Demonstrates the pod pattern at laptop scale: a point stream is fed onto
the mesh chunk-by-chunk (no full-size host buffer), every ITIS level runs
under shard_map — ring-kNN TC, distributed Luby-MIS seeding, cross-shard
prototype reduction, rebalance — and the final prototype set is clustered
by mesh-aware weighted k-means without ever gathering points to one
device (DESIGN.md §4). The result is bit-identical to the single-device
``ihtc()`` when the level sizes divide the device count evenly.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/massive_clustering.py --n 65536
"""
import argparse
import os
import sys

if os.environ.get("XLA_FLAGS") is None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")

import time

import jax
import numpy as np


def main():
    from repro.cluster.metrics import clustering_accuracy
    from repro.core.distributed import ihtc_sharded, make_data_mesh
    from repro.data import PointStreamConfig, point_chunks, stream_to_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65_536)
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--m", type=int, default=4)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    mesh = make_data_mesh()
    print(f"devices: {n_dev}; n = {args.n}; t* = {args.t}; m = {args.m}")

    # --- streamed ingestion: chunks of the paper's §4 GMM onto the mesh ---
    cfg = PointStreamConfig(n=args.n, d=2, chunk=16_384, seed=0, kind="gmm")
    t0 = time.perf_counter()
    x, valid = stream_to_mesh(point_chunks(cfg), mesh, cfg.n, cfg.d)
    print(f"ingest: {time.perf_counter() - t0:.2f}s "
          f"({-(-cfg.n // cfg.chunk)} chunks → {x.sharding.spec})")

    # --- end-to-end sharded IHTC ---
    t0 = time.perf_counter()
    res = ihtc_sharded(x, args.t, args.m, "kmeans", k=3, valid=valid,
                       mesh=mesh, key=jax.random.PRNGKey(0))
    jax.block_until_ready(res.labels)
    sec = time.perf_counter() - t0
    print(f"sharded IHTC: {sec:.2f}s, "
          f"{int(res.n_prototypes)} prototypes at level {args.m}")

    # --- score against the generative component labels (the stream is a
    # pure function of (seed, chunk), so truth is regenerable, not stored) ---
    rng_truth = []
    for i in range(-(-cfg.n // cfg.chunk)):
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, i]))
        c = min(cfg.chunk, cfg.n - i * cfg.chunk)
        rng_truth.append(rng.choice(3, size=c, p=[0.5, 0.3, 0.2]))
    comp = np.concatenate(rng_truth)
    lab = np.asarray(res.labels)[np.asarray(valid)]
    acc = clustering_accuracy(comp, lab, 3)
    print(f"accuracy vs generative components: {acc:.4f}")


if __name__ == "__main__":
    main()
