"""Distributed IHTC: one ``repro.fit()`` over the data mesh, two ways.

Demonstrates the pod pattern at laptop scale (DESIGN.md §4, §13):

  1. **sharded** — a point stream is fed onto the mesh chunk-by-chunk (no
     full-size host buffer) and the resident sharded array is fit: every
     ITIS level runs under shard_map — ring-kNN TC, distributed Luby-MIS
     seeding, cross-shard prototype reduction, rebalance — and the final
     prototypes are clustered by mesh-aware weighted k-means without ever
     gathering points to one device. Bit-identical to the single-device
     fit when the level sizes divide the device count evenly.
  2. **streaming_sharded** — the composed executor: the same chunks are
     reduced *as they stream* by sharded level steps into a bounded
     mesh-sharded reservoir, so peak device memory stays
     O(chunk + reservoir) while every device still works on every chunk —
     out-of-core and multi-device at once.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/massive_clustering.py --n 65536
"""
import argparse
import os
import sys

if os.environ.get("XLA_FLAGS") is None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")

import time

import jax
import numpy as np


def main():
    import repro
    from repro.cluster.metrics import clustering_accuracy
    from repro.core.distributed import make_data_mesh
    from repro.data import PointStreamConfig, point_chunks, stream_to_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65_536)
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--m", type=int, default=4)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    mesh = make_data_mesh()
    print(f"devices: {n_dev}; n = {args.n}; t* = {args.t}; m = {args.m}")

    # --- the generative component labels (the stream is a pure function of
    # (seed, chunk), so truth is regenerable, not stored) ---
    cfg = PointStreamConfig(n=args.n, d=2, chunk=16_384, seed=0, kind="gmm")
    rng_truth = []
    for i in range(-(-cfg.n // cfg.chunk)):
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, i]))
        c = min(cfg.chunk, cfg.n - i * cfg.chunk)
        rng_truth.append(rng.choice(3, size=c, p=[0.5, 0.3, 0.2]))
    comp = np.concatenate(rng_truth)

    # --- way 1: streamed ingestion to a resident sharded array, then the
    # "sharded" executor (repro.fit picks it from the mesh) ---
    t0 = time.perf_counter()
    x, valid = stream_to_mesh(point_chunks(cfg), mesh, cfg.n, cfg.d)
    print(f"ingest: {time.perf_counter() - t0:.2f}s "
          f"({-(-cfg.n // cfg.chunk)} chunks → {x.sharding.spec})")
    t0 = time.perf_counter()
    res = repro.fit(x, args.t, args.m, "kmeans", k=3, valid=valid,
                    mesh=mesh, key=jax.random.PRNGKey(0))
    jax.block_until_ready(res.labels)
    sec = time.perf_counter() - t0
    print(f"{res.executor} fit: {sec:.2f}s, "
          f"{int(res.n_prototypes)} prototypes at level {args.m}")
    lab = np.asarray(res.labels)[np.asarray(valid)]
    acc = clustering_accuracy(comp, lab, 3)
    print(f"accuracy vs generative components: {acc:.4f}")

    # --- way 2: the composed streaming_sharded executor — same chunks,
    # never resident: O(chunk + reservoir) device memory, every device busy
    t0 = time.perf_counter()
    res2 = repro.fit(point_chunks(cfg), args.t, args.m, "kmeans", k=3,
                     mesh=mesh, chunk_n=cfg.chunk,
                     key=jax.random.PRNGKey(0))
    jax.block_until_ready(res2.proto_labels)
    sec = time.perf_counter() - t0
    print(f"{res2.executor} fit: {sec:.2f}s, {res2.n_chunks} chunks, "
          f"{res2.n_cascades} cascades, "
          f"{int(res2.n_prototypes)} prototypes")
    acc2 = clustering_accuracy(comp, res2.labels(), 3)
    print(f"accuracy vs generative components: {acc2:.4f}")

    # both freeze into the same servable artifact
    index = res2.to_index()
    q = jax.numpy.asarray(next(point_chunks(cfg))[:256])
    labels_q = np.asarray(index.assign(q))
    print(f"online assign of {q.shape[0]} fresh rows → "
          f"{len(np.unique(labels_q[labels_q >= 0]))} clusters")


if __name__ == "__main__":
    main()
