"""Out-of-core streaming IHTC fit: bounded device memory vs growing n.

Sweeps the dataset size n at a *fixed* chunk/reservoir budget and records,
per point: streaming wall time, fit throughput, and the peak live
device-buffer footprint (sampled at every chunk boundary plus the
finalize/backend steps), against the same numbers for the in-memory
``repro.fit`` executor. The claim under test is the memory contract: the
streaming column stays O(chunk + reservoir) — flat — while the in-memory
column grows with n (and is skipped entirely past ``--inmem-max-n``, the
point of the exercise).

``executors`` picks which streaming-family executor(s) run: the plain
single-device ``streaming`` path and/or the composed ``streaming_sharded``
path (host chunks reduced by sharded level steps into a mesh-sharded
reservoir — run it under ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` to smoke the composition; CI does exactly that).

Writes benchmarks/results/BENCH_streaming.json (schema in
docs/BENCHMARKS.md); discovered and summarized by run.py's benchmark
registry (``--bench streaming``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# direct-run support: repo root for the benchmarks package, src/ for repro
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import live_mb, print_csv

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")

# benchmark-registry entry (benchmarks/run.py --bench streaming)
BENCH = {
    "name": "streaming",
    "artifact": "BENCH_streaming.json",
    "summary": ("n", "stream_peak_mb"),
    "quick": dict(ns=(8_192, 32_768), chunk=2_048, inmem_max_n=32_768,
                  mode="quick"),
    "full": lambda mx: dict(
        ns=tuple(n for n in (65_536, 262_144, 1_048_576) if n <= mx) or (mx,),
        chunk=8_192, inmem_max_n=min(mx, 262_144), mode="full"),
}


def _watched(chunks, peak):
    """Pass chunks through, sampling the live device footprint between
    every chunk (the reservoir + per-chunk buffers are live right here)."""
    for c in chunks:
        peak[0] = max(peak[0], live_mb())
        yield c


def _default_executors():
    """The composed executor joins the sweep whenever the host actually has
    multiple devices to compose over."""
    execs = ["streaming"]
    if len(jax.devices()) > 1:
        execs.append("streaming_sharded")
    return tuple(execs)


def run(
    ns=(8_192, 32_768, 131_072),
    chunk: int = 2_048,
    reservoir: int = 0,
    t: int = 2,
    m: int = 2,
    d: int = 8,
    k: int = 4,
    inmem_max_n: int = 32_768,
    seed: int = 0,
    mode: str = "quick",
    executors=None,
):
    import repro
    from repro.core import ihtc, make_data_mesh
    from repro.data import PointStreamConfig, point_chunks

    executors = _default_executors() if executors is None else executors
    mesh = (make_data_mesh()
            if any(e == "streaming_sharded" for e in executors) else None)
    rows = []
    for n in ns:
        cfg = PointStreamConfig(n=n, d=d, chunk=chunk, seed=seed,
                                kind="blobs", k=k)
        for executor in executors:
            peak = [0.0]
            t0 = time.perf_counter()
            res = repro.fit(
                _watched(point_chunks(cfg), peak), t, m, "kmeans", k=k,
                executor=executor, chunk_n=chunk,
                reservoir_n=reservoir or None,
                mesh=mesh if executor == "streaming_sharded" else None,
                key=jax.random.PRNGKey(seed))
            jax.block_until_ready(res.proto_labels)
            peak[0] = max(peak[0], live_mb())
            stream_sec = time.perf_counter() - t0
            n_assigned = sum(int((lab >= 0).sum())
                             for lab in res.iter_labels())
            row = {
                "n": n,
                "executor": executor,
                "chunks": res.n_chunks,
                "cascades": res.n_cascades,
                "n_prototypes": int(res.n_prototypes),
                "all_assigned": n_assigned == n,
                "stream_seconds": round(stream_sec, 4),
                "stream_points_per_sec": round(n / stream_sec),
                "stream_peak_mb": round(peak[0], 3),
                "inmem_seconds": None,
                "inmem_peak_mb": None,
            }
            del res
            if executor == "streaming" and n <= inmem_max_n:
                x = jnp.asarray(np.concatenate(list(point_chunks(cfg))))
                t0 = time.perf_counter()
                mem = ihtc(x, t, m, "kmeans", k=k,
                           key=jax.random.PRNGKey(seed))
                jax.block_until_ready(mem.labels)
                row["inmem_seconds"] = round(time.perf_counter() - t0, 4)
                # x + the O(n) level-0 assignment maps are all live here
                row["inmem_peak_mb"] = round(live_mb(), 3)
                del x, mem
            rows.append(row)

    print_csv(
        "streaming_ihtc",
        [(r["n"], r["executor"], r["chunks"], r["cascades"],
          r["stream_seconds"], r["stream_points_per_sec"],
          r["stream_peak_mb"], r["inmem_seconds"], r["inmem_peak_mb"])
         for r in rows],
        "n,executor,chunks,cascades,stream_seconds,stream_points_per_sec,"
        "stream_peak_mb,inmem_seconds,inmem_peak_mb",
    )

    os.makedirs(RESULTS, exist_ok=True)
    artifact = {
        "name": "streaming_ihtc",
        "mode": mode,
        "t": t, "m": m, "d": d, "k": k,
        "chunk_n": chunk,
        "reservoir_n": reservoir,
        "devices": len(jax.devices()),
        "executors": list(executors),
        "recorded_unix": round(time.time(), 1),
        "rows": rows,
    }
    path = os.path.join(RESULTS, "BENCH_streaming.json")
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    print(f"# wrote {os.path.relpath(path, _REPO)}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", type=str, default="")
    ap.add_argument("--chunk", type=int, default=2_048)
    ap.add_argument("--reservoir", type=int, default=0,
                    help="0 = auto (4x the per-chunk prototype budget)")
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--inmem-max-n", type=int, default=32_768,
                    help="skip the in-memory comparison above this n")
    ap.add_argument("--executors", type=str, default="",
                    help="comma list among streaming,streaming_sharded "
                         "(default: streaming, plus the composed executor "
                         "when more than one device is visible)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny sweep for CI smoke")
    args = ap.parse_args()
    executors = tuple(args.executors.split(",")) if args.executors else None
    if args.quick:
        run(ns=(4_096, 8_192), chunk=1_024, t=args.t, m=args.m, d=2,
            inmem_max_n=8_192, mode="smoke", executors=executors)
        return
    ns = (tuple(int(v) for v in args.ns.split(",")) if args.ns
          else (8_192, 32_768, 131_072))
    run(ns=ns, chunk=args.chunk, reservoir=args.reservoir, t=args.t,
        m=args.m, d=args.d, inmem_max_n=args.inmem_max_n, mode="cli",
        executors=executors)


if __name__ == "__main__":
    main()
