"""Beyond-paper: online serving throughput of ``ClusterIndex.assign``.

Fits an index on the paper's GMM mixture, then sweeps the micro-batching
buckets of :class:`repro.serve.ClusterService`, reporting per-bucket assign
latency and points/sec (compiles excluded — the service's pad-to-bucket
front-end is exactly what keeps production requests off the compile path).
Writes the sweep to benchmarks/results/BENCH_serve.json (schema in
docs/BENCHMARKS.md); summarized by run.py.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# direct-run support (python benchmarks/bench_serve.py): repo root for the
# benchmarks package, src/ for repro — same bootstrap as run.py
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp

from benchmarks.common import gmm_sample, print_csv, timed
from repro.cluster.registry import available_backends
from repro.core.index import ClusterIndex
from repro.serve.cluster_service import ClusterService

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")

# benchmark-registry entry (benchmarks/run.py --bench serve)
BENCH = {
    "name": "serve",
    "artifact": "BENCH_serve.json",
    "summary": ("batch", "points_per_sec"),
    "quick": dict(n=20_000, buckets=(32, 128, 512, 2048), mode="quick"),
    "full": lambda mx: dict(n=min(mx, 1_000_000), m=3,
                            buckets=(32, 128, 512, 2048, 8192, 32_768),
                            mode="full"),
}


def run(
    n: int = 20_000,
    t: int = 2,
    m: int = 2,
    backend: str = "kmeans",
    buckets=(32, 128, 512, 2048, 8192),
    block: int = 0,
    seed: int = 0,
    mode: str = "quick",
):
    x, _ = gmm_sample(n, seed)
    xj = jnp.asarray(x)
    index, fit_sec = timed(
        lambda: ClusterIndex.build(xj, t, m, backend, k=3,
                                   key=jax.random.PRNGKey(seed)),
        warmup=0)
    service = ClusterService(index, buckets=buckets, block=block)
    service.warmup()

    rows = []
    for b in service.buckets:
        q = jnp.asarray(gmm_sample(b, seed + 1)[0])
        _, sec = timed(service.assign, q, warmup=1, iters=5)
        rows.append((b, round(sec * 1e3, 3), round(b / sec), int(index.n_prototypes)))
    print_csv("serve_assign", rows, "batch,ms,points_per_sec,n_prototypes")

    os.makedirs(RESULTS, exist_ok=True)
    art = {
        "name": "serve_assign",
        "mode": mode,
        "fit": {"n": n, "t": t, "m": m, "backend": backend,
                "n_prototypes": int(index.n_prototypes),
                "fit_seconds": round(fit_sec, 4)},
        "rows": [
            {"batch": b, "ms": ms, "points_per_sec": pps}
            for b, ms, pps, _ in rows
        ],
    }
    with open(os.path.join(RESULTS, "BENCH_serve.json"), "w") as f:
        json.dump(art, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--backend", choices=available_backends(),
                    default="kmeans")
    ap.add_argument("--block", type=int, default=0,
                    help="stream the prototype set in blocks of this size")
    args = ap.parse_args()
    run(n=args.n, t=args.t, m=args.m, backend=args.backend, block=args.block,
        mode="cli")


if __name__ == "__main__":
    main()
