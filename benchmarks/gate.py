"""Noise-aware perf-regression gate over the committed ``BENCH_*.json``
baselines.

The benchmark trajectories used to be write-only: a PR could halve a
kernel's throughput and nothing would fail. This gate closes the loop —
it re-runs any harness from ``run.py``'s registry, compares the fresh
artifact row-by-row against the committed baseline, and exits nonzero on
a regression beyond per-metric tolerance.

Noise treatment (docs/BENCHMARKS.md "The perf gate"):

  * rows are matched on their identity fields (``n``, ``executor``,
    ``devices``, ``batch``, ``dataset``, ``t``, ``m``), never on position,
    so reordered or added sweep points don't misalign;
  * every metric has a direction and a *relative* tolerance
    (``METRIC_RULES``): time/memory regress upward, throughput regresses
    downward. ``--tol metric=x`` / ``--default-tol`` override;
  * baselines below a per-family absolute noise floor are skipped —
    a 3 ms cell doubling to 6 ms on a shared CI runner is scheduler
    noise, not a regression;
  * ``--repeats R`` runs the harness R times and gates on the per-cell
    **median**, the same discipline ``repro.tune`` applies.

Modes:

  ``--bench a[,b]``       run registered harness(es), gate, restore the
                          baseline file (working tree left clean)
  ``--update-baselines``  accept the fresh (median) artifact as the new
                          committed baseline instead of gating
  ``--baseline X --fresh Y``  compare two recorded artifacts, no run
  ``--self-test``         verify the gate machinery catches an injected
                          2x slowdown (and passes on identical artifacts)
  ``--keep-fresh DIR``    also write the fresh artifacts to DIR (CI
                          uploads them as workflow artifacts)

``run.py --bench <names> --gate`` forwards here, so one command runs a
registered bench and gates it.
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import statistics
import sys
from typing import Dict, List, Optional, Tuple

# direct-run support: repo root for the benchmarks package, src/ for repro
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")

#: row-identity fields (whichever exist in a row form its match key)
KEY_FIELDS = ("n", "executor", "devices", "batch", "dataset", "t", "m",
              "phase", "offered_qps", "n_protos", "n_queries", "impl",
              "prefetch_depth", "donate")

#: metric -> (direction, default relative tolerance, absolute noise floor)
#: direction "lower": fresh > base*(1+tol) regresses; "higher": fresh <
#: base/(1+tol) regresses. Baselines under the floor are skipped outright.
METRIC_RULES: Dict[str, Tuple[str, float, float]] = {
    "seconds": ("lower", 0.5, 0.05),
    "wall_s": ("lower", 0.5, 0.05),
    "stream_seconds": ("lower", 0.5, 0.05),
    "inmem_seconds": ("lower", 0.5, 0.05),
    "ingest_seconds": ("lower", 0.5, 0.05),
    "ms": ("lower", 0.5, 5.0),
    "points_per_sec": ("higher", 0.5, 0.0),
    "stream_points_per_sec": ("higher", 0.5, 0.0),
    # async-serving latency percentiles (bench_serve_async): tails on a
    # shared runner are noisy, so the defaults are loose but still < 1.0
    # so the self-test's 2x injection trips the strict ratio > 1+tol check
    "p50_ms": ("lower", 0.75, 1.0),
    "p99_ms": ("lower", 0.9, 2.0),
    "qps": ("higher", 0.5, 0.0),
    # assign-path throughput (bench_assign): single jitted call, low noise
    "queries_per_sec": ("higher", 0.5, 0.0),
    # lifecycle swap metrics (bench_lifecycle): the swap pipeline runs
    # snapshot + backend + warmup compiles, so wall time is dominated by
    # compile noise on shared runners — tolerances are deliberately wide
    "swap_ms": ("lower", 1.5, 50.0),
    "swap_stall_p99_ms": ("lower", 1.5, 25.0),
    # refreshed-vs-stale mean assign distance on drifted traffic: seeded
    # and deterministic, should stay well under 1.0 after any refresh
    "dist_ratio": ("lower", 0.5, 0.0),
    "peak_mb": ("lower", 0.25, 0.01),
    "stream_peak_mb": ("lower", 0.25, 0.01),
    "inmem_peak_mb": ("lower", 0.25, 0.01),
}


def row_key(row: dict) -> tuple:
    return tuple((f, row[f]) for f in KEY_FIELDS if f in row)


def _fmt_key(key: tuple) -> str:
    return " ".join(f"{f}={v}" for f, v in key) or "<single row>"


def median_artifact(artifacts: List[dict]) -> dict:
    """Merge repeated runs of one harness: per-cell per-metric median of
    every numeric gated metric (non-gated fields come from the last run,
    which also defines the row set)."""
    if len(artifacts) == 1:
        return artifacts[0]
    out = copy.deepcopy(artifacts[-1])
    by_key = [{row_key(r): r for r in a.get("rows", [])} for a in artifacts]
    for row in out.get("rows", []):
        key = row_key(row)
        for metric in METRIC_RULES:
            vals = [m[key][metric] for m in by_key
                    if key in m and isinstance(m[key].get(metric),
                                               (int, float))]
            if vals:
                row[metric] = statistics.median(vals)
    return out


def compare(
    baseline: dict,
    fresh: dict,
    *,
    tols: Optional[Dict[str, float]] = None,
    default_tol: Optional[float] = None,
) -> dict:
    """Gate one fresh artifact against its baseline.

    Returns ``{"regressions": [...], "improvements": [...], "checked": N,
    "unmatched": [...]}``; each finding is a printable dict. The caller
    decides the exit code.
    """
    tols = tols or {}
    base_rows = {row_key(r): r for r in baseline.get("rows", [])}
    regressions, improvements, unmatched = [], [], []
    checked = 0
    for row in fresh.get("rows", []):
        key = row_key(row)
        base = base_rows.get(key)
        if base is None:
            unmatched.append(key)
            continue
        for metric, (direction, rule_tol, floor) in METRIC_RULES.items():
            b, f = base.get(metric), row.get(metric)
            if not isinstance(b, (int, float)) or not isinstance(f,
                                                                 (int, float)):
                continue
            if b <= floor:
                continue  # below the noise floor: not gateable
            tol = tols.get(metric, default_tol if default_tol is not None
                           else rule_tol)
            checked += 1
            ratio = f / b
            finding = {
                "name": fresh.get("name", baseline.get("name", "?")),
                "key": key, "metric": metric, "baseline": b, "fresh": f,
                "ratio": ratio, "tol": tol, "direction": direction,
            }
            if direction == "lower":
                if ratio > 1.0 + tol:
                    regressions.append(finding)
                elif ratio < 1.0 / (1.0 + tol):
                    improvements.append(finding)
            else:
                if ratio < 1.0 / (1.0 + tol):
                    regressions.append(finding)
                elif ratio > 1.0 + tol:
                    improvements.append(finding)
    fresh_keys = {row_key(r) for r in fresh.get("rows", [])}
    missing = [k for k in base_rows if k not in fresh_keys]
    return {"regressions": regressions, "improvements": improvements,
            "checked": checked, "unmatched": unmatched, "missing": missing}


def print_report(report: dict, *, verbose_improvements: bool = True) -> None:
    for f in report["regressions"]:
        print(f"REGRESSION {f['name']} [{_fmt_key(f['key'])}] {f['metric']}: "
              f"{f['baseline']:g} -> {f['fresh']:g} "
              f"({f['ratio']:.2f}x, tol {1 + f['tol']:.2f}x "
              f"{'slower' if f['direction'] == 'lower' else 'lower'})")
    if verbose_improvements:
        for f in report["improvements"]:
            print(f"# improvement {f['name']} [{_fmt_key(f['key'])}] "
                  f"{f['metric']}: {f['baseline']:g} -> {f['fresh']:g} "
                  f"({f['ratio']:.2f}x)")
    for key in report["unmatched"]:
        print(f"# note: fresh row [{_fmt_key(key)}] has no baseline row "
              f"(new sweep point?)")
    for key in report.get("missing", []):
        print(f"# note: baseline row [{_fmt_key(key)}] missing from the "
              f"fresh run (fewer devices / executors here?) — not gated")
    print(f"# gate: {report['checked']} metric cells checked, "
          f"{len(report['regressions'])} regressions, "
          f"{len(report['improvements'])} improvements")


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def gate_bench(
    name: str,
    *,
    full: bool = False,
    max_n: int = 1_000_000,
    repeats: int = 1,
    tols: Optional[Dict[str, float]] = None,
    default_tol: Optional[float] = None,
    update_baselines: bool = False,
    keep_fresh: Optional[str] = None,
    baseline_path: Optional[str] = None,
) -> int:
    """Run one registered harness ``repeats`` times, gate the per-cell
    median against the committed baseline, restore the baseline file
    (unless ``--update-baselines``). Returns the exit code."""
    import importlib

    from benchmarks.run import discover_benches

    specs = discover_benches()
    if name not in specs:
        print(f"unknown bench {name!r}; have {sorted(specs)}",
              file=sys.stderr)
        return 2
    spec = specs[name]
    if not spec.get("artifact"):
        print(f"bench {name!r} records no artifact; nothing to gate",
              file=sys.stderr)
        return 2
    artifact_path = os.path.join(RESULTS, spec["artifact"])
    baseline_file = baseline_path or artifact_path
    if not os.path.exists(baseline_file):
        print(f"no baseline at {baseline_file}; run the bench and commit "
              f"its artifact first (or pass --update-baselines)",
              file=sys.stderr)
        if not update_baselines:
            return 2
    baseline_bytes = (open(baseline_file, "rb").read()
                      if os.path.exists(baseline_file) else None)
    # snapshot of the artifact file itself for the restore — distinct from
    # baseline_bytes when --baseline points at a different file
    artifact_bytes = (open(artifact_path, "rb").read()
                      if os.path.exists(artifact_path) else None)

    mod = importlib.import_module(spec["module_name"])
    bench = getattr(mod, "BENCH", {})
    kwargs = bench.get("full") if full else bench.get("quick", {})
    if callable(kwargs):
        kwargs = kwargs(max_n)
    kwargs = kwargs or {}  # a bench may register only one of quick/full
    runs = []
    try:
        for r in range(max(repeats, 1)):
            print(f"# gate run {r + 1}/{repeats}: {spec['module_name']}"
                  f".run({', '.join(f'{k}={v!r}' for k, v in kwargs.items())})")
            mod.run(**(kwargs or {}))
            runs.append(_load(artifact_path))
        fresh = median_artifact(runs)
        if keep_fresh:
            os.makedirs(keep_fresh, exist_ok=True)
            with open(os.path.join(keep_fresh, spec["artifact"]), "w") as f:
                json.dump(fresh, f, indent=1)
        if update_baselines:
            with open(artifact_path, "w") as f:
                json.dump(fresh, f, indent=1)
            print(f"# baseline updated: {os.path.relpath(artifact_path, _REPO)}")
            return 0
        report = compare(json.loads(baseline_bytes), fresh, tols=tols,
                         default_tol=default_tol)
        print_report(report)
        return 1 if report["regressions"] else 0
    finally:
        # leave the working tree exactly as committed unless updating
        if artifact_bytes is not None and not update_baselines:
            with open(artifact_path, "wb") as f:
                f.write(artifact_bytes)


LATENCY_METRICS = ("p50_ms", "p99_ms")


def self_test() -> int:
    """Prove the gate machinery works: identical artifacts must pass, an
    injected 2x slowdown (+ halved throughput) must be flagged. Artifacts
    carrying serving-latency percentiles get a second, latency-only
    injection — a tail-latency regression must be caught even when
    throughput is unchanged."""
    candidates = sorted(
        p for p in (os.path.join(RESULTS, f) for f in sorted(os.listdir(RESULTS))
                    if f.startswith("BENCH_") and f.endswith(".json"))
        if os.path.isfile(p)) if os.path.isdir(RESULTS) else []
    if not candidates:
        print("self-test: no BENCH_*.json artifacts to test against",
              file=sys.stderr)
        return 2
    failures = 0
    for path in candidates:
        baseline = _load(path)
        clean = compare(baseline, baseline)
        slowed = inject_slowdown(baseline, factor=2.0)
        flagged = compare(baseline, slowed)
        gated_cells = clean["checked"]
        ok = (not clean["regressions"]
              and (gated_cells == 0 or flagged["regressions"]))
        status = "ok" if ok else "FAIL"
        print(f"# self-test {os.path.basename(path)}: identical -> "
              f"{len(clean['regressions'])} regressions, 2x-slowed -> "
              f"{len(flagged['regressions'])} regressions "
              f"({gated_cells} cells) {status}")
        failures += 0 if ok else 1
        has_latency = any(
            isinstance(r.get(m), (int, float))
            for r in baseline.get("rows", []) for m in LATENCY_METRICS)
        if has_latency:
            tail = compare(baseline, inject_slowdown(
                baseline, factor=3.0, metrics=list(LATENCY_METRICS)))
            lat_ok = any(f["metric"] in LATENCY_METRICS
                         for f in tail["regressions"])
            lat_status = "ok" if lat_ok else "FAIL"
            print(f"# self-test {os.path.basename(path)}: latency-only "
                  f"3x tail injection -> "
                  f"{len(tail['regressions'])} regressions {lat_status}")
            failures += 0 if lat_ok else 1
    return 1 if failures else 0


def inject_slowdown(artifact: dict, factor: float = 2.0,
                    metrics: Optional[List[str]] = None) -> dict:
    """Copy of ``artifact`` with gated metrics degraded by ``factor``
    (times/memory multiplied, throughput divided) — the synthetic
    regression the self-test feeds the comparator. ``metrics`` restricts
    the injection to a subset (e.g. latency-only), leaving the rest
    untouched."""
    out = copy.deepcopy(artifact)
    for row in out.get("rows", []):
        for metric, (direction, _, _) in METRIC_RULES.items():
            if metrics is not None and metric not in metrics:
                continue
            v = row.get(metric)
            if isinstance(v, (int, float)):
                row[metric] = v * factor if direction == "lower" else v / factor
    return out


def _parse_tols(pairs: List[str]) -> Dict[str, float]:
    tols = {}
    for p in pairs:
        if "=" not in p:
            raise SystemExit(f"--tol wants metric=value, got {p!r}")
        k, v = p.split("=", 1)
        if k not in METRIC_RULES:
            raise SystemExit(
                f"--tol: unknown metric {k!r}; gated metrics: "
                f"{sorted(METRIC_RULES)}")
        tols[k] = float(v)
    return tols


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="perf-regression gate over committed BENCH_*.json "
                    "baselines (see docs/BENCHMARKS.md)")
    ap.add_argument("--bench", default="",
                    help="comma list of registered harnesses to run + gate")
    ap.add_argument("--full", action="store_true",
                    help="gate the full-mode sweep (default: quick)")
    ap.add_argument("--max-n", type=int, default=1_000_000)
    ap.add_argument("--repeats", type=int, default=1,
                    help="runs per harness; the gate sees per-cell medians")
    ap.add_argument("--tol", action="append", default=[],
                    metavar="METRIC=X",
                    help="per-metric relative tolerance override")
    ap.add_argument("--default-tol", type=float, default=None,
                    help="one tolerance for every metric (e.g. 1.0 = only "
                         ">2x fails — the CI quick-mode setting)")
    ap.add_argument("--update-baselines", action="store_true",
                    help="accept the fresh run as the new baseline")
    ap.add_argument("--keep-fresh", default="",
                    help="also write fresh artifacts to this directory")
    ap.add_argument("--baseline", default="",
                    help="baseline artifact file (with --fresh: compare "
                         "two files, run nothing)")
    ap.add_argument("--fresh", default="",
                    help="fresh artifact file to compare against --baseline")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate catches an injected 2x slowdown")
    args = ap.parse_args(argv)
    tols = _parse_tols(args.tol)

    if args.self_test:
        return self_test()

    if args.fresh or (args.baseline and not args.bench):
        if not (args.baseline and args.fresh):
            ap.error("file-compare mode needs both --baseline and --fresh")
        report = compare(_load(args.baseline), _load(args.fresh), tols=tols,
                         default_tol=args.default_tol)
        print_report(report)
        return 1 if report["regressions"] else 0

    if not args.bench:
        ap.error("nothing to do: pass --bench, --baseline/--fresh, "
                 "or --self-test")
    rc = 0
    for name in [n.strip() for n in args.bench.split(",") if n.strip()]:
        rc = max(rc, gate_bench(
            name, full=args.full, max_n=args.max_n, repeats=args.repeats,
            tols=tols, default_tol=args.default_tol,
            update_baselines=args.update_baselines,
            keep_fresh=args.keep_fresh or None,
            baseline_path=args.baseline or None))
    return rc


if __name__ == "__main__":
    sys.exit(main())
