"""Shared benchmark utilities: the paper's GMM generator, synthetic analogs
of the six real datasets (the container is offline), timing and working-set
measurement, and the one-line-per-row CSV emitter every harness uses.

Conventions (docs/BENCHMARKS.md):
  * every harness prints exactly one ``# <name>: <header>`` line followed by
    ``<name>,<row>`` CSV lines — grep a name to extract one table;
  * timings come from :func:`timed` (jit warmup excluded, device sync
    included); memory is :func:`live_mb` (live device buffers, the analog of
    the paper's R memory profiling);
  * sweeps worth keeping across runs are also written as JSON artifacts to
    benchmarks/results/ (``BENCH_*.json`` for benchmark trajectories, as in
    bench_distributed; tagged per-cell files under results/hillclimb and
    results/dryrun for the LM stack).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Tuple

import jax
import numpy as np


def gmm_sample(n: int, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Paper §4: mixture of three bivariate Gaussians (.5/.3/.2)."""
    rng = np.random.default_rng(seed)
    mus = np.array([[1, 2], [7, 8], [3, 5]], float)
    sds = np.array([[1, 0.5], [2, 1], [3, 4]], float) ** 0.5
    comp = rng.choice(3, size=n, p=[0.5, 0.3, 0.2])
    x = mus[comp] + rng.normal(size=(n, 2)) * sds[comp]
    return x.astype(np.float32), comp


@dataclass(frozen=True)
class DatasetSpec:
    """Shape of one of the paper's Table-3 real datasets: n rows, d numeric
    features, k clusters requested in the paper's experiments."""
    name: str
    n: int
    d: int
    k: int


# Table 3 of the paper; data drawn as a k-component Gaussian mixture with the
# matching (n, d, k) since the container has no network access. The paper's
# claims under test (runtime/memory vs m, BSS/TSS preservation) depend on
# scale and cluster structure, not on the exact real-world marginals.
PAPER_DATASETS = [
    DatasetSpec("pm25", 41_757, 5, 4),
    DatasetSpec("credit_score", 120_269, 6, 5),
    DatasetSpec("black_friday", 166_986, 7, 4),
    DatasetSpec("covertype", 581_012, 6, 7),
    DatasetSpec("house_price", 2_885_485, 5, 5),
    DatasetSpec("stock", 7_026_593, 5, 7),
]


def dataset_analog(spec: DatasetSpec, seed: int = 0, max_n: int = 0) -> np.ndarray:
    """Synthetic stand-in for a Table-3 dataset: a k-component Gaussian
    mixture with the spec's (n, d, k); ``max_n`` truncates for quick mode."""
    n = min(spec.n, max_n) if max_n else spec.n
    rng = np.random.default_rng(seed)
    centers = rng.normal(scale=4.0, size=(spec.k, spec.d))
    comp = rng.integers(0, spec.k, size=n)
    scales = rng.uniform(0.5, 1.5, size=(spec.k, spec.d))
    x = centers[comp] + rng.normal(size=(n, spec.d)) * scales[comp]
    return x.astype(np.float32)


def live_mb() -> float:
    """Current live device-buffer footprint in MB (the working-set metric —
    the analog of the paper's R memory profiling)."""
    return sum(a.nbytes for a in jax.live_arrays()) / 1e6


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 1, **kw):
    """(result, seconds) with jit warmup excluded and device sync included."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
    return out, (time.perf_counter() - t0) / iters


def print_csv(name: str, rows: list, header: str) -> None:
    """Emit one benchmark table: a ``# name: header`` comment line, then one
    ``name,<row>`` line per row (grep the name to extract the table)."""
    print(f"# {name}: {header}")
    for r in rows:
        print(f"{name}," + ",".join(str(x) for x in r))
