"""Serve-side assign hot path: composed dense vs the fused streaming
family (DESIGN.md §16).

Sweeps n_protos x n_queries x impl over ``ClusterIndex.assign`` on a
well-separated synthetic index (so the quantized shortlist+rescore
variants must agree with the exact path label-for-label) and reports

  * ``p50_ms``          median assign latency (compiles excluded),
  * ``queries_per_sec`` nq / p50,
  * ``peak_mb``         the *working set* of the impl's distance stage —
    code-anchored accounting, not a profiler read: jit temporaries are
    invisible to ``live_mb()``, while these formulas follow directly from
    the buffers each path materializes (docs/BENCHMARKS.md):

      ref         p*d*4  + nq*p*4          (prototypes + dense distances)
      fused       p*d*4  + nq*bk*4         (distance tile never hits HBM)
      fused_bf16  p*d*2  + nq*bk*4 + nq*r*d*4   (+ f32 rescore gather)
      fused_int8  p*d*1  + nq*bk*4 + nq*r*d*4

  * ``label_agreement`` fraction of labels matching ``impl="ref"``
    (1.0 for fused; the quantized rows are the accuracy evidence).

Writes benchmarks/results/BENCH_assign.json; gated by gate.py (rows keyed
on n_protos/n_queries/impl).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

# direct-run support: repo root for the benchmarks package, src/ for repro
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_csv
from repro import runtime
from repro.core.index import ClusterIndex
from repro.kernels.fused_assign import RESCORE_K

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")

IMPLS = ("ref", "fused", "fused_bf16", "fused_int8")

# benchmark-registry entry (benchmarks/run.py --bench assign)
BENCH = {
    "name": "assign",
    "artifact": "BENCH_assign.json",
    "summary": ("impl", "queries_per_sec"),
    # quick keeps an 8k-prototype bucket: the committed baseline must
    # show fused beating the composed dense path where it matters
    "quick": dict(protos=(2048, 8192), queries=(256, 2048), iters=5,
                  mode="quick"),
    "full": lambda mx: dict(protos=(2048, 8192, 32768), queries=(256, 2048),
                            iters=10, mode="full"),
}


def _index(p: int, d: int, c: int, seed: int) -> ClusterIndex:
    """Well-separated c-center index (centers 50 sigma apart, prototype
    jitter 0.05): the quantized variants' 8-bit shortlist has orders of
    magnitude more resolution than the inter-center gaps, so any label
    disagreement vs the exact path is a bug, not noise."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(c, d)) * 50.0
    comp = np.arange(p) % c
    protos = centers[comp] + rng.normal(size=(p, d)) * 0.05
    return ClusterIndex.build(ClusterIndex(
        protos=jnp.asarray(protos, jnp.float32),
        proto_mass=jnp.ones((p,), jnp.float32),
        proto_valid=jnp.ones((p,), bool),
        proto_labels=jnp.asarray(comp, jnp.int32),
        n_prototypes=jnp.asarray(p, jnp.int32),
    ))


def _queries(nq: int, d: int, c: int, seed: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed + 1)
    centers = np.random.default_rng(seed).normal(size=(c, d)) * 50.0
    q = centers[rng.integers(0, c, size=nq)] + rng.normal(size=(nq, d)) * 0.05
    return jnp.asarray(q, jnp.float32)


def working_set_mb(impl: str, p: int, nq: int, d: int, bk: int) -> float:
    """Distance-stage working set of one assign call, in MB (formulas in
    the module docstring — keyed to the buffers each path materializes)."""
    r = min(RESCORE_K, p)
    if impl == "ref":
        return (p * d * 4 + nq * p * 4) / 1e6
    if impl == "fused":
        return (p * d * 4 + nq * bk * 4) / 1e6
    if impl == "fused_bf16":
        return (p * d * 2 + nq * bk * 4 + nq * r * d * 4) / 1e6
    if impl == "fused_int8":
        return (p * d * 1 + nq * bk * 4 + nq * r * d * 4) / 1e6
    raise ValueError(impl)


def run(
    protos=(2048, 8192, 32768),
    queries=(256, 2048),
    d: int = 8,
    c: int = 16,
    iters: int = 10,
    seed: int = 0,
    mode: str = "quick",
):
    bk = runtime.active().block_k
    rows = []
    for p in protos:
        idx = _index(p, d, c, seed)
        for nq in queries:
            q = _queries(nq, d, c, seed)
            ref_labels = np.asarray(idx.assign(q, impl="ref"))
            for impl in IMPLS:
                labels = idx.assign(q, impl=impl)
                jax.block_until_ready(labels)  # compile excluded
                times = []
                for _ in range(iters):
                    t0 = time.perf_counter()
                    jax.block_until_ready(idx.assign(q, impl=impl))
                    times.append(time.perf_counter() - t0)
                p50 = statistics.median(times)
                agree = float((np.asarray(labels) == ref_labels).mean())
                rows.append({
                    "n_protos": p,
                    "n_queries": nq,
                    "impl": impl,
                    "p50_ms": round(p50 * 1e3, 3),
                    "queries_per_sec": round(nq / p50),
                    "peak_mb": round(working_set_mb(impl, p, nq, d, bk), 3),
                    "label_agreement": agree,
                })
    print_csv(
        "assign",
        [(r["n_protos"], r["n_queries"], r["impl"], r["p50_ms"],
          r["queries_per_sec"], r["peak_mb"], r["label_agreement"])
         for r in rows],
        "n_protos,n_queries,impl,p50_ms,queries_per_sec,peak_mb,"
        "label_agreement")

    os.makedirs(RESULTS, exist_ok=True)
    art = {
        "name": "assign",
        "mode": mode,
        "config": {"d": d, "centers": c, "block_k": bk, "iters": iters,
                   "rescore_k": RESCORE_K,
                   "backend": jax.default_backend()},
        "rows": rows,
    }
    with open(os.path.join(RESULTS, "BENCH_assign.json"), "w") as f:
        json.dump(art, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--protos", default="2048,8192,32768",
                    help="comma list of prototype counts")
    ap.add_argument("--queries", default="256,2048",
                    help="comma list of query-batch sizes")
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--iters", type=int, default=10)
    args = ap.parse_args()
    run(protos=tuple(int(v) for v in args.protos.split(",")),
        queries=tuple(int(v) for v in args.queries.split(",")),
        d=args.d, iters=args.iters, mode="cli")


if __name__ == "__main__":
    main()
