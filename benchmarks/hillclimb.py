"""§Perf hillclimb driver: runs the hypothesis→change→measure iterations on
the three chosen cells and writes tagged JSON artifacts. Each knob here maps
to a hypothesis recorded in EXPERIMENTS.md §Perf."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses
import json
import sys
import traceback

from repro.configs import ARCHS
from repro.launch.dryrun import run_cell

OUT = "benchmarks/results/hillclimb"


def save(tag, res):
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, tag + ".json"), "w") as f:
        json.dump(res, f, indent=1)
    if res["status"] == "ok":
        r = res["roofline"]
        print(f">>> {tag}: compute {r['compute_term_s']:.2e} | "
              f"memory {r['memory_term_s']:.2e} | "
              f"collective {r['collective_term_s']:.2e} | {r['dominant']} | "
              f"MFU {r['mfu_bound']*100:.2f}% | "
              f"peak {res['memory']['peak_gb']:.1f} GB\n")


RUNS = {
    # --- cell 1: deepseek train_4k pod1 (collective-bound) ---
    "ds_iter1_groups": lambda: run_cell(
        "deepseek-moe-16b", "train_4k", "pod1",
        cfg_override=dataclasses.replace(ARCHS["deepseek-moe-16b"],
                                         moe_groups=32)),
    "ds_iter2_groups_bf16": lambda: run_cell(
        "deepseek-moe-16b", "train_4k", "pod1", param_dtype="bfloat16",
        cfg_override=dataclasses.replace(ARCHS["deepseek-moe-16b"],
                                         moe_groups=32)),
    # --- cell 2: qwen train_4k pod1 (memory-bound) ---
    "qwen_iter1_seqheads": lambda: run_cell(
        "qwen2.5-32b", "train_4k", "pod1", heads_mode="seq"),
    "qwen_iter2_seqheads_bf16": lambda: run_cell(
        "qwen2.5-32b", "train_4k", "pod1", heads_mode="seq",
        param_dtype="bfloat16"),
    # --- cell 3: granite long_500k (paper technique) ---
    "gr_ref_uncompressed": lambda: run_cell(
        "granite-20b", "long_500k", "pod1", force=True),
    "gr_iter1_ihtc_bf16": lambda: run_cell(
        "granite-20b", "long_500k", "pod1", variant="ihtc-kv",
        param_dtype="bfloat16"),
    # bonus: serving-shape cells for the compression story at batch
    "qwen_decode_baseline_bf16": lambda: run_cell(
        "qwen2.5-32b", "decode_32k", "pod1", param_dtype="bfloat16"),
    "qwen_decode_ihtc_bf16": lambda: run_cell(
        "qwen2.5-32b", "decode_32k", "pod1", variant="ihtc-kv",
        param_dtype="bfloat16"),
}

if __name__ == "__main__":
    only = sys.argv[1:] or list(RUNS)
    for tag in only:
        try:
            save(tag, RUNS[tag]())
        except Exception:
            traceback.print_exc()
            print(f">>> {tag}: FAILED")

# appended iterations (see EXPERIMENTS.md §Perf for the hypothesis log)
RUNS["qwen_iter2_bf16p_bf16params"] = lambda: run_cell(
    "qwen2.5-32b", "train_4k", "pod1", param_dtype="bfloat16")
RUNS["ds_iter3_groups_bf16_all"] = RUNS["ds_iter2_groups_bf16"]
